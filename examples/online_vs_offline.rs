//! Online vs offline ABFT (paper §5.5 / Fig 22) — both the live-system
//! comparison and the analytical crossover.
//!
//!     make artifacts && cargo run --release --example online_vs_offline
//!
//! Live: runs both policies on the serving stack under increasing error
//! rates and reports effective work (kernel launches) per correct result.
//! Model: prints the Fig 22 overhead curves and the crossover size.

use ftgemm::codegen::ShapeClass;
use ftgemm::faults::model::{expected_offline_runs, overall_error_rate};
use ftgemm::faults::{FaultCampaign, SeuModel};
use ftgemm::gpusim::analytic;
use ftgemm::gpusim::device::T4;
use ftgemm::prelude::*;

fn main() -> anyhow::Result<()> {
    let engine = Engine::start(EngineConfig::default())?;
    let coord = Coordinator::new(engine, CoordinatorConfig::default());
    let (m, n, k) = (128usize, 128usize, 128usize);
    let rounds = 20;

    println!("live comparison @ {m}x{n}x{k}, {rounds} GEMMs per cell");
    println!("{:>10} {:>8} {:>10} {:>12} {:>12}", "SEUs/GEMM", "policy", "detected", "recomputes", "launches");
    for count in [0usize, 1, 2] {
        for policy in [FtPolicy::Online, FtPolicy::Offline] {
            let model = if count == 0 {
                SeuModel::None
            } else {
                SeuModel::PerGemm { count }
            };
            let rep = FaultCampaign::new(coord.clone(), model, policy, 11 + count as u64)
                .run(m, n, k, rounds)?;
            println!(
                "{count:>10} {:>8} {:>10} {:>12} {:>12}",
                policy.name(),
                rep.detected,
                rep.recomputes,
                rep.kernel_launches
            );
            assert!(rep.max_error_vs_reference < 0.5);
        }
    }
    println!("-> online: constant launches regardless of errors;");
    println!("   offline: launches grow ~(1 + detections) — the §5.5 trade-off.\n");

    // analytical Fig 22
    let p = ShapeClass::Huge.params();
    let gamma0 = 1.0 / 256.0;
    println!("modeled T4 overhead vs unprotected (gamma0 = 1/256):");
    println!("{:>8} {:>10} {:>11} {:>9} {:>14}", "M=N=K", "online %", "offline %", "gamma", "E[offline runs]");
    for s in [256usize, 512, 1024, 2048, 4096, 6144] {
        let on = analytic::online_overhead_pct(&T4, p, s, s, s);
        let off = analytic::offline_overhead_pct(&T4, p, s, s, s, gamma0);
        let gamma = overall_error_rate(gamma0, s, s, p.m_tb, p.n_tb);
        let runs = if gamma < 0.499 { expected_offline_runs(gamma) } else { f64::NAN };
        println!("{s:>8} {on:>10.2} {off:>11.2} {gamma:>9.4} {runs:>14.3}");
    }
    if let Some(x) = analytic::crossover_size(&T4, p, gamma0) {
        println!("\ncrossover: online becomes cheaper at M=N=K ≈ {x}");
    }
    println!("online_vs_offline OK");
    Ok(())
}
