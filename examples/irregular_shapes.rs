//! Irregular-shape serving: the codegen/routing story (paper §3.2, Figs
//! 10/11) on the live stack.
//!
//!     make artifacts && cargo run --release --example irregular_shapes
//!
//! Sweeps awkward GEMM shapes — tall-skinny, tiny, prime-sized, oversize —
//! and shows the router classifying each into a Table-1 bucket (padding or
//! splitting as needed), with every result verified against the host
//! matmul, FT on. Then prints the gpusim view of the same sweep: the
//! modeled GFLOPS of the heuristic's pick vs hard-coded vs cuBLAS.

use ftgemm::codegen::select::{select_bucket, select_class};
use ftgemm::figures::{generated_gflops, preset_gflops};
use ftgemm::gpusim::cublas::cublas_gflops;
use ftgemm::gpusim::device::T4;
use ftgemm::prelude::*;

fn main() -> anyhow::Result<()> {
    let engine = Engine::start(EngineConfig::default())?;
    let coord = Coordinator::new(engine, CoordinatorConfig::default());

    let shapes: &[(usize, usize, usize, &str)] = &[
        (31, 17, 53, "tiny primes"),
        (64, 64, 64, "exact small bucket"),
        (100, 90, 70, "irregular"),
        (97, 430, 211, "tall-skinny primes"),
        (250, 250, 250, "just under large"),
        (257, 257, 257, "just over large"),
        (640, 640, 640, "oversize -> split"),
    ];

    println!("{:24} {:>14} {:>8} {:>9} {:>10}", "shape", "class/bucket", "blocks", "launches", "max err");
    for &(m, n, k, label) in shapes {
        let a = Matrix::rand_uniform(m, k, m as u64 * 31 + 1);
        let b = Matrix::rand_uniform(k, n, n as u64 * 37 + 2);
        let out = coord.gemm(&a, &b, FtPolicy::Online)?;
        let want = a.matmul(&b);
        let class = select_bucket(m, n, k)
            .map(|bu| bu.name())
            .unwrap_or("split(huge)");
        println!(
            "{label:24} {class:>14} {:>8} {:>9} {:>10.1e}",
            out.buckets.len(),
            out.kernel_launches,
            out.c.max_abs_diff(&want)
        );
        assert!(out.c.max_abs_diff(&want) < 5e-3 * (k as f32).max(1.0) / 64.0 + 1e-3);
    }

    // gpusim view: what the paper's Figs 10/11 measure
    println!("\nmodeled T4 GFLOPS (K=256): generated vs hard-coded vs cuBLAS");
    println!("{:>6} {:>10} {:>10} {:>10} {:>12}", "M=N", "generated", "hardcoded", "cuBLAS", "class");
    for m in (64..=490).step_by(64) {
        let gen = generated_gflops(&T4, m, m, 256);
        let hard = preset_gflops(&T4, ftgemm::codegen::ShapeClass::Huge.params(), m, m, 256);
        let cb = cublas_gflops(&T4, m, m, 256);
        println!(
            "{m:>6} {gen:>10.0} {hard:>10.0} {cb:>10.0} {:>12}",
            select_class(m, m, 256).name()
        );
    }
    println!("irregular_shapes OK");
    Ok(())
}
