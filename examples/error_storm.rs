//! Error-storm campaign: the paper's abstract claim — "minimal overhead
//! ... even with hundreds of errors injected per minute" — exercised for
//! real on the serving stack.
//!
//!     make artifacts && cargo run --release --example error_storm
//!
//! Runs three campaigns over the same workload: unprotected (to size the
//! baseline), online ABFT under a Poisson SEU storm, and offline ABFT
//! under the same storm (counting its recomputes). Every result is
//! checked against the host matmul.

use std::time::Instant;

use ftgemm::faults::{FaultCampaign, SeuModel};
use ftgemm::prelude::*;

fn main() -> anyhow::Result<()> {
    let engine = Engine::start(EngineConfig::default())?;
    let coord = Coordinator::new(engine, CoordinatorConfig::default());
    let (m, n, k) = (128usize, 128usize, 128usize);
    let rounds = 30;

    // baseline: unprotected, fault-free
    let t0 = Instant::now();
    let clean = FaultCampaign::new(coord.clone(), SeuModel::None, FtPolicy::None, 1)
        .run(m, n, k, rounds)?;
    let t_base = t0.elapsed();
    println!(
        "baseline  : {rounds} GEMMs in {t_base:?}, max err {:.1e}",
        clean.max_error_vs_reference
    );

    // online ABFT under a storm: 4 SEUs per GEMM
    let storm = SeuModel::PerGemm { count: 4 };
    let t1 = Instant::now();
    let online = FaultCampaign::new(coord.clone(), storm, FtPolicy::Online, 2)
        .run(m, n, k, rounds)?;
    let t_online = t1.elapsed();
    println!(
        "online FT : {rounds} GEMMs in {t_online:?}; injected {} detected {} corrected {} ({:.0} errors/min), max err {:.1e}",
        online.injected,
        online.detected,
        online.corrected,
        online.errors_per_minute(),
        online.max_error_vs_reference
    );
    // `corrected` can exceed `injected`: correcting a 2^20-magnitude offset
    // leaves an O(eps*mag) residue that the next verification refines again.
    assert!(online.corrected >= online.injected, "online must correct everything");
    assert_eq!(online.recomputes, 0, "online never recomputes");
    assert!(online.max_error_vs_reference < 0.5);

    // offline ABFT under a lighter storm (1 SEU/GEMM): every detection is
    // a full recompute
    let t2 = Instant::now();
    let offline = FaultCampaign::new(
        coord.clone(),
        SeuModel::PerGemm { count: 1 },
        FtPolicy::Offline,
        3,
    )
    .run(m, n, k, rounds)?;
    let t_offline = t2.elapsed();
    println!(
        "offline FT: {rounds} GEMMs in {t_offline:?}; injected {} detected {} recomputes {} (2x work per hit), max err {:.1e}",
        offline.injected,
        offline.detected,
        offline.recomputes,
        offline.max_error_vs_reference
    );
    assert_eq!(offline.recomputes as usize, rounds, "1 SEU/GEMM -> 1 recompute each");
    assert!(offline.max_error_vs_reference < 1e-3);

    println!(
        "\nonline overhead vs baseline: {:+.1}% | offline (under storm): {:+.1}%",
        (t_online.as_secs_f64() / t_base.as_secs_f64() - 1.0) * 100.0,
        (t_offline.as_secs_f64() / t_base.as_secs_f64() - 1.0) * 100.0
    );
    println!(
        "coordinator counters: {:?}",
        coord.counters().snapshot()
    );
    println!("error_storm OK");
    Ok(())
}
