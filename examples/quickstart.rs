//! Quickstart: one fault-tolerant GEMM through the public API.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Starts the PJRT engine, routes a 100x80x60 request (padded into the
//! `small` bucket), injects one SEU, and shows the online kernel detect
//! and correct it — result still matches the host reference.

use ftgemm::abft::injection::InjectionPlan;
use ftgemm::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. engine: loads artifacts/manifest.json, owns the PJRT client
    let engine = Engine::start(EngineConfig::default())?;
    println!("loaded {} AOT artifacts", engine.manifest().len());

    // 2. coordinator: routing + fault-tolerance policies
    let coord = Coordinator::new(engine, CoordinatorConfig::default());

    // 3. an irregular GEMM — the router pads it into a Table-1 bucket
    let a = Matrix::rand_uniform(100, 60, 1);
    let b = Matrix::rand_uniform(60, 80, 2);

    let clean = coord.gemm(&a, &b, FtPolicy::Online)?;
    println!(
        "clean run: bucket={:?} launches={} errors={}",
        clean.buckets, clean.kernel_launches, clean.errors_detected
    );

    // 4. same GEMM with a simulated silent data corruption: +1000 on the
    //    accumulator of C[17, 23] at k-step 0 (the §5.3 protocol)
    let inj = InjectionPlan::single(17, 23, 0, 1000.0);
    let hit = coord.gemm_with_faults(&a, &b, FtPolicy::Online, &inj)?;
    println!(
        "injected run: detected={} corrected={} (in-kernel, no recompute)",
        hit.errors_detected, hit.errors_corrected
    );

    // 5. verify against the host reference
    let want = a.matmul(&b);
    let diff = hit.c.max_abs_diff(&want);
    println!("max |C - reference| = {diff:.3e}");
    assert!(diff < 1e-2, "online ABFT must hide the fault");
    assert_eq!(hit.errors_corrected, 1);
    println!("quickstart OK");
    Ok(())
}
