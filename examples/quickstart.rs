//! Quickstart: the request-centric serving API in one screen.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Starts the engine, builds a `GemmRequest` for an irregular 100x80x60
//! GEMM (padded into the `small` bucket), submits it for a `Ticket`, then
//! does it again with an injected SEU and per-request options — the
//! online kernel detects and corrects the fault, and the result still
//! matches the host reference.

use ftgemm::abft::injection::InjectionPlan;
use ftgemm::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. engine: loads artifacts/manifest.json (or the built-in registry)
    let engine = Engine::start(EngineConfig::default())?;
    println!("loaded {} AOT artifacts", engine.manifest().len());

    // 2. coordinator: the submission queue + planner + scheduler
    let coord = Coordinator::new(engine, CoordinatorConfig::default());

    // 3. an irregular GEMM — the router pads it into a Table-1 bucket.
    //    submit() returns a Ticket immediately; wait() blocks for the
    //    result + request metadata.
    let a = Matrix::rand_uniform(100, 60, 1);
    let b = Matrix::rand_uniform(60, 80, 2);
    let clean = coord
        .submit(GemmRequest::new(a.clone(), b.clone()).policy(FtPolicy::Online))?
        .wait()?;
    println!(
        "clean run: id={} bucket={:?} launches={} errors={} queued={:?}",
        clean.meta.id,
        clean.result.buckets,
        clean.result.kernel_launches,
        clean.result.errors_detected,
        clean.meta.queued
    );

    // 4. same GEMM with a simulated silent data corruption (+1000 on the
    //    accumulator of C[17, 23] at k-step 0 — the §5.3 protocol) and
    //    per-request options: high priority and a generous deadline.
    let hit = coord
        .submit(
            GemmRequest::new(a.clone(), b.clone())
                .policy(FtPolicy::Online)
                .inject(InjectionPlan::single(17, 23, 0, 1000.0))
                .priority(Priority::High)
                .deadline(std::time::Duration::from_secs(30)),
        )?
        .wait()?;
    println!(
        "injected run: detected={} corrected={} (in-kernel, no recompute)",
        hit.result.errors_detected, hit.result.errors_corrected
    );

    // 5. verify against the host reference
    let want = a.matmul(&b);
    let diff = hit.result.c.max_abs_diff(&want);
    println!("max |C - reference| = {diff:.3e}");
    assert!(diff < 1e-2, "online ABFT must hide the fault");
    assert_eq!(hit.result.errors_corrected, 1);

    // 6. the blocking one-liner is still there: gemm == submit + wait
    let direct = coord.gemm(&a, &b, FtPolicy::Online)?;
    assert!(direct.c.max_abs_diff(&want) < 1e-2);
    println!("quickstart OK");
    Ok(())
}
