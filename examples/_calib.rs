//! Model-calibration report (dev tool): prints the gpusim model's values
//! next to every paper-measured number it is fitted against. Re-run after
//! touching `gpusim::device` constants.
fn main() {
    use ftgemm::codegen::ShapeClass;
    use ftgemm::figures::*;
    use ftgemm::gpusim::cublas::cublas_gflops;
    use ftgemm::gpusim::device::{A100, T4};
    use ftgemm::gpusim::ft_model::{overhead_pct, FtLevel, FtVariant};
    use ftgemm::gpusim::stepwise::{average_gflops, ladder};

    println!("== Fig 9 ladder (T4) ==");
    for s in ladder() {
        let g = average_gflops(&T4, &s.config);
        println!(
            "{:14} model {:7.0}  paper {:7.0}  ({:+.1}%)",
            s.name,
            g,
            s.paper_t4_gflops,
            (g / s.paper_t4_gflops - 1.0) * 100.0
        );
    }
    let huge = ShapeClass::Huge.params();
    let sizes = [1024usize, 2048, 3072, 4096, 5120, 6144];
    for dev in [&T4, &A100] {
        println!("== FT overheads vs base ({}) avg 1024..6144 ==", dev.name);
        for (name, v) in [
            ("tb", FtVariant::Fused(FtLevel::Tb)),
            ("warp", FtVariant::Fused(FtLevel::Warp)),
            ("thread", FtVariant::Fused(FtLevel::Thread)),
            ("detect", FtVariant::DetectOnly),
            ("nonfused", FtVariant::NonFused { ks: 256 }),
        ] {
            let avg: f64 =
                sizes.iter().map(|&s| overhead_pct(dev, huge, s, s, s, v)).sum::<f64>() / 6.0;
            println!("  {name:9} {avg:+6.2}%");
        }
        let base: f64 = sizes
            .iter()
            .map(|&s| preset_gflops(dev, huge, s, s, s))
            .sum::<f64>()
            / 6.0;
        let cb: f64 = sizes.iter().map(|&s| cublas_gflops(dev, s, s, s)).sum::<f64>() / 6.0;
        println!("  ours {base:.0} GF vs cublas {cb:.0} GF -> ours/cublas = {:.3}", base / cb);
    }
    for (dev, nm) in [(&T4, "T4"), (&A100, "A100")] {
        let avg: f64 = irregular_sizes()
            .iter()
            .map(|&m| generated_gflops(dev, m, m, 256) / cublas_gflops(dev, m, m, 256))
            .sum::<f64>()
            / irregular_sizes().len() as f64;
        println!("{nm}: generated/cublas (K=256 sweep) avg {avg:.3}  [paper: T4 1.1821, A100 1.2245]");
    }
}
