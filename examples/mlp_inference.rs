//! End-to-end driver: serve a real small workload through the full stack.
//!
//!     make artifacts && cargo run --release --example mlp_inference
//!
//! A 3-layer MLP (784 -> 512 -> 256 -> 10, ~550k parameters) classifies
//! batches of synthetic MNIST-like inputs. EVERY matmul of the forward
//! pass is served by the coordinator — routed onto AOT Pallas kernels,
//! executed on PJRT, protected by online ABFT — while an SEU storm
//! corrupts accumulators mid-GEMM. The run proves all three layers
//! compose: L1 pallas kernels inside L2 jax artifacts driven by the L3
//! rust coordinator, with Python nowhere at runtime.
//!
//! Reports latency/throughput with FT off/on (the paper's overhead claim)
//! and verifies logits match the unprotected, un-attacked host reference.

use std::time::Instant;

use ftgemm::abft::injection::InjectionPlan;
use ftgemm::coordinator::batcher::{Batcher, BatcherConfig};
use ftgemm::faults::model::KernelGeom;
use ftgemm::faults::SeuModel;
use ftgemm::prelude::*;
use ftgemm::util::rng::Pcg32;

struct Mlp {
    w1: Matrix, // 784 x 512
    w2: Matrix, // 512 x 256
    w3: Matrix, // 256 x 10
}

impl Mlp {
    fn new(seed: u64) -> Mlp {
        // Xavier-ish init, deterministic
        let scale = |m: Matrix, f: f32| {
            let mut m = m;
            for v in m.data_mut() {
                *v *= f;
            }
            m
        };
        Mlp {
            w1: scale(Matrix::randn(784, 512, seed), (2.0f32 / 784.0).sqrt()),
            w2: scale(Matrix::randn(512, 256, seed + 1), (2.0f32 / 512.0).sqrt()),
            w3: scale(Matrix::randn(256, 10, seed + 2), (2.0f32 / 256.0).sqrt()),
        }
    }

    /// Forward pass with every GEMM served by the coordinator.
    fn forward(
        &self,
        coord: &Coordinator,
        x: &Matrix,
        policy: FtPolicy,
        storm: Option<(&SeuModel, &mut Pcg32)>,
    ) -> anyhow::Result<(Matrix, u64)> {
        let mut corrected = 0;
        let mut rng_holder = storm;
        let mut layer = |input: &Matrix, w: &Matrix| -> anyhow::Result<Matrix> {
            let plan = match &mut rng_holder {
                Some((model, rng)) if policy != FtPolicy::None => {
                    model.plan(&KernelGeom::for_shape(input.rows(), w.cols(), w.rows()), 0.0, rng)
                }
                _ => InjectionPlan::none(),
            };
            let out = coord.gemm_with_faults(input, w, policy, &plan)?;
            corrected += out.errors_corrected + out.recomputes;
            // ReLU
            let mut h = out.c;
            for v in h.data_mut() {
                *v = v.max(0.0);
            }
            Ok(h)
        };

        let h1 = layer(x, &self.w1)?;
        let h2 = layer(&h1, &self.w2)?;
        // final layer: no ReLU (logits)
        let plan = match &mut rng_holder {
            Some((model, rng)) if policy != FtPolicy::None => {
                model.plan(&KernelGeom::for_shape(h2.rows(), 10, 256), 0.0, rng)
            }
            _ => InjectionPlan::none(),
        };
        let out = coord.gemm_with_faults(&h2, &self.w3, policy, &plan)?;
        corrected += out.errors_corrected + out.recomputes;
        Ok((out.c, corrected))
    }

    /// Host-side reference forward (pure rust matmul).
    fn forward_ref(&self, x: &Matrix) -> Matrix {
        let relu = |mut m: Matrix| {
            for v in m.data_mut() {
                *v = v.max(0.0);
            }
            m
        };
        let h1 = relu(x.matmul(&self.w1));
        let h2 = relu(h1.matmul(&self.w2));
        h2.matmul(&self.w3)
    }
}

fn argmax_rows(m: &Matrix) -> Vec<usize> {
    (0..m.rows())
        .map(|i| {
            let row = m.row(i);
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let engine = Engine::start(EngineConfig::default())?;
    let coord = Coordinator::new(engine, CoordinatorConfig::default());
    let mlp = Mlp::new(42);
    let batch = 64usize;
    let batches = 12usize;

    println!("MLP 784->512->256->10 (~550k params), {batches} batches of {batch}");

    // ---- pass 1: FT off, fault-free (baseline latency)
    let t0 = Instant::now();
    let mut baseline_logits = Vec::new();
    for bi in 0..batches {
        let x = Matrix::rand_uniform(batch, 784, 1000 + bi as u64);
        let (logits, _) = mlp.forward(&coord, &x, FtPolicy::None, None)?;
        baseline_logits.push(logits);
    }
    let t_off = t0.elapsed();

    // ---- pass 2: FT on + SEU storm (the paper's "hundreds of errors per
    // minute" regime)
    let storm = SeuModel::PerGemm { count: 2 }; // 2 SEUs per GEMM, 3 GEMMs/batch
    let mut rng = Pcg32::seeded(777);
    let t1 = Instant::now();
    let mut total_corrected = 0;
    let mut ft_logits = Vec::new();
    for bi in 0..batches {
        let x = Matrix::rand_uniform(batch, 784, 1000 + bi as u64);
        let (logits, corrected) =
            mlp.forward(&coord, &x, FtPolicy::Online, Some((&storm, &mut rng)))?;
        total_corrected += corrected;
        ft_logits.push(logits);
    }
    let t_on = t1.elapsed();

    // ---- verify: corrected logits match the host reference
    let mut max_diff = 0f32;
    let mut pred_mismatches = 0usize;
    for (bi, logits) in ft_logits.iter().enumerate() {
        let x = Matrix::rand_uniform(batch, 784, 1000 + bi as u64);
        let want = mlp.forward_ref(&x);
        max_diff = max_diff.max(logits.max_abs_diff(&want));
        pred_mismatches += argmax_rows(logits)
            .iter()
            .zip(argmax_rows(&want))
            .filter(|(a, b)| **a != *b)
            .count();
    }

    let inferences = (batches * batch) as f64;
    let injected = (batches * 3 * 2) as u64;
    println!("FT off: {t_off:?}  ({:.0} inferences/s)", inferences / t_off.as_secs_f64());
    println!(
        "FT on + storm: {t_on:?}  ({:.0} inferences/s), {injected} SEUs injected, {total_corrected} corrected",
        inferences / t_on.as_secs_f64()
    );
    println!(
        "online-FT serving overhead: {:+.1}%",
        (t_on.as_secs_f64() / t_off.as_secs_f64() - 1.0) * 100.0
    );
    println!("max |logits - host reference| = {max_diff:.3e}; prediction mismatches = {pred_mismatches}");

    // >=: huge-magnitude corrections may be refined at a later verification
    assert!(total_corrected >= injected, "every SEU must be corrected");
    assert_eq!(pred_mismatches, 0, "corruption must not change predictions");
    assert!(max_diff < 0.05);

    // ---- bonus: the same workload through the dynamic batcher
    let batcher = Batcher::start(coord.clone(), BatcherConfig::default());
    let t2 = Instant::now();
    let tickets: Vec<_> = (0..batches)
        .map(|bi| {
            let x = Matrix::rand_uniform(batch, 784, 1000 + bi as u64);
            batcher.submit(GemmRequest::new(x, mlp.w1.clone()).policy(FtPolicy::Online))
        })
        .collect::<Result<_, _>>()?;
    for t in tickets {
        t.wait()?;
    }
    println!(
        "batcher: {} layer-1 GEMMs in {:?} ({} groups, {} co-scheduled)",
        batches,
        t2.elapsed(),
        batcher.stats().groups,
        batcher.stats().coscheduled
    );
    println!("mlp_inference OK");
    Ok(())
}
