//! Worker-pool scaling, two ways: within one split request, and across
//! many concurrently submitted requests.
//!
//!     cargo run --release --example engine_pool
//!
//! Part 1 serves one oversize (split) FT-GEMM — 1024³, which the router
//! decomposes into 8 huge-bucket blocks — through engines with 1, 2, and
//! 4 workers, and prints the measured wall times next to the gpusim
//! serving model. Part 2 re-serves the same request on the `blocked`
//! backend (`--backend` on the CLI, `[engine].backend` in config) — the
//! cache-blocked, register-tiled, multithreaded executor with fused ABFT.
//! Part 3 holds 8 *distinct* requests in flight at once through
//! `Coordinator::submit`, the cross-request concurrency the submission
//! API exists for. Works with or without AOT artifacts (reference
//! backend fallback).

use std::time::Instant;

use ftgemm::gpusim::{self, device::T4};
use ftgemm::prelude::*;

fn main() -> anyhow::Result<()> {
    let (m, n, k) = (1024usize, 1024usize, 1024usize);
    let a = Matrix::rand_uniform(m, k, 1);
    let b = Matrix::rand_uniform(k, n, 2);
    let want = a.matmul(&b);

    println!("serving {m}x{n}x{k} (8 huge blocks) with a growing engine pool:\n");
    println!(
        "{:>8} {:>10} {:>9} {:>13} {:>14}",
        "workers", "wall", "speedup", "peak inflight", "model speedup"
    );
    let mut base = None;
    for workers in [1usize, 2, 4] {
        let engine = Engine::start(EngineConfig { workers, ..Default::default() })?;
        let coord = Coordinator::new(engine.clone(), CoordinatorConfig::default());
        // warm every worker's cache, then time one served request
        coord.gemm(&a, &b, FtPolicy::Online)?;
        let t0 = Instant::now();
        let out = coord.gemm(&a, &b, FtPolicy::Online)?;
        let wall = t0.elapsed();
        assert_eq!(out.kernel_launches, 8);
        assert!(out.c.max_abs_diff(&want) < 1e-2);
        let secs = wall.as_secs_f64();
        let base = *base.get_or_insert(secs);
        println!(
            "{workers:>8} {wall:>10.2?} {:>8.2}x {:>13} {:>13.2}x",
            base / secs,
            engine.peak_inflight(),
            gpusim::pipeline_speedup(&T4, m, n, k, true, workers),
        );
    }

    // --- backend axis: same request, reference vs blocked executors
    // (blocked-scalar pins the portable kernel, so the last row shows
    // what runtime SIMD dispatch is worth on this host) ------------------
    println!("\nbackend shootout: same 1024^3 FT-GEMM, 1 engine worker:\n");
    println!("{:>14} {:>8} {:>10} {:>9}", "backend", "kernel", "wall", "speedup");
    let mut ref_wall = None;
    for backend in ["reference", "blocked-scalar", "blocked"] {
        let engine = Engine::start(EngineConfig {
            workers: 1,
            backend: backend.into(),
            ..Default::default()
        })?;
        let kernel = engine.backend().kernel_isa;
        let coord = Coordinator::new(engine, CoordinatorConfig::default());
        coord.gemm(&a, &b, FtPolicy::Online)?; // warm the executable cache
        let t0 = Instant::now();
        let out = coord.gemm(&a, &b, FtPolicy::Online)?;
        let wall = t0.elapsed();
        assert!(out.c.max_abs_diff(&want) < 1e-2, "{backend} diverged");
        let base = *ref_wall.get_or_insert(wall.as_secs_f64());
        println!(
            "{backend:>14} {kernel:>8} {wall:>10.2?} {:>8.2}x",
            base / wall.as_secs_f64()
        );
    }

    // --- cross-request concurrency: 8 distinct requests, one pool -------
    println!("\n8 concurrent submitted requests (4 workers, max_inflight 8):\n");
    let engine = Engine::start(EngineConfig { workers: 4, ..Default::default() })?;
    let coord = Coordinator::new(
        engine.clone(),
        CoordinatorConfig { max_inflight: 8, ..Default::default() },
    );
    let mats: Vec<(Matrix, Matrix)> = (0..8u64)
        .map(|i| {
            (Matrix::rand_uniform(512, 512, 10 + i), Matrix::rand_uniform(512, 512, 30 + i))
        })
        .collect();
    let wants: Vec<Matrix> = mats.iter().map(|(a, b)| a.matmul(b)).collect();
    // warm the pool on the huge bucket, then time the whole wave
    coord.gemm(&mats[0].0, &mats[0].1, FtPolicy::Online)?;
    let t0 = Instant::now();
    let tickets: Vec<Ticket> = mats
        .iter()
        .map(|(a, b)| {
            coord.submit(GemmRequest::new(a.clone(), b.clone()).policy(FtPolicy::Online))
        })
        .collect::<anyhow::Result<_>>()?;
    println!(
        "submitted: queue depth {} (bound {}), engine inflight {}",
        coord.queue_depth(),
        coord.max_inflight(),
        engine.inflight()
    );
    for (t, want) in tickets.into_iter().zip(&wants) {
        let resp = t.wait()?;
        assert!(resp.result.c.max_abs_diff(want) < 1e-2);
    }
    println!(
        "8 requests done in {:?}; engine peak inflight {}",
        t0.elapsed(),
        engine.peak_inflight()
    );
    println!("\nengine_pool OK");
    Ok(())
}
