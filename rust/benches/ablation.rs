//! Ablation bench (`cargo bench --bench ablation`): design-choice
//! experiments DESIGN.md calls out.
//!
//! 1. verify_every — the fused kernel's verification period (L1): how much
//!    of the FT cost is the periodic verification sweep vs the running
//!    checksum updates? (SEU interval grows with the period — the paper's
//!    §4.1 trade-off.)
//! 2. FT level — thread vs warp vs tb exec time on the live CPU stack
//!    (structural echo of Fig 12; CPU wallclock, not a GPU claim).
//! 3. bucket padding — cost of serving an ill-fitting shape.

use std::hint::black_box;

use ftgemm::abft::matrix::Matrix;
use ftgemm::bench::Harness;
use ftgemm::runtime::engine::Tensor;
use ftgemm::runtime::{Engine, EngineConfig};

fn main() {
    let Ok(engine) = Engine::start(EngineConfig::default()) else {
        eprintln!("artifacts not built — run `make artifacts`");
        return;
    };
    let a = Matrix::rand_uniform(128, 128, 1);
    let b = Matrix::rand_uniform(128, 128, 2);
    let inj = vec![0.0f32; 8 * 4];
    let exec = |name: &str| {
        engine
            .execute(
                name,
                vec![
                    Tensor::new(vec![128, 128], a.data().to_vec()),
                    Tensor::new(vec![128, 128], b.data().to_vec()),
                    Tensor::new(vec![8, 4], inj.clone()),
                ],
            )
            .unwrap()
    };
    let exec_plain = || {
        engine
            .execute(
                "gemm_medium",
                vec![
                    Tensor::new(vec![128, 128], a.data().to_vec()),
                    Tensor::new(vec![128, 128], b.data().to_vec()),
                ],
            )
            .unwrap()
    };

    let mut h = Harness::quick();
    h.bench("baseline/gemm_medium", || {
        black_box(exec_plain());
    });
    // verify_every ablation: 1 = verify every k-step, 16 = every 16 steps
    for (name, art) in [
        ("verify_every/1", "ftgemm_tb_medium_ve1"),
        ("verify_every/4", "ftgemm_tb_medium_ve4"),
        ("verify_every/8(default)", "ftgemm_tb_medium"),
        ("verify_every/16", "ftgemm_tb_medium_ve16"),
    ] {
        engine.warm(art).unwrap();
        h.bench(name, || {
            black_box(exec(art));
        });
    }
    // FT level ablation
    for (name, art) in [
        ("level/tb", "ftgemm_tb_medium"),
        ("level/warp", "ftgemm_warp_medium"),
        ("level/thread", "ftgemm_thread_medium"),
        ("level/detect_only", "ftdetect_medium"),
    ] {
        engine.warm(art).unwrap();
        h.bench(name, || {
            black_box(exec(art));
        });
    }
    println!("{}", h.summary());
}
