//! Per-figure benchmarks (`cargo bench --bench figures`): one bench per
//! paper table/figure — times regeneration and prints each figure's
//! headline series means so `bench_output.txt` doubles as a results
//! digest for EXPERIMENTS.md.

use std::hint::black_box;

use ftgemm::bench::Harness;
use ftgemm::figures::catalog;

fn main() {
    let mut h = Harness::quick();
    for id in catalog::FIGURE_IDS {
        h.bench(&format!("figure/{id}"), || {
            black_box(catalog::generate(id).unwrap());
        });
    }
    println!("{}", h.summary());

    // headline digest per figure
    for id in catalog::FIGURE_IDS {
        for t in catalog::generate(id).unwrap() {
            let means: Vec<String> = t
                .series
                .iter()
                .map(|s| format!("{}={:.0}", s.name, s.mean_y()))
                .collect();
            println!("{}\n  mean: {}", t.title, means.join("  "));
        }
    }
}
