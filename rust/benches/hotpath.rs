//! Hot-path benchmarks (custom harness; `cargo bench --bench hotpath`).
//!
//! Covers the request-path components the §Perf pass optimizes:
//! router planning, ABFT host verification, injection marshalling, host
//! GEMM (the offline recompute path), JSON manifest parsing, live engine
//! execution + the full coordinator round trip per policy, and the
//! **worker-count axis**: 1-worker vs N-worker wall time on an oversize
//! (split) shape served through the plan → schedule → execute pipeline,
//! plus the **repeat-operand axis**: the same Arc-shared operands
//! resubmitted with the packed-operand cache on vs off (the
//! `--min-cache-speedup` gate point). The worker sweep writes
//! `BENCH_pipeline.json` next to the manifest it ran from.

use std::hint::black_box;
use std::sync::Arc;
use std::time::Instant;

use ftgemm::abft::checksum::{verify, ChecksumPair, Thresholds};
use ftgemm::abft::injection::InjectionPlan;
use ftgemm::abft::matrix::Matrix;
use ftgemm::bench::Harness;
use ftgemm::coordinator::{router, Coordinator, CoordinatorConfig, FtPolicy, GemmRequest};
use ftgemm::gpusim::{self, device::T4};
use ftgemm::runtime::{Engine, EngineConfig};
use ftgemm::util::json::Json;
use ftgemm::util::rng::Pcg32;

fn main() {
    let mut h = Harness::default();

    // --- router planning
    h.bench("router/route_exact_128", || {
        black_box(router::route(128, 128, 128));
    });
    h.bench("router/route_padded_irregular", || {
        black_box(router::route(100, 70, 90));
    });
    h.bench("router/route_split_1536", || {
        black_box(router::route(1536, 1536, 1536));
    });

    // --- ABFT host-side verification (defense-in-depth path)
    let a = Matrix::rand_uniform(256, 256, 1);
    let b = Matrix::rand_uniform(256, 256, 2);
    let c = a.matmul(&b);
    let pair = ChecksumPair::of_product(&a, &b);
    h.bench("abft/checksum_of_product_256", || {
        black_box(ChecksumPair::of_product(&a, &b));
    });
    h.bench("abft/verify_clean_256", || {
        black_box(verify(&c, &pair, Thresholds::default()));
    });

    // --- injection plan marshalling
    let mut rng = Pcg32::seeded(3);
    let plan = InjectionPlan::random_seu(512, 512, 64, 8, 128, 128, 8, &mut rng);
    h.bench("faults/plan_to_tensor", || {
        black_box(plan.to_tensor(8));
    });

    // --- host GEMM (offline recompute path)
    h.bench("matrix/matmul_blocked_256", || {
        black_box(a.matmul(&b));
    });
    let big_a = Matrix::rand_uniform(512, 512, 4);
    let big_b = Matrix::rand_uniform(512, 512, 5);
    h.bench("matrix/matmul_blocked_512", || {
        black_box(big_a.matmul(&big_b));
    });
    h.bench("matrix/pad_to_512", || {
        black_box(a.pad_to(512, 512));
    });

    // --- manifest parsing
    if let Ok(text) = std::fs::read_to_string("artifacts/manifest.json") {
        h.bench("json/parse_manifest", || {
            black_box(Json::parse(&text).unwrap());
        });
    }

    // --- live engine + coordinator (needs artifacts)
    if let Ok(engine) = Engine::start(EngineConfig::default()) {
        for name in ["gemm_small", "gemm_medium", "ftgemm_tb_medium", "ftdetect_medium"] {
            engine.warm(name).unwrap();
        }
        let coord = Coordinator::new(engine.clone(), CoordinatorConfig::default());
        let (ea, eb) = (Matrix::rand_uniform(128, 128, 6), Matrix::rand_uniform(128, 128, 7));
        let mut hq = Harness::quick();
        hq.bench("engine/exec_gemm_medium", || {
            use ftgemm::runtime::engine::Tensor;
            black_box(
                engine
                    .execute(
                        "gemm_medium",
                        vec![
                            Tensor::new(vec![128, 128], ea.data().to_vec()),
                            Tensor::new(vec![128, 128], eb.data().to_vec()),
                        ],
                    )
                    .unwrap(),
            );
        });
        hq.bench("coord/gemm_none_128", || {
            black_box(coord.gemm(&ea, &eb, FtPolicy::None).unwrap());
        });
        hq.bench("coord/gemm_online_128", || {
            black_box(coord.gemm(&ea, &eb, FtPolicy::Online).unwrap());
        });
        hq.bench("coord/gemm_offline_128", || {
            black_box(coord.gemm(&ea, &eb, FtPolicy::Offline).unwrap());
        });
        let pa = Matrix::rand_uniform(100, 70, 8);
        let pb = Matrix::rand_uniform(70, 90, 9);
        hq.bench("coord/gemm_padded_100x90x70", || {
            black_box(coord.gemm(&pa, &pb, FtPolicy::Online).unwrap());
        });
        println!("\n== live engine/coordinator ==\n{}", hq.summary());
    } else {
        eprintln!("(artifacts not built — engine benches skipped)");
    }

    bench_worker_pipeline();

    println!("\n== host hot paths ==\n{}", h.summary());
}

/// The acceptance benchmark of the pipeline + backend work: the same
/// oversize (split) FT-GEMM served through the engine pool on all three
/// registered backends — reference and blocked across 1/2/4 workers,
/// plus the pinned-scalar blocked variant at the workers=1 gate point —
/// results written to BENCH_pipeline.json alongside the analytic model.
/// The `gate` block is what CI's `bench-check` binary enforces: blocked
/// clears `--min-speedup` over reference AND `--min-simd-speedup` over
/// its own scalar kernel at 1024^3 with FT enabled. The `ft_overhead`
/// series times each blocked variant clean (FtPolicy::None) vs fused-FT
/// (FtPolicy::Online) so the paper's ~9% fused-ABFT overhead claim is
/// tracked per kernel ISA.
fn bench_worker_pipeline() {
    const SHAPE: (usize, usize, usize) = (1024, 1024, 1024); // 2x2x2 huge blocks
    // worker axis for the analytic (gpusim) scaling curves
    const WORKER_COUNTS: [usize; 3] = [1, 2, 4];
    // (workers-per-pool, pools) sweep points. blocked-scalar only pins the
    // workers=1 gate/overhead points; the worker axis is covered by the
    // dispatched backends, and blocked additionally traces the
    // engine-sharding axis (workers=1, pools 2/4) that the serving
    // scaling gate exercises end to end.
    const SWEEP: [(&str, &[(usize, usize)]); 3] = [
        ("reference", &[(1, 1), (2, 1), (4, 1)]),
        ("blocked-scalar", &[(1, 1)]),
        ("blocked", &[(1, 1), (2, 1), (4, 1), (1, 2), (1, 4)]),
    ];

    let a = Matrix::rand_uniform(SHAPE.0, SHAPE.2, 10);
    let b = Matrix::rand_uniform(SHAPE.2, SHAPE.1, 11);

    let mut hq = Harness::quick();
    let mut live = Json::Arr(Vec::new());
    let mut ft_overhead = Json::Arr(Vec::new());
    let mut manifest_source = String::from("builtin");
    let mut blocks = 0u64;
    // (backend, mean wall time, kernel ISA) at the workers=1 gate point
    let mut gate_means: Vec<(&str, f64, &'static str)> = Vec::new();
    for &(backend, sweep_points) in &SWEEP {
        let mut base_mean: Option<f64> = None;
        for &(workers, pools) in sweep_points {
            let engine = Engine::start(EngineConfig {
                workers,
                pools,
                backend: backend.to_string(),
                ..Default::default()
            })
            .expect("engine starts (builtin manifest fallback)");
            if !engine.manifest().is_builtin() {
                manifest_source = "artifacts".into();
            }
            let kernel_isa = engine.backend().kernel_isa;
            let coord = Coordinator::new(engine.clone(), CoordinatorConfig::default());
            // warm every worker's executable cache before timing
            let first = coord.gemm(&a, &b, FtPolicy::Online).expect("warmup gemm");
            blocks = first.buckets.len() as u64;
            let label = if pools == 1 {
                format!("pipeline/split1024/{backend}/workers{workers}")
            } else {
                format!("pipeline/split1024/{backend}/pools{pools}")
            };
            let r = hq.bench(&label, || {
                black_box(coord.gemm(&a, &b, FtPolicy::Online).unwrap());
            });
            let mean_s = r.mean.as_secs_f64();
            let base = *base_mean.get_or_insert(mean_s);
            if workers == 1 && pools == 1 {
                gate_means.push((backend, mean_s, kernel_isa));
                if backend != "reference" {
                    // clean-vs-FT overhead at the gate point (paper's
                    // ~9% fused-ABFT claim, tracked per kernel ISA)
                    coord.gemm(&a, &b, FtPolicy::None).expect("clean warmup");
                    let rc = hq.bench(&format!("pipeline/split1024/{backend}/clean"), || {
                        black_box(coord.gemm(&a, &b, FtPolicy::None).unwrap());
                    });
                    let clean_s = rc.mean.as_secs_f64();
                    let mut e = Json::obj();
                    e.set("backend", Json::Str(backend.into()));
                    e.set("kernel_isa", Json::Str(kernel_isa.into()));
                    e.set("clean_mean_s", Json::Num(clean_s));
                    e.set("ft_mean_s", Json::Num(mean_s));
                    e.set("overhead", Json::Num(mean_s / clean_s - 1.0));
                    ft_overhead.push(e);
                }
            }
            let mut entry = Json::obj();
            entry.set("backend", Json::Str(backend.into()));
            entry.set("kernel_isa", Json::Str(kernel_isa.into()));
            entry.set("workers", Json::Num(workers as f64));
            entry.set("pools", Json::Num(pools as f64));
            entry.set("mean_s", Json::Num(mean_s));
            entry.set("speedup_vs_1worker", Json::Num(base / mean_s));
            entry.set("peak_inflight", Json::Num(engine.peak_inflight() as f64));
            live.push(entry);
        }
    }
    println!("\n== pipeline worker/backend sweep ==\n{}", hq.summary());

    let repeat_cache = bench_repeat_cache(&a, &b, &mut hq);
    let largek = bench_largek(&mut hq);

    let mut ideal = Json::Arr(Vec::new());
    let mut modeled = Json::Arr(Vec::new());
    for &workers in &WORKER_COUNTS {
        let cost = gpusim::pipeline_wall(&T4, SHAPE.0, SHAPE.1, SHAPE.2, true, workers);
        let mut e = Json::obj();
        e.set("workers", Json::Num(workers as f64));
        e.set("speedup", Json::Num(cost.ideal_speedup()));
        ideal.push(e);
        let mut e = Json::obj();
        e.set("workers", Json::Num(workers as f64));
        e.set(
            "speedup",
            Json::Num(gpusim::pipeline_speedup(&T4, SHAPE.0, SHAPE.1, SHAPE.2, true, workers)),
        );
        e.set("modeled_wall_s", Json::Num(cost.wall_s));
        modeled.push(e);
    }

    let mut root = Json::obj();
    root.set("schema", Json::Str("ftgemm-bench-pipeline/6".into()));
    root.set(
        "shape",
        Json::Arr(vec![
            Json::Num(SHAPE.0 as f64),
            Json::Num(SHAPE.1 as f64),
            Json::Num(SHAPE.2 as f64),
        ]),
    );
    root.set("policy", Json::Str("online".into()));
    root.set(
        "backends",
        Json::Arr(SWEEP.iter().map(|(b, _)| Json::Str((*b).into())).collect()),
    );
    root.set("manifest", Json::Str(manifest_source));
    root.set("blocks", Json::Num(blocks as f64));
    root.set("live", live);
    root.set("ft_overhead", ft_overhead);
    root.set("repeat_cache", repeat_cache);
    root.set("largek", largek);
    let gate_of = |name: &str| {
        gate_means
            .iter()
            .find(|(b, _, _)| *b == name)
            .map(|&(_, s, isa)| (s, isa))
            .unwrap_or((f64::NAN, "unknown"))
    };
    let (reference_mean, _) = gate_of("reference");
    let (scalar_mean, _) = gate_of("blocked-scalar");
    let (blocked_mean, blocked_isa) = gate_of("blocked");
    let mut gate = Json::obj();
    gate.set("point", Json::Str("workers=1".into()));
    gate.set("kernel_isa", Json::Str(blocked_isa.into()));
    gate.set("reference_mean_s", Json::Num(reference_mean));
    gate.set("blocked_scalar_mean_s", Json::Num(scalar_mean));
    gate.set("blocked_mean_s", Json::Num(blocked_mean));
    gate.set("blocked_speedup", Json::Num(reference_mean / blocked_mean));
    gate.set("simd_speedup", Json::Num(scalar_mean / blocked_mean));
    root.set("gate", gate);
    println!(
        "gate: blocked[{blocked_isa}] {blocked_mean:.4}s vs reference {reference_mean:.4}s \
         ({:.2}x) and vs blocked-scalar {scalar_mean:.4}s ({:.2}x) at 1024^3, FT on",
        reference_mean / blocked_mean,
        scalar_mean / blocked_mean
    );
    let mut model = Json::obj();
    model.set("ideal_wave_scaling", ideal);
    model.set("gpusim_t4", modeled);
    root.set("model", model);
    // The network-serving series is measured by a separate closed-loop
    // harness (`loadgen --bench-out`), which replaces this placeholder
    // with throughput/latency entries; CI runs it right after this bench.
    // `pool_scaling` is derived by the same merge once the series spans
    // two shard counts (a pools=1 run plus an --append-serving multi-pool
    // run) and is what `bench-check --require-scaling` gates on.
    root.set("serving", Json::Null);
    root.set("pool_scaling", Json::Null);
    root.set(
        "note",
        Json::Str(
            "live = measured coordinator wall time for one oversize FT-GEMM vs engine worker \
             count and backend; `gate` is the workers=1 comparison the CI bench-check binary \
             enforces (blocked vs reference, and blocked vs its pinned-scalar kernel); \
             `ft_overhead` = clean (policy=none) vs fused-FT (policy=online) wall time per \
             blocked variant at that point; `serving` = gateway throughput/latency measured \
             over TCP by `loadgen --bench-out` (null until it runs) and `pool_scaling` = the \
             multi-pool throughput ratio loadgen derives from it (null until a two-shard-count \
             series exists); `repeat_cache` = the same Arc-shared operands resubmitted with the \
             packed-operand cache on vs off (first/cold vs steady-state wall time, and the \
             steady-state speedup `bench-check --min-cache-speedup` gates on); `largek` = \
             deep-reduction shapes run directly on the blocked backend with the class-resolved \
             KC vs pinned KC=k (the per-shape full/blocked ratio is what `bench-check \
             --min-largek-speedup` gates on); regenerate with \
             `cargo bench --bench hotpath` then the loadgen smoke"
                .into(),
        ),
    );
    match std::fs::write("BENCH_pipeline.json", root.to_string_pretty()) {
        Ok(()) => println!("wrote BENCH_pipeline.json"),
        Err(e) => eprintln!("could not write BENCH_pipeline.json: {e}"),
    }
}

/// The large-k series behind `bench-check --min-largek-speedup`: ad-hoc
/// deep-reduction GEMMs executed directly on the blocked backend — the
/// coordinator router would split `k` at the bucket depth, which is
/// precisely the cache-residency effect this measures. Each shape runs
/// once with the class-resolved KC (the blocked k-panel nest) and once
/// pinned to KC = k (the pre-blocking full-depth fold, whose A/B panels
/// overflow L1/L2 at these depths); the per-shape ratio `full / blocked`
/// must clear the gate on every shape, so `min_speedup` is what the
/// check enforces. Results are bitwise identical between the two
/// configurations (the KC-invariance contract), so this is purely a
/// residency comparison.
fn bench_largek(hq: &mut Harness) -> Json {
    use ftgemm::runtime::engine::Tensor;
    use ftgemm::runtime::{Artifact, ArtifactKind, Backend, BlockedBackend, TensorSpec};
    use std::path::PathBuf;

    let spec = |shape: &[usize], role: &str| TensorSpec {
        shape: shape.to_vec(),
        dtype: "float32".into(),
        role: role.into(),
    };
    let mut entries = Json::Arr(Vec::new());
    let mut min_speedup = f64::INFINITY;
    let mut isa_name = "unknown";
    for &(m, n, k) in &[(256usize, 256usize, 8192usize), (64, 64, 8192)] {
        let art = Artifact {
            name: format!("bench_largek_{m}x{n}x{k}"),
            file: PathBuf::from("<bench>"),
            kind: ArtifactKind::Gemm,
            bucket: "bench".into(),
            m,
            n,
            k,
            ks: 0,
            inputs: vec![spec(&[m, k], ""), spec(&[k, n], "")],
            outputs: vec![spec(&[m, n], "c")],
            params: None,
            ft_level: None,
            max_inj: 0,
            verify_every: 0,
            sub_m: 0,
            sub_n: 0,
        };
        let a = Matrix::rand_uniform(m, k, 40);
        let b = Matrix::rand_uniform(k, n, 41);
        let inputs = || {
            vec![
                Tensor::new(vec![m, k], a.data().to_vec()),
                Tensor::new(vec![k, n], b.data().to_vec()),
            ]
        };
        let mut blocked = BlockedBackend::with_threads(4);
        let mut full = BlockedBackend::with_threads(4).with_kc(Some(k));
        isa_name = blocked.kernel_isa().name();
        black_box(blocked.execute(&art, inputs()).expect("largek warmup (blocked)"));
        black_box(full.execute(&art, inputs()).expect("largek warmup (full)"));
        let rb = hq.bench(&format!("largek/{m}x{n}x{k}/kc_blocked"), || {
            black_box(blocked.execute(&art, inputs()).unwrap());
        });
        let rf = hq.bench(&format!("largek/{m}x{n}x{k}/kc_full"), || {
            black_box(full.execute(&art, inputs()).unwrap());
        });
        let (blocked_s, full_s) = (rb.mean.as_secs_f64(), rf.mean.as_secs_f64());
        let speedup = full_s / blocked_s;
        min_speedup = min_speedup.min(speedup);
        let mut e = Json::obj();
        e.set(
            "shape",
            Json::Arr(vec![Json::Num(m as f64), Json::Num(n as f64), Json::Num(k as f64)]),
        );
        e.set("blocked_mean_s", Json::Num(blocked_s));
        e.set("kc_full_mean_s", Json::Num(full_s));
        e.set("speedup", Json::Num(speedup));
        entries.push(e);
        println!(
            "largek {m}x{n}x{k}: KC-blocked {blocked_s:.4}s vs KC=k {full_s:.4}s ({speedup:.3}x)"
        );
    }
    let mut out = Json::obj();
    out.set("kernel_isa", Json::Str(isa_name.into()));
    out.set("entries", entries);
    out.set("min_speedup", Json::Num(min_speedup));
    out
}

/// The repeat-operand series behind `bench-check --min-cache-speedup`:
/// the same `Arc`-shared operands resubmitted through the blocked
/// backend with the packed-operand cache at its default budget vs
/// disabled (`pack_cache_mb = 0`). The first submission is timed
/// separately — that is the cold pack + checksum-encode both
/// configurations pay — and the harness then times the steady state,
/// where every packing lookup is a cache hit when the cache is on. The
/// steady-state ratio (off / on) isolates exactly the packing work the
/// cache removes from the request path.
fn bench_repeat_cache(a: &Matrix, b: &Matrix, hq: &mut Harness) -> Json {
    let mut out = Json::obj();
    let mut steady: Vec<(&str, f64)> = Vec::new();
    for &(label, mb) in &[("on", None), ("off", Some(0usize))] {
        let engine = Engine::start(EngineConfig {
            workers: 4,
            pools: 1,
            backend: "blocked".to_string(),
            pack_cache_mb: mb,
            ..Default::default()
        })
        .expect("engine starts (builtin manifest fallback)");
        let coord = Coordinator::new(engine.clone(), CoordinatorConfig::default());
        let (aa, ab) = (Arc::new(a.clone()), Arc::new(b.clone()));
        let run = || {
            let req = GemmRequest::new(Arc::clone(&aa), Arc::clone(&ab)).policy(FtPolicy::Online);
            coord.submit(req).expect("submit").wait().expect("gemm")
        };
        let t0 = Instant::now();
        black_box(run());
        let first_s = t0.elapsed().as_secs_f64();
        let r = hq.bench(&format!("pipeline/repeat1024/cache_{label}"), || {
            black_box(run());
        });
        let steady_s = r.mean.as_secs_f64();
        steady.push((label, steady_s));
        let stats = engine.pack_cache_stats();
        let mut e = Json::obj();
        e.set("first_s", Json::Num(first_s));
        e.set("steady_mean_s", Json::Num(steady_s));
        e.set("hits", Json::Num(stats.map_or(0, |s| s.hits) as f64));
        e.set("misses", Json::Num(stats.map_or(0, |s| s.misses) as f64));
        e.set("bytes", Json::Num(stats.map_or(0, |s| s.bytes) as f64));
        out.set(&format!("cache_{label}"), e);
    }
    let on = steady.iter().find(|(l, _)| *l == "on").map(|&(_, s)| s).unwrap_or(f64::NAN);
    let off = steady.iter().find(|(l, _)| *l == "off").map(|&(_, s)| s).unwrap_or(f64::NAN);
    out.set("steady_speedup", Json::Num(off / on));
    println!(
        "repeat-operand cache: steady {on:.4}s (on) vs {off:.4}s (off) — {:.3}x at 1024^3, FT on",
        off / on
    );
    out
}
