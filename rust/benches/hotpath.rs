//! Hot-path benchmarks (custom harness; `cargo bench --bench hotpath`).
//!
//! Covers the request-path components the §Perf pass optimizes:
//! router planning, ABFT host verification, injection marshalling, host
//! GEMM (the offline recompute path), JSON manifest parsing, and — when
//! artifacts are present — live engine execution + the full coordinator
//! round trip per policy.

use std::hint::black_box;

use ftgemm::abft::checksum::{verify, ChecksumPair, Thresholds};
use ftgemm::abft::injection::InjectionPlan;
use ftgemm::abft::matrix::Matrix;
use ftgemm::bench::Harness;
use ftgemm::coordinator::{router, Coordinator, CoordinatorConfig, FtPolicy};
use ftgemm::runtime::{Engine, EngineConfig};
use ftgemm::util::json::Json;
use ftgemm::util::rng::Pcg32;

fn main() {
    let mut h = Harness::default();

    // --- router planning
    h.bench("router/route_exact_128", || {
        black_box(router::route(128, 128, 128));
    });
    h.bench("router/route_padded_irregular", || {
        black_box(router::route(100, 70, 90));
    });
    h.bench("router/route_split_1536", || {
        black_box(router::route(1536, 1536, 1536));
    });

    // --- ABFT host-side verification (defense-in-depth path)
    let a = Matrix::rand_uniform(256, 256, 1);
    let b = Matrix::rand_uniform(256, 256, 2);
    let c = a.matmul(&b);
    let pair = ChecksumPair::of_product(&a, &b);
    h.bench("abft/checksum_of_product_256", || {
        black_box(ChecksumPair::of_product(&a, &b));
    });
    h.bench("abft/verify_clean_256", || {
        black_box(verify(&c, &pair, Thresholds::default()));
    });

    // --- injection plan marshalling
    let mut rng = Pcg32::seeded(3);
    let plan = InjectionPlan::random_seu(512, 512, 64, 8, 128, 128, 8, &mut rng);
    h.bench("faults/plan_to_tensor", || {
        black_box(plan.to_tensor(8));
    });

    // --- host GEMM (offline recompute path)
    h.bench("matrix/matmul_blocked_256", || {
        black_box(a.matmul(&b));
    });
    let big_a = Matrix::rand_uniform(512, 512, 4);
    let big_b = Matrix::rand_uniform(512, 512, 5);
    h.bench("matrix/matmul_blocked_512", || {
        black_box(big_a.matmul(&big_b));
    });
    h.bench("matrix/pad_to_512", || {
        black_box(a.pad_to(512, 512));
    });

    // --- manifest parsing
    if let Ok(text) = std::fs::read_to_string("artifacts/manifest.json") {
        h.bench("json/parse_manifest", || {
            black_box(Json::parse(&text).unwrap());
        });
    }

    // --- live engine + coordinator (needs artifacts)
    if let Ok(engine) = Engine::start(EngineConfig::default()) {
        for name in ["gemm_small", "gemm_medium", "ftgemm_tb_medium", "ftdetect_medium"] {
            engine.warm(name).unwrap();
        }
        let coord = Coordinator::new(engine.clone(), CoordinatorConfig::default());
        let (ea, eb) = (Matrix::rand_uniform(128, 128, 6), Matrix::rand_uniform(128, 128, 7));
        let mut hq = Harness::quick();
        hq.bench("engine/exec_gemm_medium", || {
            use ftgemm::runtime::engine::Tensor;
            black_box(
                engine
                    .execute(
                        "gemm_medium",
                        vec![
                            Tensor::new(vec![128, 128], ea.data().to_vec()),
                            Tensor::new(vec![128, 128], eb.data().to_vec()),
                        ],
                    )
                    .unwrap(),
            );
        });
        hq.bench("coord/gemm_none_128", || {
            black_box(coord.gemm(&ea, &eb, FtPolicy::None).unwrap());
        });
        hq.bench("coord/gemm_online_128", || {
            black_box(coord.gemm(&ea, &eb, FtPolicy::Online).unwrap());
        });
        hq.bench("coord/gemm_offline_128", || {
            black_box(coord.gemm(&ea, &eb, FtPolicy::Offline).unwrap());
        });
        let pa = Matrix::rand_uniform(100, 70, 8);
        let pb = Matrix::rand_uniform(70, 90, 9);
        hq.bench("coord/gemm_padded_100x90x70", || {
            black_box(coord.gemm(&pa, &pb, FtPolicy::Online).unwrap());
        });
        println!("\n== live engine/coordinator ==\n{}", hq.summary());
    } else {
        eprintln!("(artifacts not built — engine benches skipped)");
    }

    println!("\n== host hot paths ==\n{}", h.summary());
}
