//! Request planning: compile a request into an explicit [`ExecutionPlan`]
//! before anything executes.
//!
//! Planning resolves *everything the execution will need* up front — block
//! decomposition (via [`router`]), per-block injection localization,
//! artifact resolution per (policy, bucket), checksum/verify strategy, and
//! accumulation targets — so the [`scheduler`](super::scheduler) is a pure
//! executor: it dispatches independent plan nodes concurrently over the
//! engine pool and folds partials into the output as they complete. A plan
//! that compiles cannot fail on a missing artifact mid-flight, and every
//! serving path (`Coordinator::gemm`, the [`Batcher`](super::batcher), the
//! non-fused [`ding`](super::ding) baseline) goes through these same types
//! — there is exactly one block-execution loop in the system.

use anyhow::{anyhow, bail, Result};

use crate::abft::checksum::Thresholds;
use crate::abft::injection::InjectionPlan;
use crate::runtime::backend::BackendInfo;
use crate::runtime::manifest::{ArtifactKind, Manifest};

use super::router::{self, BlockPlan};
use super::{CoordinatorConfig, FtPolicy};

/// A compiled request: the DAG of kernel-level work that computes it.
#[derive(Debug, Clone)]
pub struct ExecutionPlan {
    /// Output extents.
    pub m: usize,
    pub n: usize,
    /// Reduction extent.
    pub k: usize,
    /// Detection thresholds for host-side verification fallbacks.
    pub thresholds: Thresholds,
    /// True when the request needed block decomposition.
    pub split: bool,
    /// Nodes in id order (`nodes[i].id == i`).
    pub nodes: Vec<PlanNode>,
}

impl ExecutionPlan {
    /// Bucket names of the block nodes, block order (what
    /// `GemmResult::buckets` reports).
    pub fn block_buckets(&self) -> Vec<&'static str> {
        self.nodes
            .iter()
            .filter_map(|n| match &n.op {
                NodeOp::Block { block, .. } => Some(block.bucket.name()),
                _ => None,
            })
            .collect()
    }

    /// Any block padded?
    pub fn is_padded(&self) -> bool {
        self.nodes.iter().any(|n| match &n.op {
            NodeOp::Block { block, .. } => block.is_padded(),
            _ => false,
        })
    }

    /// Nodes with no dependencies — the initially dispatchable frontier.
    pub fn roots(&self) -> usize {
        self.nodes.iter().filter(|n| n.deps.is_empty()).count()
    }
}

/// One schedulable unit of work.
#[derive(Debug, Clone)]
pub struct PlanNode {
    pub id: usize,
    /// Node ids that must complete first.
    pub deps: Vec<usize>,
    /// Dispatch-affinity label (the artifact bucket this node hits).
    pub bucket: String,
    pub op: NodeOp,
}

#[derive(Debug, Clone)]
pub enum NodeOp {
    /// One routed block: extract + zero-pad the operand blocks, run the
    /// policy's kernel, slice the result, accumulate it at
    /// `(block.row0, block.col0)`. Independent of every other block.
    Block {
        block: BlockPlan,
        kernel: KernelOp,
        /// Injections translated into the block's local frame.
        inj: InjectionPlan,
    },
    /// Ding'11 encode launch: (A, B) -> (A^c, B^r).
    DingEncode { artifact: String },
    /// One Ding'11 panel: step launch, host-side fault window, verify
    /// launch. Panels chain through C^f (deps: encode + previous panel).
    DingPanel {
        step_artifact: String,
        verify_artifact: String,
        /// Node id of the encode whose outputs this panel reads.
        encode_node: usize,
        /// Previous panel's node id (`None` for the first panel).
        prev_node: Option<usize>,
        /// k-offset and width of this panel.
        s0: usize,
        ks: usize,
        /// Host-side injections landing in this panel's fault window.
        inj: InjectionPlan,
        /// The last panel yields the finished C^f.
        last: bool,
    },
}

/// Which kernel(s) a block node launches.
#[derive(Debug, Clone)]
pub enum KernelOp {
    /// Unprotected codegen GEMM.
    Plain { artifact: String },
    /// Fused online ABFT: detect + correct in kernel, one launch.
    Fused { artifact: String, max_inj: usize },
    /// Offline ABFT: detect (in-kernel when a detect artifact exists, else
    /// plain kernel + host checksum verify), recompute on detection.
    DetectRecompute {
        detect: Option<(String, usize)>,
        plain: Option<String>,
        max_recomputes: usize,
    },
}

/// Compiles requests against a manifest + coordinator config + the
/// serving backend's capabilities.
pub struct Planner<'a> {
    manifest: &'a Manifest,
    config: &'a CoordinatorConfig,
    /// Capabilities of the backend the plan will execute on. Defaults to
    /// fully capable; [`Planner::for_backend`] narrows it (a backend
    /// without in-kernel fused FT gets the online policy compiled to the
    /// detect-and-recompute strategy instead of an unservable plan).
    fused_ft: bool,
}

impl<'a> Planner<'a> {
    pub fn new(manifest: &'a Manifest, config: &'a CoordinatorConfig) -> Self {
        Planner { manifest, config, fused_ft: true }
    }

    /// Resolve artifacts against what `backend` can actually execute.
    pub fn for_backend(mut self, backend: BackendInfo) -> Self {
        self.fused_ft = backend.fused_ft;
        self
    }

    /// Compile `C = A·B` under `policy` with SEU injection into a plan of
    /// independent block nodes.
    pub fn plan_gemm(
        &self,
        m: usize,
        n: usize,
        k: usize,
        policy: FtPolicy,
        inj: &InjectionPlan,
    ) -> Result<ExecutionPlan> {
        if policy == FtPolicy::None && !inj.is_empty() {
            bail!("cannot inject into the unprotected kernel (no inj input); use Online/Offline");
        }
        let route = router::route(m, n, k);
        let mut nodes = Vec::with_capacity(route.blocks.len());
        for (id, block) in route.blocks.iter().enumerate() {
            let bucket = block.bucket.name();
            let kernel = self.kernel_for(policy, bucket)?;
            let local = localize_injections(inj, block);
            if let KernelOp::Fused { artifact, max_inj } = &kernel {
                if local.len() > *max_inj {
                    bail!(
                        "{artifact}: {} injections exceed kernel capacity {max_inj}",
                        local.len()
                    );
                }
            }
            nodes.push(PlanNode {
                id,
                deps: Vec::new(),
                bucket: bucket.to_string(),
                op: NodeOp::Block { block: block.clone(), kernel, inj: local },
            });
        }
        Ok(ExecutionPlan {
            m,
            n,
            k,
            thresholds: self.config.thresholds,
            split: route.split,
            nodes,
        })
    }

    /// Resolve the kernel op serving (policy, bucket).
    fn kernel_for(&self, policy: FtPolicy, bucket: &str) -> Result<KernelOp> {
        let missing = |p: FtPolicy| anyhow!("no {p:?} artifact for bucket {bucket}");
        Ok(match policy {
            FtPolicy::None => KernelOp::Plain {
                artifact: self
                    .manifest
                    .find(ArtifactKind::Gemm, bucket, None)
                    .ok_or_else(|| missing(policy))?
                    .name
                    .clone(),
            },
            FtPolicy::Online if self.fused_ft => {
                let art = self
                    .manifest
                    .find(ArtifactKind::FtGemm, bucket, Some(self.config.ft_level.as_str()))
                    .or_else(|| self.manifest.find(ArtifactKind::FtGemm, bucket, Some("tb")))
                    .ok_or_else(|| missing(policy))?;
                KernelOp::Fused { artifact: art.name.clone(), max_inj: art.max_inj.max(1) }
            }
            // Backend without in-kernel fused FT (a future PJRT client
            // serving detect-only HLO, say): the online policy degrades to
            // the offline strategy at plan time rather than failing.
            FtPolicy::Online => self.offline_kernel(bucket, policy)?,
            FtPolicy::Offline => self.offline_kernel(bucket, policy)?,
        })
    }

    /// The detect-and-recompute strategy for one bucket: in-kernel
    /// detection when a detect artifact exists, host checksum detection
    /// over the plain kernel otherwise.
    fn offline_kernel(&self, bucket: &str, policy: FtPolicy) -> Result<KernelOp> {
        let detect = self
            .manifest
            .find(ArtifactKind::FtDetect, bucket, None)
            .map(|a| (a.name.clone(), a.max_inj.max(1)));
        let plain = match &detect {
            Some(_) => None,
            None => Some(
                self.manifest
                    .find(ArtifactKind::Gemm, bucket, None)
                    .ok_or_else(|| anyhow!("no {policy:?} artifact for bucket {bucket}"))?
                    .name
                    .clone(),
            ),
        };
        Ok(KernelOp::DetectRecompute {
            detect,
            plain,
            max_recomputes: self.config.max_recomputes,
        })
    }
}

/// Compile the non-fused Ding'11 baseline for one bucket into a plan:
/// encode, then a chain of (step, inject, verify) panel nodes threading
/// C^f. Needs only a manifest (no coordinator config).
pub fn plan_ding(manifest: &Manifest, bucket: &str, inj: &InjectionPlan) -> Result<ExecutionPlan> {
    let encode = manifest
        .find(ArtifactKind::DingEncode, bucket, None)
        .ok_or_else(|| anyhow!("no ding_encode for {bucket}"))?;
    let step = manifest
        .find(ArtifactKind::DingStep, bucket, None)
        .ok_or_else(|| anyhow!("no ding_step for {bucket}"))?;
    let verify = manifest
        .find(ArtifactKind::DingVerify, bucket, None)
        .ok_or_else(|| anyhow!("no ding_verify for {bucket}"))?;
    let (m, n, k, ks) = (encode.m, encode.n, encode.k, step.ks.max(1));
    let panels = k / ks;
    // A ragged tail panel would need a differently-shaped step kernel; a
    // manifest like that is malformed — fail loudly rather than compute a
    // truncated reduction.
    if panels == 0 || panels * ks != k {
        bail!("ding pipeline for {bucket}: panel width ks={ks} must divide k={k}");
    }

    let mut nodes = Vec::with_capacity(panels + 1);
    nodes.push(PlanNode {
        id: 0,
        deps: Vec::new(),
        bucket: bucket.to_string(),
        op: NodeOp::DingEncode { artifact: encode.name.clone() },
    });
    for panel in 0..panels {
        let id = panel + 1;
        let prev_node = (panel > 0).then_some(id - 1);
        let mut deps = vec![0];
        deps.extend(prev_node);
        nodes.push(PlanNode {
            id,
            deps,
            bucket: bucket.to_string(),
            op: NodeOp::DingPanel {
                step_artifact: step.name.clone(),
                verify_artifact: verify.name.clone(),
                encode_node: 0,
                prev_node,
                s0: panel * ks,
                ks,
                inj: InjectionPlan {
                    injections: inj
                        .injections
                        .iter()
                        .filter(|e| e.step == panel)
                        .cloned()
                        .collect(),
                },
                last: panel == panels - 1,
            },
        });
    }
    Ok(ExecutionPlan {
        m,
        n,
        k,
        thresholds: Thresholds::default(),
        split: false,
        nodes,
    })
}

/// Translate global injection coordinates into a block's local frame; drop
/// entries outside the block; split GEMMs inject on the first k-partial.
pub fn localize_injections(inj: &InjectionPlan, block: &BlockPlan) -> InjectionPlan {
    if inj.is_empty() {
        return InjectionPlan::none();
    }
    let mut out = InjectionPlan::none();
    for e in &inj.injections {
        let in_rows = e.row >= block.row0 && e.row < block.row0 + block.m;
        let in_cols = e.col >= block.col0 && e.col < block.col0 + block.n;
        if in_rows && in_cols && block.k0 == 0 {
            out.injections.push(crate::abft::injection::Injection {
                row: e.row - block.row0,
                col: e.col - block.col0,
                step: e.step,
                magnitude: e.magnitude,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::abft::injection::Injection;

    fn planner_fixture() -> (Manifest, CoordinatorConfig) {
        (Manifest::builtin(), CoordinatorConfig::default())
    }

    #[test]
    fn exact_fit_plans_one_plain_node() {
        let (man, cfg) = planner_fixture();
        let plan = Planner::new(&man, &cfg)
            .plan_gemm(128, 128, 128, FtPolicy::None, &InjectionPlan::none())
            .unwrap();
        assert_eq!(plan.nodes.len(), 1);
        assert!(!plan.split && !plan.is_padded());
        assert_eq!(plan.block_buckets(), vec!["medium"]);
        assert_eq!(plan.roots(), 1);
        match &plan.nodes[0].op {
            NodeOp::Block { kernel: KernelOp::Plain { artifact }, .. } => {
                assert_eq!(artifact, "gemm_medium");
            }
            other => panic!("expected plain block, got {other:?}"),
        }
    }

    #[test]
    fn split_plan_nodes_are_independent_and_injections_localize() {
        let (man, cfg) = planner_fixture();
        let inj = InjectionPlan::single(550, 13, 2, 4096.0); // lands in block (1, 0)
        let plan = Planner::new(&man, &cfg)
            .plan_gemm(600, 600, 600, FtPolicy::Online, &inj)
            .unwrap();
        assert!(plan.split);
        assert_eq!(plan.nodes.len(), 8);
        assert_eq!(plan.roots(), 8, "block nodes must have no dependencies");
        let carrying: Vec<_> = plan
            .nodes
            .iter()
            .filter(|node| match &node.op {
                NodeOp::Block { inj, .. } => !inj.is_empty(),
                _ => false,
            })
            .collect();
        assert_eq!(carrying.len(), 1, "exactly one block owns the injection");
        match &carrying[0].op {
            NodeOp::Block { block, inj, .. } => {
                assert_eq!((block.row0, block.col0, block.k0), (512, 0, 0));
                assert_eq!(inj.injections[0].row, 38);
                assert_eq!(inj.injections[0].col, 13);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn online_level_fallback_and_offline_artifacts() {
        let (man, _) = planner_fixture();
        // "small" has only the tb fused level: warp request falls back
        let cfg =
            CoordinatorConfig { ft_level: crate::coordinator::FtLevel::Warp, ..Default::default() };
        let plan = Planner::new(&man, &cfg)
            .plan_gemm(64, 64, 64, FtPolicy::Online, &InjectionPlan::none())
            .unwrap();
        match &plan.nodes[0].op {
            NodeOp::Block { kernel: KernelOp::Fused { artifact, .. }, .. } => {
                assert_eq!(artifact, "ftgemm_tb_small");
            }
            other => panic!("{other:?}"),
        }
        // medium has a detect artifact; small falls back to host detection
        let cfg = CoordinatorConfig::default();
        let planner = Planner::new(&man, &cfg);
        let medium = planner
            .plan_gemm(128, 128, 128, FtPolicy::Offline, &InjectionPlan::none())
            .unwrap();
        match &medium.nodes[0].op {
            NodeOp::Block { kernel: KernelOp::DetectRecompute { detect, plain, .. }, .. } => {
                assert!(detect.is_some() && plain.is_none());
            }
            other => panic!("{other:?}"),
        }
        let small = planner
            .plan_gemm(64, 64, 64, FtPolicy::Offline, &InjectionPlan::none())
            .unwrap();
        match &small.nodes[0].op {
            NodeOp::Block { kernel: KernelOp::DetectRecompute { detect, plain, .. }, .. } => {
                assert!(detect.is_none());
                assert_eq!(plain.as_deref(), Some("gemm_small"));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn online_degrades_to_detect_recompute_without_fused_ft() {
        let (man, cfg) = planner_fixture();
        let caps = BackendInfo {
            name: "nofuse",
            description: "test",
            fused_ft: false,
            kernel_isa: "portable",
        };
        let plan = Planner::new(&man, &cfg)
            .for_backend(caps)
            .plan_gemm(128, 128, 128, FtPolicy::Online, &InjectionPlan::none())
            .unwrap();
        match &plan.nodes[0].op {
            NodeOp::Block { kernel: KernelOp::DetectRecompute { detect, .. }, .. } => {
                assert!(detect.is_some(), "medium bucket has a detect artifact");
            }
            other => panic!("expected detect+recompute, got {other:?}"),
        }
        // a fully capable backend keeps the fused kernel
        let plan = Planner::new(&man, &cfg)
            .for_backend(BackendInfo {
                name: "full",
                description: "test",
                fused_ft: true,
                kernel_isa: "portable",
            })
            .plan_gemm(128, 128, 128, FtPolicy::Online, &InjectionPlan::none())
            .unwrap();
        assert!(matches!(
            &plan.nodes[0].op,
            NodeOp::Block { kernel: KernelOp::Fused { .. }, .. }
        ));
    }

    #[test]
    fn unprotected_kernel_refuses_injection_at_plan_time() {
        let (man, cfg) = planner_fixture();
        let err = Planner::new(&man, &cfg)
            .plan_gemm(64, 64, 64, FtPolicy::None, &InjectionPlan::single(0, 0, 0, 9.0))
            .unwrap_err();
        assert!(err.to_string().contains("unprotected"));
    }

    #[test]
    fn ding_plan_chains_panels_through_cf() {
        let man = Manifest::builtin();
        let plan = plan_ding(&man, "medium", &InjectionPlan::single(3, 4, 1, 512.0)).unwrap();
        // medium: k=128, ks=64 -> encode + 2 panels
        assert_eq!(plan.nodes.len(), 3);
        assert_eq!(plan.roots(), 1);
        assert!(matches!(plan.nodes[0].op, NodeOp::DingEncode { .. }));
        match &plan.nodes[1].op {
            NodeOp::DingPanel { prev_node, inj, last, s0, .. } => {
                assert_eq!(*prev_node, None);
                assert_eq!(*s0, 0);
                assert!(inj.is_empty() && !last);
            }
            other => panic!("{other:?}"),
        }
        match &plan.nodes[2].op {
            NodeOp::DingPanel { prev_node, inj, last, s0, .. } => {
                assert_eq!(*prev_node, Some(1));
                assert_eq!(plan.nodes[2].deps, vec![0, 1]);
                assert_eq!(*s0, 64);
                assert_eq!(inj.len(), 1, "step indexes the panel");
                assert!(*last);
            }
            other => panic!("{other:?}"),
        }
        assert!(plan_ding(&man, "small", &InjectionPlan::none()).is_err());
    }

    #[test]
    fn localize_filters_and_translates() {
        let block = BlockPlan {
            row0: 10,
            col0: 20,
            k0: 0,
            m: 10,
            n: 10,
            k: 64,
            bucket: crate::codegen::select::BUCKETS[0],
        };
        let inj = InjectionPlan {
            injections: vec![
                Injection { row: 15, col: 25, step: 1, magnitude: 9.0 },
                Injection { row: 5, col: 25, step: 0, magnitude: 7.0 },
            ],
        };
        let local = localize_injections(&inj, &block);
        assert_eq!(local.len(), 1);
        assert_eq!(local.injections[0].row, 5);
        assert_eq!(local.injections[0].col, 5);
    }
}
