//! Request routing: map an arbitrary (m, n, k) GEMM onto the fixed-shape
//! artifact buckets.
//!
//! Three regimes:
//! * **exact** — the request matches a bucket exactly: execute directly.
//! * **padded** — the request fits inside a bucket: zero-pad operands,
//!   execute, slice the result (zero padding is exact for both GEMM and
//!   checksum algebra).
//! * **split** — the request exceeds every bucket: block-decompose over the
//!   largest bucket, execute one kernel per (i, j, s) block and accumulate
//!   partials host-side. This is the same outer-product decomposition the
//!   paper's threadblock grid performs, one level up.

use crate::codegen::select::{select_bucket, Bucket, BUCKETS};
use crate::codegen::ShapeClass;

/// Where one block of a (possibly split) GEMM lands.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockPlan {
    /// Row/col offset of this block in the full output.
    pub row0: usize,
    pub col0: usize,
    /// k offset in the full reduction.
    pub k0: usize,
    /// Actual (un-padded) extents of this block.
    pub m: usize,
    pub n: usize,
    pub k: usize,
    /// The bucket this block executes in.
    pub bucket: Bucket,
}

impl BlockPlan {
    pub fn is_padded(&self) -> bool {
        self.m != self.bucket.m || self.n != self.bucket.n || self.k != self.bucket.k
    }
}

/// A routed request: the list of kernel executions that compute it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RoutePlan {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub blocks: Vec<BlockPlan>,
    /// True when the request needed block decomposition.
    pub split: bool,
}

impl RoutePlan {
    /// Number of k-partials that accumulate into each output block.
    pub fn k_splits(&self) -> usize {
        if self.blocks.is_empty() {
            return 0;
        }
        let (r0, c0) = (self.blocks[0].row0, self.blocks[0].col0);
        self.blocks.iter().filter(|b| b.row0 == r0 && b.col0 == c0).count()
    }

    /// Total padded FLOPs the plan executes (for waste accounting).
    pub fn padded_flops(&self) -> f64 {
        self.blocks
            .iter()
            .map(|b| 2.0 * (b.bucket.m * b.bucket.n * b.bucket.k) as f64)
            .sum()
    }

    pub fn useful_flops(&self) -> f64 {
        2.0 * (self.m * self.n * self.k) as f64
    }
}

/// Build the execution plan for an (m, n, k) request.
pub fn route(m: usize, n: usize, k: usize) -> RoutePlan {
    assert!(m > 0 && n > 0 && k > 0, "degenerate GEMM shape");
    if let Some(bucket) = select_bucket(m, n, k) {
        return RoutePlan {
            m,
            n,
            k,
            blocks: vec![BlockPlan { row0: 0, col0: 0, k0: 0, m, n, k, bucket }],
            split: false,
        };
    }
    // Oversize: tile with the huge bucket. Remainder blocks still go
    // through the same bucket (padded) so every execution hits the same
    // warm executable.
    let huge = BUCKETS
        .iter()
        .find(|b| b.class == ShapeClass::Huge)
        .copied()
        .expect("huge bucket exists");
    let mut blocks = Vec::new();
    for row0 in (0..m).step_by(huge.m) {
        let bm = (m - row0).min(huge.m);
        for col0 in (0..n).step_by(huge.n) {
            let bn = (n - col0).min(huge.n);
            for k0 in (0..k).step_by(huge.k) {
                let bk = (k - k0).min(huge.k);
                blocks.push(BlockPlan {
                    row0,
                    col0,
                    k0,
                    m: bm,
                    n: bn,
                    k: bk,
                    bucket: huge,
                });
            }
        }
    }
    RoutePlan { m, n, k, blocks, split: true }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_fit_single_block_unpadded() {
        let plan = route(128, 128, 128);
        assert!(!plan.split);
        assert_eq!(plan.blocks.len(), 1);
        assert!(!plan.blocks[0].is_padded());
        assert_eq!(plan.blocks[0].bucket.class, ShapeClass::Medium);
    }

    #[test]
    fn small_request_padded_into_small_bucket() {
        let plan = route(30, 50, 40);
        assert_eq!(plan.blocks.len(), 1);
        assert!(plan.blocks[0].is_padded());
        assert_eq!(plan.blocks[0].bucket.class, ShapeClass::Small);
    }

    #[test]
    fn tall_request_routes_to_tall_bucket() {
        let plan = route(100, 500, 200);
        assert_eq!(plan.blocks[0].bucket.class, ShapeClass::Tall);
    }

    #[test]
    fn oversize_splits_cover_output_exactly() {
        let (m, n, k) = (1000, 700, 600);
        let plan = route(m, n, k);
        assert!(plan.split);
        // coverage check: every output element covered by exactly one
        // (row0, col0) block family; k fully covered within each family.
        let mut cover = vec![0u32; m * n];
        for b in &plan.blocks {
            if b.k0 == 0 {
                for i in b.row0..b.row0 + b.m {
                    for j in b.col0..b.col0 + b.n {
                        cover[i * n + j] += 1;
                    }
                }
            }
        }
        assert!(cover.iter().all(|&c| c == 1));
        let ksum: usize = plan
            .blocks
            .iter()
            .filter(|b| b.row0 == 0 && b.col0 == 0)
            .map(|b| b.k)
            .sum();
        assert_eq!(ksum, k);
        assert_eq!(plan.k_splits(), 2);
    }

    #[test]
    fn oversize_block_count_matches_grid() {
        let plan = route(1024, 1024, 1024);
        assert_eq!(plan.blocks.len(), 2 * 2 * 2);
        assert!(plan.blocks.iter().all(|b| !b.is_padded()));
    }

    #[test]
    fn waste_accounting() {
        let plan = route(64, 64, 64);
        assert_eq!(plan.padded_flops(), plan.useful_flops());
        let padded = route(40, 64, 64);
        assert!(padded.padded_flops() > padded.useful_flops());
    }

    #[test]
    #[should_panic]
    fn zero_dim_rejected() {
        route(0, 4, 4);
    }
}
