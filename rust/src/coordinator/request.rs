//! The request-centric serving surface: an owned, self-describing
//! [`GemmRequest`] submitted via [`Coordinator::submit`] for a [`Ticket`].
//!
//! A request carries everything the serving stack needs — operands, the
//! [`FtPolicy`], and per-request [`RequestOptions`] (FT granularity,
//! detection thresholds, host-verify mode, recompute budget, injection
//! plan, priority, deadline) — so callers can keep many requests with
//! *different* protection schemes in flight at once, the way FT-BLAS and
//! arithmetic-intensity-guided FT vary the scheme per routine/layer
//! rather than per process. The [`Ticket`] is the wait/poll/cancel handle;
//! its result is the existing [`GemmResult`] plus request-scoped
//! [`RequestMeta`].
//!
//! [`Coordinator::submit`]: super::Coordinator::submit

use std::collections::HashMap;
use std::fmt;
use std::str::FromStr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock, Weak};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::abft::checksum::Thresholds;
use crate::abft::injection::InjectionPlan;
use crate::abft::matrix::Matrix;
use crate::runtime::pack_cache::OperandId;

use super::{FtPolicy, GemmResult};

/// The shared FT-granularity enum (re-exported from [`crate::abft`]): the
/// same type the gpusim overhead model and the execution backends use, so
/// "which checksum placement" is spelled identically across the system.
pub use crate::abft::FtLevel;

/// When the coordinator re-derives the product checksums from the operands
/// on the host and checks the returned `C` against them (defense in depth;
/// `O(mk + kn)` extra host work per request).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum HostVerify {
    /// Never re-verify.
    #[default]
    Off,
    /// Re-verify only requests with **no injection plan**. An injected
    /// SEU that the kernel corrected leaves an `O(eps·magnitude)`
    /// residue, which can trip the thresholds on a result that is in
    /// fact good — so injected runs are deliberately not re-verified
    /// under this mode. Use [`HostVerify::Always`] to verify them anyway.
    CleanOnly,
    /// Re-verify every request, injected or not. Pair with thresholds
    /// loose enough to absorb the correction residue.
    Always,
}

impl HostVerify {
    pub fn as_str(&self) -> &'static str {
        match self {
            HostVerify::Off => "off",
            HostVerify::CleanOnly => "clean_only",
            HostVerify::Always => "always",
        }
    }
}

impl FromStr for HostVerify {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<HostVerify> {
        match s {
            "off" => Ok(HostVerify::Off),
            "clean_only" => Ok(HostVerify::CleanOnly),
            "always" => Ok(HostVerify::Always),
            other => Err(anyhow!("unknown host-verify mode {other:?} (off|clean_only|always)")),
        }
    }
}

/// Dispatch priority. Higher priorities dequeue first; within a priority,
/// earlier deadline first, then submission order (FIFO).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Priority {
    Low,
    #[default]
    Normal,
    High,
}

impl Priority {
    pub fn as_str(&self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }
}

impl FromStr for Priority {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Priority> {
        match s {
            "low" => Ok(Priority::Low),
            "normal" => Ok(Priority::Normal),
            "high" => Ok(Priority::High),
            other => Err(anyhow!("unknown priority {other:?} (low|normal|high)")),
        }
    }
}

/// Per-request knobs. `None` fields inherit the coordinator's
/// [`CoordinatorConfig`](super::CoordinatorConfig) defaults.
#[derive(Debug, Clone, Default)]
pub struct RequestOptions {
    /// Online-policy FT granularity override.
    pub ft_level: Option<FtLevel>,
    /// Detection-threshold override (host-side verification paths).
    pub thresholds: Option<Thresholds>,
    /// Host re-verification mode override.
    pub host_verify: Option<HostVerify>,
    /// Offline-policy recompute budget override.
    pub max_recomputes: Option<usize>,
    /// Dequeue priority.
    pub priority: Priority,
    /// Fail the request (status [`TicketStatus::Expired`]) if it is still
    /// queued this long after submission.
    pub deadline: Option<Duration>,
}

/// How a request is compiled into an execution plan.
#[derive(Debug, Clone)]
pub(crate) enum Route {
    /// The standard path: block decomposition + per-block kernel nodes.
    Blocks,
    /// The non-fused Ding'11 baseline for one fixed-shape bucket:
    /// encode node + chained per-panel step/verify nodes.
    Ding { bucket: String },
}

/// An owned, self-describing GEMM request: operands + policy + injection
/// plan + per-request options, built fluently and submitted with
/// [`Coordinator::submit`](super::Coordinator::submit).
///
/// ```
/// use ftgemm::prelude::*;
///
/// let engine = Engine::start(EngineConfig::default())?;
/// let coord = Coordinator::new(engine, CoordinatorConfig::default());
///
/// let a = Matrix::rand_uniform(64, 64, 1);
/// let b = Matrix::rand_uniform(64, 64, 2);
/// let want = a.matmul(&b);
///
/// let ticket = coord.submit(
///     GemmRequest::new(a, b)
///         .policy(FtPolicy::Online)
///         .priority(Priority::High)
///         .deadline(std::time::Duration::from_secs(30)),
/// )?;
/// let resp = ticket.wait()?;
/// assert!(resp.result.c.max_abs_diff(&want) < 1e-3);
/// assert_eq!(resp.meta.policy, FtPolicy::Online);
/// # Ok::<(), anyhow::Error>(())
/// ```
#[derive(Debug, Clone)]
pub struct GemmRequest {
    /// Operands are shared (`Arc`): cloning a request, parking it in the
    /// batcher, and fanning its blocks across the scheduler pool are all
    /// refcount bumps, never matrix copies.
    pub(crate) a: Arc<Matrix>,
    pub(crate) b: Arc<Matrix>,
    pub(crate) policy: FtPolicy,
    pub(crate) inj: InjectionPlan,
    pub(crate) route: Route,
    pub(crate) opts: RequestOptions,
    /// Pack-cache content addresses for the operands, when known.
    /// The gateway sets wire-level `Seed` ids (the request *is*
    /// content-addressed on the wire); `Coordinator::submit` derives
    /// ABA-safe `Ptr` ids for any still-unkeyed `Arc` operand when the
    /// engine's pack cache is on. `None` opts the operand out.
    pub(crate) key_a: Option<OperandId>,
    pub(crate) key_b: Option<OperandId>,
}

impl GemmRequest {
    /// `C = A·B` under [`FtPolicy::Online`] (the paper's default scheme);
    /// override with [`GemmRequest::policy`]. Takes owned `Matrix` or
    /// `Arc<Matrix>` operands — pass `Arc`s to share one operand across
    /// many requests without copies.
    pub fn new(a: impl Into<Arc<Matrix>>, b: impl Into<Arc<Matrix>>) -> GemmRequest {
        GemmRequest {
            a: a.into(),
            b: b.into(),
            policy: FtPolicy::Online,
            inj: InjectionPlan::none(),
            route: Route::Blocks,
            opts: RequestOptions::default(),
            key_a: None,
            key_b: None,
        }
    }

    /// A request for the non-fused Ding'11 baseline pipeline of `bucket`
    /// (operands must match the bucket's fixed shape).
    pub fn ding(
        a: impl Into<Arc<Matrix>>,
        b: impl Into<Arc<Matrix>>,
        bucket: &str,
    ) -> GemmRequest {
        GemmRequest { route: Route::Ding { bucket: bucket.to_string() }, ..GemmRequest::new(a, b) }
    }

    pub fn policy(mut self, policy: FtPolicy) -> GemmRequest {
        self.policy = policy;
        self
    }

    /// Attach an SEU injection plan (§5.3 protocol; global output
    /// coordinates).
    pub fn inject(mut self, inj: InjectionPlan) -> GemmRequest {
        self.inj = inj;
        self
    }

    /// Replace the whole option block at once.
    pub fn options(mut self, opts: RequestOptions) -> GemmRequest {
        self.opts = opts;
        self
    }

    pub fn ft_level(mut self, level: FtLevel) -> GemmRequest {
        self.opts.ft_level = Some(level);
        self
    }

    pub fn thresholds(mut self, th: Thresholds) -> GemmRequest {
        self.opts.thresholds = Some(th);
        self
    }

    pub fn host_verify(mut self, mode: HostVerify) -> GemmRequest {
        self.opts.host_verify = Some(mode);
        self
    }

    pub fn max_recomputes(mut self, n: usize) -> GemmRequest {
        self.opts.max_recomputes = Some(n);
        self
    }

    pub fn priority(mut self, p: Priority) -> GemmRequest {
        self.opts.priority = p;
        self
    }

    /// Expire the request if it is still queued `d` after submission.
    pub fn deadline(mut self, d: Duration) -> GemmRequest {
        self.opts.deadline = Some(d);
        self
    }

    /// Attach explicit pack-cache content addresses for the operands
    /// (the gateway uses the wire `(rows, cols, seed)` tuples). Operands
    /// left `None` get an ABA-safe pointer-identity id derived at
    /// submission when the engine's pack cache is on; pass `None, None`
    /// after the fact to keep that default.
    pub fn operand_ids(
        mut self,
        key_a: Option<OperandId>,
        key_b: Option<OperandId>,
    ) -> GemmRequest {
        self.key_a = key_a;
        self.key_b = key_b;
        self
    }

    /// Output shape `(m, n)` and reduction extent `k` of the request.
    pub fn shape(&self) -> (usize, usize, usize) {
        (self.a.rows(), self.b.cols(), self.a.cols())
    }

    pub fn get_policy(&self) -> FtPolicy {
        self.policy
    }

    pub fn get_options(&self) -> &RequestOptions {
        &self.opts
    }

    pub fn injections(&self) -> &InjectionPlan {
        &self.inj
    }
}

/// An ABA-safe pointer-identity [`OperandId`] for an `Arc`-shared
/// operand: the allocation address plus a generation stamp.
///
/// Address equality alone is not identity — an operand can be dropped
/// and its allocation reused by a *different* matrix at the same
/// address, which would silently alias the dead operand's pack-cache
/// entries. A process-wide registry of weak handles closes that hole:
/// if the address is registered and its weak still upgrades to **this**
/// allocation, the stored generation is reused (same operand, same id —
/// that's the whole point of the cache); otherwise the slot is
/// restamped from a monotonic counter, so a recycled address gets a
/// fresh id and can never hit stale entries. Dead slots are pruned
/// opportunistically once the registry grows past a small bound.
pub(crate) fn ptr_operand_id(m: &Arc<Matrix>) -> OperandId {
    static REG: OnceLock<Mutex<HashMap<usize, (Weak<Matrix>, u64)>>> = OnceLock::new();
    static GEN: AtomicU64 = AtomicU64::new(0);
    let addr = Arc::as_ptr(m) as usize;
    let mut reg = REG.get_or_init(|| Mutex::new(HashMap::new())).lock().unwrap();
    if reg.len() >= 1024 {
        reg.retain(|_, (w, _)| w.strong_count() > 0);
    }
    let generation = match reg.get(&addr) {
        Some((w, g)) if w.upgrade().is_some_and(|live| Arc::ptr_eq(&live, m)) => *g,
        _ => {
            let g = GEN.fetch_add(1, Ordering::Relaxed);
            reg.insert(addr, (Arc::downgrade(m), g));
            g
        }
    };
    OperandId::Ptr { addr, gen: generation }
}

/// Request-scoped metadata returned alongside the [`GemmResult`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RequestMeta {
    /// Coordinator-assigned request id (unique per coordinator).
    pub id: u64,
    pub policy: FtPolicy,
    pub priority: Priority,
    /// Time spent queued between submission and dispatch.
    pub queued: Duration,
    /// Global dispatch-order stamp: request X dequeued before request Y
    /// iff `X.dispatch_seq < Y.dispatch_seq` (the priority-ordering
    /// witness the tests read).
    pub dispatch_seq: u64,
    /// Engine pool (shard) the request executed on — the routed pool, or
    /// the thief's pool when the request was stolen.
    pub pool: usize,
}

/// A fulfilled request: the computation result plus its [`RequestMeta`].
#[derive(Debug, Clone)]
pub struct GemmResponse {
    pub result: GemmResult,
    pub meta: RequestMeta,
}

/// Observable lifecycle of a [`Ticket`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TicketStatus {
    /// Submitted, not yet dispatched.
    Queued,
    /// Dispatched; a plan is executing.
    Running,
    /// Finished successfully; `wait` returns `Ok`.
    Done,
    /// Finished with an error; `wait` returns `Err`.
    Failed,
    /// Canceled before dispatch.
    Canceled,
    /// Deadline passed while still queued.
    Expired,
}

struct Slot {
    status: TicketStatus,
    outcome: Option<Result<GemmResponse>>,
    /// Absolute queue deadline, stamped at enqueue. Lets the ticket side
    /// (`poll`/`wait`) expire itself even if no dispatcher ever dequeues
    /// the entry (e.g. priority starvation under a saturated pool).
    deadline: Option<Instant>,
}

struct TicketShared {
    id: u64,
    slot: Mutex<Slot>,
    cv: Condvar,
}

impl TicketShared {
    /// Queued past the deadline → settle as Expired. Safe to call from
    /// either side; the queue's dequeue-time check aborts the same way,
    /// and whichever fires first wins (the other is a no-op).
    fn expire_due(&self, slot: &mut Slot) {
        if slot.status != TicketStatus::Queued {
            return;
        }
        if let Some(d) = slot.deadline {
            if Instant::now() >= d {
                slot.status = TicketStatus::Expired;
                slot.outcome = Some(Err(anyhow!(
                    "request {}: deadline exceeded while queued",
                    self.id
                )));
                self.cv.notify_all();
            }
        }
    }
}

/// Wait/poll/cancel handle for a submitted [`GemmRequest`].
pub struct Ticket {
    shared: Arc<TicketShared>,
}

impl Ticket {
    /// Coordinator-assigned request id.
    pub fn id(&self) -> u64 {
        self.shared.id
    }

    /// Non-blocking status probe. A queued ticket whose deadline has
    /// passed reports (and settles as) [`TicketStatus::Expired`] here,
    /// without waiting for a dispatcher to reach it.
    pub fn poll(&self) -> TicketStatus {
        let mut slot = self.shared.slot.lock().unwrap();
        self.shared.expire_due(&mut slot);
        slot.status
    }

    /// Cancel the request if it has not been dispatched yet. Returns
    /// `true` iff **this call** canceled it (it was still queued); once a
    /// request is running it runs to completion and `cancel` returns
    /// `false`.
    pub fn cancel(&self) -> bool {
        let mut slot = self.shared.slot.lock().unwrap();
        if slot.status != TicketStatus::Queued {
            return false;
        }
        slot.status = TicketStatus::Canceled;
        slot.outcome =
            Some(Err(anyhow!("request {} canceled before dispatch", self.shared.id)));
        self.shared.cv.notify_all();
        true
    }

    /// Block until the request settles; consumes the ticket. A queued
    /// request past its deadline settles as Expired right here — waiting
    /// never outlives the deadline just because every dispatcher is busy.
    pub fn wait(self) -> Result<GemmResponse> {
        self.wait_outcome().1
    }

    /// [`Ticket::wait`], but paired with the terminal [`TicketStatus`] —
    /// for callers (the serving gateway) that must distinguish *why* a
    /// request failed (expired vs canceled vs failed) without string-
    /// matching the error.
    pub fn wait_outcome(self) -> (TicketStatus, Result<GemmResponse>) {
        let mut slot = self.shared.slot.lock().unwrap();
        loop {
            self.shared.expire_due(&mut slot);
            if let Some(outcome) = slot.outcome.take() {
                return (slot.status, outcome);
            }
            let queue_deadline =
                if slot.status == TicketStatus::Queued { slot.deadline } else { None };
            slot = match queue_deadline {
                None => self.shared.cv.wait(slot).unwrap(),
                Some(d) => {
                    let timeout = d.saturating_duration_since(Instant::now());
                    self.shared.cv.wait_timeout(slot, timeout).unwrap().0
                }
            };
        }
    }

    /// Like [`Ticket::wait`], but gives up (with an error) after `d`.
    /// Consumes the ticket either way — a timed-out request keeps running
    /// detached and its result is dropped on completion.
    pub fn wait_timeout(self, d: Duration) -> Result<GemmResponse> {
        let give_up = Instant::now() + d;
        let mut slot = self.shared.slot.lock().unwrap();
        loop {
            self.shared.expire_due(&mut slot);
            if let Some(outcome) = slot.outcome.take() {
                return outcome;
            }
            let now = Instant::now();
            if now >= give_up {
                return Err(anyhow!(
                    "request {}: no result within {d:?} (status {:?})",
                    self.shared.id,
                    slot.status
                ));
            }
            let mut until = give_up;
            if slot.status == TicketStatus::Queued {
                if let Some(dl) = slot.deadline {
                    until = until.min(dl);
                }
            }
            let (guard, _) = self
                .shared
                .cv
                .wait_timeout(slot, until.saturating_duration_since(now))
                .unwrap();
            slot = guard;
        }
    }
}

impl fmt::Debug for Ticket {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Ticket")
            .field("id", &self.shared.id)
            .field("status", &self.poll())
            .finish()
    }
}

/// Producer side of a [`Ticket`]: held by the submission queue (or the
/// batcher while a request waits for its round) and consumed exactly once
/// to settle the ticket.
pub(crate) struct Completion {
    shared: Arc<TicketShared>,
}

impl Completion {
    pub(crate) fn id(&self) -> u64 {
        self.shared.id
    }

    pub(crate) fn is_canceled(&self) -> bool {
        self.status() == TicketStatus::Canceled
    }

    /// Current status, applying deadline self-expiry first so queue-side
    /// bookkeeping (compaction, depth) never counts an expired corpse as
    /// live.
    pub(crate) fn status(&self) -> TicketStatus {
        let mut slot = self.shared.slot.lock().unwrap();
        self.shared.expire_due(&mut slot);
        slot.status
    }

    /// Record the absolute queue deadline so the ticket side can expire
    /// itself (called at enqueue). Wakes any waiter already blocked on
    /// the ticket: a batched request reaches the queue *after* its ticket
    /// was handed out, and a waiter sleeping without a deadline must
    /// recompute its sleep against the new one.
    pub(crate) fn set_deadline(&self, d: Instant) {
        let mut slot = self.shared.slot.lock().unwrap();
        slot.deadline = Some(d);
        self.shared.expire_due(&mut slot);
        self.shared.cv.notify_all();
    }

    /// Queued → Running. Returns `false` (and leaves the ticket alone) if
    /// the request was canceled in the meantime.
    pub(crate) fn start(&self) -> bool {
        let mut slot = self.shared.slot.lock().unwrap();
        if slot.status != TicketStatus::Queued {
            return false;
        }
        slot.status = TicketStatus::Running;
        true
    }

    /// Settle with an execution outcome (status Done / Failed).
    pub(crate) fn finish(self, meta: RequestMeta, result: Result<GemmResult>) {
        let mut slot = self.shared.slot.lock().unwrap();
        if slot.outcome.is_some() || slot.status == TicketStatus::Canceled {
            return;
        }
        match result {
            Ok(result) => {
                slot.status = TicketStatus::Done;
                slot.outcome = Some(Ok(GemmResponse { result, meta }));
            }
            Err(e) => {
                slot.status = TicketStatus::Failed;
                slot.outcome = Some(Err(e));
            }
        }
        self.shared.cv.notify_all();
    }

    /// Settle without having run: rejected, expired, or shut down.
    pub(crate) fn abort(self, status: TicketStatus, err: anyhow::Error) {
        let mut slot = self.shared.slot.lock().unwrap();
        if slot.outcome.is_some() || slot.status == TicketStatus::Canceled {
            return;
        }
        slot.status = status;
        slot.outcome = Some(Err(err));
        self.shared.cv.notify_all();
    }
}

impl Drop for Completion {
    /// Last line of defense: a completion dropped without settling (an
    /// executor panicked, or a holding queue was torn down abruptly)
    /// fails the ticket instead of leaving `wait` blocked forever.
    /// `finish`/`abort` set the outcome before this runs, so the normal
    /// paths are no-ops here.
    fn drop(&mut self) {
        if let Ok(mut slot) = self.shared.slot.lock() {
            if slot.outcome.is_none() && slot.status != TicketStatus::Canceled {
                slot.status = TicketStatus::Failed;
                slot.outcome = Some(Err(anyhow!(
                    "request {} abandoned without a result",
                    self.shared.id
                )));
                self.shared.cv.notify_all();
            }
        }
    }
}

/// New (ticket, completion) pair for request `id`.
pub(crate) fn ticket(id: u64) -> (Ticket, Completion) {
    let shared = Arc::new(TicketShared {
        id,
        slot: Mutex::new(Slot { status: TicketStatus::Queued, outcome: None, deadline: None }),
        cv: Condvar::new(),
    });
    (Ticket { shared: Arc::clone(&shared) }, Completion { shared })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ptr_operand_ids_are_stable_for_live_arcs_and_aba_safe() {
        let a = Arc::new(Matrix::rand_uniform(8, 8, 1));
        let id1 = ptr_operand_id(&a);
        let id2 = ptr_operand_id(&a);
        assert_eq!(id1, id2, "same live Arc must keep one id");
        let b = Arc::new(Matrix::rand_uniform(8, 8, 2));
        assert_ne!(ptr_operand_id(&b), id1, "distinct allocations get distinct ids");
        // ABA: drop `a`, then mint new matrices until the allocator
        // reuses its address (usually immediately). A recycled address
        // must NOT resurrect the dead operand's id.
        let addr_a = Arc::as_ptr(&a) as usize;
        drop(a);
        for seed in 3..64 {
            let c = Arc::new(Matrix::rand_uniform(8, 8, seed));
            let id3 = ptr_operand_id(&c);
            if Arc::as_ptr(&c) as usize == addr_a {
                assert_ne!(id3, id1, "recycled address aliased a dead operand's id");
                return;
            }
        }
        // Allocator never reused the address — nothing left to check.
    }

    #[test]
    fn operand_ids_builder_sets_wire_keys() {
        let a = Matrix::rand_uniform(8, 8, 1);
        let b = Matrix::rand_uniform(8, 8, 2);
        let id = OperandId::Seed { rows: 8, cols: 8, seed: 42 };
        let req = GemmRequest::new(a, b).operand_ids(Some(id), None);
        assert_eq!(req.key_a, Some(id));
        assert_eq!(req.key_b, None);
    }

    #[test]
    fn ft_level_parses_and_round_trips() {
        for level in FtLevel::ALL {
            assert_eq!(level.as_str().parse::<FtLevel>().unwrap(), level);
        }
        assert_eq!("tb".parse::<FtLevel>().unwrap(), FtLevel::Tb);
        assert_eq!("warp".parse::<FtLevel>().unwrap(), FtLevel::Warp);
        assert_eq!("thread".parse::<FtLevel>().unwrap(), FtLevel::Thread);
        assert!("threadblock".parse::<FtLevel>().is_err());
        assert!("".parse::<FtLevel>().is_err());
        assert_eq!(FtLevel::default(), FtLevel::Tb, "fallback level is tb");
    }

    #[test]
    fn priority_orders_low_normal_high() {
        assert!(Priority::Low < Priority::Normal);
        assert!(Priority::Normal < Priority::High);
        assert_eq!(Priority::default(), Priority::Normal);
        assert_eq!("high".parse::<Priority>().unwrap(), Priority::High);
        assert!("urgent".parse::<Priority>().is_err());
    }

    #[test]
    fn host_verify_parses() {
        assert_eq!("off".parse::<HostVerify>().unwrap(), HostVerify::Off);
        assert_eq!("clean_only".parse::<HostVerify>().unwrap(), HostVerify::CleanOnly);
        assert_eq!("always".parse::<HostVerify>().unwrap(), HostVerify::Always);
        assert!("sometimes".parse::<HostVerify>().is_err());
        assert_eq!(HostVerify::default(), HostVerify::Off);
    }

    #[test]
    fn builder_accumulates_options() {
        let a = Matrix::zeros(4, 6);
        let b = Matrix::zeros(6, 2);
        let req = GemmRequest::new(a, b)
            .policy(FtPolicy::Offline)
            .ft_level(FtLevel::Warp)
            .max_recomputes(3)
            .priority(Priority::High)
            .deadline(Duration::from_millis(250))
            .inject(InjectionPlan::single(1, 1, 0, 9.0));
        assert_eq!(req.shape(), (4, 2, 6));
        assert_eq!(req.get_policy(), FtPolicy::Offline);
        assert_eq!(req.get_options().ft_level, Some(FtLevel::Warp));
        assert_eq!(req.get_options().max_recomputes, Some(3));
        assert_eq!(req.get_options().priority, Priority::High);
        assert_eq!(req.get_options().deadline, Some(Duration::from_millis(250)));
        assert_eq!(req.injections().len(), 1);
    }

    #[test]
    fn cancel_flips_queued_tickets_only_once() {
        let (t, _c) = ticket(7);
        assert_eq!(t.id(), 7);
        assert_eq!(t.poll(), TicketStatus::Queued);
        assert!(t.cancel());
        assert!(!t.cancel(), "second cancel is a no-op");
        assert_eq!(t.poll(), TicketStatus::Canceled);
        let err = t.wait().unwrap_err();
        assert!(err.to_string().contains("canceled"), "{err}");
    }

    #[test]
    fn start_refuses_canceled_requests() {
        let (t, c) = ticket(1);
        assert!(t.cancel());
        assert!(!c.start());
    }

    #[test]
    fn finish_settles_and_wait_returns() {
        let (t, c) = ticket(3);
        assert!(c.start());
        let meta = RequestMeta {
            id: 3,
            policy: FtPolicy::None,
            priority: Priority::Normal,
            queued: Duration::ZERO,
            dispatch_seq: 0,
            pool: 0,
        };
        let result = GemmResult {
            c: Matrix::zeros(1, 1),
            errors_detected: 0,
            errors_corrected: 0,
            recomputes: 0,
            kernel_launches: 1,
            exec_time: Duration::from_millis(1),
            buckets: vec!["small"],
        };
        c.finish(meta, Ok(result));
        assert_eq!(t.poll(), TicketStatus::Done);
        let resp = t.wait().unwrap();
        assert_eq!(resp.meta.id, 3);
        assert_eq!(resp.result.kernel_launches, 1);
    }

    #[test]
    fn abort_reports_status_and_error() {
        let (t, c) = ticket(4);
        c.abort(TicketStatus::Expired, anyhow!("deadline exceeded"));
        assert_eq!(t.poll(), TicketStatus::Expired);
        assert!(t.wait().unwrap_err().to_string().contains("deadline"));
    }

    #[test]
    fn wait_outcome_pairs_status_with_result() {
        let (t, c) = ticket(11);
        c.abort(TicketStatus::Expired, anyhow!("deadline exceeded"));
        let (status, outcome) = t.wait_outcome();
        assert_eq!(status, TicketStatus::Expired);
        assert!(outcome.unwrap_err().to_string().contains("deadline"));

        let (t, c) = ticket(12);
        assert!(t.cancel());
        drop(c);
        let (status, outcome) = t.wait_outcome();
        assert_eq!(status, TicketStatus::Canceled);
        assert!(outcome.is_err());
    }

    #[test]
    fn dropped_completion_fails_the_ticket_instead_of_hanging() {
        let (t, c) = ticket(9);
        drop(c); // e.g. the executor panicked before settling
        assert_eq!(t.poll(), TicketStatus::Failed);
        let err = t.wait().unwrap_err();
        assert!(err.to_string().contains("abandoned"), "{err}");
        // a canceled ticket keeps its cancel outcome through the drop
        let (t, c) = ticket(10);
        assert!(t.cancel());
        drop(c);
        assert_eq!(t.poll(), TicketStatus::Canceled);
    }

    #[test]
    fn wait_timeout_gives_up_on_unsettled_tickets() {
        let (t, _c) = ticket(5);
        let err = t.wait_timeout(Duration::from_millis(10)).unwrap_err();
        assert!(err.to_string().contains("no result"), "{err}");
    }
}
