//! The non-fused ABFT baseline (Ding et al. 2011) driven end-to-end.
//!
//! This is the comparison system of Figs 12–16: an outer-product GEMM over
//! *encoded* operands where every stage is a separate kernel launch —
//! encode, K/K_s panel updates, and a verify/correct pass per panel. The
//! coordinator chains one PJRT execution per launch, so the baseline pays
//! the real cost of its extra memory passes (C^f re-read/re-written every
//! panel), exactly the deficit the paper's fused kernels eliminate.

use anyhow::{anyhow, bail, Result};

use crate::abft::injection::InjectionPlan;
use crate::abft::matrix::Matrix;
use crate::runtime::engine::{Engine, Tensor};
use crate::runtime::manifest::{Artifact, ArtifactKind};

/// Outcome of a non-fused FT-GEMM.
#[derive(Debug, Clone)]
pub struct DingResult {
    pub c: Matrix,
    pub errors_corrected: u64,
    pub kernel_launches: u64,
    pub panels: usize,
}

/// Driver for one bucket's Ding pipeline.
pub struct DingPipeline {
    engine: Engine,
    encode: Artifact,
    step: Artifact,
    verify: Artifact,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub ks: usize,
}

impl DingPipeline {
    /// Build the pipeline for a bucket that has ding artifacts
    /// ("medium" | "large" | "huge").
    pub fn new(engine: Engine, bucket: &str) -> Result<Self> {
        let m = engine.manifest();
        let encode = m
            .find(ArtifactKind::DingEncode, bucket, None)
            .cloned()
            .ok_or_else(|| anyhow!("no ding_encode for {bucket}"))?;
        let step = m
            .find(ArtifactKind::DingStep, bucket, None)
            .cloned()
            .ok_or_else(|| anyhow!("no ding_step for {bucket}"))?;
        let verify = m
            .find(ArtifactKind::DingVerify, bucket, None)
            .cloned()
            .ok_or_else(|| anyhow!("no ding_verify for {bucket}"))?;
        let (mm, nn, kk, ks) = (encode.m, encode.n, encode.k, step.ks);
        Ok(DingPipeline { engine, encode, step, verify, m: mm, n: nn, k: kk, ks })
    }

    pub fn panels(&self) -> usize {
        self.k / self.ks
    }

    /// Run C = A·B with optional per-panel SEU injection.
    ///
    /// `inj.step` indexes the *panel* here (Ding's K_s protocol); the
    /// offset is applied host-side to C^f between the panel update and its
    /// verify launch — the fault window of the original scheme.
    pub fn gemm_with_faults(&self, a: &Matrix, b: &Matrix, inj: &InjectionPlan) -> Result<DingResult> {
        if a.rows() != self.m || a.cols() != self.k || b.rows() != self.k || b.cols() != self.n {
            bail!(
                "ding pipeline is fixed-shape {}x{}x{}; got {}x{} @ {}x{}",
                self.m,
                self.n,
                self.k,
                a.rows(),
                a.cols(),
                b.rows(),
                b.cols()
            );
        }
        let mut launches = 0u64;

        // 1. encode: (A, B) -> (A^c, B^r)
        let enc = self.engine.execute(
            &self.encode.name,
            vec![
                Tensor::new(vec![self.m, self.k], a.data().to_vec()),
                Tensor::new(vec![self.k, self.n], b.data().to_vec()),
            ],
        )?;
        launches += 1;
        let ac = &enc.outputs[self.encode.output_index("ac").unwrap()];
        let br = &enc.outputs[self.encode.output_index("br").unwrap()];
        let ac = Matrix::from_vec(self.m + 1, self.k, ac.data.clone());
        let br = Matrix::from_vec(self.k, self.n + 1, br.data.clone());

        // 2. panel loop: step -> (inject) -> verify+correct
        let mut cf = Matrix::zeros(self.m + 1, self.n + 1);
        let mut corrected = 0u64;
        for (panel, s) in (0..self.k).step_by(self.ks).enumerate() {
            let ac_panel = panel_cols(&ac, s, self.ks);
            let br_panel = panel_rows(&br, s, self.ks);
            let out = self.engine.execute(
                &self.step.name,
                vec![
                    Tensor::new(vec![self.m + 1, self.n + 1], cf.into_data()),
                    Tensor::new(vec![self.m + 1, self.ks], ac_panel.into_data()),
                    Tensor::new(vec![self.ks, self.n + 1], br_panel.into_data()),
                ],
            )?;
            launches += 1;
            cf = Matrix::from_vec(
                self.m + 1,
                self.n + 1,
                out.outputs[self.step.output_index("cf").unwrap()].data.clone(),
            );

            // host-side SEU injection into this panel's accumulation window
            for e in &inj.injections {
                if e.step == panel {
                    cf.add_at(e.row, e.col, e.magnitude);
                }
            }

            let ver = self.engine.execute(
                &self.verify.name,
                vec![Tensor::new(vec![self.m + 1, self.n + 1], cf.into_data())],
            )?;
            launches += 1;
            cf = Matrix::from_vec(
                self.m + 1,
                self.n + 1,
                ver.outputs[self.verify.output_index("cf").unwrap()].data.clone(),
            );
            corrected += ver.outputs[self.verify.output_index("errcount").unwrap()]
                .scalar_sum()
                .round() as u64;
        }

        Ok(DingResult {
            c: cf.slice_to(self.m, self.n),
            errors_corrected: corrected,
            kernel_launches: launches,
            panels: self.panels(),
        })
    }

    pub fn gemm(&self, a: &Matrix, b: &Matrix) -> Result<DingResult> {
        self.gemm_with_faults(a, b, &InjectionPlan::none())
    }
}

fn panel_cols(m: &Matrix, col0: usize, cols: usize) -> Matrix {
    Matrix::from_fn(m.rows(), cols, |i, j| m.at(i, col0 + j))
}

fn panel_rows(m: &Matrix, row0: usize, rows: usize) -> Matrix {
    Matrix::from_fn(rows, m.cols(), |i, j| m.at(row0 + i, j))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn panel_extraction() {
        let m = Matrix::from_fn(3, 6, |i, j| (i * 6 + j) as f32);
        let p = panel_cols(&m, 2, 2);
        assert_eq!(p.rows(), 3);
        assert_eq!(p.at(0, 0), 2.0);
        assert_eq!(p.at(2, 1), 15.0);
        let q = panel_rows(&m, 1, 2);
        assert_eq!(q.at(0, 0), 6.0);
        assert_eq!(q.rows(), 2);
    }
}
