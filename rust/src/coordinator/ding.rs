//! The non-fused ABFT baseline (Ding et al. 2011) driven end-to-end.
//!
//! This is the comparison system of Figs 12–16: an outer-product GEMM over
//! *encoded* operands where every stage is a separate kernel launch —
//! encode, K/K_s panel updates, and a verify/correct pass per panel. The
//! pipeline is a thin client of the **same submission API** as the fused
//! serving path: each run is a [`GemmRequest::ding`] submitted through
//! [`Coordinator::submit`], planned as one encode node plus a chain of
//! per-panel nodes threading C^f, and dispatched from the same
//! priority/deadline queue as every other request — so the baseline pays
//! the real cost of its extra memory passes (C^f re-read / re-written
//! every panel), exactly the deficit the paper's fused kernels eliminate.

use anyhow::{bail, Result};

use crate::abft::injection::InjectionPlan;
use crate::abft::matrix::Matrix;
use crate::runtime::engine::Engine;

use super::plan::{plan_ding, NodeOp};
use super::request::{GemmRequest, Ticket};
use super::Coordinator;

/// Outcome of a non-fused FT-GEMM.
#[derive(Debug, Clone)]
pub struct DingResult {
    pub c: Matrix,
    pub errors_corrected: u64,
    pub kernel_launches: u64,
    pub panels: usize,
}

/// Driver for one bucket's Ding pipeline — a shape-checked front end over
/// [`Coordinator::submit`].
pub struct DingPipeline {
    coord: Coordinator,
    bucket: String,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub ks: usize,
}

impl DingPipeline {
    /// Build the pipeline for a bucket that has ding artifacts
    /// ("medium" | "large" | "huge").
    pub fn new(coord: Coordinator, bucket: &str) -> Result<Self> {
        // Compile a fault-free plan up front: it both validates the
        // artifact set and is the single source of the pipeline geometry.
        let plan = plan_ding(coord.engine().manifest(), bucket, &InjectionPlan::none())?;
        let (m, n, k) = (plan.m, plan.n, plan.k);
        let ks = plan
            .nodes
            .iter()
            .find_map(|node| match &node.op {
                NodeOp::DingPanel { ks, .. } => Some(*ks),
                _ => None,
            })
            .unwrap_or(k);
        Ok(DingPipeline { coord, bucket: bucket.to_string(), m, n, k, ks })
    }

    pub fn panels(&self) -> usize {
        self.k / self.ks
    }

    pub fn engine(&self) -> &Engine {
        self.coord.engine()
    }

    pub fn coordinator(&self) -> &Coordinator {
        &self.coord
    }

    /// Submit one Ding-baseline run; returns the coordinator's [`Ticket`]
    /// immediately (wait/poll/cancel as usual).
    ///
    /// `inj.step` indexes the *panel* here (Ding's K_s protocol); the
    /// offset is applied host-side to C^f between the panel update and its
    /// verify launch — the fault window of the original scheme.
    pub fn submit(&self, a: Matrix, b: Matrix, inj: InjectionPlan) -> Result<Ticket> {
        if a.rows() != self.m || a.cols() != self.k || b.rows() != self.k || b.cols() != self.n {
            bail!(
                "ding pipeline is fixed-shape {}x{}x{}; got {}x{} @ {}x{}",
                self.m,
                self.n,
                self.k,
                a.rows(),
                a.cols(),
                b.rows(),
                b.cols()
            );
        }
        self.coord.submit(GemmRequest::ding(a, b, &self.bucket).inject(inj))
    }

    /// Run C = A·B with optional per-panel SEU injection; blocking
    /// wrapper over [`DingPipeline::submit`].
    pub fn gemm_with_faults(
        &self,
        a: &Matrix,
        b: &Matrix,
        inj: &InjectionPlan,
    ) -> Result<DingResult> {
        let resp = self.submit(a.clone(), b.clone(), inj.clone())?.wait()?;
        Ok(DingResult {
            c: resp.result.c,
            errors_corrected: resp.result.errors_corrected,
            kernel_launches: resp.result.kernel_launches,
            panels: self.panels(),
        })
    }

    pub fn gemm(&self, a: &Matrix, b: &Matrix) -> Result<DingResult> {
        self.gemm_with_faults(a, b, &InjectionPlan::none())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;
    use crate::runtime::engine::EngineConfig;

    fn coordinator() -> Coordinator {
        let engine = Engine::start(EngineConfig::default()).unwrap();
        Coordinator::new(engine, CoordinatorConfig::default())
    }

    #[test]
    fn pipeline_dims_come_from_the_manifest() {
        let pipe = DingPipeline::new(coordinator(), "medium").unwrap();
        assert_eq!((pipe.m, pipe.n, pipe.k, pipe.ks), (128, 128, 128, 64));
        assert_eq!(pipe.panels(), 2);
    }

    #[test]
    fn missing_bucket_is_rejected() {
        assert!(DingPipeline::new(coordinator(), "small").is_err());
    }
}
