//! The non-fused ABFT baseline (Ding et al. 2011) driven end-to-end.
//!
//! This is the comparison system of Figs 12–16: an outer-product GEMM over
//! *encoded* operands where every stage is a separate kernel launch —
//! encode, K/K_s panel updates, and a verify/correct pass per panel. The
//! pipeline is a thin client of the same [`plan`](super::plan) /
//! [`scheduler`](super::scheduler) types as the fused serving path: one
//! encode node plus a chain of per-panel nodes threading C^f, so the
//! baseline pays the real cost of its extra memory passes (C^f re-read /
//! re-written every panel), exactly the deficit the paper's fused kernels
//! eliminate.

use anyhow::{bail, Result};

use crate::abft::injection::InjectionPlan;
use crate::abft::matrix::Matrix;
use crate::runtime::engine::Engine;

use super::plan::{plan_ding, NodeOp};
use super::scheduler::{Scheduler, SchedulerConfig};

/// Outcome of a non-fused FT-GEMM.
#[derive(Debug, Clone)]
pub struct DingResult {
    pub c: Matrix,
    pub errors_corrected: u64,
    pub kernel_launches: u64,
    pub panels: usize,
}

/// Driver for one bucket's Ding pipeline.
pub struct DingPipeline {
    engine: Engine,
    scheduler: Scheduler,
    bucket: String,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub ks: usize,
}

impl DingPipeline {
    /// Build the pipeline for a bucket that has ding artifacts
    /// ("medium" | "large" | "huge").
    pub fn new(engine: Engine, bucket: &str) -> Result<Self> {
        // Compile a fault-free plan up front: it both validates the
        // artifact set and is the single source of the pipeline geometry.
        let plan = plan_ding(engine.manifest(), bucket, &InjectionPlan::none())?;
        let (m, n, k) = (plan.m, plan.n, plan.k);
        let ks = plan
            .nodes
            .iter()
            .find_map(|node| match &node.op {
                NodeOp::DingPanel { ks, .. } => Some(*ks),
                _ => None,
            })
            .unwrap_or(k);
        let scheduler = Scheduler::new(engine.clone(), SchedulerConfig::default());
        Ok(DingPipeline { engine, scheduler, bucket: bucket.to_string(), m, n, k, ks })
    }

    pub fn panels(&self) -> usize {
        self.k / self.ks
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Run C = A·B with optional per-panel SEU injection.
    ///
    /// `inj.step` indexes the *panel* here (Ding's K_s protocol); the
    /// offset is applied host-side to C^f between the panel update and its
    /// verify launch — the fault window of the original scheme.
    pub fn gemm_with_faults(
        &self,
        a: &Matrix,
        b: &Matrix,
        inj: &InjectionPlan,
    ) -> Result<DingResult> {
        if a.rows() != self.m || a.cols() != self.k || b.rows() != self.k || b.cols() != self.n {
            bail!(
                "ding pipeline is fixed-shape {}x{}x{}; got {}x{} @ {}x{}",
                self.m,
                self.n,
                self.k,
                a.rows(),
                a.cols(),
                b.rows(),
                b.cols()
            );
        }
        let plan = plan_ding(self.engine.manifest(), &self.bucket, inj)?;
        let out = self.scheduler.run(&plan, a, b)?;
        Ok(DingResult {
            c: out.c,
            errors_corrected: out.corrected,
            kernel_launches: out.launches,
            panels: self.panels(),
        })
    }

    pub fn gemm(&self, a: &Matrix, b: &Matrix) -> Result<DingResult> {
        self.gemm_with_faults(a, b, &InjectionPlan::none())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::engine::EngineConfig;

    #[test]
    fn pipeline_dims_come_from_the_manifest() {
        let engine = Engine::start(EngineConfig::default()).unwrap();
        let pipe = DingPipeline::new(engine, "medium").unwrap();
        assert_eq!((pipe.m, pipe.n, pipe.k, pipe.ks), (128, 128, 128, 64));
        assert_eq!(pipe.panels(), 2);
    }

    #[test]
    fn missing_bucket_is_rejected() {
        let engine = Engine::start(EngineConfig::default()).unwrap();
        assert!(DingPipeline::new(engine, "small").is_err());
    }
}
