//! Plan execution: dispatch independent [`ExecutionPlan`] nodes
//! concurrently over the engine worker pool and fold partials into the
//! output as they complete.
//!
//! The scheduler is deliberately dumb: all routing/artifact/injection
//! decisions were made at plan time ([`plan`](super::plan)), so running a
//! node is mechanical — extract operand blocks, launch kernels, hand the
//! partial back. Node jobs run on a bounded [`ThreadPool`] (sized to the
//! engine worker count by default) and block inside `Engine::execute`;
//! with `workers >= 2` the engine overlaps them, which is where the
//! split-GEMM speedup comes from (BENCH_pipeline.json). Completions stream
//! back over a channel; the caller's thread accumulates each block partial
//! the moment it lands (the k-partial sum order is completion order —
//! float-associativity drift is bounded by the usual GEMM tolerance).
//!
//! `run` is `&self` and re-entrant: the coordinator's submission
//! dispatchers call it concurrently for distinct requests, so one shared
//! pool interleaves the nodes of many in-flight plans (each run keeps its
//! own completion channel and bookkeeping).
//!
//! Failure model: the first node error wins; remaining in-flight nodes are
//! drained (never detached) before the error returns, so a failed request
//! cannot leak work into the next one.

use std::collections::HashMap;
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Result};

use crate::abft::checksum::{self, ChecksumPair, Thresholds};
use crate::abft::injection::InjectionPlan;
use crate::abft::matrix::Matrix;
use crate::runtime::engine::{Engine, ExecOutput, Tensor};
use crate::runtime::pack_cache::{OperandId, OperandKey};
use crate::util::pool::ThreadPool;

use super::plan::{ExecutionPlan, KernelOp, NodeOp, PlanNode};
use super::router::BlockPlan;

/// Scheduler configuration.
#[derive(Debug, Clone, Copy, Default)]
pub struct SchedulerConfig {
    /// Concurrent node-dispatch threads; 0 = match the engine worker count.
    pub threads: usize,
}

/// Aggregate outcome of one plan run.
#[derive(Debug, Clone)]
pub struct RunOutcome {
    pub c: Matrix,
    pub detected: u64,
    pub corrected: u64,
    pub recomputes: u64,
    pub launches: u64,
}

/// Executes [`ExecutionPlan`]s against one engine. Owns a bounded thread
/// pool; shared across requests (wrap in `Arc` to clone).
pub struct Scheduler {
    engine: Engine,
    pool: ThreadPool,
    threads: usize,
}

impl Scheduler {
    pub fn new(engine: Engine, config: SchedulerConfig) -> Scheduler {
        let threads = match config.threads {
            0 => engine.worker_count(),
            t => t,
        }
        .max(1);
        Scheduler { pool: ThreadPool::new(threads), engine, threads }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Run a plan against borrowed operands `a`, `b`; blocks until every
    /// node is accounted for. Multi-node plans copy the operands once
    /// into shared ownership — callers that already hold `Arc`s (the
    /// submission dispatchers) should use [`Scheduler::run_shared`].
    pub fn run(&self, plan: &ExecutionPlan, a: &Matrix, b: &Matrix) -> Result<RunOutcome> {
        if plan.nodes.is_empty() {
            bail!("empty execution plan");
        }
        if is_single_node(plan) {
            return self.run_single(plan, a, b, None, (None, None));
        }
        self.run_pooled(plan, Arc::new(a.clone()), Arc::new(b.clone()), (None, None))
    }

    /// Like [`Scheduler::run`] but with shared operands: the multi-node
    /// path clones refcounts, never matrices.
    pub fn run_shared(
        &self,
        plan: &ExecutionPlan,
        a: Arc<Matrix>,
        b: Arc<Matrix>,
    ) -> Result<RunOutcome> {
        self.run_shared_on(plan, a, b, None)
    }

    /// [`Scheduler::run_shared`] with an engine-pool hint. Single-node
    /// plans execute pinned to `pool` (keeping a shape class's executable
    /// warm on its affinity shard); multi-node plans ignore the hint —
    /// their blocks deliberately span every pool through global
    /// warm-affine dispatch, and partial accumulation still lands exactly
    /// once in this run's private output.
    pub fn run_shared_on(
        &self,
        plan: &ExecutionPlan,
        a: Arc<Matrix>,
        b: Arc<Matrix>,
        pool: Option<usize>,
    ) -> Result<RunOutcome> {
        self.run_keyed_on(plan, a, b, pool, (None, None))
    }

    /// [`Scheduler::run_shared_on`] with pack-cache content addresses
    /// for the operands: every block node derives its window key from
    /// the operand id, so the backend can share packed panels + fused
    /// checksums across requests. `(None, None)` keys run identically
    /// to [`Scheduler::run_shared_on`].
    pub fn run_keyed_on(
        &self,
        plan: &ExecutionPlan,
        a: Arc<Matrix>,
        b: Arc<Matrix>,
        pool: Option<usize>,
        keys: (Option<OperandId>, Option<OperandId>),
    ) -> Result<RunOutcome> {
        if plan.nodes.is_empty() {
            bail!("empty execution plan");
        }
        if is_single_node(plan) {
            return self.run_single(plan, &a, &b, pool, keys);
        }
        self.run_pooled(plan, a, b, keys)
    }

    /// Single-node fast path: no concurrency to buy, so skip the pool and
    /// any owned operand copies and run on the caller's thread.
    fn run_single(
        &self,
        plan: &ExecutionPlan,
        a: &Matrix,
        b: &Matrix,
        pool: Option<usize>,
        keys: (Option<OperandId>, Option<OperandId>),
    ) -> Result<RunOutcome> {
        let values = Mutex::new(HashMap::new());
        let ctx = Ctx {
            engine: &self.engine,
            pool,
            a,
            b,
            key_a: keys.0,
            key_b: keys.1,
            thresholds: plan.thresholds,
            values: &values,
        };
        let done = exec_node(&ctx, &plan.nodes[0])?;
        let mut c = Matrix::zeros(plan.m, plan.n);
        if let Some((partial, row0, col0)) = done.partial {
            accumulate(&mut c, &partial, row0, col0);
        }
        Ok(RunOutcome {
            c,
            detected: done.detected,
            corrected: done.corrected,
            recomputes: done.recomputes,
            launches: done.launches,
        })
    }

    /// Multi-node path: fan the DAG out over the bounded pool.
    fn run_pooled(
        &self,
        plan: &ExecutionPlan,
        a: Arc<Matrix>,
        b: Arc<Matrix>,
        keys: (Option<OperandId>, Option<OperandId>),
    ) -> Result<RunOutcome> {
        let total = plan.nodes.len();
        let ctx = Arc::new(OwnedCtx {
            engine: self.engine.clone(),
            a,
            b,
            key_a: keys.0,
            key_b: keys.1,
            thresholds: plan.thresholds,
            values: Mutex::new(HashMap::new()),
        });
        let (tx, rx) = channel::<(usize, Result<NodeDone>)>();

        // Dependency bookkeeping.
        let mut deps_left: Vec<usize> = plan.nodes.iter().map(|n| n.deps.len()).collect();
        let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); total];
        for node in &plan.nodes {
            for &d in &node.deps {
                if d >= total {
                    bail!("plan node {} depends on unknown node {d}", node.id);
                }
                dependents[d].push(node.id);
            }
        }

        let dispatch = |node: &PlanNode| {
            let ctx = Arc::clone(&ctx);
            let node = node.clone();
            let tx = tx.clone();
            self.pool.execute(move || {
                // A panicking node must still produce a completion, or the
                // recv loop below would wait forever.
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    exec_node(&ctx.view(), &node)
                }))
                .unwrap_or_else(|_| Err(anyhow!("plan node {} panicked", node.id)));
                let _ = tx.send((node.id, result));
            });
        };

        let mut outstanding = 0usize;
        let mut finished = 0usize;
        for node in plan.nodes.iter().filter(|n| n.deps.is_empty()) {
            dispatch(node);
            outstanding += 1;
        }

        let mut c = Matrix::zeros(plan.m, plan.n);
        let mut out = RunOutcome {
            c: Matrix::zeros(0, 0),
            detected: 0,
            corrected: 0,
            recomputes: 0,
            launches: 0,
        };
        let mut first_err: Option<anyhow::Error> = None;

        while outstanding > 0 {
            let (id, result) = rx
                .recv()
                .map_err(|_| anyhow!("scheduler pool dropped a node completion"))?;
            outstanding -= 1;
            finished += 1;
            match result {
                Err(e) if first_err.is_none() => first_err = Some(e),
                Err(_) => {}
                Ok(done) => {
                    out.detected += done.detected;
                    out.corrected += done.corrected;
                    out.recomputes += done.recomputes;
                    out.launches += done.launches;
                    if let Some((partial, row0, col0)) = done.partial {
                        accumulate(&mut c, &partial, row0, col0);
                    }
                    if let Some(value) = done.value {
                        ctx.values.lock().unwrap().insert(id, value);
                    }
                    if first_err.is_none() {
                        for &dep in &dependents[id] {
                            deps_left[dep] -= 1;
                            if deps_left[dep] == 0 {
                                dispatch(&plan.nodes[dep]);
                                outstanding += 1;
                            }
                        }
                    }
                }
            }
        }

        if let Some(e) = first_err {
            return Err(e);
        }
        if finished != total {
            bail!("execution plan deadlocked: {finished}/{total} nodes ran (cyclic deps?)");
        }
        out.c = c;
        Ok(out)
    }
}

fn is_single_node(plan: &ExecutionPlan) -> bool {
    plan.nodes.len() == 1 && plan.nodes[0].deps.is_empty()
}

/// Owned execution context shared by pooled node jobs.
struct OwnedCtx {
    engine: Engine,
    a: Arc<Matrix>,
    b: Arc<Matrix>,
    key_a: Option<OperandId>,
    key_b: Option<OperandId>,
    thresholds: Thresholds,
    values: Mutex<HashMap<usize, NodeValue>>,
}

impl OwnedCtx {
    fn view(&self) -> Ctx<'_> {
        Ctx {
            engine: &self.engine,
            // pooled (multi-node) runs span every engine shard on purpose
            pool: None,
            a: &self.a,
            b: &self.b,
            key_a: self.key_a,
            key_b: self.key_b,
            thresholds: self.thresholds,
            values: &self.values,
        }
    }
}

/// Borrowed view the node executors work against — also constructible
/// directly from caller-borrowed operands on the single-node fast path
/// (no operand copies).
struct Ctx<'a> {
    engine: &'a Engine,
    /// Engine-pool pin for kernel launches (`None` = global warm-affine).
    pool: Option<usize>,
    a: &'a Matrix,
    b: &'a Matrix,
    /// Pack-cache content addresses of `a`/`b` (`None` = unkeyed; the
    /// backend then packs per request).
    key_a: Option<OperandId>,
    key_b: Option<OperandId>,
    thresholds: Thresholds,
    /// Inter-node values (the Ding C^f chain and encode outputs).
    values: &'a Mutex<HashMap<usize, NodeValue>>,
}

enum NodeValue {
    Encoded { ac: Arc<Matrix>, br: Arc<Matrix> },
    Cf(Matrix),
}

struct NodeDone {
    /// Partial result + its (row0, col0) accumulation target.
    partial: Option<(Matrix, usize, usize)>,
    /// Value consumed by dependent nodes.
    value: Option<NodeValue>,
    detected: u64,
    corrected: u64,
    recomputes: u64,
    launches: u64,
}

impl NodeDone {
    fn new() -> NodeDone {
        NodeDone {
            partial: None,
            value: None,
            detected: 0,
            corrected: 0,
            recomputes: 0,
            launches: 0,
        }
    }
}

fn exec_node(ctx: &Ctx<'_>, node: &PlanNode) -> Result<NodeDone> {
    match &node.op {
        NodeOp::Block { block, kernel, inj } => exec_block(ctx, block, kernel, inj),
        NodeOp::DingEncode { artifact } => exec_ding_encode(ctx, artifact),
        NodeOp::DingPanel {
            step_artifact,
            verify_artifact,
            encode_node,
            prev_node,
            s0,
            ks,
            inj,
            last,
        } => exec_ding_panel(
            ctx,
            step_artifact,
            verify_artifact,
            *encode_node,
            *prev_node,
            *s0,
            *ks,
            inj,
            *last,
        ),
    }
}

// ---------------------------------------------------------------------
// Block nodes (the Coordinator::gemm path)
// ---------------------------------------------------------------------

fn exec_block(
    ctx: &Ctx<'_>,
    block: &BlockPlan,
    kernel: &KernelOp,
    inj: &InjectionPlan,
) -> Result<NodeDone> {
    let bk = &block.bucket;
    // Extract + zero-pad operand blocks in one pass (one allocation and
    // one row-wise copy each — §Perf).
    let a_blk = extract_padded(ctx.a, block.row0, block.k0, block.m, block.k, bk.m, bk.k);
    let b_blk = extract_padded(ctx.b, block.k0, block.col0, block.k, block.n, bk.k, bk.n);
    // Content addresses of the two windows just extracted: operand id +
    // window origin/extent + padded (bucket) dims — everything that
    // determines the padded block's bytes, so equal keys are guaranteed
    // bitwise-equal operands for the backend's pack cache.
    let ka = ctx.key_a.map(|id| OperandKey {
        id,
        row0: block.row0,
        col0: block.k0,
        rows: block.m,
        cols: block.k,
        pad_rows: bk.m,
        pad_cols: bk.k,
    });
    let kb = ctx.key_b.map(|id| OperandKey {
        id,
        row0: block.k0,
        col0: block.col0,
        rows: block.k,
        cols: block.n,
        pad_rows: bk.k,
        pad_cols: bk.n,
    });
    let mut done = NodeDone::new();

    let c_full = match kernel {
        KernelOp::Plain { artifact } => {
            done.launches = 1;
            exec_gemm(ctx, artifact, a_blk, b_blk, ka, kb)?
        }
        KernelOp::Fused { artifact, max_inj } => {
            let (c_full, errs) = exec_ft(ctx, artifact, *max_inj, a_blk, b_blk, ka, kb, inj)?;
            done.detected = errs;
            done.corrected = errs;
            done.launches = 1;
            c_full
        }
        KernelOp::DetectRecompute { detect, plain, max_recomputes } => {
            let mut attempt = 0usize;
            loop {
                // Injection only on the first attempt: the recompute runs
                // on presumed-healthy hardware (recompute-time faults are
                // treated analytically — gpusim::analytic).
                let this_inj = if attempt == 0 { inj.clone() } else { InjectionPlan::none() };
                done.launches += 1;
                // Operands are reused across recompute attempts, so this
                // path clones (the retry loop is cold).
                let (c_full, errs) = match detect {
                    Some((artifact, max_inj)) => exec_ft(
                        ctx,
                        artifact,
                        *max_inj,
                        a_blk.clone(),
                        b_blk.clone(),
                        ka,
                        kb,
                        &this_inj,
                    )?,
                    None => {
                        let artifact = plain
                            .as_deref()
                            .ok_or_else(|| anyhow!("offline plan missing both kernels"))?;
                        let mut c_full =
                            exec_gemm(ctx, artifact, a_blk.clone(), b_blk.clone(), ka, kb)?;
                        this_inj.apply_to(&mut c_full);
                        let pair = ChecksumPair::of_product(&a_blk, &b_blk);
                        let errs = match checksum::verify(&c_full, &pair, ctx.thresholds) {
                            checksum::Detection::Clean => 0,
                            _ => 1,
                        };
                        (c_full, errs)
                    }
                };
                done.detected += errs;
                if errs == 0 {
                    done.recomputes = attempt as u64;
                    break c_full;
                }
                attempt += 1;
                if attempt > *max_recomputes {
                    bail!("offline ABFT: fault persisted after {max_recomputes} recomputes");
                }
            }
        }
    };

    done.partial = Some((c_full.slice_to(block.m, block.n), block.row0, block.col0));
    Ok(done)
}

fn exec_gemm(
    ctx: &Ctx<'_>,
    artifact: &str,
    a: Matrix,
    b: Matrix,
    ka: Option<OperandKey>,
    kb: Option<OperandKey>,
) -> Result<Matrix> {
    let (ar, ac, br, bc) = (a.rows(), a.cols(), b.rows(), b.cols());
    let out = ctx.engine.execute_on(
        ctx.pool,
        artifact,
        vec![
            // moves, not copies: the padded operand blocks are owned
            Tensor::new(vec![ar, ac], a.into_data()).with_key(ka),
            Tensor::new(vec![br, bc], b.into_data()).with_key(kb),
        ],
    )?;
    take_matrix(ctx, artifact, out, "c")
}

/// Execute an FT artifact (fused or detect-only); returns (C, errcount).
#[allow(clippy::too_many_arguments)]
fn exec_ft(
    ctx: &Ctx<'_>,
    artifact: &str,
    max_inj: usize,
    a: Matrix,
    b: Matrix,
    ka: Option<OperandKey>,
    kb: Option<OperandKey>,
    inj: &InjectionPlan,
) -> Result<(Matrix, u64)> {
    if inj.len() > max_inj {
        bail!("{artifact}: {} injections exceed kernel capacity {max_inj}", inj.len());
    }
    let (ar, ac, br, bc) = (a.rows(), a.cols(), b.rows(), b.cols());
    let out = ctx.engine.execute_on(
        ctx.pool,
        artifact,
        vec![
            Tensor::new(vec![ar, ac], a.into_data()).with_key(ka),
            Tensor::new(vec![br, bc], b.into_data()).with_key(kb),
            Tensor::new(vec![max_inj, 4], inj.to_tensor(max_inj)),
        ],
    )?;
    let e_idx = output_index(ctx, artifact, "errcount")?;
    let errs = out.outputs[e_idx].scalar_sum().round() as u64;
    Ok((take_matrix(ctx, artifact, out, "c")?, errs))
}

// ---------------------------------------------------------------------
// Ding nodes (the non-fused baseline path)
// ---------------------------------------------------------------------

fn exec_ding_encode(ctx: &Ctx<'_>, artifact: &str) -> Result<NodeDone> {
    let (a, b) = (ctx.a, ctx.b);
    let out = ctx.engine.execute_on(
        ctx.pool,
        artifact,
        vec![
            Tensor::new(vec![a.rows(), a.cols()], a.data().to_vec()),
            Tensor::new(vec![b.rows(), b.cols()], b.data().to_vec()),
        ],
    )?;
    let ac_idx = output_index(ctx, artifact, "ac")?;
    let br_idx = output_index(ctx, artifact, "br")?;
    let ac = tensor_matrix(&out.outputs[ac_idx])?;
    let br = tensor_matrix(&out.outputs[br_idx])?;
    let mut done = NodeDone::new();
    done.launches = 1;
    done.value = Some(NodeValue::Encoded { ac: Arc::new(ac), br: Arc::new(br) });
    Ok(done)
}

#[allow(clippy::too_many_arguments)]
fn exec_ding_panel(
    ctx: &Ctx<'_>,
    step_artifact: &str,
    verify_artifact: &str,
    encode_node: usize,
    prev_node: Option<usize>,
    s0: usize,
    ks: usize,
    inj: &InjectionPlan,
    last: bool,
) -> Result<NodeDone> {
    let step_art = ctx.engine.manifest().get(step_artifact)?;
    let (m, n) = (step_art.m, step_art.n);

    // Pull the encode outputs (shared by every panel) and the previous
    // panel's C^f (consumed exactly once) out of the value store.
    let (ac, br, mut cf) = {
        let mut values = ctx.values.lock().unwrap();
        let (ac, br) = match values.get(&encode_node) {
            Some(NodeValue::Encoded { ac, br }) => (Arc::clone(ac), Arc::clone(br)),
            _ => bail!("ding panel scheduled before its encode output"),
        };
        let cf = match prev_node {
            None => Matrix::zeros(m + 1, n + 1),
            Some(p) => match values.remove(&p) {
                Some(NodeValue::Cf(cf)) => cf,
                _ => bail!("ding panel scheduled before its predecessor's C^f"),
            },
        };
        (ac, br, cf)
    };

    let ac_panel = panel_cols(&ac, s0, ks);
    let br_panel = panel_rows(&br, s0, ks);
    let out = ctx.engine.execute_on(
        ctx.pool,
        step_artifact,
        vec![
            Tensor::new(vec![m + 1, n + 1], cf.into_data()),
            Tensor::new(vec![m + 1, ks], ac_panel.into_data()),
            Tensor::new(vec![ks, n + 1], br_panel.into_data()),
        ],
    )?;
    cf = take_matrix(ctx, step_artifact, out, "cf")?;

    // Host-side SEU injection into this panel's accumulation window — the
    // fault window of the original scheme (between step and verify).
    for e in &inj.injections {
        cf.add_at(e.row, e.col, e.magnitude);
    }

    let out = ctx.engine.execute_on(
        ctx.pool,
        verify_artifact,
        vec![Tensor::new(vec![m + 1, n + 1], cf.into_data())],
    )?;
    let e_idx = output_index(ctx, verify_artifact, "errcount")?;
    let corrected = out.outputs[e_idx].scalar_sum().round() as u64;
    cf = take_matrix(ctx, verify_artifact, out, "cf")?;

    let mut done = NodeDone::new();
    done.launches = 2;
    done.detected = corrected;
    done.corrected = corrected;
    if last {
        done.partial = Some((cf.slice_to(m, n), 0, 0));
    } else {
        done.value = Some(NodeValue::Cf(cf));
    }
    Ok(done)
}

// ---------------------------------------------------------------------
// Shared plumbing
// ---------------------------------------------------------------------

fn accumulate(c: &mut Matrix, partial: &Matrix, row0: usize, col0: usize) {
    let n = c.cols();
    for i in 0..partial.rows() {
        let base = (row0 + i) * n + col0;
        let dst = &mut c.data_mut()[base..base + partial.cols()];
        for (d, s) in dst.iter_mut().zip(partial.row(i)) {
            *d += s;
        }
    }
}

fn output_index(ctx: &Ctx<'_>, artifact: &str, role: &str) -> Result<usize> {
    ctx.engine
        .manifest()
        .get(artifact)?
        .output_index(role)
        .ok_or_else(|| anyhow!("{artifact} has no {role:?} output"))
}

/// Move the named output of an [`ExecOutput`] out as a Matrix (no copy).
fn take_matrix(ctx: &Ctx<'_>, artifact: &str, out: ExecOutput, role: &str) -> Result<Matrix> {
    let idx = output_index(ctx, artifact, role)?;
    let t = out
        .outputs
        .into_iter()
        .nth(idx)
        .ok_or_else(|| anyhow!("output index {idx} out of range"))?;
    if t.shape.len() != 2 {
        bail!("{artifact} output {role:?} is not a matrix: shape {:?}", t.shape);
    }
    Ok(Matrix::from_vec(t.shape[0], t.shape[1], t.data))
}

fn tensor_matrix(t: &Tensor) -> Result<Matrix> {
    if t.shape.len() != 2 {
        bail!("expected a matrix, got shape {:?}", t.shape);
    }
    Ok(Matrix::from_vec(t.shape[0], t.shape[1], t.data.clone()))
}

/// Extract the `(rows, cols)` sub-matrix at `(row0, col0)`, zero-padded to
/// `(pad_rows, pad_cols)`, in a single allocation + row-wise memcpy.
fn extract_padded(
    m: &Matrix,
    row0: usize,
    col0: usize,
    rows: usize,
    cols: usize,
    pad_rows: usize,
    pad_cols: usize,
) -> Matrix {
    debug_assert!(pad_rows >= rows && pad_cols >= cols);
    let mut out = Matrix::zeros(pad_rows, pad_cols);
    for i in 0..rows {
        let src = &m.row(row0 + i)[col0..col0 + cols];
        out.data_mut()[i * pad_cols..i * pad_cols + cols].copy_from_slice(src);
    }
    out
}

fn panel_cols(m: &Matrix, col0: usize, cols: usize) -> Matrix {
    Matrix::from_fn(m.rows(), cols, |i, j| m.at(i, col0 + j))
}

fn panel_rows(m: &Matrix, row0: usize, rows: usize) -> Matrix {
    Matrix::from_fn(rows, m.cols(), |i, j| m.at(row0 + i, j))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::plan::{plan_ding, Planner};
    use crate::coordinator::{CoordinatorConfig, FtPolicy};
    use crate::runtime::engine::EngineConfig;

    fn scheduler(workers: usize) -> Scheduler {
        let engine = Engine::start(EngineConfig { workers, ..Default::default() }).unwrap();
        Scheduler::new(engine, SchedulerConfig::default())
    }

    #[test]
    fn threads_default_to_engine_workers() {
        let s = scheduler(3);
        assert_eq!(s.threads(), 3);
    }

    #[test]
    fn runs_single_block_plan() {
        let s = scheduler(1);
        let cfg = CoordinatorConfig::default();
        let plan = Planner::new(s.engine().manifest(), &cfg)
            .plan_gemm(64, 64, 64, FtPolicy::None, &InjectionPlan::none())
            .unwrap();
        let a = Matrix::rand_uniform(64, 64, 1);
        let b = Matrix::rand_uniform(64, 64, 2);
        let out = s.run(&plan, &a, &b).unwrap();
        assert_eq!(out.launches, 1);
        assert!(out.c.max_abs_diff(&a.matmul(&b)) < 1e-3);
    }

    #[test]
    fn split_plan_accumulates_k_partials() {
        let s = scheduler(4);
        let cfg = CoordinatorConfig::default();
        let plan = Planner::new(s.engine().manifest(), &cfg)
            .plan_gemm(600, 600, 600, FtPolicy::None, &InjectionPlan::none())
            .unwrap();
        let a = Matrix::rand_uniform(600, 600, 3);
        let b = Matrix::rand_uniform(600, 600, 4);
        let out = s.run(&plan, &a, &b).unwrap();
        assert_eq!(out.launches, 8);
        assert!(out.c.max_abs_diff(&a.matmul(&b)) < 5e-3);
    }

    #[test]
    fn ding_plan_runs_through_the_same_scheduler() {
        let s = scheduler(2);
        let plan = plan_ding(s.engine().manifest(), "medium", &InjectionPlan::none()).unwrap();
        let a = Matrix::rand_uniform(128, 128, 5);
        let b = Matrix::rand_uniform(128, 128, 6);
        let out = s.run(&plan, &a, &b).unwrap();
        assert_eq!(out.launches, 1 + 2 * 2, "encode + 2 launches per panel");
        assert!(out.c.max_abs_diff(&a.matmul(&b)) < 2e-3);
    }

    #[test]
    fn node_error_propagates_and_drains() {
        let s = scheduler(2);
        let cfg = CoordinatorConfig::default();
        let mut plan = Planner::new(s.engine().manifest(), &cfg)
            .plan_gemm(600, 600, 600, FtPolicy::None, &InjectionPlan::none())
            .unwrap();
        // sabotage one node with a nonexistent artifact
        if let NodeOp::Block { kernel: KernelOp::Plain { artifact }, .. } =
            &mut plan.nodes[3].op
        {
            *artifact = "no_such_kernel".into();
        }
        let a = Matrix::rand_uniform(600, 600, 7);
        let b = Matrix::rand_uniform(600, 600, 8);
        let err = s.run(&plan, &a, &b).unwrap_err();
        assert!(err.to_string().contains("not in manifest"));
        // the scheduler remains serviceable
        let ok_plan = Planner::new(s.engine().manifest(), &cfg)
            .plan_gemm(64, 64, 64, FtPolicy::None, &InjectionPlan::none())
            .unwrap();
        assert!(s.run(&ok_plan, &a.slice_to(64, 64), &b.slice_to(64, 64)).is_ok());
    }

    #[test]
    fn extract_padded_pulls_and_pads() {
        let m = Matrix::from_fn(4, 4, |i, j| (i * 4 + j) as f32);
        let s = extract_padded(&m, 1, 2, 2, 2, 3, 4);
        assert_eq!((s.rows(), s.cols()), (3, 4));
        assert_eq!(s.at(0, 0), 6.0);
        assert_eq!(s.at(0, 1), 7.0);
        assert_eq!(s.at(1, 0), 10.0);
        assert_eq!(s.at(1, 1), 11.0);
        // padding region is exact zero
        assert_eq!(s.at(2, 3), 0.0);
        assert_eq!(s.at(0, 2), 0.0);
    }

    #[test]
    fn panel_extraction() {
        let m = Matrix::from_fn(3, 6, |i, j| (i * 6 + j) as f32);
        let p = panel_cols(&m, 2, 2);
        assert_eq!(p.rows(), 3);
        assert_eq!(p.at(0, 0), 2.0);
        assert_eq!(p.at(2, 1), 15.0);
        let q = panel_rows(&m, 1, 2);
        assert_eq!(q.at(0, 0), 6.0);
        assert_eq!(q.rows(), 2);
    }

    #[test]
    fn accumulate_targets_offsets() {
        let mut c = Matrix::zeros(4, 4);
        let p = Matrix::from_fn(2, 2, |i, j| (i * 2 + j + 1) as f32);
        accumulate(&mut c, &p, 1, 2);
        accumulate(&mut c, &p, 1, 2);
        assert_eq!(c.at(1, 2), 2.0);
        assert_eq!(c.at(2, 3), 8.0);
        assert_eq!(c.at(0, 0), 0.0);
    }
}
