//! The submission queue behind [`Coordinator::submit`]: admission control
//! plus deadline/priority-aware dispatch of whole requests.
//!
//! Submitted requests enter a priority queue (higher [`Priority`] first,
//! then earlier deadline, then FIFO) and are drained by a fixed pool of
//! dispatcher threads — the **admission-control bound on in-flight
//! plans** (`CoordinatorConfig::max_inflight`). Each dispatcher compiles
//! and runs one request at a time through the shared plan → schedule →
//! execute pipeline, so distinct requests overlap on the engine worker
//! pool exactly like the blocks of one split request do. A second,
//! optional bound (`max_queue`) rejects submissions outright once the
//! backlog is that deep — fail fast at the front door instead of
//! accumulating unbounded latency.
//!
//! Cancellation is resolved at dequeue time: a canceled ticket is
//! dropped without running (entries are deleted lazily, with compaction
//! at admission pressure so corpses never hold `max_queue` quota).
//! Deadlines are enforced from **both** sides: the dispatcher expires a
//! late entry at dequeue, and the ticket itself expires on `poll`/`wait`
//! once the deadline passes — so a starved request fails on time even if
//! no dispatcher ever reaches it. Shutdown (the last `Coordinator` clone
//! dropping) fails everything still queued and joins the dispatchers —
//! in-flight requests drain, never detach.
//!
//! [`Coordinator::submit`]: super::Coordinator::submit

use std::cmp::Ordering as CmpOrdering;
use std::collections::BinaryHeap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::metrics::recorder::Counters;

use super::request::{ticket, Completion, GemmRequest, Priority, RequestMeta, Ticket, TicketStatus};
use super::Core;

/// One queued request. Ordering (via `Ord`) is dequeue preference:
/// priority desc, then earlier deadline, then submission order.
pub(crate) struct Entry {
    priority: Priority,
    deadline: Option<Instant>,
    seq: u64,
    submitted: Instant,
    req: GemmRequest,
    completion: Completion,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}

impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        // BinaryHeap pops the maximum: greater = dispatched earlier.
        self.priority
            .cmp(&other.priority)
            .then_with(|| match (self.deadline, other.deadline) {
                // an earlier deadline outranks a later one; no deadline
                // ranks last within the priority class
                (Some(a), Some(b)) => b.cmp(&a),
                (Some(_), None) => CmpOrdering::Greater,
                (None, Some(_)) => CmpOrdering::Less,
                (None, None) => CmpOrdering::Equal,
            })
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

struct QueueInner {
    heap: BinaryHeap<Entry>,
    shutdown: bool,
}

struct SubmitState {
    queue: Mutex<QueueInner>,
    cv: Condvar,
    /// Monotonic submission stamp (FIFO tiebreak).
    seq: AtomicU64,
    /// Monotonic request-id source for tickets.
    next_id: AtomicU64,
    /// Monotonic dequeue stamp (`RequestMeta::dispatch_seq`).
    dispatch_seq: AtomicU64,
    /// Reject submissions once this many requests are queued; 0 = no cap.
    max_queue: usize,
}

/// The coordinator's submission machinery: queue + dispatcher pool.
pub(crate) struct Submission {
    state: Arc<SubmitState>,
    core: Arc<Core>,
    dispatchers: usize,
    workers: Vec<JoinHandle<()>>,
}

impl Submission {
    pub(crate) fn start(core: Arc<Core>, dispatchers: usize, max_queue: usize) -> Submission {
        let state = Arc::new(SubmitState {
            queue: Mutex::new(QueueInner { heap: BinaryHeap::new(), shutdown: false }),
            cv: Condvar::new(),
            seq: AtomicU64::new(0),
            next_id: AtomicU64::new(0),
            dispatch_seq: AtomicU64::new(0),
            max_queue,
        });
        let workers = (0..dispatchers.max(1))
            .map(|i| {
                let state = Arc::clone(&state);
                let core = Arc::clone(&core);
                std::thread::Builder::new()
                    .name(format!("ftgemm-dispatch-{i}"))
                    .spawn(move || dispatcher_loop(&core, &state))
                    .expect("spawn dispatcher")
            })
            .collect();
        Submission { state, core, dispatchers: dispatchers.max(1), workers }
    }

    /// The in-flight bound (dispatcher-thread count).
    pub(crate) fn dispatchers(&self) -> usize {
        self.dispatchers
    }

    /// Live requests queued but not yet dispatched. Canceled and
    /// self-expired tickets settle immediately but their entries are
    /// deleted lazily (at dequeue or at admission-pressure compaction),
    /// so count them out.
    pub(crate) fn queue_depth(&self) -> usize {
        self.state
            .queue
            .lock()
            .unwrap()
            .heap
            .iter()
            .filter(|e| e.completion.status() == TicketStatus::Queued)
            .count()
    }

    /// Mint a fresh (ticket, completion) pair with a coordinator-unique
    /// request id. Used directly by clients (the batcher) that hand the
    /// ticket out *before* the request reaches the queue.
    pub(crate) fn new_ticket(&self) -> (Ticket, Completion) {
        ticket(self.state.next_id.fetch_add(1, Ordering::Relaxed) + 1)
    }

    /// Enqueue a request against an already-minted completion.
    /// `submitted` is the instant the caller handed out the ticket — for
    /// the batcher that predates this call by up to a batching round, and
    /// deadlines/queue-time metadata count from it, not from here. On
    /// rejection (shutdown / admission control) the completion is settled
    /// with the same error that is returned.
    pub(crate) fn push(
        &self,
        req: GemmRequest,
        completion: Completion,
        submitted: Instant,
    ) -> Result<()> {
        let priority = req.opts.priority;
        let deadline = req.opts.deadline.map(|d| submitted + d);
        let mut q = self.state.queue.lock().unwrap();
        if q.shutdown {
            drop(q);
            completion.abort(TicketStatus::Failed, anyhow!("coordinator is shut down"));
            bail!("coordinator is shut down");
        }
        if self.state.max_queue > 0 && q.heap.len() >= self.state.max_queue {
            // Settled entries (canceled tickets, or deadline self-expiry
            // via poll/wait) are deleted lazily; don't let corpses hold
            // admission quota against live traffic. Compacted entries get
            // their counter bump here instead of at dequeue.
            q.heap.retain(|e| match e.completion.status() {
                TicketStatus::Queued => true,
                TicketStatus::Canceled => {
                    Counters::bump(&self.core.counters.canceled);
                    false
                }
                TicketStatus::Expired => {
                    Counters::bump(&self.core.counters.expired);
                    false
                }
                _ => false,
            });
        }
        if self.state.max_queue > 0 && q.heap.len() >= self.state.max_queue {
            let depth = q.heap.len();
            drop(q);
            completion.abort(
                TicketStatus::Failed,
                anyhow!("admission control: {depth} requests queued (max_queue)"),
            );
            bail!("admission control: {depth} requests already queued (max_queue = {})",
                self.state.max_queue);
        }
        Counters::bump(&self.core.counters.requests);
        if let Some(d) = deadline {
            // admitted: the ticket side can now expire itself (poll/wait)
            // even if no dispatcher ever reaches the entry
            completion.set_deadline(d);
        }
        q.heap.push(Entry {
            priority,
            deadline,
            seq: self.state.seq.fetch_add(1, Ordering::Relaxed),
            submitted,
            req,
            completion,
        });
        drop(q);
        self.state.cv.notify_one();
        Ok(())
    }

    /// Mint a ticket and enqueue in one step (the `Coordinator::submit`
    /// fast path).
    pub(crate) fn submit(&self, req: GemmRequest) -> Result<Ticket> {
        let (ticket, completion) = self.new_ticket();
        self.push(req, completion, Instant::now())?;
        Ok(ticket)
    }
}

impl Drop for Submission {
    fn drop(&mut self) {
        let drained: Vec<Entry> = {
            let mut q = self.state.queue.lock().unwrap();
            q.shutdown = true;
            self.state.cv.notify_all();
            q.heap.drain().collect()
        };
        for e in drained {
            e.completion.abort(
                TicketStatus::Failed,
                anyhow!("coordinator shut down with request {} still queued", e.completion.id()),
            );
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn dispatcher_loop(core: &Arc<Core>, state: &Arc<SubmitState>) {
    loop {
        // dispatch_seq is taken under the queue lock so the stamps agree
        // with dequeue order even with several dispatchers popping.
        let (entry, dispatch_seq) = {
            let mut q = state.queue.lock().unwrap();
            loop {
                if let Some(e) = q.heap.pop() {
                    break (e, state.dispatch_seq.fetch_add(1, Ordering::SeqCst));
                }
                if q.shutdown {
                    return;
                }
                q = state.cv.wait(q).unwrap();
            }
        };
        let Entry { priority, deadline, submitted, req, completion, .. } = entry;
        if completion.is_canceled() {
            Counters::bump(&core.counters.canceled);
            continue;
        }
        if deadline.is_some_and(|d| Instant::now() >= d) {
            Counters::bump(&core.counters.expired);
            completion.abort(
                TicketStatus::Expired,
                anyhow!(
                    "request {}: deadline exceeded after {:?} in queue",
                    completion.id(),
                    submitted.elapsed()
                ),
            );
            continue;
        }
        let meta = RequestMeta {
            id: completion.id(),
            policy: req.policy,
            priority,
            queued: submitted.elapsed(),
            dispatch_seq,
        };
        if !completion.start() {
            // canceled in the window between the checks above
            Counters::bump(&core.counters.canceled);
            continue;
        }
        // A panicking request must not kill the dispatcher (that would
        // silently shrink the admission bound) nor strand its waiter.
        let id = completion.id();
        let result = catch_unwind(AssertUnwindSafe(|| core.execute(&req)))
            .unwrap_or_else(|_| Err(anyhow!("request {id} panicked during execution")));
        completion.finish(meta, result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::abft::matrix::Matrix;
    use std::time::Duration;

    fn entry(priority: Priority, deadline: Option<Duration>, seq: u64) -> Entry {
        let now = Instant::now();
        let (_t, completion) = ticket(seq);
        Entry {
            priority,
            deadline: deadline.map(|d| now + d),
            seq,
            submitted: now,
            req: GemmRequest::new(Matrix::zeros(1, 1), Matrix::zeros(1, 1)),
            completion,
        }
    }

    fn pop_order(mut entries: Vec<Entry>) -> Vec<u64> {
        let mut heap = BinaryHeap::new();
        for e in entries.drain(..) {
            heap.push(e);
        }
        let mut order = Vec::new();
        while let Some(e) = heap.pop() {
            order.push(e.seq);
        }
        order
    }

    #[test]
    fn priority_outranks_everything() {
        let order = pop_order(vec![
            entry(Priority::Low, None, 0),
            entry(Priority::High, None, 1),
            entry(Priority::Normal, Some(Duration::from_millis(1)), 2),
        ]);
        assert_eq!(order, vec![1, 2, 0]);
    }

    #[test]
    fn earlier_deadline_outranks_within_priority() {
        let order = pop_order(vec![
            entry(Priority::Normal, None, 0),
            entry(Priority::Normal, Some(Duration::from_secs(5)), 1),
            entry(Priority::Normal, Some(Duration::from_secs(1)), 2),
        ]);
        assert_eq!(order, vec![2, 1, 0], "deadline asc, deadline-free last");
    }

    #[test]
    fn fifo_breaks_remaining_ties() {
        let order = pop_order(vec![
            entry(Priority::Normal, None, 2),
            entry(Priority::Normal, None, 0),
            entry(Priority::Normal, None, 1),
        ]);
        assert_eq!(order, vec![0, 1, 2]);
    }
}
