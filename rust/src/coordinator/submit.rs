//! The submission queue behind [`Coordinator::submit`]: admission control
//! plus deadline/priority-aware dispatch of whole requests.
//!
//! Submitted requests enter a priority queue (higher [`Priority`] first,
//! then earlier deadline, then FIFO) and are drained by a fixed pool of
//! dispatcher threads — the **admission-control bound on in-flight
//! plans** (`CoordinatorConfig::max_inflight`). Each dispatcher compiles
//! and runs one request at a time through the shared plan → schedule →
//! execute pipeline, so distinct requests overlap on the engine worker
//! pool exactly like the blocks of one split request do. A second,
//! optional bound (`max_queue`) rejects submissions outright once the
//! backlog is that deep — fail fast at the front door instead of
//! accumulating unbounded latency.
//!
//! Cancellation is resolved at dequeue time: a canceled ticket is
//! dropped without running (entries are deleted lazily, with compaction
//! at admission pressure so corpses never hold `max_queue` quota).
//! Deadlines are enforced from **both** sides: the dispatcher expires a
//! late entry at dequeue, and the ticket itself expires on `poll`/`wait`
//! once the deadline passes — so a starved request fails on time even if
//! no dispatcher ever reaches it. Shutdown (the last `Coordinator` clone
//! dropping) fails everything still queued and joins the dispatchers —
//! in-flight requests drain, never detach.
//!
//! **Sharded dispatch.** With a multi-pool engine the queue shards into
//! one priority heap per engine pool (all under a single lock — the
//! per-pool contention win comes from sharding the engine's warm caches
//! and worker sets, not from splitting this short critical section). The
//! router places each request by **shape class + cache affinity**: the
//! first request of a shape class pins the class to the least-loaded
//! pool, and later requests follow that pin — so a class's executables
//! stay warm on one shard — unless the pinned pool's live backlog
//! exceeds the least-loaded pool's by `steal_threshold`, in which case
//! the pin moves (affinity invalidation under skew). Each dispatcher
//! thread has a *home* pool (round-robin) and drains that heap first;
//! when home is empty it **steals** from the deepest other heap, but
//! only once that backlog reaches `steal_threshold` — light skew stays
//! put and keeps caches warm, heavy skew is rebalanced. A stolen
//! request executes on the thief's pool. Multi-block (split-GEMM) plans
//! ignore the pin downstream and span every pool via the DAG scheduler;
//! accumulation still lands exactly once per request. `max_queue`
//! bounds each pool's heap independently.
//!
//! [`Coordinator::submit`]: super::Coordinator::submit

use std::cmp::Ordering as CmpOrdering;
use std::collections::{BinaryHeap, HashMap};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::codegen::select::select_class;
use crate::metrics::recorder::Counters;
use crate::runtime::pack_cache::OperandId;

use super::request::{ticket, Completion, GemmRequest, Priority, RequestMeta, Ticket, TicketStatus};
use super::Core;

/// One queued request. Ordering (via `Ord`) is dequeue preference:
/// priority desc, then earlier deadline, then submission order.
pub(crate) struct Entry {
    priority: Priority,
    deadline: Option<Instant>,
    seq: u64,
    submitted: Instant,
    req: GemmRequest,
    completion: Completion,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}

impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        // BinaryHeap pops the maximum: greater = dispatched earlier.
        self.priority
            .cmp(&other.priority)
            .then_with(|| match (self.deadline, other.deadline) {
                // an earlier deadline outranks a later one; no deadline
                // ranks last within the priority class
                (Some(a), Some(b)) => b.cmp(&a),
                (Some(_), None) => CmpOrdering::Greater,
                (None, Some(_)) => CmpOrdering::Less,
                (None, None) => CmpOrdering::Equal,
            })
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

struct QueueInner {
    /// One priority heap per engine pool (shard), all under this lock.
    heaps: Vec<BinaryHeap<Entry>>,
    shutdown: bool,
}

impl QueueInner {
    /// Live (still-`Queued`) entries in one shard's heap — the load
    /// signal the router and the stealer compare. Corpses (canceled /
    /// self-expired tickets awaiting lazy deletion) don't count.
    fn live_depth(&self, pool: usize) -> usize {
        self.heaps[pool]
            .iter()
            .filter(|e| e.completion.status() == TicketStatus::Queued)
            .count()
    }
}

/// Cumulative per-pool routing counters (monotonic; survive for the
/// coordinator's lifetime so they reconcile with `Counters` totals).
#[derive(Default)]
pub(crate) struct PoolQueueStats {
    /// Requests the router placed on this pool at admission.
    routed: AtomicU64,
    /// Requests that started executing on this pool (home or stolen).
    dispatched: AtomicU64,
    /// Dispatched requests this pool's dispatchers stole from another
    /// pool's heap.
    steals: AtomicU64,
    /// Of `routed`, requests that followed an existing affinity pin
    /// (operand or shape-class) onto this pool — warm-cache placements.
    affinity_hits: AtomicU64,
    /// Total submission→theft queue wait (µs) of this pool's stolen
    /// requests; divide by `steals` for mean steal latency.
    steal_wait_us: AtomicU64,
}

/// Point-in-time view of one pool's queue, for `Coordinator::stats()`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct PoolQueueSnapshot {
    pub queue_depth: usize,
    pub routed: u64,
    pub dispatched: u64,
    pub steals: u64,
    pub affinity_hits: u64,
    pub steal_wait_us: u64,
}

struct SubmitState {
    queue: Mutex<QueueInner>,
    cv: Condvar,
    /// Monotonic submission stamp (FIFO tiebreak).
    seq: AtomicU64,
    /// Monotonic request-id source for tickets.
    next_id: AtomicU64,
    /// Monotonic dequeue stamp (`RequestMeta::dispatch_seq`).
    dispatch_seq: AtomicU64,
    /// Reject submissions once this many requests sit in the routed
    /// pool's heap; 0 = no cap. Bounds each shard independently.
    max_queue: usize,
    /// Backlog skew (in live requests) that triggers both work stealing
    /// and affinity re-pinning. Clamped to >= 1.
    steal_threshold: usize,
    /// Shape-class -> pool cache-affinity pins (`ShapeClass::name()`
    /// keys; the class's executables are warm on that shard).
    affinity: Mutex<HashMap<&'static str, usize>>,
    /// Operand -> pool pins: the pool whose packed-panel cache holds (or
    /// is about to hold) that operand's panels. Outranks the shape-class
    /// pin, same skew guard. Cleared wholesale at capacity — pins are
    /// re-established on the next sighting, nothing is lost but warmth.
    operand_affinity: Mutex<HashMap<OperandId, usize>>,
    /// Per-pool routing/steal counters, pool order.
    pool_stats: Vec<PoolQueueStats>,
}

/// The coordinator's submission machinery: queue + dispatcher pool.
pub(crate) struct Submission {
    state: Arc<SubmitState>,
    core: Arc<Core>,
    dispatchers: usize,
    workers: Vec<JoinHandle<()>>,
}

impl Submission {
    pub(crate) fn start(
        core: Arc<Core>,
        dispatchers: usize,
        max_queue: usize,
        steal_threshold: usize,
    ) -> Submission {
        let pools = core.engine.pool_count().max(1);
        // every pool needs at least one home dispatcher, or a backlog
        // below the steal threshold could sit unserved forever
        let dispatchers = dispatchers.max(1).max(pools);
        let state = Arc::new(SubmitState {
            queue: Mutex::new(QueueInner {
                heaps: (0..pools).map(|_| BinaryHeap::new()).collect(),
                shutdown: false,
            }),
            cv: Condvar::new(),
            seq: AtomicU64::new(0),
            next_id: AtomicU64::new(0),
            dispatch_seq: AtomicU64::new(0),
            max_queue,
            steal_threshold: steal_threshold.max(1),
            affinity: Mutex::new(HashMap::new()),
            operand_affinity: Mutex::new(HashMap::new()),
            pool_stats: (0..pools).map(|_| PoolQueueStats::default()).collect(),
        });
        let workers = (0..dispatchers)
            .map(|i| {
                let state = Arc::clone(&state);
                let core = Arc::clone(&core);
                let home = i % pools;
                std::thread::Builder::new()
                    .name(format!("ftgemm-dispatch-{i}"))
                    .spawn(move || dispatcher_loop(&core, &state, home))
                    .expect("spawn dispatcher")
            })
            .collect();
        Submission { state, core, dispatchers, workers }
    }

    /// The in-flight bound (dispatcher-thread count).
    pub(crate) fn dispatchers(&self) -> usize {
        self.dispatchers
    }

    /// Live requests queued but not yet dispatched, across every pool.
    /// Canceled and self-expired tickets settle immediately but their
    /// entries are deleted lazily (at dequeue or at admission-pressure
    /// compaction), so count them out.
    pub(crate) fn queue_depth(&self) -> usize {
        let q = self.state.queue.lock().unwrap();
        (0..q.heaps.len()).map(|p| q.live_depth(p)).sum()
    }

    /// Per-pool queue depth + cumulative routing counters, pool order.
    pub(crate) fn pool_snapshots(&self) -> Vec<PoolQueueSnapshot> {
        let q = self.state.queue.lock().unwrap();
        self.state
            .pool_stats
            .iter()
            .enumerate()
            .map(|(p, s)| PoolQueueSnapshot {
                queue_depth: q.live_depth(p),
                routed: s.routed.load(Ordering::SeqCst),
                dispatched: s.dispatched.load(Ordering::SeqCst),
                steals: s.steals.load(Ordering::SeqCst),
                affinity_hits: s.affinity_hits.load(Ordering::SeqCst),
                steal_wait_us: s.steal_wait_us.load(Ordering::SeqCst),
            })
            .collect()
    }

    /// Mint a fresh (ticket, completion) pair with a coordinator-unique
    /// request id. Used directly by clients (the batcher) that hand the
    /// ticket out *before* the request reaches the queue.
    pub(crate) fn new_ticket(&self) -> (Ticket, Completion) {
        ticket(self.state.next_id.fetch_add(1, Ordering::Relaxed) + 1)
    }

    /// Enqueue a request against an already-minted completion.
    /// `submitted` is the instant the caller handed out the ticket — for
    /// the batcher that predates this call by up to a batching round, and
    /// deadlines/queue-time metadata count from it, not from here. On
    /// rejection (shutdown / admission control) the completion is settled
    /// with the same error that is returned.
    pub(crate) fn push(
        &self,
        req: GemmRequest,
        completion: Completion,
        submitted: Instant,
    ) -> Result<()> {
        let priority = req.opts.priority;
        let deadline = req.opts.deadline.map(|d| submitted + d);
        let (m, n, k) = req.shape();
        let class = select_class(m, n, k).name();
        let mut q = self.state.queue.lock().unwrap();
        if q.shutdown {
            drop(q);
            completion.abort(TicketStatus::Failed, anyhow!("coordinator is shut down"));
            bail!("coordinator is shut down");
        }
        let (pool, affinity_hit) = self.route(&q, class, req.key_a.or(req.key_b));
        if self.state.max_queue > 0 && q.heaps[pool].len() >= self.state.max_queue {
            // Settled entries (canceled tickets, or deadline self-expiry
            // via poll/wait) are deleted lazily; don't let corpses hold
            // admission quota against live traffic. Compacted entries get
            // their counter bump here instead of at dequeue.
            let canceled = &self.core.counters.canceled;
            let expired = &self.core.counters.expired;
            q.heaps[pool].retain(|e| match e.completion.status() {
                TicketStatus::Queued => true,
                TicketStatus::Canceled => {
                    Counters::bump(canceled);
                    false
                }
                TicketStatus::Expired => {
                    Counters::bump(expired);
                    false
                }
                _ => false,
            });
        }
        if self.state.max_queue > 0 && q.heaps[pool].len() >= self.state.max_queue {
            let depth = q.heaps[pool].len();
            drop(q);
            completion.abort(
                TicketStatus::Failed,
                anyhow!("admission control: {depth} requests queued (max_queue)"),
            );
            bail!(
                "admission control: {depth} requests already queued on pool {pool} \
                 (max_queue = {})",
                self.state.max_queue
            );
        }
        Counters::bump(&self.core.counters.requests);
        Counters::bump(&self.state.pool_stats[pool].routed);
        if affinity_hit {
            Counters::bump(&self.state.pool_stats[pool].affinity_hits);
        }
        if let Some(d) = deadline {
            // admitted: the ticket side can now expire itself (poll/wait)
            // even if no dispatcher ever reaches the entry
            completion.set_deadline(d);
        }
        q.heaps[pool].push(Entry {
            priority,
            deadline,
            seq: self.state.seq.fetch_add(1, Ordering::Relaxed),
            submitted,
            req,
            completion,
        });
        drop(q);
        // notify_all, not notify_one: the woken dispatcher might not be
        // the new entry's home dispatcher, and a non-home dispatcher can
        // only take it past the steal threshold — a single wakeup could
        // strand the request until the next push.
        self.state.cv.notify_all();
        Ok(())
    }

    /// Shape-class + cache-affinity routing. First sighting of a class
    /// pins it to the least-loaded pool; later requests follow the pin so
    /// the class's executables stay warm on one shard — unless the
    /// pinned pool's live backlog exceeds the least-loaded pool's by the
    /// steal threshold, in which case the pin moves (affinity
    /// invalidation under skew). Ties pick the lowest pool index.
    ///
    /// A request carrying an operand id (`hot`) is pinned by operand
    /// instead: the pool whose packed-panel cache holds that operand's
    /// panels is preferred, under the same skew guard. The returned bool
    /// is true when an existing pin of either kind was followed (the
    /// `affinity_hits` numerator).
    fn route(&self, q: &QueueInner, class: &'static str, hot: Option<OperandId>) -> (usize, bool) {
        let pools = q.heaps.len();
        if pools == 1 {
            return (0, false);
        }
        let depths: Vec<usize> = (0..pools).map(|p| q.live_depth(p)).collect();
        let least = depths
            .iter()
            .enumerate()
            .min_by_key(|(_, d)| **d)
            .map(|(p, _)| p)
            .unwrap_or(0);
        let balanced =
            |p: usize| depths[p] < depths[least].saturating_add(self.state.steal_threshold);
        if let Some(id) = hot {
            let mut pins = self.state.operand_affinity.lock().unwrap();
            if pins.len() >= 4096 {
                pins.clear();
            }
            return match pins.get(&id).copied() {
                Some(p) if balanced(p) => (p, true),
                _ => {
                    pins.insert(id, least);
                    (least, false)
                }
            };
        }
        let mut affinity = self.state.affinity.lock().unwrap();
        match affinity.get(class).copied() {
            Some(p) if balanced(p) => (p, true),
            _ => {
                affinity.insert(class, least);
                (least, false)
            }
        }
    }

    /// Mint a ticket and enqueue in one step (the `Coordinator::submit`
    /// fast path).
    pub(crate) fn submit(&self, req: GemmRequest) -> Result<Ticket> {
        let (ticket, completion) = self.new_ticket();
        self.push(req, completion, Instant::now())?;
        Ok(ticket)
    }
}

impl Drop for Submission {
    fn drop(&mut self) {
        let drained: Vec<Entry> = {
            let mut q = self.state.queue.lock().unwrap();
            q.shutdown = true;
            self.state.cv.notify_all();
            // drain in place: the heaps Vec stays indexable for any
            // dispatcher still inside its pop loop
            q.heaps.iter_mut().flat_map(|h| h.drain()).collect()
        };
        for e in drained {
            e.completion.abort(
                TicketStatus::Failed,
                anyhow!("coordinator shut down with request {} still queued", e.completion.id()),
            );
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn dispatcher_loop(core: &Arc<Core>, state: &Arc<SubmitState>, home: usize) {
    loop {
        // dispatch_seq is taken under the queue lock so the stamps agree
        // with dequeue order even with several dispatchers popping.
        // Home heap first; when it's empty, steal from the deepest other
        // heap — but only once its live backlog reaches the threshold.
        let (entry, dispatch_seq, stolen) = {
            let mut q = state.queue.lock().unwrap();
            loop {
                if let Some(e) = q.heaps[home].pop() {
                    break (e, state.dispatch_seq.fetch_add(1, Ordering::SeqCst), false);
                }
                let victim = (0..q.heaps.len())
                    .filter(|&p| p != home)
                    .map(|p| (q.live_depth(p), p))
                    .max_by_key(|&(d, p)| (d, std::cmp::Reverse(p)));
                if let Some((depth, v)) = victim {
                    if depth >= state.steal_threshold {
                        if let Some(e) = q.heaps[v].pop() {
                            break (e, state.dispatch_seq.fetch_add(1, Ordering::SeqCst), true);
                        }
                    }
                }
                if q.shutdown {
                    return;
                }
                q = state.cv.wait(q).unwrap();
            }
        };
        let Entry { priority, deadline, submitted, req, completion, .. } = entry;
        if completion.is_canceled() {
            Counters::bump(&core.counters.canceled);
            continue;
        }
        if deadline.is_some_and(|d| Instant::now() >= d) {
            Counters::bump(&core.counters.expired);
            completion.abort(
                TicketStatus::Expired,
                anyhow!(
                    "request {}: deadline exceeded after {:?} in queue",
                    completion.id(),
                    submitted.elapsed()
                ),
            );
            continue;
        }
        // A stolen request executes on the thief's shard — the victim's
        // backlog is the problem being solved; paying one cold compile
        // here beats queueing behind it.
        let meta = RequestMeta {
            id: completion.id(),
            policy: req.policy,
            priority,
            queued: submitted.elapsed(),
            dispatch_seq,
            pool: home,
        };
        if !completion.start() {
            // canceled in the window between the checks above
            Counters::bump(&core.counters.canceled);
            continue;
        }
        // dispatched/steals bump only after start() succeeds, so the
        // per-pool counters reconcile with executed-request totals.
        Counters::bump(&state.pool_stats[home].dispatched);
        if stolen {
            Counters::bump(&state.pool_stats[home].steals);
            // u128→u64: a theft after 584k years of queue wait can saturate.
            let waited = meta.queued.as_micros().min(u64::MAX as u128) as u64;
            Counters::add(&state.pool_stats[home].steal_wait_us, waited);
        }
        // A panicking request must not kill the dispatcher (that would
        // silently shrink the admission bound) nor strand its waiter.
        let id = completion.id();
        let result = catch_unwind(AssertUnwindSafe(|| core.execute(&req, Some(home))))
            .unwrap_or_else(|_| Err(anyhow!("request {id} panicked during execution")));
        completion.finish(meta, result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::abft::matrix::Matrix;
    use std::time::Duration;

    fn entry(priority: Priority, deadline: Option<Duration>, seq: u64) -> Entry {
        let now = Instant::now();
        let (_t, completion) = ticket(seq);
        Entry {
            priority,
            deadline: deadline.map(|d| now + d),
            seq,
            submitted: now,
            req: GemmRequest::new(Matrix::zeros(1, 1), Matrix::zeros(1, 1)),
            completion,
        }
    }

    fn pop_order(mut entries: Vec<Entry>) -> Vec<u64> {
        let mut heap = BinaryHeap::new();
        for e in entries.drain(..) {
            heap.push(e);
        }
        let mut order = Vec::new();
        while let Some(e) = heap.pop() {
            order.push(e.seq);
        }
        order
    }

    #[test]
    fn priority_outranks_everything() {
        let order = pop_order(vec![
            entry(Priority::Low, None, 0),
            entry(Priority::High, None, 1),
            entry(Priority::Normal, Some(Duration::from_millis(1)), 2),
        ]);
        assert_eq!(order, vec![1, 2, 0]);
    }

    #[test]
    fn earlier_deadline_outranks_within_priority() {
        let order = pop_order(vec![
            entry(Priority::Normal, None, 0),
            entry(Priority::Normal, Some(Duration::from_secs(5)), 1),
            entry(Priority::Normal, Some(Duration::from_secs(1)), 2),
        ]);
        assert_eq!(order, vec![2, 1, 0], "deadline asc, deadline-free last");
    }

    #[test]
    fn fifo_breaks_remaining_ties() {
        let order = pop_order(vec![
            entry(Priority::Normal, None, 2),
            entry(Priority::Normal, None, 0),
            entry(Priority::Normal, None, 1),
        ]);
        assert_eq!(order, vec![0, 1, 2]);
    }
}
