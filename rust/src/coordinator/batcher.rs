//! Dynamic request batching (vLLM-style).
//!
//! Callers submit GEMM requests and receive a ticket; a background worker
//! drains the queue, **groups requests by (bucket, policy)** so consecutive
//! kernel launches hit the same warm executables (executable switches are
//! the main source of cache-miss latency on the engine workers), and
//! fulfills each ticket through a oneshot channel. Execution goes through
//! the same plan → schedule pipeline as direct [`Coordinator`] calls.
//!
//! Batching discipline: block on `recv` while idle (an idle batcher burns
//! no CPU), then gather everything already queued — optionally waiting up
//! to `batch_window` for stragglers — up to `max_batch`; order groups by
//! arrival of their oldest member — bounded staleness, no starvation.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::abft::injection::InjectionPlan;
use crate::abft::matrix::Matrix;
use crate::codegen::select::select_bucket;
use crate::util::pool::oneshot;

use super::{Coordinator, FtPolicy, GemmResult};

/// A submitted request awaiting execution.
struct Pending {
    a: Matrix,
    b: Matrix,
    policy: FtPolicy,
    inj: InjectionPlan,
    reply: oneshot::OneSender<Result<GemmResult>>,
}

/// Ticket for a submitted request.
pub struct Ticket {
    rx: oneshot::OneReceiver<Result<GemmResult>>,
}

impl Ticket {
    /// Block until the result is ready.
    pub fn wait(self) -> Result<GemmResult> {
        self.rx.recv().map_err(|_| anyhow!("batcher dropped the request"))?
    }

    pub fn wait_timeout(self, d: Duration) -> Result<GemmResult> {
        self.rx
            .recv_timeout(d)
            .map_err(|_| anyhow!("batcher response timed out"))?
    }
}

/// Batcher configuration.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Max requests drained per scheduling round.
    pub max_batch: usize,
    /// After the first request of a round arrives, keep gathering for this
    /// long so co-batchable requests land in the same round. Zero = serve
    /// whatever is already queued (no added latency). The worker blocks
    /// (no polling) while idle regardless.
    pub batch_window: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 64, batch_window: Duration::ZERO }
    }
}

enum Msg {
    Submit(Pending),
    Shutdown,
}

/// Dynamic batcher over a [`Coordinator`].
pub struct Batcher {
    tx: Sender<Msg>,
    handle: Option<JoinHandle<()>>,
    stats: Arc<Mutex<BatchStats>>,
}

/// Scheduling statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct BatchStats {
    pub rounds: u64,
    pub requests: u64,
    pub groups: u64,
    /// Requests that shared a group with at least one other request.
    pub coscheduled: u64,
}

impl Batcher {
    pub fn start(coord: Coordinator, config: BatcherConfig) -> Batcher {
        let (tx, rx) = channel::<Msg>();
        let stats = Arc::new(Mutex::new(BatchStats::default()));
        let wstats = Arc::clone(&stats);
        let handle = std::thread::Builder::new()
            .name("ftgemm-batcher".into())
            .spawn(move || worker_loop(coord, config, rx, wstats))
            .expect("spawn batcher");
        Batcher { tx, handle: Some(handle), stats }
    }

    /// Submit a request; returns a [`Ticket`] immediately.
    pub fn submit(
        &self,
        a: Matrix,
        b: Matrix,
        policy: FtPolicy,
        inj: InjectionPlan,
    ) -> Result<Ticket> {
        let (otx, orx) = oneshot::channel();
        let p = Pending { a, b, policy, inj, reply: otx };
        self.tx
            .send(Msg::Submit(p))
            .map_err(|_| anyhow!("batcher is shut down"))?;
        Ok(Ticket { rx: orx })
    }

    pub fn stats(&self) -> BatchStats {
        *self.stats.lock().unwrap()
    }
}

fn worker_loop(
    coord: Coordinator,
    config: BatcherConfig,
    rx: Receiver<Msg>,
    stats: Arc<Mutex<BatchStats>>,
) {
    let mut queue: VecDeque<Pending> = VecDeque::new();
    loop {
        // Idle: block until work arrives — no poll interval, no spin.
        if queue.is_empty() {
            match rx.recv() {
                Ok(Msg::Submit(p)) => queue.push_back(p),
                Ok(Msg::Shutdown) | Err(_) => break,
            }
        }
        // Gather the round: everything queued, plus (optionally) whatever
        // trickles in during the batch window.
        let mut shutdown = false;
        let deadline =
            (!config.batch_window.is_zero()).then(|| Instant::now() + config.batch_window);
        while queue.len() < config.max_batch {
            let msg = match deadline {
                None => match rx.try_recv() {
                    Ok(m) => m,
                    Err(_) => break,
                },
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        break;
                    }
                    match rx.recv_timeout(d - now) {
                        Ok(m) => m,
                        Err(_) => break,
                    }
                }
            };
            match msg {
                Msg::Submit(p) => queue.push_back(p),
                Msg::Shutdown => {
                    shutdown = true;
                    break;
                }
            }
        }
        // Group by (bucket, policy), keep arrival order of the oldest
        // member per group.
        let round: Vec<Pending> = queue.drain(..).collect();
        let mut groups: Vec<(String, Vec<Pending>)> = Vec::new();
        for p in round {
            let bucket = select_bucket(p.a.rows(), p.b.cols(), p.a.cols())
                .map(|b| b.name().to_string())
                .unwrap_or_else(|| "split".into());
            let key = format!("{bucket}/{}", p.policy.name());
            match groups.iter_mut().find(|(k, _)| *k == key) {
                Some((_, v)) => v.push(p),
                None => groups.push((key, vec![p])),
            }
        }
        {
            let mut s = stats.lock().unwrap();
            s.rounds += 1;
            s.groups += groups.len() as u64;
            for (_, v) in &groups {
                s.requests += v.len() as u64;
                if v.len() > 1 {
                    s.coscheduled += v.len() as u64;
                }
            }
        }
        for (_, members) in groups {
            for p in members {
                let r = coord.gemm_with_faults(&p.a, &p.b, p.policy, &p.inj);
                let _ = p.reply.send(r);
            }
        }
        if shutdown {
            break;
        }
    }
    // Fail any stragglers.
    for p in queue {
        let _ = p.reply.send(Err(anyhow!("batcher shut down")));
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_blocks_instead_of_polling() {
        let c = BatcherConfig::default();
        assert!(c.max_batch >= 1);
        assert!(c.batch_window.is_zero());
    }
    // End-to-end batcher tests (engine + coordinator) live in
    // rust/tests/integration.rs.
}
