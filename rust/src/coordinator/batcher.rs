//! Dynamic request batching (vLLM-style) over the submission API.
//!
//! Callers submit [`GemmRequest`]s and receive the same [`Ticket`] handle
//! that [`Coordinator::submit`] returns; a background worker drains the
//! queue, **groups requests by (bucket, policy)** so consecutive kernel
//! launches hit the same warm executables (executable switches are the
//! main source of cache-miss latency on the engine workers), and then
//! forwards each group — in arrival order of its oldest member — into the
//! coordinator's submission queue. The batcher owns **no execution path
//! of its own**: once a round is flushed, dispatch, priority, deadlines,
//! cancellation, and completion are all the coordinator's, and a ticket
//! handed out here behaves exactly like one from a direct `submit`.
//!
//! Batching discipline: block on `recv` while idle (an idle batcher burns
//! no CPU), then gather everything already queued — optionally waiting up
//! to `batch_window` for stragglers — up to `max_batch`; order groups by
//! arrival of their oldest member — bounded staleness, no starvation.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::codegen::select::select_bucket;
use crate::metrics::recorder::Counters;

use super::request::{Completion, GemmRequest, Ticket, TicketStatus};
use super::Coordinator;

/// A request waiting for its batching round, already paired with the
/// ticket the caller holds.
struct Pending {
    req: GemmRequest,
    completion: Completion,
    /// When the caller's ticket was minted: deadlines and queue-time
    /// metadata count from here, not from the round flush.
    submitted: Instant,
}

/// Batcher configuration.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Max requests drained per scheduling round.
    pub max_batch: usize,
    /// After the first request of a round arrives, keep gathering for this
    /// long so co-batchable requests land in the same round. Zero = serve
    /// whatever is already queued (no added latency). The worker blocks
    /// (no polling) while idle regardless.
    pub batch_window: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 64, batch_window: Duration::ZERO }
    }
}

enum Msg {
    Submit(Pending),
    Shutdown,
}

/// Dynamic batcher over a [`Coordinator`] — a grouping stage in front of
/// [`Coordinator::submit`].
pub struct Batcher {
    coord: Coordinator,
    tx: Sender<Msg>,
    handle: Option<JoinHandle<()>>,
    stats: Arc<Mutex<BatchStats>>,
}

/// Scheduling statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct BatchStats {
    pub rounds: u64,
    pub requests: u64,
    pub groups: u64,
    /// Requests that shared a group with at least one other request.
    pub coscheduled: u64,
}

impl Batcher {
    pub fn start(coord: Coordinator, config: BatcherConfig) -> Batcher {
        let (tx, rx) = channel::<Msg>();
        let stats = Arc::new(Mutex::new(BatchStats::default()));
        let wstats = Arc::clone(&stats);
        let wcoord = coord.clone();
        let handle = std::thread::Builder::new()
            .name("ftgemm-batcher".into())
            .spawn(move || worker_loop(wcoord, config, rx, wstats))
            .expect("spawn batcher");
        Batcher { coord, tx, handle: Some(handle), stats }
    }

    /// Submit a request; returns its [`Ticket`] immediately. The ticket is
    /// the same handle [`Coordinator::submit`] returns — wait, poll, and
    /// cancel behave identically (a cancel that lands before the batching
    /// round flushes skips coordinator submission entirely).
    pub fn submit(&self, req: GemmRequest) -> Result<Ticket> {
        let (ticket, completion) = self.coord.new_ticket();
        let pending = Pending { req, completion, submitted: Instant::now() };
        match self.tx.send(Msg::Submit(pending)) {
            Ok(()) => Ok(ticket),
            Err(send) => {
                if let Msg::Submit(p) = send.0 {
                    p.completion
                        .abort(TicketStatus::Failed, anyhow!("batcher is shut down"));
                }
                Err(anyhow!("batcher is shut down"))
            }
        }
    }

    pub fn stats(&self) -> BatchStats {
        *self.stats.lock().unwrap()
    }
}

fn worker_loop(
    coord: Coordinator,
    config: BatcherConfig,
    rx: Receiver<Msg>,
    stats: Arc<Mutex<BatchStats>>,
) {
    let mut queue: VecDeque<Pending> = VecDeque::new();
    loop {
        // Idle: block until work arrives — no poll interval, no spin.
        if queue.is_empty() {
            match rx.recv() {
                Ok(Msg::Submit(p)) => queue.push_back(p),
                Ok(Msg::Shutdown) | Err(_) => break,
            }
        }
        // Gather the round: everything queued, plus (optionally) whatever
        // trickles in during the batch window.
        let mut shutdown = false;
        let deadline =
            (!config.batch_window.is_zero()).then(|| Instant::now() + config.batch_window);
        while queue.len() < config.max_batch {
            let msg = match deadline {
                None => match rx.try_recv() {
                    Ok(m) => m,
                    Err(_) => break,
                },
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        break;
                    }
                    match rx.recv_timeout(d - now) {
                        Ok(m) => m,
                        Err(_) => break,
                    }
                }
            };
            match msg {
                Msg::Submit(p) => queue.push_back(p),
                Msg::Shutdown => {
                    shutdown = true;
                    break;
                }
            }
        }
        // Group by (bucket, policy), keep arrival order of the oldest
        // member per group.
        let round: Vec<Pending> = queue.drain(..).collect();
        let mut groups: Vec<(String, Vec<Pending>)> = Vec::new();
        for p in round {
            let (m, n, k) = p.req.shape();
            let bucket = select_bucket(m, n, k)
                .map(|b| b.name().to_string())
                .unwrap_or_else(|| "split".into());
            let key = format!("{bucket}/{}", p.req.get_policy().name());
            match groups.iter_mut().find(|(g, _)| *g == key) {
                Some((_, v)) => v.push(p),
                None => groups.push((key, vec![p])),
            }
        }
        {
            let mut s = stats.lock().unwrap();
            s.rounds += 1;
            s.groups += groups.len() as u64;
            for (_, v) in &groups {
                s.requests += v.len() as u64;
                if v.len() > 1 {
                    s.coscheduled += v.len() as u64;
                }
            }
        }
        // Flush the round group by group into the coordinator's queue.
        // Warm-affine engine dispatch does the rest: consecutive
        // same-bucket requests hit warm executables. Rejections
        // (admission control / shutdown) already settled the ticket
        // inside submit_prepared.
        for (_, members) in groups {
            for p in members {
                if p.completion.is_canceled() {
                    // count it as a (canceled) request, as the direct
                    // submit path would — canceled must never exceed
                    // requests in a snapshot
                    Counters::bump(&coord.counters().requests);
                    Counters::bump(&coord.counters().canceled);
                    continue;
                }
                let _ = coord.submit_prepared(p.req, p.completion, p.submitted);
            }
        }
        if shutdown {
            break;
        }
    }
    // Fail any stragglers.
    for p in queue {
        p.completion.abort(TicketStatus::Failed, anyhow!("batcher shut down"));
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_blocks_instead_of_polling() {
        let c = BatcherConfig::default();
        assert!(c.max_batch >= 1);
        assert!(c.batch_window.is_zero());
    }
    // End-to-end batcher tests (engine + coordinator + tickets) live in
    // rust/tests/integration.rs.
}
