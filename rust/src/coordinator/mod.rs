//! The L3 coordinator: a GEMM-serving engine with pluggable fault
//! tolerance.
//!
//! This is the serving-side reproduction of the paper's system: requests of
//! arbitrary shape are routed onto the AOT kernel buckets ([`router`]),
//! executed through the PJRT engine, and protected by one of three
//! [`FtPolicy`]s:
//!
//! * [`FtPolicy::None`] — the plain codegen GEMM (the §3 baseline);
//! * [`FtPolicy::Online`] — the fused FT-GEMM: detection *and* correction
//!   inside the kernel (§4, the paper's contribution);
//! * [`FtPolicy::Offline`] — detect-only kernel + recompute-on-detection
//!   (§5.5's comparison point);
//!
//! plus the [`ding`] module, the non-fused Ding'11 baseline pipeline
//! (Figs 12–16) driven as separate kernel launches.
//!
//! [`batcher`] adds dynamic request batching on top (vLLM-style: group by
//! bucket so consecutive executions reuse the warm executable).

pub mod batcher;
pub mod ding;
pub mod router;

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::abft::checksum::{self, ChecksumPair, Thresholds};
use crate::abft::injection::InjectionPlan;
use crate::abft::matrix::Matrix;
use crate::metrics::recorder::{Counters, LatencyRecorder};
use crate::runtime::engine::{Engine, Tensor};
use crate::runtime::manifest::{Artifact, ArtifactKind};

use router::BlockPlan;

/// Fault-tolerance policy for a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FtPolicy {
    /// No protection — fastest, silent-corruption-prone.
    None,
    /// Fused online ABFT: in-kernel detect + correct (the paper's scheme).
    Online,
    /// Detect-only kernel; recompute the whole GEMM when a fault fires
    /// (offline ABFT, §5.5).
    Offline,
}

impl FtPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            FtPolicy::None => "none",
            FtPolicy::Online => "online",
            FtPolicy::Offline => "offline",
        }
    }
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// FT granularity for the online policy: "tb" | "warp" | "thread".
    /// Buckets without that level fall back to "tb" (always present).
    pub ft_level: String,
    /// Re-verify returned C against operand-derived checksums on the host
    /// (defense in depth; O(mk + kn) extra host work).
    pub host_verify: bool,
    /// Max recompute attempts for the offline policy before giving up.
    pub max_recomputes: usize,
    /// Detection thresholds for host-side verification.
    pub thresholds: Thresholds,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            ft_level: "tb".into(),
            host_verify: false,
            max_recomputes: 8,
            thresholds: Thresholds::default(),
        }
    }
}

/// Result of one coordinated GEMM.
#[derive(Debug, Clone)]
pub struct GemmResult {
    pub c: Matrix,
    pub errors_detected: u64,
    pub errors_corrected: u64,
    pub recomputes: u64,
    pub kernel_launches: u64,
    pub exec_time: Duration,
    /// Which buckets served the request (one entry per block).
    pub buckets: Vec<&'static str>,
}

/// The serving coordinator. Cheap to clone (`Arc` internals); thread-safe.
#[derive(Clone)]
pub struct Coordinator {
    engine: Engine,
    config: CoordinatorConfig,
    counters: Arc<Counters>,
    latency: Arc<LatencyRecorder>,
}

impl Coordinator {
    pub fn new(engine: Engine, config: CoordinatorConfig) -> Self {
        Coordinator {
            engine,
            config,
            counters: Arc::new(Counters::new()),
            latency: Arc::new(LatencyRecorder::new()),
        }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn config(&self) -> &CoordinatorConfig {
        &self.config
    }

    pub fn counters(&self) -> &Counters {
        &self.counters
    }

    pub fn latency(&self) -> &LatencyRecorder {
        &self.latency
    }

    /// C = A·B under `policy`, fault-free.
    pub fn gemm(&self, a: &Matrix, b: &Matrix, policy: FtPolicy) -> Result<GemmResult> {
        self.gemm_with_faults(a, b, policy, &InjectionPlan::none())
    }

    /// C = A·B under `policy` with SEU injection (§5.3 protocol).
    ///
    /// Injection coordinates are global output positions; `step` indexes
    /// the serving bucket's k-loop (clamped kernel-side). For split
    /// (oversize) GEMMs, each injection lands in the block containing its
    /// (row, col) at the first k-partial.
    pub fn gemm_with_faults(
        &self,
        a: &Matrix,
        b: &Matrix,
        policy: FtPolicy,
        inj: &InjectionPlan,
    ) -> Result<GemmResult> {
        if a.cols() != b.rows() {
            bail!(
                "inner dimensions disagree: {}x{} @ {}x{}",
                a.rows(),
                a.cols(),
                b.rows(),
                b.cols()
            );
        }
        Counters::bump(&self.counters.requests);
        let t0 = Instant::now();
        let plan = router::route(a.rows(), b.cols(), a.cols());
        if plan.split {
            Counters::bump(&self.counters.batched_groups);
        }
        if plan.blocks.iter().any(|bl| bl.is_padded()) {
            Counters::bump(&self.counters.padded_requests);
        }

        let mut c = Matrix::zeros(plan.m, plan.n);
        let mut detected = 0u64;
        let mut corrected = 0u64;
        let mut recomputes = 0u64;
        let mut launches = 0u64;
        let mut buckets = Vec::with_capacity(plan.blocks.len());

        for block in &plan.blocks {
            let block_inj = localize_injections(inj, block);
            let out = self.run_block(a, b, block, policy, &block_inj)?;
            detected += out.detected;
            corrected += out.corrected;
            recomputes += out.recomputes;
            launches += out.launches;
            buckets.push(block.bucket.name());
            // accumulate the block partial into the output region
            for i in 0..block.m {
                for j in 0..block.n {
                    c.add_at(block.row0 + i, block.col0 + j, out.c.at(i, j));
                }
            }
        }

        if self.config.host_verify && inj.is_empty() {
            // Defense in depth: O(mk + kn) re-derivation of the product
            // checksums from the operands, compared against C.
            let pair = ChecksumPair::of_product(a, b);
            if checksum::verify(&c, &pair, self.config.thresholds) != checksum::Detection::Clean {
                bail!("host re-verification failed on a supposedly clean result");
            }
        }

        let exec_time = t0.elapsed();
        self.latency.record(exec_time);
        Counters::add(&self.counters.executions, launches);
        Counters::add(&self.counters.errors_detected, detected);
        Counters::add(&self.counters.errors_corrected, corrected);
        Counters::add(&self.counters.recomputes, recomputes);
        Ok(GemmResult {
            c,
            errors_detected: detected,
            errors_corrected: corrected,
            recomputes,
            kernel_launches: launches,
            exec_time,
            buckets,
        })
    }

    // ------------------------------------------------------------------

    fn artifact_for(&self, policy: FtPolicy, bucket: &str) -> Result<Artifact> {
        let m = self.engine.manifest();
        let found = match policy {
            FtPolicy::None => m.find(ArtifactKind::Gemm, bucket, None),
            FtPolicy::Online => m
                .find(ArtifactKind::FtGemm, bucket, Some(self.config.ft_level.as_str()))
                .or_else(|| m.find(ArtifactKind::FtGemm, bucket, Some("tb"))),
            FtPolicy::Offline => m.find(ArtifactKind::FtDetect, bucket, None),
        };
        found
            .cloned()
            .ok_or_else(|| anyhow!("no {policy:?} artifact for bucket {bucket}"))
    }

    fn run_block(
        &self,
        a: &Matrix,
        b: &Matrix,
        block: &BlockPlan,
        policy: FtPolicy,
        inj: &InjectionPlan,
    ) -> Result<BlockOutcome> {
        let bk = &block.bucket;
        // Extract + zero-pad operand blocks in one pass (one allocation
        // and one row-wise copy each — §Perf).
        let a_blk = extract_padded(a, block.row0, block.k0, block.m, block.k, bk.m, bk.k);
        let b_blk = extract_padded(b, block.k0, block.col0, block.k, block.n, bk.k, bk.n);
        match policy {
            FtPolicy::None => {
                if !inj.is_empty() {
                    bail!("cannot inject into the unprotected kernel (no inj input); use Online/Offline");
                }
                let art = self.artifact_for(policy, bk.name())?;
                let out = self.exec_gemm(&art, a_blk, b_blk)?;
                Ok(BlockOutcome {
                    c: out.slice_to(block.m, block.n),
                    detected: 0,
                    corrected: 0,
                    recomputes: 0,
                    launches: 1,
                })
            }
            FtPolicy::Online => {
                let art = self.artifact_for(policy, bk.name())?;
                let (c_full, errs) = self.exec_ft(&art, a_blk, b_blk, inj)?;
                Ok(BlockOutcome {
                    c: c_full.slice_to(block.m, block.n),
                    detected: errs,
                    corrected: errs,
                    recomputes: 0,
                    launches: 1,
                })
            }
            FtPolicy::Offline => {
                // Detect-only artifact where available, else plain kernel +
                // host-side detector (same detect→recompute control flow).
                let detect_art = self.artifact_for(policy, bk.name()).ok();
                let mut detected = 0u64;
                let mut launches = 0u64;
                let mut attempt = 0usize;
                loop {
                    // Injection only on the first attempt: the recompute
                    // runs on presumed-healthy hardware (recompute-time
                    // faults are treated analytically — gpusim::analytic).
                    let this_inj =
                        if attempt == 0 { inj.clone() } else { InjectionPlan::none() };
                    launches += 1;
                    let (c_full, errs) = match &detect_art {
                        // operands are reused across recompute attempts, so
                        // this path clones (the retry loop is cold)
                        Some(art) => self.exec_ft(art, a_blk.clone(), b_blk.clone(), &this_inj)?,
                        None => {
                            let plain = self.artifact_for(FtPolicy::None, bk.name())?;
                            let mut c_full =
                                self.exec_gemm(&plain, a_blk.clone(), b_blk.clone())?;
                            this_inj.apply_to(&mut c_full);
                            let pair = ChecksumPair::of_product(&a_blk, &b_blk);
                            let det =
                                checksum::verify(&c_full, &pair, self.config.thresholds);
                            let errs =
                                if det == checksum::Detection::Clean { 0 } else { 1 };
                            (c_full, errs)
                        }
                    };
                    detected += errs;
                    if errs == 0 {
                        return Ok(BlockOutcome {
                            c: c_full.slice_to(block.m, block.n),
                            detected,
                            corrected: 0,
                            recomputes: attempt as u64,
                            launches,
                        });
                    }
                    attempt += 1;
                    if attempt > self.config.max_recomputes {
                        bail!(
                            "offline ABFT: fault persisted after {} recomputes",
                            self.config.max_recomputes
                        );
                    }
                }
            }
        }
    }

    fn exec_gemm(&self, art: &Artifact, a: Matrix, b: Matrix) -> Result<Matrix> {
        let (ar, ac, br2, bc) = (a.rows(), a.cols(), b.rows(), b.cols());
        let out = self.engine.execute(
            &art.name,
            vec![
                // moves, not copies: the padded operand blocks are owned
                Tensor::new(vec![ar, ac], a.into_data()),
                Tensor::new(vec![br2, bc], b.into_data()),
            ],
        )?;
        let c_idx = art
            .output_index("c")
            .ok_or_else(|| anyhow!("{} has no 'c' output", art.name))?;
        take_matrix(out, c_idx)
    }

    /// Execute an FT artifact (fused or detect-only); returns (C, errcount).
    fn exec_ft(
        &self,
        art: &Artifact,
        a: Matrix,
        b: Matrix,
        inj: &InjectionPlan,
    ) -> Result<(Matrix, u64)> {
        let max_inj = art.max_inj.max(1);
        if inj.len() > max_inj {
            bail!("{}: {} injections exceed kernel capacity {max_inj}", art.name, inj.len());
        }
        let (ar, ac, br2, bc) = (a.rows(), a.cols(), b.rows(), b.cols());
        let out = self.engine.execute(
            &art.name,
            vec![
                Tensor::new(vec![ar, ac], a.into_data()),
                Tensor::new(vec![br2, bc], b.into_data()),
                Tensor::new(vec![max_inj, 4], inj.to_tensor(max_inj)),
            ],
        )?;
        let c_idx = art
            .output_index("c")
            .ok_or_else(|| anyhow!("{} has no 'c' output", art.name))?;
        let e_idx = art
            .output_index("errcount")
            .ok_or_else(|| anyhow!("{} has no 'errcount' output", art.name))?;
        let errs = out.outputs[e_idx].scalar_sum().round() as u64;
        Ok((take_matrix(out, c_idx)?, errs))
    }
}

struct BlockOutcome {
    c: Matrix,
    detected: u64,
    corrected: u64,
    recomputes: u64,
    launches: u64,
}

/// Move output `idx` out of an [`ExecOutput`] as a Matrix (no data copy).
fn take_matrix(out: crate::runtime::engine::ExecOutput, idx: usize) -> Result<Matrix> {
    let t = out
        .outputs
        .into_iter()
        .nth(idx)
        .ok_or_else(|| anyhow!("output index {idx} out of range"))?;
    if t.shape.len() != 2 {
        bail!("output {idx} is not a matrix: shape {:?}", t.shape);
    }
    let (r, c) = (t.shape[0], t.shape[1]);
    Ok(Matrix::from_vec(r, c, t.data))
}

/// Extract the `(rows, cols)` sub-matrix at `(row0, col0)`, zero-padded to
/// `(pad_rows, pad_cols)`, in a single allocation + row-wise memcpy.
fn extract_padded(
    m: &Matrix,
    row0: usize,
    col0: usize,
    rows: usize,
    cols: usize,
    pad_rows: usize,
    pad_cols: usize,
) -> Matrix {
    debug_assert!(pad_rows >= rows && pad_cols >= cols);
    let mut out = Matrix::zeros(pad_rows, pad_cols);
    for i in 0..rows {
        let src = &m.row(row0 + i)[col0..col0 + cols];
        out.data_mut()[i * pad_cols..i * pad_cols + cols].copy_from_slice(src);
    }
    out
}

/// Translate global injection coordinates into a block's local frame; drop
/// entries outside the block; split GEMMs inject on the first k-partial.
fn localize_injections(inj: &InjectionPlan, block: &BlockPlan) -> InjectionPlan {
    if inj.is_empty() {
        return InjectionPlan::none();
    }
    let mut out = InjectionPlan::none();
    for e in &inj.injections {
        let in_rows = e.row >= block.row0 && e.row < block.row0 + block.m;
        let in_cols = e.col >= block.col0 && e.col < block.col0 + block.n;
        if in_rows && in_cols && block.k0 == 0 {
            out.injections.push(crate::abft::injection::Injection {
                row: e.row - block.row0,
                col: e.col - block.col0,
                step: e.step,
                magnitude: e.magnitude,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_names() {
        assert_eq!(FtPolicy::Online.name(), "online");
        assert_eq!(FtPolicy::Offline.name(), "offline");
        assert_eq!(FtPolicy::None.name(), "none");
    }

    #[test]
    fn extract_padded_pulls_and_pads() {
        let m = Matrix::from_fn(4, 4, |i, j| (i * 4 + j) as f32);
        let s = extract_padded(&m, 1, 2, 2, 2, 3, 4);
        assert_eq!((s.rows(), s.cols()), (3, 4));
        assert_eq!(s.at(0, 0), 6.0);
        assert_eq!(s.at(0, 1), 7.0);
        assert_eq!(s.at(1, 0), 10.0);
        assert_eq!(s.at(1, 1), 11.0);
        // padding region is exact zero
        assert_eq!(s.at(2, 3), 0.0);
        assert_eq!(s.at(0, 2), 0.0);
    }

    #[test]
    fn localize_filters_and_translates() {
        let block = BlockPlan {
            row0: 10,
            col0: 20,
            k0: 0,
            m: 10,
            n: 10,
            k: 64,
            bucket: crate::codegen::select::BUCKETS[0],
        };
        let inj = InjectionPlan {
            injections: vec![
                crate::abft::injection::Injection { row: 15, col: 25, step: 1, magnitude: 9.0 },
                crate::abft::injection::Injection { row: 5, col: 25, step: 0, magnitude: 7.0 },
            ],
        };
        let local = localize_injections(&inj, &block);
        assert_eq!(local.len(), 1);
        assert_eq!(local.injections[0].row, 5);
        assert_eq!(local.injections[0].col, 5);
    }
}
