//! The L3 coordinator: an async, request-centric GEMM-serving engine with
//! pluggable fault tolerance, structured as an explicit submit → plan →
//! schedule → execute pipeline.
//!
//! The serving surface is an owned, self-describing [`GemmRequest`]
//! (operands + [`FtPolicy`] + per-request [`RequestOptions`]) submitted
//! with [`Coordinator::submit`], which returns immediately with a
//! [`Ticket`] — a wait/poll/cancel handle. Submitted requests enter a
//! deadline/priority-aware queue (`submit.rs`) drained by a bounded pool
//! of dispatchers (the admission-control limit on in-flight plans); each
//! dispatched request is **compiled** by [`plan`] into an
//! [`ExecutionPlan`](plan::ExecutionPlan) — block decomposition
//! ([`router`]), per-block artifact + injection resolution, checksum /
//! verify strategy, accumulation targets — and **run** by the
//! [`scheduler`], which spreads independent plan nodes over the engine
//! worker pool. Requests therefore overlap with each other exactly like
//! the blocks of one split request do.
//!
//! Every serving path is a thin client of the same submission API:
//!
//! * [`Coordinator::gemm`] / [`Coordinator::gemm_with_faults`] — blocking
//!   convenience wrappers: `submit(...)` + [`Ticket::wait`];
//! * [`batcher`] — dynamic request batching on top (vLLM-style: group by
//!   bucket so consecutive executions reuse warm executables), feeding the
//!   same queue and handing out the same tickets;
//! * [`ding`] — the non-fused Ding'11 baseline (Figs 12–16), submitted as
//!   a [`GemmRequest::ding`] and planned as an encode node plus a chain of
//!   per-panel step/verify nodes.
//!
//! Protection is one of three [`FtPolicy`]s:
//!
//! * [`FtPolicy::None`] — the plain codegen GEMM (the §3 baseline);
//! * [`FtPolicy::Online`] — the fused FT-GEMM: detection *and* correction
//!   inside the kernel (§4, the paper's contribution);
//! * [`FtPolicy::Offline`] — detect-only kernel + recompute-on-detection
//!   (§5.5's comparison point).

pub mod batcher;
pub mod ding;
pub mod plan;
pub mod request;
pub mod router;
pub mod scheduler;
mod submit;

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::abft::checksum::{self, ChecksumPair, Thresholds};
use crate::abft::injection::InjectionPlan;
use crate::abft::matrix::Matrix;
use crate::metrics::recorder::{CounterSnapshot, Counters, LatencyRecorder, LatencySummary};
use crate::runtime::backend::BackendInfo;
use crate::runtime::engine::Engine;
use crate::runtime::manifest::ArtifactKind;
use crate::runtime::pack_cache::PackCacheStats;

pub use plan::{ExecutionPlan, Planner};
pub use request::{
    FtLevel, GemmRequest, GemmResponse, HostVerify, Priority, RequestMeta, RequestOptions,
    Ticket, TicketStatus,
};
pub use scheduler::{Scheduler, SchedulerConfig};

use request::{Completion, Route};
use submit::Submission;

/// Fault-tolerance policy for a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FtPolicy {
    /// No protection — fastest, silent-corruption-prone.
    None,
    /// Fused online ABFT: in-kernel detect + correct (the paper's scheme).
    Online,
    /// Detect-only kernel; recompute the whole GEMM when a fault fires
    /// (offline ABFT, §5.5).
    Offline,
}

impl FtPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            FtPolicy::None => "none",
            FtPolicy::Online => "online",
            FtPolicy::Offline => "offline",
        }
    }
}

impl std::str::FromStr for FtPolicy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<FtPolicy> {
        match s {
            "none" => Ok(FtPolicy::None),
            "online" => Ok(FtPolicy::Online),
            "offline" => Ok(FtPolicy::Offline),
            other => Err(anyhow::anyhow!("unknown policy {other:?} (none|online|offline)")),
        }
    }
}

/// Coordinator configuration — the **defaults** a [`GemmRequest`] inherits
/// when its [`RequestOptions`] leave a knob unset.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// FT granularity for the online policy. Buckets lowered without that
    /// level fall back to [`FtLevel::Tb`] (always present).
    pub ft_level: FtLevel,
    /// Host-side re-verification of returned results against
    /// operand-derived checksums (defense in depth; O(mk + kn) extra host
    /// work). See [`HostVerify`] for how injected runs are treated.
    pub host_verify: HostVerify,
    /// Max recompute attempts for the offline policy before giving up.
    pub max_recomputes: usize,
    /// Detection thresholds for host-side verification.
    pub thresholds: Thresholds,
    /// Concurrent plan-node dispatch threads; 0 = match the engine worker
    /// count.
    pub scheduler_threads: usize,
    /// Admission-control bound: how many submitted requests may be
    /// dispatched (planning/executing) at once. 0 = twice the engine
    /// worker count (min 2).
    pub max_inflight: usize,
    /// Reject submissions once this many requests are queued awaiting
    /// dispatch on one engine pool (fail fast instead of accumulating
    /// unbounded latency). Bounds each pool's run queue independently.
    /// 0 = unbounded.
    pub max_queue: usize,
    /// Backlog skew (in live queued requests) past which a pool-less
    /// dispatcher steals from the deepest pool's queue — and past which
    /// the shard router re-pins a shape class away from its overloaded
    /// affinity pool. Irrelevant with one pool. 0 is treated as 1.
    pub steal_threshold: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            ft_level: FtLevel::Tb,
            host_verify: HostVerify::Off,
            max_recomputes: 8,
            thresholds: Thresholds::default(),
            scheduler_threads: 0,
            max_inflight: 0,
            max_queue: 0,
            steal_threshold: 4,
        }
    }
}

impl CoordinatorConfig {
    /// This config with a request's option overrides applied — what one
    /// dispatched request actually runs under.
    pub fn effective(&self, opts: &RequestOptions) -> CoordinatorConfig {
        let mut cfg = self.clone();
        if let Some(level) = opts.ft_level {
            cfg.ft_level = level;
        }
        if let Some(th) = opts.thresholds {
            cfg.thresholds = th;
        }
        if let Some(hv) = opts.host_verify {
            cfg.host_verify = hv;
        }
        if let Some(n) = opts.max_recomputes {
            cfg.max_recomputes = n;
        }
        cfg
    }
}

/// Result of one coordinated GEMM.
#[derive(Debug, Clone)]
pub struct GemmResult {
    pub c: Matrix,
    pub errors_detected: u64,
    pub errors_corrected: u64,
    pub recomputes: u64,
    pub kernel_launches: u64,
    /// Plan + execute + verify wall time (excludes queue wait — that is
    /// [`RequestMeta::queued`]).
    pub exec_time: Duration,
    /// Which buckets served the request (one entry per block; empty for
    /// Ding-baseline requests).
    pub buckets: Vec<&'static str>,
}

/// One coherent snapshot of the coordinator's observable state: queue,
/// admission bounds, engine pool, counters, and latency — everything the
/// `metrics` wire verb and `ftgemm info` report, gathered in one place
/// instead of callers poking individual getters.
#[derive(Debug, Clone)]
pub struct CoordinatorStats {
    /// Live requests queued awaiting dispatch.
    pub queue_depth: usize,
    /// Admission-control bound (dispatcher-thread count).
    pub max_inflight: usize,
    /// Plan nodes currently executing on the engine worker pool.
    pub engine_inflight: usize,
    /// Engine worker-pool size.
    pub workers: usize,
    /// The execution backend serving this coordinator.
    pub backend: BackendInfo,
    pub counters: CounterSnapshot,
    /// Execution-latency summary (seconds; excludes queue wait).
    pub latency: LatencySummary,
    /// Per-pool (shard) state, pool order. One entry even with a single
    /// pool, so consumers can iterate unconditionally.
    pub pools: Vec<PoolStats>,
    /// Packed-operand cache counters merged across every pool (`None`
    /// when the cache is disabled on all pools).
    pub pack_cache: Option<PackCacheStats>,
}

/// One engine pool's observable state inside [`CoordinatorStats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolStats {
    /// Live requests queued on this pool awaiting dispatch.
    pub queue_depth: usize,
    /// Plan nodes currently queued/executing on this pool's workers.
    pub engine_inflight: usize,
    /// Cumulative requests the shard router placed on this pool.
    pub routed: u64,
    /// Cumulative requests that started executing on this pool.
    pub dispatched: u64,
    /// Of `dispatched`, how many were stolen from another pool's queue.
    pub steals: u64,
    /// Of `routed`, how many landed on the pool their shape class (or
    /// hot operand) was already pinned to — the warm-cache affinity
    /// hit-rate numerator (`affinity_hits / routed`).
    pub affinity_hits: u64,
    /// Total queue wait (µs) of the stolen requests, measured
    /// submission → theft; `steal_wait_us / steals` is the mean
    /// steal latency the `metrics` verb reports.
    pub steal_wait_us: u64,
    /// This pool's packed-operand cache counters (`None` = cache
    /// disabled via `pack_cache_mb = 0`).
    pub pack_cache: Option<PackCacheStats>,
}

impl CoordinatorStats {
    /// Serialize for the gateway's `metrics` verb (stable keys; one
    /// nesting level per component).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut o = Json::obj();
        o.set("queue_depth", Json::from(self.queue_depth));
        o.set("max_inflight", Json::from(self.max_inflight));
        o.set("engine_inflight", Json::from(self.engine_inflight));
        o.set("workers", Json::from(self.workers));
        let mut b = Json::obj();
        b.set("name", Json::from(self.backend.name));
        b.set("kernel_isa", Json::from(self.backend.kernel_isa));
        b.set("fused_ft", Json::from(self.backend.fused_ft));
        o.set("backend", b);
        let c = &self.counters;
        let mut co = Json::obj();
        for (key, v) in [
            ("requests", c.requests),
            ("executions", c.executions),
            ("errors_detected", c.errors_detected),
            ("errors_corrected", c.errors_corrected),
            ("recomputes", c.recomputes),
            ("padded_requests", c.padded_requests),
            ("batched_groups", c.batched_groups),
            ("canceled", c.canceled),
            ("expired", c.expired),
        ] {
            co.set(key, Json::Num(v as f64));
        }
        o.set("counters", co);
        let l = &self.latency;
        let mut lo = Json::obj();
        lo.set("count", Json::Num(l.count as f64));
        lo.set("mean_s", Json::Num(l.mean));
        lo.set("min_s", Json::Num(l.min));
        lo.set("max_s", Json::Num(l.max));
        lo.set("p50_s", Json::Num(l.p50));
        lo.set("p99_s", Json::Num(l.p99));
        o.set("latency", lo);
        let mut pools = Json::Arr(Vec::new());
        for p in &self.pools {
            let mut po = Json::obj();
            po.set("queue_depth", Json::from(p.queue_depth));
            po.set("engine_inflight", Json::from(p.engine_inflight));
            po.set("routed", Json::Num(p.routed as f64));
            po.set("dispatched", Json::Num(p.dispatched as f64));
            po.set("steals", Json::Num(p.steals as f64));
            po.set("affinity_hits", Json::Num(p.affinity_hits as f64));
            po.set("steal_wait_us", Json::Num(p.steal_wait_us as f64));
            if let Some(pc) = &p.pack_cache {
                po.set("pack_cache", pack_cache_json(pc));
            }
            pools.push(po);
        }
        o.set("pools", pools);
        if let Some(pc) = &self.pack_cache {
            o.set("pack_cache", pack_cache_json(pc));
        }
        o
    }
}

fn pack_cache_json(s: &PackCacheStats) -> crate::util::json::Json {
    use crate::util::json::Json;
    let mut o = Json::obj();
    o.set("hits", Json::Num(s.hits as f64));
    o.set("misses", Json::Num(s.misses as f64));
    o.set("evictions", Json::Num(s.evictions as f64));
    o.set("bytes", Json::Num(s.bytes as f64));
    o.set("entries", Json::Num(s.entries as f64));
    o
}

/// Shared execution state: everything a dispatcher needs to run one
/// request end to end.
pub(crate) struct Core {
    pub(crate) engine: Engine,
    pub(crate) config: CoordinatorConfig,
    pub(crate) scheduler: Scheduler,
    pub(crate) counters: Counters,
    pub(crate) latency: LatencyRecorder,
}

impl Core {
    /// Plan, schedule, and (optionally) host-verify one request. Runs on a
    /// dispatcher thread. `pool` pins single-node plans to that engine
    /// shard (the dispatcher's home pool); multi-node plans span every
    /// pool regardless.
    pub(crate) fn execute(&self, req: &GemmRequest, pool: Option<usize>) -> Result<GemmResult> {
        let t0 = Instant::now();
        let cfg = self.config.effective(&req.opts);
        let plan = match &req.route {
            Route::Blocks => Planner::new(self.engine.manifest(), &cfg)
                .for_backend(self.engine.backend())
                .plan_gemm(req.a.rows(), req.b.cols(), req.a.cols(), req.policy, &req.inj)?,
            Route::Ding { bucket } => plan::plan_ding(self.engine.manifest(), bucket, &req.inj)?,
        };
        if plan.split {
            Counters::bump(&self.counters.batched_groups);
        }
        if plan.is_padded() {
            Counters::bump(&self.counters.padded_requests);
        }

        let out = self.scheduler.run_keyed_on(
            &plan,
            Arc::clone(&req.a),
            Arc::clone(&req.b),
            pool,
            (req.key_a, req.key_b),
        )?;

        let reverify = match cfg.host_verify {
            HostVerify::Off => false,
            // An injected-and-corrected result carries an O(eps·magnitude)
            // correction residue that can trip the thresholds even though
            // the result is good, so CleanOnly skips injected runs —
            // explicitly, per HostVerify's contract.
            HostVerify::CleanOnly => req.inj.is_empty(),
            HostVerify::Always => true,
        };
        if reverify {
            let pair = ChecksumPair::of_product(&req.a, &req.b);
            if checksum::verify(&out.c, &pair, cfg.thresholds) != checksum::Detection::Clean {
                bail!("host re-verification failed on a supposedly clean result");
            }
        }

        let exec_time = t0.elapsed();
        self.latency.record(exec_time);
        Counters::add(&self.counters.executions, out.launches);
        Counters::add(&self.counters.errors_detected, out.detected);
        Counters::add(&self.counters.errors_corrected, out.corrected);
        Counters::add(&self.counters.recomputes, out.recomputes);
        Ok(GemmResult {
            c: out.c,
            errors_detected: out.detected,
            errors_corrected: out.corrected,
            recomputes: out.recomputes,
            kernel_launches: out.launches,
            exec_time,
            buckets: plan.block_buckets(),
        })
    }
}

/// The serving coordinator. Cheap to clone (`Arc` internals); thread-safe.
/// The last clone to drop shuts the dispatcher pool down, failing any
/// still-queued tickets.
#[derive(Clone)]
pub struct Coordinator {
    core: Arc<Core>,
    submission: Arc<Submission>,
}

impl Coordinator {
    pub fn new(engine: Engine, config: CoordinatorConfig) -> Self {
        let scheduler = Scheduler::new(
            engine.clone(),
            SchedulerConfig { threads: config.scheduler_threads },
        );
        let dispatchers = match config.max_inflight {
            0 => (engine.worker_count() * 2).max(2),
            n => n,
        };
        let max_queue = config.max_queue;
        let steal_threshold = config.steal_threshold;
        let core = Arc::new(Core {
            engine,
            config,
            scheduler,
            counters: Counters::new(),
            latency: LatencyRecorder::new(),
        });
        let submission = Arc::new(Submission::start(
            Arc::clone(&core),
            dispatchers,
            max_queue,
            steal_threshold,
        ));
        Coordinator { core, submission }
    }

    pub fn engine(&self) -> &Engine {
        &self.core.engine
    }

    pub fn config(&self) -> &CoordinatorConfig {
        &self.core.config
    }

    pub fn scheduler(&self) -> &Scheduler {
        &self.core.scheduler
    }

    pub fn counters(&self) -> &Counters {
        &self.core.counters
    }

    pub fn latency(&self) -> &LatencyRecorder {
        &self.core.latency
    }

    /// The admission-control bound: dispatcher threads executing
    /// submitted requests concurrently.
    pub fn max_inflight(&self) -> usize {
        self.submission.dispatchers()
    }

    /// Requests queued but not yet dispatched.
    pub fn queue_depth(&self) -> usize {
        self.submission.queue_depth()
    }

    /// One coherent snapshot of queue/engine/counter/latency state — the
    /// single source for the gateway's `metrics` verb and `ftgemm info`.
    pub fn stats(&self) -> CoordinatorStats {
        let engine_per_pool = self.core.engine.inflight_per_pool();
        let cache_per_pool = self.core.engine.pack_cache_stats_per_pool();
        let pools = self
            .submission
            .pool_snapshots()
            .into_iter()
            .enumerate()
            .map(|(p, s)| PoolStats {
                queue_depth: s.queue_depth,
                engine_inflight: engine_per_pool.get(p).copied().unwrap_or(0),
                routed: s.routed,
                dispatched: s.dispatched,
                steals: s.steals,
                affinity_hits: s.affinity_hits,
                steal_wait_us: s.steal_wait_us,
                pack_cache: cache_per_pool.get(p).copied().flatten(),
            })
            .collect();
        CoordinatorStats {
            queue_depth: self.queue_depth(),
            max_inflight: self.max_inflight(),
            engine_inflight: self.core.engine.inflight(),
            workers: self.core.engine.worker_count(),
            backend: self.core.engine.backend(),
            counters: self.core.counters.snapshot(),
            latency: self.core.latency.summary(),
            pools,
            pack_cache: self.core.engine.pack_cache_stats(),
        }
    }

    /// Compile a request into its execution plan without running it
    /// (introspection / dry-run). Uses the coordinator's default options
    /// and the engine backend's capabilities.
    pub fn plan(
        &self,
        m: usize,
        n: usize,
        k: usize,
        policy: FtPolicy,
        inj: &InjectionPlan,
    ) -> Result<ExecutionPlan> {
        Planner::new(self.core.engine.manifest(), &self.core.config)
            .for_backend(self.core.engine.backend())
            .plan_gemm(m, n, k, policy, inj)
    }

    /// Submit an owned [`GemmRequest`]; returns immediately with the
    /// [`Ticket`] to wait/poll/cancel on. Shape validation happens here
    /// (fail fast); everything else — planning, artifact resolution,
    /// execution, verification — happens on a dispatcher and settles the
    /// ticket.
    pub fn submit(&self, mut req: GemmRequest) -> Result<Ticket> {
        self.validate(&req)?;
        self.derive_operand_ids(&mut req);
        self.submission.submit(req)
    }

    /// Enqueue a request against a ticket that was handed out earlier
    /// (the batcher path). `submitted` is when that ticket was minted —
    /// deadlines and queue-time metadata count from it, so time spent in
    /// the batcher's round is not forgiven. On rejection the completion
    /// is settled with the same error that is returned.
    pub(crate) fn submit_prepared(
        &self,
        mut req: GemmRequest,
        completion: Completion,
        submitted: Instant,
    ) -> Result<()> {
        if let Err(e) = self.validate(&req) {
            completion.abort(TicketStatus::Failed, anyhow::anyhow!("{e:#}"));
            return Err(e);
        }
        self.derive_operand_ids(&mut req);
        self.submission.push(req, completion, submitted)
    }

    /// Stamp ABA-safe pointer-identity operand ids on a request that
    /// arrived without wire-level (seed) keys, so repeat submissions of
    /// the same `Arc<Matrix>` operands hit the packed-operand cache.
    /// No-op when every pool's cache is disabled — unkeyed tensors
    /// bypass cache lookups entirely.
    fn derive_operand_ids(&self, req: &mut GemmRequest) {
        if !self.core.engine.pack_cache_enabled() {
            return;
        }
        if req.key_a.is_none() {
            req.key_a = Some(request::ptr_operand_id(&req.a));
        }
        if req.key_b.is_none() {
            req.key_b = Some(request::ptr_operand_id(&req.b));
        }
    }

    /// Mint a (ticket, completion) pair without enqueueing anything yet.
    pub(crate) fn new_ticket(&self) -> (Ticket, Completion) {
        self.submission.new_ticket()
    }

    fn validate(&self, req: &GemmRequest) -> Result<()> {
        match &req.route {
            Route::Blocks => {
                if req.a.cols() != req.b.rows() {
                    bail!(
                        "inner dimensions disagree: {}x{} @ {}x{}",
                        req.a.rows(),
                        req.a.cols(),
                        req.b.rows(),
                        req.b.cols()
                    );
                }
            }
            Route::Ding { bucket } => {
                // Ding plans are bucket-fixed-shape; fail fast with the
                // geometry instead of an opaque backend shape error from
                // deep inside the encode node.
                let enc = self
                    .core
                    .engine
                    .manifest()
                    .find(ArtifactKind::DingEncode, bucket, None)
                    .ok_or_else(|| anyhow::anyhow!("no ding_encode artifact for {bucket}"))?;
                let ok = req.a.rows() == enc.m
                    && req.a.cols() == enc.k
                    && req.b.rows() == enc.k
                    && req.b.cols() == enc.n;
                if !ok {
                    bail!(
                        "ding request for {bucket} is fixed-shape {}x{}x{}; got {}x{} @ {}x{}",
                        enc.m,
                        enc.n,
                        enc.k,
                        req.a.rows(),
                        req.a.cols(),
                        req.b.rows(),
                        req.b.cols()
                    );
                }
            }
        }
        Ok(())
    }

    /// C = A·B under `policy`, fault-free. Blocking convenience wrapper:
    /// `submit(...)` + [`Ticket::wait`].
    pub fn gemm(&self, a: &Matrix, b: &Matrix, policy: FtPolicy) -> Result<GemmResult> {
        self.gemm_with_faults(a, b, policy, &InjectionPlan::none())
    }

    /// C = A·B under `policy` with SEU injection (§5.3 protocol).
    /// Blocking convenience wrapper over [`Coordinator::submit`].
    ///
    /// Injection coordinates are global output positions; `step` indexes
    /// the serving bucket's k-loop (clamped kernel-side). For split
    /// (oversize) GEMMs, each injection lands in the block containing its
    /// (row, col) at the first k-partial.
    ///
    /// Note on defense in depth: under [`HostVerify::CleanOnly`] (the mode
    /// the boolean config key maps to), an injected request is **not**
    /// host-re-verified — the in-kernel correction leaves a residue that
    /// host thresholds may flag on a good result. Opt into
    /// [`HostVerify::Always`] (config or [`RequestOptions`]) to re-verify
    /// injected runs too.
    pub fn gemm_with_faults(
        &self,
        a: &Matrix,
        b: &Matrix,
        policy: FtPolicy,
        inj: &InjectionPlan,
    ) -> Result<GemmResult> {
        let req = GemmRequest::new(a.clone(), b.clone()).policy(policy).inject(inj.clone());
        Ok(self.submit(req)?.wait()?.result)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_names() {
        assert_eq!(FtPolicy::Online.name(), "online");
        assert_eq!(FtPolicy::Offline.name(), "offline");
        assert_eq!(FtPolicy::None.name(), "none");
    }

    #[test]
    fn config_default_autosizes_scheduler_and_pool() {
        let cfg = CoordinatorConfig::default();
        assert_eq!(cfg.scheduler_threads, 0);
        assert_eq!(cfg.ft_level, FtLevel::Tb);
        assert_eq!(cfg.host_verify, HostVerify::Off);
        assert_eq!(cfg.max_inflight, 0);
        assert_eq!(cfg.max_queue, 0);
        assert_eq!(cfg.steal_threshold, 4);
    }

    #[test]
    fn effective_config_applies_request_overrides() {
        let base = CoordinatorConfig::default();
        let opts = RequestOptions {
            ft_level: Some(FtLevel::Warp),
            max_recomputes: Some(2),
            host_verify: Some(HostVerify::Always),
            thresholds: Some(Thresholds { rel: 0.5, abs: 0.25 }),
            ..Default::default()
        };
        let eff = base.effective(&opts);
        assert_eq!(eff.ft_level, FtLevel::Warp);
        assert_eq!(eff.max_recomputes, 2);
        assert_eq!(eff.host_verify, HostVerify::Always);
        assert!((eff.thresholds.rel - 0.5).abs() < 1e-9);
        // unset fields keep the coordinator defaults
        let eff = base.effective(&RequestOptions::default());
        assert_eq!(eff.ft_level, FtLevel::Tb);
        assert_eq!(eff.max_recomputes, 8);
    }
}
