//! The L3 coordinator: a GEMM-serving engine with pluggable fault
//! tolerance, structured as an explicit plan → schedule → execute pipeline.
//!
//! This is the serving-side reproduction of the paper's system: a request
//! of arbitrary shape is **compiled** by the [`plan`] module into an
//! [`ExecutionPlan`](plan::ExecutionPlan) — block decomposition
//! ([`router`]), per-block artifact + injection resolution, checksum/verify
//! strategy, accumulation targets — and then **run** by the [`scheduler`],
//! which dispatches independent plan nodes concurrently over the engine
//! worker pool and folds partials into the output as they complete. Every
//! serving path is a thin client of those two types:
//!
//! * [`Coordinator::gemm`] / [`Coordinator::gemm_with_faults`] — one
//!   request, one plan;
//! * [`batcher`] — dynamic request batching on top (vLLM-style: group by
//!   bucket so consecutive executions reuse warm executables);
//! * [`ding`] — the non-fused Ding'11 baseline (Figs 12–16), planned as an
//!   encode node plus a chain of per-panel step/verify nodes.
//!
//! Protection is one of three [`FtPolicy`]s:
//!
//! * [`FtPolicy::None`] — the plain codegen GEMM (the §3 baseline);
//! * [`FtPolicy::Online`] — the fused FT-GEMM: detection *and* correction
//!   inside the kernel (§4, the paper's contribution);
//! * [`FtPolicy::Offline`] — detect-only kernel + recompute-on-detection
//!   (§5.5's comparison point).

pub mod batcher;
pub mod ding;
pub mod plan;
pub mod router;
pub mod scheduler;

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::abft::checksum::{self, ChecksumPair, Thresholds};
use crate::abft::injection::InjectionPlan;
use crate::abft::matrix::Matrix;
use crate::metrics::recorder::{Counters, LatencyRecorder};
use crate::runtime::engine::Engine;

pub use plan::{ExecutionPlan, Planner};
pub use scheduler::{Scheduler, SchedulerConfig};

/// Fault-tolerance policy for a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FtPolicy {
    /// No protection — fastest, silent-corruption-prone.
    None,
    /// Fused online ABFT: in-kernel detect + correct (the paper's scheme).
    Online,
    /// Detect-only kernel; recompute the whole GEMM when a fault fires
    /// (offline ABFT, §5.5).
    Offline,
}

impl FtPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            FtPolicy::None => "none",
            FtPolicy::Online => "online",
            FtPolicy::Offline => "offline",
        }
    }
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// FT granularity for the online policy: "tb" | "warp" | "thread".
    /// Buckets without that level fall back to "tb" (always present).
    pub ft_level: String,
    /// Re-verify returned C against operand-derived checksums on the host
    /// (defense in depth; O(mk + kn) extra host work).
    pub host_verify: bool,
    /// Max recompute attempts for the offline policy before giving up.
    pub max_recomputes: usize,
    /// Detection thresholds for host-side verification.
    pub thresholds: Thresholds,
    /// Concurrent plan-node dispatch threads; 0 = match the engine worker
    /// count.
    pub scheduler_threads: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            ft_level: "tb".into(),
            host_verify: false,
            max_recomputes: 8,
            thresholds: Thresholds::default(),
            scheduler_threads: 0,
        }
    }
}

/// Result of one coordinated GEMM.
#[derive(Debug, Clone)]
pub struct GemmResult {
    pub c: Matrix,
    pub errors_detected: u64,
    pub errors_corrected: u64,
    pub recomputes: u64,
    pub kernel_launches: u64,
    pub exec_time: Duration,
    /// Which buckets served the request (one entry per block).
    pub buckets: Vec<&'static str>,
}

/// The serving coordinator. Cheap to clone (`Arc` internals); thread-safe.
#[derive(Clone)]
pub struct Coordinator {
    engine: Engine,
    config: CoordinatorConfig,
    scheduler: Arc<Scheduler>,
    counters: Arc<Counters>,
    latency: Arc<LatencyRecorder>,
}

impl Coordinator {
    pub fn new(engine: Engine, config: CoordinatorConfig) -> Self {
        let scheduler = Arc::new(Scheduler::new(
            engine.clone(),
            SchedulerConfig { threads: config.scheduler_threads },
        ));
        Coordinator {
            engine,
            config,
            scheduler,
            counters: Arc::new(Counters::new()),
            latency: Arc::new(LatencyRecorder::new()),
        }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn config(&self) -> &CoordinatorConfig {
        &self.config
    }

    pub fn scheduler(&self) -> &Scheduler {
        &self.scheduler
    }

    pub fn counters(&self) -> &Counters {
        &self.counters
    }

    pub fn latency(&self) -> &LatencyRecorder {
        &self.latency
    }

    /// Compile a request into its execution plan without running it
    /// (introspection / dry-run).
    pub fn plan(
        &self,
        m: usize,
        n: usize,
        k: usize,
        policy: FtPolicy,
        inj: &InjectionPlan,
    ) -> Result<ExecutionPlan> {
        Planner::new(self.engine.manifest(), &self.config).plan_gemm(m, n, k, policy, inj)
    }

    /// C = A·B under `policy`, fault-free.
    pub fn gemm(&self, a: &Matrix, b: &Matrix, policy: FtPolicy) -> Result<GemmResult> {
        self.gemm_with_faults(a, b, policy, &InjectionPlan::none())
    }

    /// C = A·B under `policy` with SEU injection (§5.3 protocol).
    ///
    /// Injection coordinates are global output positions; `step` indexes
    /// the serving bucket's k-loop (clamped kernel-side). For split
    /// (oversize) GEMMs, each injection lands in the block containing its
    /// (row, col) at the first k-partial.
    pub fn gemm_with_faults(
        &self,
        a: &Matrix,
        b: &Matrix,
        policy: FtPolicy,
        inj: &InjectionPlan,
    ) -> Result<GemmResult> {
        if a.cols() != b.rows() {
            bail!(
                "inner dimensions disagree: {}x{} @ {}x{}",
                a.rows(),
                a.cols(),
                b.rows(),
                b.cols()
            );
        }
        Counters::bump(&self.counters.requests);
        let t0 = Instant::now();

        let plan = self.plan(a.rows(), b.cols(), a.cols(), policy, inj)?;
        if plan.split {
            Counters::bump(&self.counters.batched_groups);
        }
        if plan.is_padded() {
            Counters::bump(&self.counters.padded_requests);
        }

        let out = self.scheduler.run(&plan, a, b)?;

        if self.config.host_verify && inj.is_empty() {
            // Defense in depth: O(mk + kn) re-derivation of the product
            // checksums from the operands, compared against C.
            let pair = ChecksumPair::of_product(a, b);
            if checksum::verify(&out.c, &pair, self.config.thresholds)
                != checksum::Detection::Clean
            {
                bail!("host re-verification failed on a supposedly clean result");
            }
        }

        let exec_time = t0.elapsed();
        self.latency.record(exec_time);
        Counters::add(&self.counters.executions, out.launches);
        Counters::add(&self.counters.errors_detected, out.detected);
        Counters::add(&self.counters.errors_corrected, out.corrected);
        Counters::add(&self.counters.recomputes, out.recomputes);
        Ok(GemmResult {
            c: out.c,
            errors_detected: out.detected,
            errors_corrected: out.corrected,
            recomputes: out.recomputes,
            kernel_launches: out.launches,
            exec_time,
            buckets: plan.block_buckets(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_names() {
        assert_eq!(FtPolicy::Online.name(), "online");
        assert_eq!(FtPolicy::Offline.name(), "offline");
        assert_eq!(FtPolicy::None.name(), "none");
    }

    #[test]
    fn config_default_autosizes_scheduler() {
        let cfg = CoordinatorConfig::default();
        assert_eq!(cfg.scheduler_threads, 0);
        assert_eq!(cfg.ft_level, "tb");
    }
}
