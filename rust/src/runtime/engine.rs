//! The execution engine: a pool of worker threads, each owning its own
//! kernel backend and compiled-executable cache.
//!
//! Kernel clients (PJRT handles in particular) are `Rc`-based and must not
//! cross threads, so each worker thread owns one [`Backend`] instance plus
//! its cache, and serves requests from an mpsc queue (the vLLM engine-loop
//! pattern, generalized from one thread to N). The cloneable [`Engine`]
//! handle is `Send`, so the coordinator's scheduler, the fault drivers and
//! the bench harness all submit work concurrently; responses return
//! through per-request oneshot channels.
//!
//! **Dispatch is warm-affine**: a request for an artifact prefers an idle
//! worker that has already compiled it (the warm executable stays warm);
//! if every warm worker is busy it spills to an idle cold worker — which
//! pays one compile and is warm from then on, so a burst of same-bucket
//! blocks floods the whole pool. Compilation happens once per (artifact,
//! worker) and is cached thereafter.
//!
//! **The engine is sharded into pools** (`EngineConfig::pools`): each pool
//! owns a disjoint worker set — and therefore a disjoint warm-executable
//! cache — with its own inflight counter. [`Engine::submit_on`] pins a
//! request to one pool (the coordinator's shard router uses this to keep a
//! shape class's executables warm on one pool), while plain
//! [`Engine::submit`] picks warm-affine across *all* pools, which is how
//! the blocks of one huge split GEMM span every shard. Backend factories
//! see the full `workers × pools` geometry via
//! [`BackendCtx`](super::backend::BackendCtx) so per-instance core
//! division stays oversubscription-free.

use std::collections::HashSet;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::pool::oneshot;

use super::backend::{Backend, BackendCtx, BackendInfo, BackendRegistry};
use super::manifest::Manifest;
use super::pack_cache::{OperandKey, PackCache, PackCacheStats};

/// A host tensor: row-major f32 with an explicit shape. The engine's only
/// data currency (all artifacts are pure-f32 by construction).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
    /// Content address of the operand this tensor is a (window of a)
    /// copy of, when the submitter knows one. Purely advisory: backends
    /// with a pack cache use it to share packed panels + fused
    /// checksums across requests; `None` (the default) opts out.
    pub key: Option<OperandKey>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data, key: None }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n], key: None }
    }

    /// Attach a pack-cache content address (see [`Tensor::key`]).
    pub fn with_key(mut self, key: Option<OperandKey>) -> Self {
        self.key = key;
        self
    }

    pub fn scalar_sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }
}

/// One execution request: artifact name + input tensors.
#[derive(Debug, Clone)]
pub struct ExecRequest {
    pub artifact: String,
    pub inputs: Vec<Tensor>,
}

/// Execution result: output tensors (manifest order) + timings.
#[derive(Debug, Clone)]
pub struct ExecOutput {
    pub outputs: Vec<Tensor>,
    /// Pure backend-execution time (excludes queueing).
    pub exec_time: Duration,
    /// Set on the first call that had to compile the artifact on the
    /// serving worker.
    pub compile_time: Option<Duration>,
}

enum Msg {
    Exec(ExecRequest, oneshot::OneSender<Result<ExecOutput>>),
    /// Pre-compile an artifact (warm-up), reply when done.
    Warm(String, oneshot::OneSender<Result<Duration>>),
    Stats(oneshot::OneSender<EngineStats>),
    Shutdown,
}

/// Engine configuration.
#[derive(Debug, Clone, Default)]
pub struct EngineConfig {
    /// Artifacts directory; `None` = discover (`FTGEMM_ARTIFACTS`,
    /// ./artifacts, ..) and fall back to the built-in manifest.
    pub artifacts_dir: Option<std::path::PathBuf>,
    /// Artifact names to compile eagerly at startup on every worker
    /// (empty = lazy).
    pub precompile: Vec<String>,
    /// Worker threads **per pool**, each with its own backend + executable
    /// cache. 0 is treated as 1.
    pub workers: usize,
    /// Which kernel backend the workers run, by [`BackendRegistry`] name
    /// (`"reference"` | `"blocked"`); empty = the registry default.
    pub backend: String,
    /// Engine pools (shards), each with its own worker set, warm-affine
    /// executable cache, and inflight counter. 0 is treated as 1. Total
    /// worker threads = `workers * pools`.
    pub pools: usize,
    /// Byte budget (in MiB) of the per-pool packed-operand & checksum
    /// cache (each shard gets its own, next to its warm-executable
    /// cache). `None` = the built-in default
    /// ([`DEFAULT_PACK_CACHE_MB`]); `Some(0)` disables caching entirely
    /// and restores pack-per-request behavior.
    pub pack_cache_mb: Option<usize>,
}

/// Default per-pool pack-cache budget when the config leaves it unset.
pub const DEFAULT_PACK_CACHE_MB: usize = 256;

impl EngineConfig {
    /// The resolved per-pool pack-cache budget in MiB (0 = disabled).
    pub fn pack_cache_budget_mb(&self) -> usize {
        self.pack_cache_mb.unwrap_or(DEFAULT_PACK_CACHE_MB)
    }
}

/// Cumulative engine-side statistics (per worker; [`Engine::stats`]
/// aggregates).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EngineStats {
    pub executions: u64,
    pub compiles: u64,
    pub total_exec_secs: f64,
    pub total_compile_secs: f64,
}

impl EngineStats {
    fn merge(&mut self, other: &EngineStats) {
        self.executions += other.executions;
        self.compiles += other.compiles;
        self.total_exec_secs += other.total_exec_secs;
        self.total_compile_secs += other.total_compile_secs;
    }
}

/// A submitted request; `wait` blocks for the result.
pub struct Pending {
    rx: oneshot::OneReceiver<Result<ExecOutput>>,
}

impl Pending {
    pub fn wait(self) -> Result<ExecOutput> {
        self.rx.recv().map_err(|_| anyhow!("engine dropped request"))?
    }

    /// Non-blocking completion probe: `None` while the request is still
    /// in flight. The result is handed out exactly once — after this
    /// returns `Some`, the handle is spent and `wait` would error.
    pub fn try_wait(&self) -> Option<Result<ExecOutput>> {
        match self.rx.try_recv() {
            Ok(Some(r)) => Some(r),
            Ok(None) => None,
            Err(_) => Some(Err(anyhow!("engine dropped request"))),
        }
    }
}

struct Worker {
    tx: Sender<Msg>,
    /// Queued + running requests on this worker (dispatch load signal).
    inflight: Arc<AtomicUsize>,
    /// Artifacts (optimistically) resident in this worker's cache.
    warmed: Mutex<HashSet<String>>,
    handle: Mutex<Option<JoinHandle<()>>>,
}

/// One engine shard: a disjoint worker set with its own warm caches and
/// load counter.
struct Pool {
    workers: Vec<Worker>,
    /// Queued + running requests on this pool (shard-level load signal).
    inflight: Arc<AtomicUsize>,
    /// This shard's packed-operand & checksum cache (`None` when
    /// disabled). Shared by the pool's workers; disjoint across pools,
    /// so affinity routing concentrates a shape class's panels here.
    pack_cache: Option<Arc<PackCache>>,
}

struct Shared {
    manifest: Arc<Manifest>,
    backend: BackendInfo,
    pools: Vec<Pool>,
    inflight_total: Arc<AtomicUsize>,
    peak_inflight: Arc<AtomicUsize>,
}

impl Shared {
    fn all_workers(&self) -> impl Iterator<Item = &Worker> {
        self.pools.iter().flat_map(|p| p.workers.iter())
    }

    fn worker_count(&self) -> usize {
        self.pools.iter().map(|p| p.workers.len()).sum()
    }
}

impl Drop for Shared {
    fn drop(&mut self) {
        for w in self.pools.iter().flat_map(|p| p.workers.iter()) {
            let _ = w.tx.send(Msg::Shutdown);
        }
        for w in self.pools.iter().flat_map(|p| p.workers.iter()) {
            if let Some(h) = w.handle.lock().unwrap().take() {
                let _ = h.join();
            }
        }
    }
}

/// Cloneable, `Send` handle to the engine worker pool.
#[derive(Clone)]
pub struct Engine {
    shared: Arc<Shared>,
}

impl Engine {
    /// Start the engine: load (or synthesize) the manifest and spin up the
    /// worker pool. The backend name resolves against
    /// [`BackendRegistry::global`]; embedders with custom backends use
    /// [`Engine::start_with`].
    pub fn start(config: EngineConfig) -> Result<Engine> {
        Engine::start_with(config, BackendRegistry::global())
    }

    /// [`Engine::start`] against a caller-provided [`BackendRegistry`] —
    /// the embedding point for custom backends (built with
    /// [`BackendRegistry::empty`] + [`BackendRegistry::register`]).
    pub fn start_with(config: EngineConfig, registry: &BackendRegistry) -> Result<Engine> {
        let manifest = match &config.artifacts_dir {
            Some(d) => Manifest::load(d)?,
            None => match Manifest::discover_path() {
                Some(d) => Manifest::load(d)?,
                None => Manifest::builtin(),
            },
        };
        let manifest = Arc::new(manifest);
        // Resolve the backend selection against the registry up front —
        // an unknown name fails here, not inside a worker thread.
        let (backend_info, factory) = registry.resolve(&config.backend)?;
        let n = config.workers.max(1);
        let pools_n = config.pools.max(1);
        let inflight_total = Arc::new(AtomicUsize::new(0));
        let peak_inflight = Arc::new(AtomicUsize::new(0));

        let pack_cache_mb = config.pack_cache_budget_mb();
        let mut pools = Vec::with_capacity(pools_n);
        for p in 0..pools_n {
            let pool_inflight = Arc::new(AtomicUsize::new(0));
            // Per-shard cache: workers of one pool share it, pools stay
            // disjoint (mirrors the warm-executable cache geometry).
            let pack_cache = PackCache::from_config_mb(pack_cache_mb);
            let mut workers = Vec::with_capacity(n);
            for i in 0..n {
                let (tx, rx) = channel::<Msg>();
                let inflight = Arc::new(AtomicUsize::new(0));
                let (ready_tx, ready_rx) = oneshot::channel::<Result<()>>();
                let thread_manifest = Arc::clone(&manifest);
                let thread_inflight = Arc::clone(&inflight);
                let thread_pool = Arc::clone(&pool_inflight);
                let thread_total = Arc::clone(&inflight_total);
                let thread_factory = Arc::clone(&factory);
                let thread_cache = pack_cache.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("ftgemm-eng-{p}.{i}"))
                    .spawn(move || {
                        // Backends may hold thread-confined (Rc-based) client
                        // state, so construction happens here, in-thread, from
                        // the Send + Sync registry factory.
                        let ctx = BackendCtx {
                            workers: n,
                            pools: pools_n,
                            pack_cache: thread_cache,
                        };
                        let mut worker =
                            EngineWorker::new(thread_manifest, (*thread_factory)(&ctx));
                        let _ = ready_tx.send(Ok(()));
                        while let Ok(msg) = rx.recv() {
                            match msg {
                                Msg::Exec(req, reply) => {
                                    // A panicking backend fails the one request
                                    // instead of killing the worker thread (and
                                    // silently shrinking the pool).
                                    let artifact = req.artifact.clone();
                                    let out =
                                        catch_unwind(AssertUnwindSafe(|| worker.execute(req)))
                                            .unwrap_or_else(|_| {
                                                Err(anyhow!(
                                                    "backend panicked executing {artifact}"
                                                ))
                                            });
                                    thread_inflight.fetch_sub(1, Ordering::SeqCst);
                                    thread_pool.fetch_sub(1, Ordering::SeqCst);
                                    thread_total.fetch_sub(1, Ordering::SeqCst);
                                    let _ = reply.send(out);
                                }
                                Msg::Warm(name, reply) => {
                                    // same containment as Exec: a panicking
                                    // compile() must not kill the worker
                                    let out =
                                        catch_unwind(AssertUnwindSafe(|| worker.warm(&name)))
                                            .unwrap_or_else(|_| {
                                                Err(anyhow!(
                                                    "backend panicked compiling {name}"
                                                ))
                                            });
                                    let _ = reply.send(out);
                                }
                                Msg::Stats(reply) => {
                                    let _ = reply.send(worker.stats);
                                }
                                Msg::Shutdown => break,
                            }
                        }
                    })
                    .context("spawn engine worker thread")?;
                ready_rx
                    .recv()
                    .map_err(|_| anyhow!("engine worker {p}.{i} died during startup"))??;
                workers.push(Worker {
                    tx,
                    inflight,
                    warmed: Mutex::new(HashSet::new()),
                    handle: Mutex::new(Some(handle)),
                });
            }
            pools.push(Pool { workers, inflight: pool_inflight, pack_cache });
        }

        let engine = Engine {
            shared: Arc::new(Shared {
                manifest,
                backend: backend_info,
                pools,
                inflight_total,
                peak_inflight,
            }),
        };
        for name in &config.precompile {
            engine.warm(name)?;
        }
        Ok(engine)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.shared.manifest
    }

    /// Metadata of the backend every worker in this pool runs (resolved
    /// from the [`BackendRegistry`] at startup). The planner keys
    /// capability decisions on this — see `coordinator::plan`.
    pub fn backend(&self) -> BackendInfo {
        self.shared.backend
    }

    /// Total number of worker threads across all pools.
    pub fn worker_count(&self) -> usize {
        self.shared.worker_count()
    }

    /// Number of engine pools (shards).
    pub fn pool_count(&self) -> usize {
        self.shared.pools.len()
    }

    /// Worker threads per pool (every pool has the same width).
    pub fn workers_per_pool(&self) -> usize {
        self.shared.pools.first().map(|p| p.workers.len()).unwrap_or(0)
    }

    /// Requests currently queued or running on one pool (shard-level load
    /// signal; the coordinator's router and stealer read it).
    pub fn pool_inflight(&self, pool: usize) -> usize {
        self.shared.pools[pool].inflight.load(Ordering::SeqCst)
    }

    /// Per-pool inflight snapshot, pool order.
    pub fn inflight_per_pool(&self) -> Vec<usize> {
        self.shared
            .pools
            .iter()
            .map(|p| p.inflight.load(Ordering::SeqCst))
            .collect()
    }

    /// Highest number of simultaneously queued/running requests observed —
    /// the concurrency witness the pipeline tests and benches read.
    pub fn peak_inflight(&self) -> usize {
        self.shared.peak_inflight.load(Ordering::SeqCst)
    }

    /// Whether the per-pool packed-operand cache is on (`pack_cache_mb`
    /// resolved to a non-zero budget). The coordinator skips operand-key
    /// derivation entirely when this is false.
    pub fn pack_cache_enabled(&self) -> bool {
        self.shared.pools.iter().any(|p| p.pack_cache.is_some())
    }

    /// The resolved per-pool pack-cache byte budget (0 = disabled). The
    /// gateway sizes its seed-materialization cache off the same knob so
    /// `pack_cache_mb = 0` disables both halves at once.
    pub fn pack_cache_budget_bytes(&self) -> usize {
        self.shared
            .pools
            .iter()
            .find_map(|p| p.pack_cache.as_ref().map(|c| c.budget_bytes()))
            .unwrap_or(0)
    }

    /// Per-pool pack-cache counters, pool order (`None` = disabled).
    pub fn pack_cache_stats_per_pool(&self) -> Vec<Option<PackCacheStats>> {
        self.shared
            .pools
            .iter()
            .map(|p| p.pack_cache.as_ref().map(|c| c.stats()))
            .collect()
    }

    /// Pack-cache counters aggregated over every pool; `None` when the
    /// cache is disabled.
    pub fn pack_cache_stats(&self) -> Option<PackCacheStats> {
        let per = self.pack_cache_stats_per_pool();
        let mut agg = PackCacheStats::default();
        let mut any = false;
        for s in per.into_iter().flatten() {
            agg.merge(&s);
            any = true;
        }
        any.then_some(agg)
    }

    /// Requests currently queued or running across the pool (live load
    /// signal; the serving layer reports it next to its queue depth).
    pub fn inflight(&self) -> usize {
        self.shared.inflight_total.load(Ordering::SeqCst)
    }

    /// Execute an artifact; blocks until the result is back. Picks a worker
    /// warm-affine across all pools.
    pub fn execute(&self, artifact: &str, inputs: Vec<Tensor>) -> Result<ExecOutput> {
        self.submit(artifact, inputs)?.wait()
    }

    /// [`Engine::execute`] pinned to one pool when `pool` is `Some`
    /// (modulo-wrapped, so a stale shard index degrades instead of
    /// panicking); `None` spans every pool.
    pub fn execute_on(
        &self,
        pool: Option<usize>,
        artifact: &str,
        inputs: Vec<Tensor>,
    ) -> Result<ExecOutput> {
        self.submit_on(pool, artifact, inputs)?.wait()
    }

    /// Queue an execution on the affinity-chosen worker across all pools;
    /// returns immediately with a [`Pending`] handle.
    pub fn submit(&self, artifact: &str, inputs: Vec<Tensor>) -> Result<Pending> {
        self.submit_on(None, artifact, inputs)
    }

    /// Queue an execution, optionally pinned to one pool's worker set.
    /// `Some(p)` keeps the request (and its warm executable) on shard
    /// `p % pool_count`; `None` picks warm-affine across every pool — the
    /// path split-GEMM blocks use to span shards.
    pub fn submit_on(
        &self,
        pool: Option<usize>,
        artifact: &str,
        inputs: Vec<Tensor>,
    ) -> Result<Pending> {
        let (otx, orx) = oneshot::channel();
        let (p, i) = self.pick_worker(pool, artifact);
        let pool_ref = &self.shared.pools[p];
        let w = &pool_ref.workers[i];
        // Affinity bookkeeping only matters with siblings to choose from;
        // skip the lock (and the allocation when already marked) otherwise.
        if self.worker_count() > 1 {
            let mut warmed = w.warmed.lock().unwrap();
            if !warmed.contains(artifact) {
                warmed.insert(artifact.to_string());
            }
        }
        w.inflight.fetch_add(1, Ordering::SeqCst);
        pool_ref.inflight.fetch_add(1, Ordering::SeqCst);
        let now = self.shared.inflight_total.fetch_add(1, Ordering::SeqCst) + 1;
        self.shared.peak_inflight.fetch_max(now, Ordering::SeqCst);
        let send = w
            .tx
            .send(Msg::Exec(ExecRequest { artifact: artifact.into(), inputs }, otx));
        if send.is_err() {
            w.inflight.fetch_sub(1, Ordering::SeqCst);
            pool_ref.inflight.fetch_sub(1, Ordering::SeqCst);
            self.shared.inflight_total.fetch_sub(1, Ordering::SeqCst);
            bail!("engine worker thread gone");
        }
        Ok(Pending { rx: orx })
    }

    /// Warm-affine worker choice: idle warm > idle cold > least-loaded
    /// warm > least-loaded overall. The candidate set is one pool's
    /// workers when pinned, or every pool's when not. Returns
    /// `(pool, worker)` indices.
    fn pick_worker(&self, pool: Option<usize>, artifact: &str) -> (usize, usize) {
        let pools = &self.shared.pools;
        let candidates: Vec<(usize, usize)> = match pool {
            Some(p) => {
                let p = p % pools.len();
                (0..pools[p].workers.len()).map(|i| (p, i)).collect()
            }
            None => pools
                .iter()
                .enumerate()
                .flat_map(|(p, pl)| (0..pl.workers.len()).map(move |i| (p, i)))
                .collect(),
        };
        if candidates.len() == 1 {
            return candidates[0];
        }
        let mut best_any = candidates[0];
        let mut best_any_load = usize::MAX;
        let mut best_warm: Option<(usize, usize)> = None;
        let mut best_warm_load = usize::MAX;
        for &(p, i) in &candidates {
            let w = &pools[p].workers[i];
            let load = w.inflight.load(Ordering::SeqCst);
            let warm = w.warmed.lock().unwrap().contains(artifact);
            if warm && load < best_warm_load {
                best_warm = Some((p, i));
                best_warm_load = load;
            }
            if load < best_any_load {
                best_any = (p, i);
                best_any_load = load;
            }
        }
        match best_warm {
            Some(pi) if best_warm_load == 0 => pi,
            _ if best_any_load == 0 => best_any,
            Some(pi) => pi,
            None => best_any,
        }
    }

    /// Compile an artifact ahead of time on EVERY worker in every pool;
    /// returns the total compile time (zero when already cached
    /// everywhere).
    pub fn warm(&self, artifact: &str) -> Result<Duration> {
        let mut total = Duration::ZERO;
        for w in self.shared.all_workers() {
            let (otx, orx) = oneshot::channel();
            w.tx
                .send(Msg::Warm(artifact.into(), otx))
                .map_err(|_| anyhow!("engine worker thread gone"))?;
            let d = orx.recv().map_err(|_| anyhow!("engine dropped request"))??;
            if !d.is_zero() {
                w.warmed.lock().unwrap().insert(artifact.to_string());
            }
            total += d;
        }
        Ok(total)
    }

    /// Aggregate statistics over every pool.
    pub fn stats(&self) -> Result<EngineStats> {
        let mut agg = EngineStats::default();
        for s in self.stats_per_worker()? {
            agg.merge(&s);
        }
        Ok(agg)
    }

    /// Per-worker statistics, flattened in (pool, worker) order.
    pub fn stats_per_worker(&self) -> Result<Vec<EngineStats>> {
        self.shared
            .all_workers()
            .map(|w| {
                let (otx, orx) = oneshot::channel();
                w.tx
                    .send(Msg::Stats(otx))
                    .map_err(|_| anyhow!("engine worker thread gone"))?;
                orx.recv().map_err(|_| anyhow!("engine dropped request"))
            })
            .collect()
    }

    /// Per-pool aggregate statistics, pool order.
    pub fn stats_per_pool(&self) -> Result<Vec<EngineStats>> {
        let per_worker = self.stats_per_worker()?;
        let width = self.workers_per_pool().max(1);
        Ok(per_worker
            .chunks(width)
            .map(|chunk| {
                let mut agg = EngineStats::default();
                for s in chunk {
                    agg.merge(s);
                }
                agg
            })
            .collect())
    }
}

/// Thread-confined worker: owns the backend and its compiled cache.
struct EngineWorker {
    manifest: Arc<Manifest>,
    backend: Box<dyn Backend>,
    stats: EngineStats,
}

impl EngineWorker {
    fn new(manifest: Arc<Manifest>, backend: Box<dyn Backend>) -> Self {
        log::info!("engine worker up: backend={}", backend.name());
        EngineWorker { manifest, backend, stats: EngineStats::default() }
    }

    fn warm(&mut self, name: &str) -> Result<Duration> {
        let art = self.manifest.get(name)?.clone();
        let t0 = Instant::now();
        if !self.backend.compile(&art)? {
            return Ok(Duration::ZERO);
        }
        // clamp away a zero reading: "compiled" must be distinguishable
        // from "was already cached" at coarse clock resolution
        let dt = t0.elapsed().max(Duration::from_nanos(1));
        self.stats.compiles += 1;
        self.stats.total_compile_secs += dt.as_secs_f64();
        log::debug!("compiled {name} in {dt:?}");
        Ok(dt)
    }

    fn execute(&mut self, req: ExecRequest) -> Result<ExecOutput> {
        let ExecRequest { artifact, inputs } = req;
        let art = self.manifest.get(&artifact)?.clone();
        // shape-check against the manifest before touching the backend
        if inputs.len() != art.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                art.name,
                art.inputs.len(),
                inputs.len()
            );
        }
        for (i, (have, want)) in inputs.iter().zip(&art.inputs).enumerate() {
            if have.shape != want.shape {
                bail!(
                    "{}: input {i} shape {:?} != manifest {:?}",
                    art.name,
                    have.shape,
                    want.shape
                );
            }
        }
        let compile_time = match self.warm(&artifact)? {
            d if d.is_zero() => None,
            d => Some(d),
        };

        let t0 = Instant::now();
        let outputs = self.backend.execute(&art, inputs)?;
        let exec_time = t0.elapsed();

        if outputs.len() != art.outputs.len() {
            bail!(
                "{}: {} outputs from backend, manifest says {}",
                art.name,
                outputs.len(),
                art.outputs.len()
            );
        }
        for (t, spec) in outputs.iter().zip(&art.outputs) {
            if t.data.len() != spec.elements() {
                bail!("{}: output size {} != {}", art.name, t.data.len(), spec.elements());
            }
        }

        self.stats.executions += 1;
        self.stats.total_exec_secs += exec_time.as_secs_f64();
        Ok(ExecOutput { outputs, exec_time, compile_time })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Engine {
        Engine::start(EngineConfig::default()).expect("reference engine always starts")
    }

    fn engine_with_workers(n: usize) -> Engine {
        Engine::start(EngineConfig { workers: n, ..Default::default() })
            .expect("reference engine always starts")
    }

    #[test]
    fn tensor_shape_checked() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.shape, vec![2, 3]);
    }

    #[test]
    #[should_panic]
    fn tensor_bad_shape_panics() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn executes_plain_gemm_against_host_matmul() {
        let eng = engine();
        let a = crate::abft::Matrix::rand_uniform(64, 64, 1);
        let b = crate::abft::Matrix::rand_uniform(64, 64, 2);
        let out = eng
            .execute(
                "gemm_small",
                vec![
                    Tensor::new(vec![64, 64], a.data().to_vec()),
                    Tensor::new(vec![64, 64], b.data().to_vec()),
                ],
            )
            .unwrap();
        let want = a.matmul(&b);
        let got = crate::abft::Matrix::from_vec(64, 64, out.outputs[0].data.clone());
        assert!(got.max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn rejects_wrong_input_shape() {
        let eng = engine();
        let err = eng
            .execute("gemm_small", vec![Tensor::zeros(vec![2, 2]), Tensor::zeros(vec![64, 64])])
            .unwrap_err();
        assert!(err.to_string().contains("shape"));
    }

    #[test]
    fn warm_is_idempotent_and_caches() {
        let eng = engine();
        let d1 = eng.warm("gemm_medium").unwrap();
        let d2 = eng.warm("gemm_medium").unwrap();
        assert!(d1 > Duration::ZERO);
        assert_eq!(d2, Duration::ZERO);
        let stats = eng.stats().unwrap();
        assert_eq!(stats.compiles, 1);
    }

    #[test]
    fn warm_reaches_every_worker() {
        let eng = engine_with_workers(3);
        eng.warm("gemm_small").unwrap();
        let per = eng.stats_per_worker().unwrap();
        assert_eq!(per.len(), 3);
        assert!(per.iter().all(|s| s.compiles == 1));
    }

    #[test]
    fn pool_spreads_same_artifact_across_workers() {
        let eng = engine_with_workers(4);
        let a = crate::abft::Matrix::rand_uniform(64, 64, 3);
        let b = crate::abft::Matrix::rand_uniform(64, 64, 4);
        let mk = || {
            vec![
                Tensor::new(vec![64, 64], a.data().to_vec()),
                Tensor::new(vec![64, 64], b.data().to_vec()),
            ]
        };
        // queue a burst without waiting: the affinity policy must spill
        // beyond worker 0 once it is busy
        let pending: Vec<Pending> =
            (0..8).map(|_| eng.submit("gemm_small", mk()).unwrap()).collect();
        for p in pending {
            p.wait().unwrap();
        }
        let busy = eng
            .stats_per_worker()
            .unwrap()
            .iter()
            .filter(|s| s.executions > 0)
            .count();
        assert!(busy >= 2, "burst stayed on {busy} worker(s)");
        assert!(eng.peak_inflight() >= 2);
    }

    #[test]
    fn pools_partition_workers_and_pin_submissions() {
        let eng = Engine::start(EngineConfig { workers: 2, pools: 2, ..Default::default() })
            .expect("reference engine always starts");
        assert_eq!(eng.pool_count(), 2);
        assert_eq!(eng.workers_per_pool(), 2);
        assert_eq!(eng.worker_count(), 4);
        eng.warm("gemm_small").unwrap();
        assert_eq!(eng.stats_per_worker().unwrap().len(), 4);

        let a = crate::abft::Matrix::rand_uniform(64, 64, 11);
        let b = crate::abft::Matrix::rand_uniform(64, 64, 12);
        let mk = || {
            vec![
                Tensor::new(vec![64, 64], a.data().to_vec()),
                Tensor::new(vec![64, 64], b.data().to_vec()),
            ]
        };
        // pinned submissions stay on their shard; index 3 wraps to pool 1
        for _ in 0..4 {
            eng.execute_on(Some(1), "gemm_small", mk()).unwrap();
        }
        eng.execute_on(Some(3), "gemm_small", mk()).unwrap();
        let per_pool = eng.stats_per_pool().unwrap();
        assert_eq!(per_pool.len(), 2);
        assert_eq!(per_pool[0].executions, 0, "pinned work leaked to pool 0");
        assert_eq!(per_pool[1].executions, 5);
        assert_eq!(eng.pool_inflight(0), 0);
        assert_eq!(eng.pool_inflight(1), 0);
        assert_eq!(eng.inflight_per_pool(), vec![0, 0]);
    }

    #[test]
    fn pack_cache_defaults_on_per_pool_and_zero_disables() {
        let eng = Engine::start(EngineConfig { pools: 2, ..Default::default() })
            .expect("reference engine always starts");
        assert!(eng.pack_cache_enabled(), "default budget must enable the cache");
        let per = eng.pack_cache_stats_per_pool();
        assert_eq!(per.len(), 2, "one cache per pool");
        assert!(per.iter().all(|s| s.is_some()));
        assert_eq!(eng.pack_cache_stats().unwrap(), PackCacheStats::default());

        let off = Engine::start(EngineConfig { pack_cache_mb: Some(0), ..Default::default() })
            .expect("reference engine always starts");
        assert!(!off.pack_cache_enabled(), "pack_cache_mb = 0 must fully disable");
        assert!(off.pack_cache_stats().is_none());
        assert_eq!(off.pack_cache_stats_per_pool(), vec![None]);
    }

    #[test]
    fn unpinned_burst_spans_pools() {
        let eng = Engine::start(EngineConfig { workers: 1, pools: 2, ..Default::default() })
            .expect("reference engine always starts");
        let a = crate::abft::Matrix::rand_uniform(64, 64, 13);
        let b = crate::abft::Matrix::rand_uniform(64, 64, 14);
        let mk = || {
            vec![
                Tensor::new(vec![64, 64], a.data().to_vec()),
                Tensor::new(vec![64, 64], b.data().to_vec()),
            ]
        };
        // global submit must spill across shards once pool 0 is busy —
        // this is the path split-GEMM blocks take
        let pending: Vec<Pending> =
            (0..8).map(|_| eng.submit("gemm_small", mk()).unwrap()).collect();
        for p in pending {
            p.wait().unwrap();
        }
        let busy_pools = eng
            .stats_per_pool()
            .unwrap()
            .iter()
            .filter(|s| s.executions > 0)
            .count();
        assert_eq!(busy_pools, 2, "burst stayed on one shard");
    }

    #[test]
    fn try_wait_polls_to_completion() {
        let eng = engine();
        let a = crate::abft::Matrix::rand_uniform(64, 64, 5);
        let b = crate::abft::Matrix::rand_uniform(64, 64, 6);
        let pending = eng
            .submit(
                "gemm_small",
                vec![
                    Tensor::new(vec![64, 64], a.data().to_vec()),
                    Tensor::new(vec![64, 64], b.data().to_vec()),
                ],
            )
            .unwrap();
        let mut polls = 0usize;
        let out = loop {
            match pending.try_wait() {
                Some(r) => break r.unwrap(),
                None => {
                    polls += 1;
                    assert!(polls < 100_000, "request never completed");
                    std::thread::yield_now();
                }
            }
        };
        assert_eq!(out.outputs[0].shape, vec![64, 64]);
        assert_eq!(eng.inflight(), 0, "completed request left the load counter");
    }

    #[test]
    fn backend_selection_resolves_through_the_registry() {
        let eng = Engine::start(EngineConfig {
            backend: "blocked".into(),
            workers: 2,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(eng.backend().name, "blocked");
        assert!(eng.backend().fused_ft);
        let a = crate::abft::Matrix::rand_uniform(64, 64, 7);
        let b = crate::abft::Matrix::rand_uniform(64, 64, 8);
        let out = eng
            .execute(
                "gemm_small",
                vec![
                    Tensor::new(vec![64, 64], a.data().to_vec()),
                    Tensor::new(vec![64, 64], b.data().to_vec()),
                ],
            )
            .unwrap();
        let got = crate::abft::Matrix::from_vec(64, 64, out.outputs[0].data.clone());
        assert!(got.max_abs_diff(&a.matmul(&b)) < 1e-3);
        // default resolves to reference; unknown names fail at startup
        assert_eq!(engine().backend().name, "reference");
        let err = Engine::start(EngineConfig { backend: "pjrt".into(), ..Default::default() })
            .unwrap_err();
        assert!(err.to_string().contains("unknown backend"), "{err}");
    }

    #[test]
    fn custom_registry_serves_through_start_with() {
        use super::super::backend::{BackendCtx, BackendInfo, BackendRegistry, ReferenceBackend};
        let mut reg = BackendRegistry::empty();
        reg.register(
            BackendInfo {
                name: "mine",
                description: "embedder backend",
                fused_ft: true,
                kernel_isa: "portable",
            },
            std::sync::Arc::new(|_ctx: &BackendCtx| {
                Box::new(ReferenceBackend::new()) as Box<dyn super::Backend>
            }),
        );
        let eng = Engine::start_with(
            EngineConfig { backend: "mine".into(), ..Default::default() },
            &reg,
        )
        .unwrap();
        assert_eq!(eng.backend().name, "mine");
        let a = crate::abft::Matrix::rand_uniform(64, 64, 9);
        let b = crate::abft::Matrix::rand_uniform(64, 64, 10);
        let out = eng
            .execute(
                "gemm_small",
                vec![
                    Tensor::new(vec![64, 64], a.data().to_vec()),
                    Tensor::new(vec![64, 64], b.data().to_vec()),
                ],
            )
            .unwrap();
        assert_eq!(out.outputs[0].shape, vec![64, 64]);
        // the custom registry is authoritative: builtins are absent
        let err = Engine::start_with(EngineConfig::default(), &reg).unwrap_err();
        assert!(err.to_string().contains("unknown backend"), "{err}");
    }

    #[test]
    fn handle_is_send_and_clone() {
        fn assert_send<T: Send>() {}
        assert_send::<Engine>();
        let eng = engine();
        let e2 = eng.clone();
        let h = std::thread::spawn(move || e2.warm("gemm_small").map(|_| ()));
        h.join().unwrap().unwrap();
    }
}
