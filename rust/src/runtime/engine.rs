//! The PJRT execution engine.
//!
//! PJRT handles in the `xla` crate are `Rc`-based and must not cross
//! threads, so a dedicated engine thread owns the `PjRtClient` plus the
//! compiled-executable cache, and serves [`ExecRequest`]s from an mpsc
//! queue (the vLLM engine-loop pattern). The cloneable [`Engine`] handle is
//! `Send`, so the coordinator, the fault drivers and the bench harness can
//! all submit work concurrently; responses return through per-request
//! oneshot channels.
//!
//! Compilation (`HloModuleProto::from_text_file` → `client.compile`) runs
//! once per artifact and is cached; the request path is parse-free.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::pool::oneshot;

use super::manifest::Manifest;

/// A host tensor: row-major f32 with an explicit shape. The engine's only
/// data currency (all artifacts are pure-f32 by construction).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn scalar_sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }
}

/// One execution request: artifact name + input tensors.
#[derive(Debug, Clone)]
pub struct ExecRequest {
    pub artifact: String,
    pub inputs: Vec<Tensor>,
}

/// Execution result: output tensors (manifest order) + timings.
#[derive(Debug, Clone)]
pub struct ExecOutput {
    pub outputs: Vec<Tensor>,
    /// Pure device-execution time (excludes queueing).
    pub exec_time: Duration,
    /// Set on the first call that had to compile the artifact.
    pub compile_time: Option<Duration>,
}

enum Msg {
    Exec(ExecRequest, oneshot::OneSender<Result<ExecOutput>>),
    /// Pre-compile an artifact (warm-up), reply when done.
    Warm(String, oneshot::OneSender<Result<Duration>>),
    Stats(oneshot::OneSender<EngineStats>),
    Shutdown,
}

/// Engine configuration.
#[derive(Debug, Clone, Default)]
pub struct EngineConfig {
    /// Artifacts directory; `None` = discover (`FTGEMM_ARTIFACTS`, ./artifacts, ..).
    pub artifacts_dir: Option<std::path::PathBuf>,
    /// Artifact names to compile eagerly at startup (empty = lazy).
    pub precompile: Vec<String>,
}

/// Cumulative engine-side statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EngineStats {
    pub executions: u64,
    pub compiles: u64,
    pub total_exec_secs: f64,
    pub total_compile_secs: f64,
}

/// Cloneable, `Send` handle to the engine thread.
#[derive(Clone)]
pub struct Engine {
    tx: Sender<Msg>,
    manifest: Arc<Manifest>,
    _joiner: Arc<Joiner>,
}

struct Joiner {
    tx: Sender<Msg>,
    handle: Option<JoinHandle<()>>,
}

impl Drop for Joiner {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Engine {
    /// Start the engine thread: load the manifest, spin up the PJRT CPU
    /// client, optionally pre-compile artifacts.
    pub fn start(config: EngineConfig) -> Result<Engine> {
        let manifest = match &config.artifacts_dir {
            Some(d) => Manifest::load(d)?,
            None => Manifest::discover()?,
        };
        let manifest = Arc::new(manifest);
        let (tx, rx) = channel::<Msg>();
        let thread_manifest = Arc::clone(&manifest);
        let (ready_tx, ready_rx) = oneshot::channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("ftgemm-engine".into())
            .spawn(move || {
                let mut worker = match EngineWorker::new(thread_manifest) {
                    Ok(w) => {
                        let _ = ready_tx.send(Ok(()));
                        w
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Exec(req, reply) => {
                            let _ = reply.send(worker.execute(&req));
                        }
                        Msg::Warm(name, reply) => {
                            let _ = reply.send(worker.warm(&name));
                        }
                        Msg::Stats(reply) => {
                            let _ = reply.send(worker.stats);
                        }
                        Msg::Shutdown => break,
                    }
                }
            })
            .context("spawn engine thread")?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))??;
        let engine = Engine {
            tx: tx.clone(),
            manifest,
            _joiner: Arc::new(Joiner { tx, handle: Some(handle) }),
        };
        for name in &config.precompile {
            engine.warm(name)?;
        }
        Ok(engine)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute an artifact; blocks until the result is back.
    pub fn execute(&self, artifact: &str, inputs: Vec<Tensor>) -> Result<ExecOutput> {
        let (otx, orx) = oneshot::channel();
        self.tx
            .send(Msg::Exec(ExecRequest { artifact: artifact.into(), inputs }, otx))
            .map_err(|_| anyhow!("engine thread gone"))?;
        orx.recv().map_err(|_| anyhow!("engine dropped request"))?
    }

    /// Compile an artifact ahead of time; returns compile duration
    /// (zero if already cached).
    pub fn warm(&self, artifact: &str) -> Result<Duration> {
        let (otx, orx) = oneshot::channel();
        self.tx
            .send(Msg::Warm(artifact.into(), otx))
            .map_err(|_| anyhow!("engine thread gone"))?;
        orx.recv().map_err(|_| anyhow!("engine dropped request"))?
    }

    pub fn stats(&self) -> Result<EngineStats> {
        let (otx, orx) = oneshot::channel();
        self.tx
            .send(Msg::Stats(otx))
            .map_err(|_| anyhow!("engine thread gone"))?;
        orx.recv().map_err(|_| anyhow!("engine dropped request"))
    }
}

/// Thread-confined worker: owns all PJRT state.
struct EngineWorker {
    client: xla::PjRtClient,
    manifest: Arc<Manifest>,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    stats: EngineStats,
}

impl EngineWorker {
    fn new(manifest: Arc<Manifest>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        log::info!(
            "engine up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(EngineWorker { client, manifest, cache: HashMap::new(), stats: EngineStats::default() })
    }

    fn warm(&mut self, name: &str) -> Result<Duration> {
        if self.cache.contains_key(name) {
            return Ok(Duration::ZERO);
        }
        let art = self.manifest.get(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            art.file.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {:?}: {e:?}", art.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        let dt = t0.elapsed();
        self.stats.compiles += 1;
        self.stats.total_compile_secs += dt.as_secs_f64();
        log::debug!("compiled {name} in {dt:?}");
        self.cache.insert(name.to_string(), exe);
        Ok(dt)
    }

    fn execute(&mut self, req: &ExecRequest) -> Result<ExecOutput> {
        let art = self.manifest.get(&req.artifact)?.clone();
        // shape-check against the manifest before touching PJRT
        if req.inputs.len() != art.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                art.name,
                art.inputs.len(),
                req.inputs.len()
            );
        }
        for (i, (have, want)) in req.inputs.iter().zip(&art.inputs).enumerate() {
            if have.shape != want.shape {
                bail!(
                    "{}: input {i} shape {:?} != manifest {:?}",
                    art.name,
                    have.shape,
                    want.shape
                );
            }
        }
        let compile_time = match self.warm(&req.artifact)? {
            d if d.is_zero() => None,
            d => Some(d),
        };
        let exe = self.cache.get(&req.artifact).expect("warmed above");

        let literals = req
            .inputs
            .iter()
            .map(|t| {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    &t.shape,
                    bytes,
                )
                .map_err(|e| anyhow!("literal: {e:?}"))
            })
            .collect::<Result<Vec<_>>>()?;

        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {e:?}", art.name))?;
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let exec_time = t0.elapsed();

        // aot.py lowers with return_tuple=True: root is always a tuple.
        let parts = root
            .to_tuple()
            .map_err(|e| anyhow!("untuple: {e:?}"))?;
        if parts.len() != art.outputs.len() {
            bail!(
                "{}: {} outputs from device, manifest says {}",
                art.name,
                parts.len(),
                art.outputs.len()
            );
        }
        let outputs = parts
            .into_iter()
            .zip(&art.outputs)
            .map(|(lit, spec)| {
                let data = lit.to_vec::<f32>().map_err(|e| anyhow!("readback: {e:?}"))?;
                if data.len() != spec.elements() {
                    bail!("{}: output size {} != {}", art.name, data.len(), spec.elements());
                }
                Ok(Tensor::new(spec.shape.clone(), data))
            })
            .collect::<Result<Vec<_>>>()?;

        self.stats.executions += 1;
        self.stats.total_exec_secs += exec_time.as_secs_f64();
        Ok(ExecOutput { outputs, exec_time, compile_time })
    }
}

#[cfg(test)]
mod tests {
    //! Engine tests run only when artifacts exist (`make artifacts`); the
    //! heavier integration suite lives in `rust/tests/`.
    use super::*;

    fn engine() -> Option<Engine> {
        Engine::start(EngineConfig::default()).ok()
    }

    #[test]
    fn tensor_shape_checked() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.shape, vec![2, 3]);
    }

    #[test]
    #[should_panic]
    fn tensor_bad_shape_panics() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn executes_plain_gemm_against_host_matmul() {
        let Some(eng) = engine() else { return };
        let a = crate::abft::Matrix::rand_uniform(64, 64, 1);
        let b = crate::abft::Matrix::rand_uniform(64, 64, 2);
        let out = eng
            .execute(
                "gemm_small",
                vec![
                    Tensor::new(vec![64, 64], a.data().to_vec()),
                    Tensor::new(vec![64, 64], b.data().to_vec()),
                ],
            )
            .unwrap();
        let want = a.matmul(&b);
        let got = crate::abft::Matrix::from_vec(64, 64, out.outputs[0].data.clone());
        assert!(got.max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn rejects_wrong_input_shape() {
        let Some(eng) = engine() else { return };
        let err = eng
            .execute("gemm_small", vec![Tensor::zeros(vec![2, 2]), Tensor::zeros(vec![64, 64])])
            .unwrap_err();
        assert!(err.to_string().contains("shape"));
    }

    #[test]
    fn warm_is_idempotent_and_caches() {
        let Some(eng) = engine() else { return };
        let d1 = eng.warm("gemm_medium").unwrap();
        let d2 = eng.warm("gemm_medium").unwrap();
        assert!(d1 > Duration::ZERO);
        assert_eq!(d2, Duration::ZERO);
        let stats = eng.stats().unwrap();
        assert_eq!(stats.compiles, 1);
    }

    #[test]
    fn handle_is_send_and_clone() {
        fn assert_send<T: Send>() {}
        assert_send::<Engine>();
        let Some(eng) = engine() else { return };
        let e2 = eng.clone();
        let h = std::thread::spawn(move || e2.warm("gemm_small").map(|_| ()));
        h.join().unwrap().unwrap();
    }
}
