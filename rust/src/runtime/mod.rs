//! Runtime: loads the AOT artifact manifest and executes kernels from the
//! rust hot path through a worker pool.
//!
//! Structure:
//! * [`manifest`] — parses `artifacts/manifest.json` (shapes, roles, tile
//!   params, FT metadata) produced by `python/compile/aot.py`, or
//!   synthesizes the same registry in-process ([`Manifest::builtin`]) when
//!   artifacts are absent.
//! * [`backend`] — pluggable kernel executors behind a named
//!   [`BackendRegistry`]. Kernel clients (PJRT) are `Rc`-based and
//!   thread-confined, so each engine worker constructs its own backend
//!   instance in-thread from a `Send + Sync` registry factory. The
//!   always-available [`backend::ReferenceBackend`] executes the artifact
//!   contract semantically on the host (see DESIGN.md "Substitutions");
//!   [`blocked::BlockedBackend`] is the high-performance engine —
//!   cache-blocked, register-tiled, multithreaded, with checksum work
//!   fused into its packing/compute loops and SIMD micro-kernels
//!   dispatched at construction time from [`simd::KernelIsa`].
//! * [`engine`] — the execution engine: a configurable pool of worker
//!   threads (the vLLM engine-loop pattern, generalized from one thread to
//!   N), each owning one backend + compiled-executable cache, with
//!   warm-affine request dispatch. Compilation happens once per (artifact,
//!   worker), lazily or eagerly at startup, and is cached thereafter.
//!
//! Python never runs here: kernels were lowered at build time and the
//! engine only compiles/executes them.

pub mod backend;
pub mod blocked;
pub mod engine;
pub mod manifest;
pub mod pack_cache;
pub mod simd;

pub use backend::{Backend, BackendFactory, BackendInfo, BackendRegistry, ReferenceBackend};
pub use blocked::BlockedBackend;
pub use pack_cache::{
    OperandId, OperandKey, PackCache, PackCacheStats, PackedOperand, PanelKey, PanelRole,
};
pub use simd::KernelIsa;
pub use engine::{Engine, EngineConfig, ExecOutput, ExecRequest, Pending};
pub use manifest::{Artifact, ArtifactKind, Manifest, TensorSpec};
