//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) and
//! executes them from the rust hot path.
//!
//! Structure:
//! * [`manifest`] — parses `artifacts/manifest.json` (shapes, roles, tile
//!   params, FT metadata) produced by `python/compile/aot.py`.
//! * [`engine`] — the execution engine. PJRT handles in the `xla` crate are
//!   `Rc`-based (not `Send`), so a dedicated **engine thread** owns the
//!   `PjRtClient` and the compiled-executable cache; the rest of the
//!   process talks to it through an [`Engine`] handle over mpsc channels
//!   (the vLLM engine-loop pattern). Compilation happens once per artifact
//!   (lazily or eagerly at startup) and is cached thereafter.
//!
//! Python never runs here: the HLO text was produced at build time and the
//! engine only parses/compiles/executes it.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, EngineConfig, ExecOutput, ExecRequest};
pub use manifest::{Artifact, ArtifactKind, Manifest, TensorSpec};
