//! `artifacts/manifest.json` — the contract between the python AOT
//! pipeline and the rust runtime. One entry per lowered kernel variant.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::codegen::params::KernelParams;
use crate::util::json::Json;

/// Shape + dtype of one kernel input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
    /// Output role: "c", "cr", "cc", "errcount", "ac", "br", "cf" — empty
    /// for inputs.
    pub role: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// What family of kernel an artifact belongs to (drives coordinator logic).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    Gemm,
    FtGemm,
    FtDetect,
    DingEncode,
    DingStep,
    DingVerify,
    Stepwise,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "gemm" => ArtifactKind::Gemm,
            "ftgemm" => ArtifactKind::FtGemm,
            "ftdetect" => ArtifactKind::FtDetect,
            "ding_encode" => ArtifactKind::DingEncode,
            "ding_step" => ArtifactKind::DingStep,
            "ding_verify" => ArtifactKind::DingVerify,
            "stepwise" => ArtifactKind::Stepwise,
            other => bail!("unknown artifact kind {other:?}"),
        })
    }
}

/// One lowered kernel variant.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub name: String,
    pub file: PathBuf,
    pub kind: ArtifactKind,
    pub bucket: String,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    /// Panel width for ding_step; 0 otherwise.
    pub ks: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub params: Option<KernelParams>,
    pub ft_level: Option<String>,
    pub max_inj: usize,
    pub verify_every: usize,
}

impl Artifact {
    /// Index of the output with the given role.
    pub fn output_index(&self, role: &str) -> Option<usize> {
        self.outputs.iter().position(|o| o.role == role)
    }
}

/// The full parsed manifest, indexed by artifact name.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    artifacts: BTreeMap<String, Artifact>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        Self::parse(&text, dir)
    }

    /// Locate the artifacts directory: `$FTGEMM_ARTIFACTS`, `./artifacts`,
    /// or `../artifacts` (tests run from the crate root or target dir).
    pub fn discover() -> Result<Manifest> {
        if let Ok(dir) = std::env::var("FTGEMM_ARTIFACTS") {
            return Self::load(dir);
        }
        for cand in ["artifacts", "../artifacts", "../../artifacts"] {
            if Path::new(cand).join("manifest.json").exists() {
                return Self::load(cand);
            }
        }
        bail!("artifacts/manifest.json not found; run `make artifacts` or set FTGEMM_ARTIFACTS")
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let root = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let format = root
            .path("format")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing format"))?;
        if format != 1 {
            bail!("unsupported manifest format {format}");
        }
        let mut artifacts = BTreeMap::new();
        for entry in root
            .path("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts[]"))?
        {
            let art = parse_artifact(entry, &dir)?;
            if artifacts.insert(art.name.clone(), art).is_some() {
                bail!("duplicate artifact name");
            }
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&Artifact> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.artifacts.keys().map(|s| s.as_str())
    }

    pub fn iter(&self) -> impl Iterator<Item = &Artifact> {
        self.artifacts.values()
    }

    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }

    /// All artifacts of one kind (e.g. every fused FT-GEMM).
    pub fn of_kind(&self, kind: ArtifactKind) -> Vec<&Artifact> {
        self.artifacts.values().filter(|a| a.kind == kind).collect()
    }

    /// The artifact serving a (kind, bucket) pair, e.g. FtGemm tb for "huge".
    pub fn find(&self, kind: ArtifactKind, bucket: &str, level: Option<&str>) -> Option<&Artifact> {
        self.artifacts.values().find(|a| {
            a.kind == kind
                && a.bucket == bucket
                && match level {
                    None => true,
                    Some(l) => a.ft_level.as_deref() == Some(l),
                }
        })
    }
}

fn parse_tensor(j: &Json) -> Result<TensorSpec> {
    let shape = j
        .path("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("tensor missing shape"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = j
        .path("dtype")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("tensor missing dtype"))?
        .to_string();
    let role = j
        .path("role")
        .and_then(Json::as_str)
        .unwrap_or_default()
        .to_string();
    Ok(TensorSpec { shape, dtype, role })
}

fn parse_artifact(j: &Json, dir: &Path) -> Result<Artifact> {
    let name = j
        .path("name")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("artifact missing name"))?
        .to_string();
    let file = dir.join(
        j.path("file")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("{name}: missing file"))?,
    );
    let meta = j.path("meta").ok_or_else(|| anyhow!("{name}: missing meta"))?;
    let kind = ArtifactKind::parse(
        meta.path("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("{name}: missing kind"))?,
    )?;
    let dim = |key: &str| meta.path(key).and_then(Json::as_usize).unwrap_or(0);
    let params = meta.path("params").map(KernelParams::from_json).transpose()?;
    let inputs = j
        .path("inputs")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("{name}: missing inputs"))?
        .iter()
        .map(parse_tensor)
        .collect::<Result<Vec<_>>>()?;
    let outputs = j
        .path("outputs")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("{name}: missing outputs"))?
        .iter()
        .map(parse_tensor)
        .collect::<Result<Vec<_>>>()?;
    Ok(Artifact {
        name: name.clone(),
        file,
        kind,
        bucket: meta
            .path("bucket")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string(),
        m: dim("m"),
        n: dim("n"),
        k: dim("k"),
        ks: dim("ks"),
        inputs,
        outputs,
        params,
        ft_level: meta
            .path("ft_level")
            .and_then(Json::as_str)
            .map(str::to_string),
        max_inj: dim("max_inj"),
        verify_every: dim("verify_every"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "artifacts": [
        {
          "name": "gemm_small",
          "file": "gemm_small.hlo.txt",
          "inputs": [
            {"shape": [64, 64], "dtype": "float32"},
            {"shape": [64, 64], "dtype": "float32"}
          ],
          "outputs": [{"role": "c", "shape": [64, 64], "dtype": "float32"}],
          "meta": {"kind": "gemm", "bucket": "small", "m": 64, "n": 64, "k": 64,
                   "params": {"m_tb": 16, "n_tb": 16, "k_tb": 16,
                               "m_w": 8, "n_w": 16, "m_t": 2, "n_t": 2}}
        },
        {
          "name": "ftgemm_tb_small",
          "file": "ftgemm_tb_small.hlo.txt",
          "inputs": [
            {"shape": [64, 64], "dtype": "float32"},
            {"shape": [64, 64], "dtype": "float32"},
            {"shape": [8, 4], "dtype": "float32"}
          ],
          "outputs": [
            {"role": "c", "shape": [64, 64], "dtype": "float32"},
            {"role": "cr", "shape": [4, 4, 1, 16, 1], "dtype": "float32"},
            {"role": "cc", "shape": [4, 4, 1, 1, 16], "dtype": "float32"},
            {"role": "errcount", "shape": [4, 4], "dtype": "float32"}
          ],
          "meta": {"kind": "ftgemm", "bucket": "small", "m": 64, "n": 64, "k": 64,
                   "ft_level": "tb", "max_inj": 8, "verify_every": 8}
        }
      ]
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.len(), 2);
        let g = m.get("gemm_small").unwrap();
        assert_eq!(g.kind, ArtifactKind::Gemm);
        assert_eq!((g.m, g.n, g.k), (64, 64, 64));
        assert_eq!(g.params.as_ref().unwrap().m_tb, 16);
        let ft = m.get("ftgemm_tb_small").unwrap();
        assert_eq!(ft.kind, ArtifactKind::FtGemm);
        assert_eq!(ft.ft_level.as_deref(), Some("tb"));
        assert_eq!(ft.output_index("errcount"), Some(3));
        assert_eq!(ft.max_inj, 8);
    }

    #[test]
    fn find_by_kind_bucket_level() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert!(m.find(ArtifactKind::FtGemm, "small", Some("tb")).is_some());
        assert!(m.find(ArtifactKind::FtGemm, "small", Some("warp")).is_none());
        assert!(m.find(ArtifactKind::Gemm, "small", None).is_some());
    }

    #[test]
    fn unknown_artifact_errors() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn rejects_bad_format_version() {
        assert!(Manifest::parse(r#"{"format": 9, "artifacts": []}"#, PathBuf::new()).is_err());
    }

    #[test]
    fn loads_real_manifest_if_built() {
        if let Ok(m) = Manifest::discover() {
            assert!(m.len() >= 20, "expected full artifact set, got {}", m.len());
            assert!(m.find(ArtifactKind::FtGemm, "huge", Some("tb")).is_some());
            for a in m.iter() {
                assert!(a.file.exists(), "{:?} missing", a.file);
            }
        }
    }
}
