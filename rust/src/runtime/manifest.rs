//! `artifacts/manifest.json` — the contract between the python AOT
//! pipeline and the rust runtime. One entry per lowered kernel variant.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::codegen::params::KernelParams;
use crate::util::json::Json;

/// Shape + dtype of one kernel input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
    /// Output role: "c", "cr", "cc", "errcount", "ac", "br", "cf" — empty
    /// for inputs.
    pub role: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// What family of kernel an artifact belongs to (drives coordinator logic).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    Gemm,
    FtGemm,
    FtDetect,
    DingEncode,
    DingStep,
    DingVerify,
    Stepwise,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "gemm" => ArtifactKind::Gemm,
            "ftgemm" => ArtifactKind::FtGemm,
            "ftdetect" => ArtifactKind::FtDetect,
            "ding_encode" => ArtifactKind::DingEncode,
            "ding_step" => ArtifactKind::DingStep,
            "ding_verify" => ArtifactKind::DingVerify,
            "stepwise" => ArtifactKind::Stepwise,
            other => bail!("unknown artifact kind {other:?}"),
        })
    }
}

/// One lowered kernel variant.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub name: String,
    pub file: PathBuf,
    pub kind: ArtifactKind,
    pub bucket: String,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    /// Panel width for ding_step; 0 otherwise.
    pub ks: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub params: Option<KernelParams>,
    pub ft_level: Option<String>,
    pub max_inj: usize,
    pub verify_every: usize,
    /// Checksum protection sub-tile for FT kernels; 0 when not applicable.
    pub sub_m: usize,
    pub sub_n: usize,
}

impl Artifact {
    /// Index of the output with the given role.
    pub fn output_index(&self, role: &str) -> Option<usize> {
        self.outputs.iter().position(|o| o.role == role)
    }
}

/// The full parsed manifest, indexed by artifact name.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    artifacts: BTreeMap<String, Artifact>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        Self::parse(&text, dir)
    }

    /// Locate the artifacts directory: `$FTGEMM_ARTIFACTS`, `./artifacts`,
    /// or `../artifacts` (tests run from the crate root or target dir).
    pub fn discover() -> Result<Manifest> {
        match Self::discover_path() {
            Some(dir) => Self::load(dir),
            None => bail!(
                "artifacts/manifest.json not found; run `make artifacts` or set FTGEMM_ARTIFACTS"
            ),
        }
    }

    /// Where [`Self::discover`] would load from, without loading. `None`
    /// when no artifacts directory exists (the engine then falls back to
    /// [`Self::builtin`]).
    pub fn discover_path() -> Option<PathBuf> {
        if let Ok(dir) = std::env::var("FTGEMM_ARTIFACTS") {
            return Some(PathBuf::from(dir));
        }
        ["artifacts", "../artifacts", "../../artifacts"]
            .iter()
            .find(|cand| Path::new(cand).join("manifest.json").exists())
            .map(PathBuf::from)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let root = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let format = root
            .path("format")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing format"))?;
        if format != 1 {
            bail!("unsupported manifest format {format}");
        }
        let mut artifacts = BTreeMap::new();
        for entry in root
            .path("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts[]"))?
        {
            let art = parse_artifact(entry, &dir)?;
            if artifacts.insert(art.name.clone(), art).is_some() {
                bail!("duplicate artifact name");
            }
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&Artifact> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.artifacts.keys().map(|s| s.as_str())
    }

    pub fn iter(&self) -> impl Iterator<Item = &Artifact> {
        self.artifacts.values()
    }

    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }

    /// All artifacts of one kind (e.g. every fused FT-GEMM).
    pub fn of_kind(&self, kind: ArtifactKind) -> Vec<&Artifact> {
        self.artifacts.values().filter(|a| a.kind == kind).collect()
    }

    /// The artifact serving a (kind, bucket) pair, e.g. FtGemm tb for "huge".
    pub fn find(&self, kind: ArtifactKind, bucket: &str, level: Option<&str>) -> Option<&Artifact> {
        self.artifacts.values().find(|a| {
            a.kind == kind
                && a.bucket == bucket
                && match level {
                    None => true,
                    Some(l) => a.ft_level.as_deref() == Some(l),
                }
        })
    }
}

// ---------------------------------------------------------------------
// Built-in manifest: the same registry `python/compile/model.py` lowers,
// described without the HLO files. Lets the engine serve through the
// reference backend when `make artifacts` has not run (and in environments
// without JAX at all) — see DESIGN.md "Substitutions".
// ---------------------------------------------------------------------

/// Fused-FT kernels track up to this many injected errors per execution
/// (python `params.MAX_INJ` — keep in sync).
pub const MAX_INJ: usize = 8;

/// Default verification interval in k-steps (python `params.VERIFY_EVERY`).
pub const VERIFY_EVERY: usize = 8;

/// K_s panel widths for the non-fused Ding baseline (python `DING_KS`).
pub const DING_KS: [(&str, usize); 3] = [("medium", 64), ("large", 128), ("huge", 256)];

fn tensor(shape: &[usize], role: &str) -> TensorSpec {
    TensorSpec { shape: shape.to_vec(), dtype: "float32".into(), role: role.into() }
}

impl Manifest {
    /// The registry of `python/compile/model.py`, built in-process: every
    /// artifact the AOT pipeline would lower, with the same names, shapes,
    /// roles, and FT metadata. `file` paths are placeholders — only the
    /// reference backend can execute a builtin manifest.
    pub fn builtin() -> Manifest {
        use crate::codegen::select::BUCKETS;

        let dir = PathBuf::from("<builtin>");
        let mut list: Vec<Artifact> = Vec::new();

        for b in BUCKETS {
            list.push(builtin_gemm(&b));
            list.push(builtin_ft(&b, "tb", true, VERIFY_EVERY, None));
        }
        for name in ["medium", "huge"] {
            let b = BUCKETS.iter().find(|b| b.name() == name).copied().expect("bucket");
            list.push(builtin_ft(&b, "warp", true, VERIFY_EVERY, None));
            list.push(builtin_ft(&b, "thread", true, VERIFY_EVERY, None));
            list.push(builtin_ft(&b, "tb", false, VERIFY_EVERY, None));
        }
        for (name, ks) in DING_KS {
            let b = BUCKETS.iter().find(|b| b.name() == name).copied().expect("bucket");
            list.extend(builtin_ding(&b, ks));
        }
        // verify-interval ablation variants (bucket suffixed so the router
        // never picks them; the ablation bench addresses them by name)
        let medium = BUCKETS.iter().find(|b| b.name() == "medium").copied().expect("bucket");
        for ve in [1, 4, 16] {
            list.push(builtin_ft(&medium, "tb", true, ve, Some(format!("medium_ve{ve}"))));
        }

        let mut artifacts = BTreeMap::new();
        for art in list {
            let replaced = artifacts.insert(art.name.clone(), art);
            debug_assert!(replaced.is_none(), "duplicate builtin artifact name");
        }
        Manifest { dir, artifacts }
    }

    /// True when this manifest came from [`Self::builtin`] (no HLO files on
    /// disk).
    pub fn is_builtin(&self) -> bool {
        self.dir == Path::new("<builtin>")
    }
}

fn builtin_gemm(b: &crate::codegen::select::Bucket) -> Artifact {
    let (m, n, k) = (b.m, b.n, b.k);
    Artifact {
        name: format!("gemm_{}", b.name()),
        file: PathBuf::from("<builtin>").join(format!("gemm_{}.hlo.txt", b.name())),
        kind: ArtifactKind::Gemm,
        bucket: b.name().to_string(),
        m,
        n,
        k,
        ks: 0,
        inputs: vec![tensor(&[m, k], ""), tensor(&[k, n], "")],
        outputs: vec![tensor(&[m, n], "c")],
        params: Some(b.class.params()),
        ft_level: None,
        max_inj: 0,
        verify_every: 0,
        sub_m: 0,
        sub_n: 0,
    }
}

fn builtin_ft(
    b: &crate::codegen::select::Bucket,
    level: &str,
    correct: bool,
    verify_every: usize,
    bucket_override: Option<String>,
) -> Artifact {
    let (m, n, k) = (b.m, b.n, b.k);
    let params = b.class.params();
    let (sub_m, sub_n) = params.sub_tile(level).expect("known FT level");
    let (gm, gn) = (m.div_ceil(sub_m), n.div_ceil(sub_n));
    let name = if correct {
        match &bucket_override {
            Some(label) => format!("ftgemm_{level}_{label}"),
            None => format!("ftgemm_{level}_{}", b.name()),
        }
    } else {
        format!("ftdetect_{}", b.name())
    };
    Artifact {
        name: name.clone(),
        file: PathBuf::from("<builtin>").join(format!("{name}.hlo.txt")),
        kind: if correct { ArtifactKind::FtGemm } else { ArtifactKind::FtDetect },
        bucket: bucket_override.unwrap_or_else(|| b.name().to_string()),
        m,
        n,
        k,
        ks: 0,
        inputs: vec![tensor(&[m, k], ""), tensor(&[k, n], ""), tensor(&[MAX_INJ, 4], "")],
        outputs: vec![
            tensor(&[m, n], "c"),
            tensor(&[m], "cr"),
            tensor(&[n], "cc"),
            tensor(&[gm, gn], "errcount"),
        ],
        params: Some(params),
        ft_level: Some(level.to_string()),
        max_inj: MAX_INJ,
        verify_every,
        sub_m,
        sub_n,
    }
}

fn builtin_ding(b: &crate::codegen::select::Bucket, ks: usize) -> Vec<Artifact> {
    let (m, n, k) = (b.m, b.n, b.k);
    let base = |name: String, kind: ArtifactKind, inputs, outputs| Artifact {
        file: PathBuf::from("<builtin>").join(format!("{name}.hlo.txt")),
        name,
        kind,
        bucket: b.name().to_string(),
        m,
        n,
        k,
        ks,
        inputs,
        outputs,
        params: Some(b.class.params()),
        ft_level: None,
        max_inj: 0,
        verify_every: 0,
        sub_m: 0,
        sub_n: 0,
    };
    vec![
        base(
            format!("ding_encode_{}", b.name()),
            ArtifactKind::DingEncode,
            vec![tensor(&[m, k], ""), tensor(&[k, n], "")],
            vec![tensor(&[m + 1, k], "ac"), tensor(&[k, n + 1], "br")],
        ),
        base(
            format!("ding_step_{}", b.name()),
            ArtifactKind::DingStep,
            vec![
                tensor(&[m + 1, n + 1], ""),
                tensor(&[m + 1, ks], ""),
                tensor(&[ks, n + 1], ""),
            ],
            vec![tensor(&[m + 1, n + 1], "cf")],
        ),
        base(
            format!("ding_verify_{}", b.name()),
            ArtifactKind::DingVerify,
            vec![tensor(&[m + 1, n + 1], "")],
            vec![tensor(&[m + 1, n + 1], "cf"), tensor(&[], "errcount")],
        ),
    ]
}

fn parse_tensor(j: &Json) -> Result<TensorSpec> {
    let shape = j
        .path("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("tensor missing shape"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = j
        .path("dtype")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("tensor missing dtype"))?
        .to_string();
    let role = j
        .path("role")
        .and_then(Json::as_str)
        .unwrap_or_default()
        .to_string();
    Ok(TensorSpec { shape, dtype, role })
}

fn parse_artifact(j: &Json, dir: &Path) -> Result<Artifact> {
    let name = j
        .path("name")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("artifact missing name"))?
        .to_string();
    let file = dir.join(
        j.path("file")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("{name}: missing file"))?,
    );
    let meta = j.path("meta").ok_or_else(|| anyhow!("{name}: missing meta"))?;
    let kind = ArtifactKind::parse(
        meta.path("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("{name}: missing kind"))?,
    )?;
    let dim = |key: &str| meta.path(key).and_then(Json::as_usize).unwrap_or(0);
    let params = meta.path("params").map(KernelParams::from_json).transpose()?;
    let inputs = j
        .path("inputs")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("{name}: missing inputs"))?
        .iter()
        .map(parse_tensor)
        .collect::<Result<Vec<_>>>()?;
    let outputs = j
        .path("outputs")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("{name}: missing outputs"))?
        .iter()
        .map(parse_tensor)
        .collect::<Result<Vec<_>>>()?;
    Ok(Artifact {
        name: name.clone(),
        file,
        kind,
        bucket: meta
            .path("bucket")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string(),
        m: dim("m"),
        n: dim("n"),
        k: dim("k"),
        ks: dim("ks"),
        inputs,
        outputs,
        params,
        ft_level: meta
            .path("ft_level")
            .and_then(Json::as_str)
            .map(str::to_string),
        max_inj: dim("max_inj"),
        verify_every: dim("verify_every"),
        sub_m: dim("sub_m"),
        sub_n: dim("sub_n"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "artifacts": [
        {
          "name": "gemm_small",
          "file": "gemm_small.hlo.txt",
          "inputs": [
            {"shape": [64, 64], "dtype": "float32"},
            {"shape": [64, 64], "dtype": "float32"}
          ],
          "outputs": [{"role": "c", "shape": [64, 64], "dtype": "float32"}],
          "meta": {"kind": "gemm", "bucket": "small", "m": 64, "n": 64, "k": 64,
                   "params": {"m_tb": 16, "n_tb": 16, "k_tb": 16,
                               "m_w": 8, "n_w": 16, "m_t": 2, "n_t": 2}}
        },
        {
          "name": "ftgemm_tb_small",
          "file": "ftgemm_tb_small.hlo.txt",
          "inputs": [
            {"shape": [64, 64], "dtype": "float32"},
            {"shape": [64, 64], "dtype": "float32"},
            {"shape": [8, 4], "dtype": "float32"}
          ],
          "outputs": [
            {"role": "c", "shape": [64, 64], "dtype": "float32"},
            {"role": "cr", "shape": [4, 4, 1, 16, 1], "dtype": "float32"},
            {"role": "cc", "shape": [4, 4, 1, 1, 16], "dtype": "float32"},
            {"role": "errcount", "shape": [4, 4], "dtype": "float32"}
          ],
          "meta": {"kind": "ftgemm", "bucket": "small", "m": 64, "n": 64, "k": 64,
                   "ft_level": "tb", "max_inj": 8, "verify_every": 8}
        }
      ]
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.len(), 2);
        let g = m.get("gemm_small").unwrap();
        assert_eq!(g.kind, ArtifactKind::Gemm);
        assert_eq!((g.m, g.n, g.k), (64, 64, 64));
        assert_eq!(g.params.as_ref().unwrap().m_tb, 16);
        let ft = m.get("ftgemm_tb_small").unwrap();
        assert_eq!(ft.kind, ArtifactKind::FtGemm);
        assert_eq!(ft.ft_level.as_deref(), Some("tb"));
        assert_eq!(ft.output_index("errcount"), Some(3));
        assert_eq!(ft.max_inj, 8);
    }

    #[test]
    fn find_by_kind_bucket_level() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert!(m.find(ArtifactKind::FtGemm, "small", Some("tb")).is_some());
        assert!(m.find(ArtifactKind::FtGemm, "small", Some("warp")).is_none());
        assert!(m.find(ArtifactKind::Gemm, "small", None).is_some());
    }

    #[test]
    fn unknown_artifact_errors() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn rejects_bad_format_version() {
        assert!(Manifest::parse(r#"{"format": 9, "artifacts": []}"#, PathBuf::new()).is_err());
    }

    #[test]
    fn builtin_manifest_mirrors_python_registry() {
        let m = Manifest::builtin();
        assert!(m.is_builtin());
        assert_eq!(m.len(), 28, "5 gemm + 5 ft_tb + 6 level/detect + 9 ding + 3 ablation");
        for b in crate::codegen::select::BUCKETS {
            assert!(m.find(ArtifactKind::Gemm, b.name(), None).is_some(), "{}", b.name());
            assert!(m.find(ArtifactKind::FtGemm, b.name(), Some("tb")).is_some());
        }
        // warp/thread/detect only where the scheme comparison runs
        assert!(m.find(ArtifactKind::FtGemm, "medium", Some("warp")).is_some());
        assert!(m.find(ArtifactKind::FtGemm, "huge", Some("thread")).is_some());
        assert!(m.find(ArtifactKind::FtDetect, "medium", None).is_some());
        assert!(m.find(ArtifactKind::FtDetect, "small", None).is_none());
        // ding stages for medium/large/huge only
        assert!(m.find(ArtifactKind::DingStep, "medium", None).is_some());
        assert!(m.find(ArtifactKind::DingEncode, "small", None).is_none());
        let ft = m.get("ftgemm_tb_huge").unwrap();
        assert_eq!((ft.sub_m, ft.sub_n), (128, 128));
        assert_eq!(ft.max_inj, MAX_INJ);
        assert_eq!(ft.output_index("errcount"), Some(3));
        // ablation variants are invisible to the router (suffixed bucket)
        let ve = m.get("ftgemm_tb_medium_ve16").unwrap();
        assert_eq!(ve.verify_every, 16);
        assert_eq!(ve.bucket, "medium_ve16");
        // ding shapes carry the encoded row/column
        let step = m.get("ding_step_huge").unwrap();
        assert_eq!(step.ks, 256);
        assert_eq!(step.inputs[1].shape, vec![513, 256]);
    }

    #[test]
    fn loads_real_manifest_if_built() {
        if let Ok(m) = Manifest::discover() {
            assert!(m.len() >= 20, "expected full artifact set, got {}", m.len());
            assert!(m.find(ArtifactKind::FtGemm, "huge", Some("tb")).is_some());
            for a in m.iter() {
                assert!(a.file.exists(), "{:?} missing", a.file);
            }
        }
    }
}
