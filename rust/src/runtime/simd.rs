//! Runtime-dispatched SIMD micro-kernels and checksum folds for
//! [`BlockedBackend`](super::blocked::BlockedBackend).
//!
//! The paper's fused-ABFT kernels keep both the C accumulators and the
//! checksum accumulators in vector registers (§4); this module is the
//! host-level analogue, in the FT-BLAS / FT-GEMM-on-x86 style:
//!
//! * **[`KernelIsa`]** — the ISA a backend instance dispatches to,
//!   detected once at construction via `is_x86_feature_detected!` /
//!   aarch64 NEON availability, overridable with `FTGEMM_FORCE_SCALAR`.
//! * **Micro-kernels** — AVX2+FMA 8x8, AVX-512F 8x16 (behind the
//!   `avx512` cargo feature: its intrinsics postdate the crate MSRV),
//!   and NEON 8x8. Each loads the MRxNR accumulator tile from the macro
//!   tile, carries it in vector registers across one `kc`-deep reduction
//!   panel, and stores it back — f32 loads/stores are exact, so chaining
//!   panels in ascending `k` produces the same single ascending-`k` fold
//!   per element as a register-resident full-`k` sweep (and as the
//!   scalar `micro_into`), bitwise, at any `kc`. The only numerical
//!   divergence from the reference backend is FMA's fused rounding (one
//!   rounding per multiply-add instead of two). See DESIGN.md "Kernel
//!   dispatch" and "Blocking hierarchy".
//! * **Canonical checksum folds** — [`fold8`]/[`sum8`] define ONE
//!   lane-split summation order for the B-side operand sums (`B·e`),
//!   used identically by the scalar path, the SIMD packing fast paths,
//!   and the reference backend's `tile_carried_checksums`, so carried
//!   checksums stay **bit-identical** across backends and ISAs and the
//!   parity suite's exact errcount-grid equality survives
//!   vectorization. A-side sums (`eᵀ·A`) keep the ascending-`i` order:
//!   SIMD lanes run along `k` there, which preserves the scalar
//!   per-lane fold exactly.

/// Lane width of the canonical checksum fold (f32 lanes in a 256-bit
/// vector). Fixed regardless of the ISA actually executing — AVX-512
/// and NEON paths reduce to the same 8-lane shape.
pub const LANES: usize = 8;

/// Which micro-kernel family a `BlockedBackend` instance dispatches to.
///
/// Detected once per instance ([`KernelIsa::detect`]); every variant is
/// defined on every architecture so the type is portable, but `detect`
/// only ever returns a variant the running host supports.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelIsa {
    /// Portable scalar `micro_into::<MR, NR>` fallback.
    Scalar,
    /// x86-64 AVX2 + FMA, 8x8 accumulator tile.
    Avx2Fma,
    /// x86-64 AVX-512F, 8x16 accumulator tile (requires the `avx512`
    /// cargo feature; the intrinsics were stabilized after our MSRV).
    Avx512,
    /// aarch64 NEON, 8x8 accumulator tile in 4-lane register pairs.
    Neon,
}

impl KernelIsa {
    /// Short stable identifier, used in `BackendInfo`, bench JSON and
    /// log lines.
    pub fn name(self) -> &'static str {
        match self {
            KernelIsa::Scalar => "scalar",
            KernelIsa::Avx2Fma => "avx2",
            KernelIsa::Avx512 => "avx512",
            KernelIsa::Neon => "neon",
        }
    }

    /// True when the `FTGEMM_FORCE_SCALAR` override is active (set to
    /// anything other than empty or `0`).
    pub fn force_scalar_requested() -> bool {
        std::env::var("FTGEMM_FORCE_SCALAR")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false)
    }

    /// Pick the widest ISA the host supports, honoring
    /// `FTGEMM_FORCE_SCALAR`. Called once per backend construction, not
    /// per kernel invocation.
    pub fn detect() -> Self {
        if Self::force_scalar_requested() {
            return KernelIsa::Scalar;
        }
        Self::widest_supported()
    }

    /// The widest host-supported ISA, ignoring the env override.
    fn widest_supported() -> Self {
        *Self::supported().last().unwrap_or(&KernelIsa::Scalar)
    }

    /// Every ISA the running host can execute, narrowest first (always
    /// includes `Scalar`), independent of the env override — the parity
    /// property suite iterates this to hold each variant equal to the
    /// reference backend, and backend construction refuses to pin an
    /// ISA outside this list (the `unsafe` kernel calls lean on that).
    ///
    /// `Avx512` additionally requires AVX2+FMA (true of every AVX-512F
    /// part): its packing fast paths reuse the AVX2 encode kernels.
    pub fn supported() -> Vec<Self> {
        let mut isas = vec![KernelIsa::Scalar];
        #[cfg(target_arch = "x86_64")]
        {
            let avx2 = std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("fma");
            if avx2 {
                isas.push(KernelIsa::Avx2Fma);
            }
            #[cfg(feature = "avx512")]
            if avx2 && std::arch::is_x86_feature_detected!("avx512f") {
                isas.push(KernelIsa::Avx512);
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                isas.push(KernelIsa::Neon);
            }
        }
        isas
    }

    /// Whether this variant uses vector packing fast paths.
    pub fn is_simd(self) -> bool {
        self != KernelIsa::Scalar
    }
}

// ---------------------------------------------------------------------
// Canonical checksum fold
// ---------------------------------------------------------------------

/// Reduce 8 lane partials with the fixed binary tree every backend and
/// ISA shares:
///
/// ```text
/// ((l0+l4) + (l2+l6)) + ((l1+l5) + (l3+l7))
/// ```
///
/// This is the classic lo+hi / movehl / shuffle horizontal-add shape, so
/// vector reductions can produce bit-identical results to the scalar
/// path by storing their accumulator lanes and calling this.
#[inline]
pub fn fold8(l: [f32; LANES]) -> f32 {
    ((l[0] + l[4]) + (l[2] + l[6])) + ((l[1] + l[5]) + (l[3] + l[7]))
}

/// Canonical sum of a slice: element `t` goes to lane `t % 8`, lanes
/// accumulate in ascending order, then [`fold8`]. Slices shorter than 8
/// leave the tail lanes at exactly `0.0`, which is additive identity, so
/// short tiles reduce to plain left-to-right sums of their permuted
/// terms. This is THE summation order for B-side operand sums (`B·e`)
/// everywhere: reference backend, scalar blocked path, SIMD packing.
#[inline]
pub fn sum8(xs: &[f32]) -> f32 {
    let mut lanes = [0.0f32; LANES];
    for (t, &v) in xs.iter().enumerate() {
        lanes[t % LANES] += v;
    }
    fold8(lanes)
}

// ---------------------------------------------------------------------
// x86-64: AVX2+FMA (and feature-gated AVX-512F)
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
pub(crate) mod x86 {
    use super::{fold8, LANES};
    use crate::abft::matrix::Matrix;
    use core::arch::x86_64::*;

    /// 8x8 AVX2+FMA micro-kernel, panel-carried: load the eight 8-lane C
    /// accumulators from the macro tile (`out[idx0 + r * stride ..]`),
    /// fold one `kc`-deep reduction panel on top in registers (ascending
    /// `kk`, FMA rounding), and store them back. Exact f32 round trips
    /// make a chain of these calls bitwise equal to one full-`k` sweep.
    ///
    /// # Safety
    /// Caller must have verified `avx2` and `fma` at backend
    /// construction; `pap`/`pbp` hold at least `kc * 8` packed elements
    /// each, and `out[idx0 + r * stride .. + 8]` is in bounds for
    /// `r < 8`.
    #[target_feature(enable = "avx2,fma")]
    pub(crate) unsafe fn micro_8x8(
        kc: usize,
        pap: &[f32],
        pbp: &[f32],
        out: &mut [f32],
        idx0: usize,
        stride: usize,
    ) {
        debug_assert!(pap.len() >= kc * 8 && pbp.len() >= kc * 8);
        debug_assert!(idx0 + 7 * stride + 8 <= out.len());
        let mut acc = [_mm256_setzero_ps(); 8];
        for (r, a) in acc.iter_mut().enumerate() {
            *a = _mm256_loadu_ps(out.as_ptr().add(idx0 + r * stride));
        }
        for kk in 0..kc {
            let bv = _mm256_loadu_ps(pbp.as_ptr().add(kk * 8));
            let af = pap.as_ptr().add(kk * 8);
            for (r, a) in acc.iter_mut().enumerate() {
                let av = _mm256_broadcast_ss(&*af.add(r));
                *a = _mm256_fmadd_ps(av, bv, *a);
            }
        }
        for (r, a) in acc.iter().enumerate() {
            _mm256_storeu_ps(out.as_mut_ptr().add(idx0 + r * stride), *a);
        }
    }

    /// 8x16 AVX-512F micro-kernel, panel-carried: eight 16-lane C
    /// accumulators loaded from / stored back to the macro tile (same
    /// carried-panel contract as [`micro_8x8`]).
    ///
    /// # Safety
    /// Caller must have verified `avx512f`; `pap` holds `kc * 8` and
    /// `pbp` holds `kc * 16` packed elements, and
    /// `out[idx0 + r * stride .. + 16]` is in bounds for `r < 8`.
    #[cfg(feature = "avx512")]
    #[target_feature(enable = "avx512f")]
    pub(crate) unsafe fn micro_8x16(
        kc: usize,
        pap: &[f32],
        pbp: &[f32],
        out: &mut [f32],
        idx0: usize,
        stride: usize,
    ) {
        debug_assert!(pap.len() >= kc * 8 && pbp.len() >= kc * 16);
        debug_assert!(idx0 + 7 * stride + 16 <= out.len());
        let mut acc = [_mm512_setzero_ps(); 8];
        for (r, a) in acc.iter_mut().enumerate() {
            *a = _mm512_loadu_ps(out.as_ptr().add(idx0 + r * stride));
        }
        for kk in 0..kc {
            let bv = _mm512_loadu_ps(pbp.as_ptr().add(kk * 16));
            let af = pap.as_ptr().add(kk * 8);
            for (r, a) in acc.iter_mut().enumerate() {
                let av = _mm512_set1_ps(*af.add(r));
                *a = _mm512_fmadd_ps(av, bv, *a);
            }
        }
        for (r, a) in acc.iter().enumerate() {
            _mm512_storeu_ps(out.as_mut_ptr().add(idx0 + r * stride), *a);
        }
    }

    /// Fused B-panel store + column-sum for one protection-tile row
    /// segment: streams 8-wide chunks of `seg` into the packed panel
    /// buffer while a vector accumulator stays register-resident across
    /// the whole segment, then reduces it through the canonical
    /// [`fold8`] tree. Bit-identical to the portable lane-cycling path
    /// by construction (lane `t % 8` accumulates element `t`).
    ///
    /// `off0` is the segment's offset inside the pack block; caller
    /// guarantees `off0 % 8 == 0` and `nr % 8 == 0` so every 8-chunk is
    /// contiguous in the panel layout.
    ///
    /// # Safety
    /// Caller must have verified `avx2` at backend construction.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn pack_colsum(
        seg: &[f32],
        out: &mut [f32],
        off0: usize,
        nr: usize,
        k: usize,
        kk: usize,
    ) -> f32 {
        debug_assert!(off0 % LANES == 0 && nr % LANES == 0);
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i + LANES <= seg.len() {
            let v = _mm256_loadu_ps(seg.as_ptr().add(i));
            acc = _mm256_add_ps(acc, v);
            let off = off0 + i;
            let idx = (off / nr) * k * nr + kk * nr + (off % nr);
            _mm256_storeu_ps(out.as_mut_ptr().add(idx), v);
            i += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        // Tail (< 8 wide) continues the lane cycle from lane 0 — `i` is
        // a multiple of LANES here, matching the portable path exactly.
        for (t, &v) in seg[i..].iter().enumerate() {
            let off = off0 + i + t;
            out[(off / nr) * k * nr + kk * nr + (off % nr)] = v;
            lanes[t] += v;
        }
        fold8(lanes)
    }

    /// Vector-resident A-side encode for one tile-bounded row run over
    /// one reduction panel: `ea_seg[kk] += a[i][kk0 + kk]` for `i` in
    /// `[r0, r1)`, with the 8-lane accumulator (lanes = adjacent `kk`)
    /// held in a register across the whole run. Per `kk` lane the adds
    /// land in ascending `i` — the scalar sink's fold order, bit-exactly
    /// — and panels partition `kk`, so per-panel calls compose into the
    /// identical full-`k` checksum row.
    ///
    /// # Safety
    /// Caller must have verified `avx2` at backend construction, and
    /// `kk0 + ea_seg.len() <= a.cols()`.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn encode_rows(
        a: &Matrix,
        r0: usize,
        r1: usize,
        kk0: usize,
        ea_seg: &mut [f32],
    ) {
        let kb = ea_seg.len();
        let mut kk = 0;
        while kk + LANES <= kb {
            let mut acc = _mm256_loadu_ps(ea_seg.as_ptr().add(kk));
            for i in r0..r1 {
                acc = _mm256_add_ps(acc, _mm256_loadu_ps(a.row(i).as_ptr().add(kk0 + kk)));
            }
            _mm256_storeu_ps(ea_seg.as_mut_ptr().add(kk), acc);
            kk += LANES;
        }
        for kk in kk..kb {
            for i in r0..r1 {
                ea_seg[kk] += a.row(i)[kk0 + kk];
            }
        }
    }
}

// ---------------------------------------------------------------------
// aarch64: NEON
// ---------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
pub(crate) mod neon {
    use super::{fold8, LANES};
    use crate::abft::matrix::Matrix;
    use core::arch::aarch64::*;

    /// 8x8 NEON micro-kernel, panel-carried: eight rows of two 4-lane C
    /// accumulators loaded from the macro tile, folded across one
    /// `kc`-deep reduction panel (FMA rounding, ascending `kk`), and
    /// stored back — the same exact-round-trip carried-panel contract as
    /// the AVX2 kernel.
    ///
    /// # Safety
    /// NEON availability verified at backend construction; `pap`/`pbp`
    /// hold at least `kc * 8` packed elements each, and
    /// `out[idx0 + r * stride .. + 8]` is in bounds for `r < 8`.
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn micro_8x8(
        kc: usize,
        pap: &[f32],
        pbp: &[f32],
        out: &mut [f32],
        idx0: usize,
        stride: usize,
    ) {
        debug_assert!(pap.len() >= kc * 8 && pbp.len() >= kc * 8);
        debug_assert!(idx0 + 7 * stride + 8 <= out.len());
        let zero = vdupq_n_f32(0.0);
        let mut acc = [[zero; 2]; 8];
        for (r, a) in acc.iter_mut().enumerate() {
            a[0] = vld1q_f32(out.as_ptr().add(idx0 + r * stride));
            a[1] = vld1q_f32(out.as_ptr().add(idx0 + r * stride + 4));
        }
        for kk in 0..kc {
            let b0 = vld1q_f32(pbp.as_ptr().add(kk * 8));
            let b1 = vld1q_f32(pbp.as_ptr().add(kk * 8 + 4));
            let af = pap.as_ptr().add(kk * 8);
            for (r, a) in acc.iter_mut().enumerate() {
                let av = vdupq_n_f32(*af.add(r));
                a[0] = vfmaq_f32(a[0], b0, av);
                a[1] = vfmaq_f32(a[1], b1, av);
            }
        }
        for (r, a) in acc.iter().enumerate() {
            vst1q_f32(out.as_mut_ptr().add(idx0 + r * stride), a[0]);
            vst1q_f32(out.as_mut_ptr().add(idx0 + r * stride + 4), a[1]);
        }
    }

    /// NEON twin of the AVX2 `pack_colsum`: two 4-lane accumulators
    /// stand in for the 8-lane AVX register; lane `t % 8` still
    /// accumulates element `t`, reduced through [`fold8`].
    ///
    /// # Safety
    /// NEON availability verified at backend construction; caller
    /// guarantees `off0 % 8 == 0` and `nr % 8 == 0`.
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn pack_colsum(
        seg: &[f32],
        out: &mut [f32],
        off0: usize,
        nr: usize,
        k: usize,
        kk: usize,
    ) -> f32 {
        debug_assert!(off0 % LANES == 0 && nr % LANES == 0);
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut i = 0;
        while i + LANES <= seg.len() {
            let v0 = vld1q_f32(seg.as_ptr().add(i));
            let v1 = vld1q_f32(seg.as_ptr().add(i + 4));
            acc0 = vaddq_f32(acc0, v0);
            acc1 = vaddq_f32(acc1, v1);
            let off = off0 + i;
            let idx = (off / nr) * k * nr + kk * nr + (off % nr);
            vst1q_f32(out.as_mut_ptr().add(idx), v0);
            vst1q_f32(out.as_mut_ptr().add(idx + 4), v1);
            i += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        vst1q_f32(lanes.as_mut_ptr(), acc0);
        vst1q_f32(lanes.as_mut_ptr().add(4), acc1);
        for (t, &v) in seg[i..].iter().enumerate() {
            let off = off0 + i + t;
            out[(off / nr) * k * nr + kk * nr + (off % nr)] = v;
            lanes[t] += v;
        }
        fold8(lanes)
    }

    /// NEON twin of the AVX2 `encode_rows`: vector-resident A-side
    /// row-run encode over one reduction panel (`ea_seg[kk] +=
    /// a[i][kk0 + kk]`), ascending `i` per `kk` lane; panels partition
    /// `kk`, so per-panel calls compose into the identical full-`k`
    /// checksum row.
    ///
    /// # Safety
    /// NEON availability verified at backend construction, and
    /// `kk0 + ea_seg.len() <= a.cols()`.
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn encode_rows(
        a: &Matrix,
        r0: usize,
        r1: usize,
        kk0: usize,
        ea_seg: &mut [f32],
    ) {
        let kb = ea_seg.len();
        let mut kk = 0;
        while kk + 4 <= kb {
            let mut acc = vld1q_f32(ea_seg.as_ptr().add(kk));
            for i in r0..r1 {
                acc = vaddq_f32(acc, vld1q_f32(a.row(i).as_ptr().add(kk0 + kk)));
            }
            vst1q_f32(ea_seg.as_mut_ptr().add(kk), acc);
            kk += 4;
        }
        for kk in kk..kb {
            for i in r0..r1 {
                ea_seg[kk] += a.row(i)[kk0 + kk];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fold8_matches_documented_tree() {
        let l = [1.0f32, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0];
        let want = ((1.0f32 + 16.0) + (4.0 + 64.0)) + ((2.0 + 32.0) + (8.0 + 128.0));
        assert_eq!(fold8(l), want);
    }

    #[test]
    fn sum8_handles_short_and_unaligned_lengths() {
        for len in [0usize, 1, 2, 3, 7, 8, 9, 15, 16, 17, 31, 64] {
            let xs: Vec<f32> = (0..len).map(|i| (i as f32) - 0.5).collect();
            let got = sum8(&xs);
            // exact reference: replay the lane cycle in plain code
            let mut lanes = [0.0f32; LANES];
            for (t, &v) in xs.iter().enumerate() {
                lanes[t % LANES] += v;
            }
            assert_eq!(got, fold8(lanes), "len {len}");
        }
    }

    #[test]
    fn detect_returns_a_supported_isa() {
        // Env-override behavior is pinned by the blocked backend's
        // `force_scalar_env_pins_the_scalar_kernel` test — the only
        // test that touches FTGEMM_FORCE_SCALAR, to keep the parallel
        // test harness race-free.
        assert!(KernelIsa::supported().contains(&KernelIsa::detect()));
    }

    #[test]
    fn supported_always_includes_scalar_first() {
        let isas = KernelIsa::supported();
        assert_eq!(isas[0], KernelIsa::Scalar);
        for isa in isas {
            assert!(!isa.name().is_empty());
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_micro_kernel_accumulates_across_panels_bit_identically() {
        if !(std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma"))
        {
            return;
        }
        // The carried-accumulator contract: splitting the reduction into
        // kc panels (exact f32 store/reload between them) must reproduce
        // the single full-k sweep bitwise, for any split.
        let k = 24usize;
        let pap: Vec<f32> = (0..k * 8).map(|i| ((i * 37 % 61) as f32) * 0.125 - 3.0).collect();
        let pbp: Vec<f32> = (0..k * 8).map(|i| ((i * 53 % 71) as f32) * 0.0625 - 2.0).collect();
        let stride = 11usize; // deliberately != 8: padded-tile strides
        let mut full = vec![0.5f32; 8 * stride];
        let mut split = full.clone();
        unsafe { x86::micro_8x8(k, &pap, &pbp, &mut full, 0, stride) };
        for (k0, kb) in [(0usize, 10usize), (10, 9), (19, 5)] {
            unsafe {
                x86::micro_8x8(kb, &pap[k0 * 8..], &pbp[k0 * 8..], &mut split, 0, stride)
            };
        }
        assert_eq!(full, split);
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_kernels_are_bit_identical_to_canonical_folds() {
        if !std::arch::is_x86_feature_detected!("avx2") {
            return;
        }
        // pack_colsum must agree with sum8 exactly, stores included.
        let k = 3usize;
        let nr = 8usize;
        for len in [4usize, 8, 11, 16, 24, 29] {
            let seg: Vec<f32> = (0..len).map(|i| (i as f32) * 0.25 - 1.0).collect();
            let mut out = vec![0.0f32; len.div_ceil(nr) * k * nr];
            let kk = 1;
            let got = unsafe { x86::pack_colsum(&seg, &mut out, 0, nr, k, kk) };
            assert_eq!(got, sum8(&seg), "len {len}");
            for (t, &v) in seg.iter().enumerate() {
                let idx = (t / nr) * k * nr + kk * nr + (t % nr);
                assert_eq!(out[idx], v, "len {len} store {t}");
            }
        }
    }
}
