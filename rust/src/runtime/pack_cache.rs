//! Cross-request packed-operand & checksum cache.
//!
//! Packing an operand into micro-panel order and fusing the ABFT
//! checksum encode into the pack loop (`blocked::pack_a_encode` /
//! `pack_b_encode`) is pure memory-bandwidth work that is recomputed
//! identically on every request — yet the workloads the serving tier
//! targets are dominated by operand reuse: a fault campaign replays the
//! same `Arc`-shared matrices every round, NN inference replays weight
//! matrices across thousands of requests, and the wire protocol is
//! already content-addressed (operands materialize from a seed). This
//! module provides the content-addressed cache those paths share.
//!
//! **Keying.** A cache entry is one operand's complete packed form for
//! one kernel configuration: every macro-block panel plus every
//! per-protection-tile checksum sum (eᵀA row sums for A, Be column
//! sums for B). The key ([`PanelKey`]) therefore spans everything that
//! changes the packed bytes: the operand's identity and the sub-rectangle
//! + zero-padding geometry ([`OperandKey`]), the operand's role (A or
//! B), the macro-block and micro-tile widths from the selected
//! [`HostTiles`](crate::codegen::select::HostTiles), the dispatched
//! [`KernelIsa`], and the protection-tile extent. Operand identity
//! ([`OperandId`]) comes from two sources: pointer identity for
//! `Arc`-shared matrices (zero hashing of element data; an ABA
//! generation stamp guards address reuse — see
//! `coordinator::request::ptr_operand_id`) and the wire `(rows, cols,
//! seed)` tuple for gateway requests, which lets the gateway skip
//! re-materialization entirely on a hit.
//!
//! **Immutability.** Cached panels and sums are handed out behind
//! `Arc`s and are never written after insertion. The blocked backend's
//! verify/correct sweeps already honor this by construction: injected
//! values are *keyed into* the per-tile recompute closures, never
//! written through the shared panels, so a cached panel observed by a
//! thousand requests stays bitwise identical to a fresh pack — which is
//! what keeps detection decisions and errcount grids unchanged with the
//! cache on (pinned by the cached-vs-fresh parity tests in
//! `runtime::blocked`).
//!
//! **Eviction.** Byte-budget LRU under a single mutex: every `get`
//! bumps a recency tick, every `insert` evicts least-recently-used
//! entries until the budget holds. An entry larger than the whole
//! budget is simply not cached. A zero budget disables the cache — the
//! engine then plumbs `None` instead of constructing one, so the hot
//! path pays nothing.
//!
//! One cache instance lives **per engine pool**, next to that pool's
//! warm-executable cache: shards stay disjoint, so the coordinator's
//! affinity routing naturally concentrates a shape class's panels (and
//! now its packed operands) on one pool.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::simd::KernelIsa;

/// Content address of an operand matrix, independent of where its bytes
/// currently live.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OperandId {
    /// Pointer identity of an `Arc<Matrix>` plus an ABA generation
    /// stamp: equal only when it is provably the *same live allocation*
    /// (see `coordinator::request::ptr_operand_id`).
    Ptr { addr: usize, gen: u64 },
    /// Wire-level content address: the operand is (or would be)
    /// `Matrix::rand_uniform(rows, cols, seed)`.
    Seed { rows: usize, cols: usize, seed: u64 },
}

/// An operand sub-rectangle as the packing routines see it: a window
/// into the identified matrix plus the zero-padded target dimensions
/// the panels are packed to. Split GEMMs pack per-block windows, so the
/// window geometry is part of the address.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct OperandKey {
    pub id: OperandId,
    /// Window origin within the source matrix.
    pub row0: usize,
    pub col0: usize,
    /// Window extent (source elements actually copied).
    pub rows: usize,
    pub cols: usize,
    /// Padded extent the pack targets (bucket dims; >= rows/cols).
    pub pad_rows: usize,
    pub pad_cols: usize,
}

impl OperandKey {
    /// Key for a whole, unpadded operand.
    pub fn whole(id: OperandId, rows: usize, cols: usize) -> Self {
        OperandKey { id, row0: 0, col0: 0, rows, cols, pad_rows: rows, pad_cols: cols }
    }
}

/// Which side of the GEMM the panels feed (A packs row panels with
/// eᵀA sums; B packs column panels with Be sums).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PanelRole {
    A,
    B,
}

/// Full cache key: operand window × role × blocking geometry × ISA ×
/// protection-tile extent. Two requests share an entry exactly when
/// the packed bytes and fused checksums would be bitwise identical.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PanelKey {
    pub op: OperandKey,
    pub role: PanelRole,
    /// Macro-block extent along the packed axis: `mc` for A, `nc` for B.
    pub block: usize,
    /// Micro-tile extent: `mr` for A, `nr` for B.
    pub micro: usize,
    /// Reduction-panel depth (KC) the panels were packed for. The
    /// packed byte layout is k-panel-major, so two KC values lay the
    /// same operand out differently — the key keeps them apart even
    /// when every other field matches (e.g. an `FTGEMM_FORCE_KC` run
    /// sharing a pool cache with default-depth traffic).
    pub kc: usize,
    /// Kernel ISA the panels were packed for (panel layout and the
    /// canonical checksum fold order are ISA-keyed).
    pub isa: KernelIsa,
    /// Protection-tile extent (`sub_m` for A, `sub_n` for B); 0 means a
    /// plain pack with no fused sums (the non-FT GEMM path).
    pub prot: usize,
}

/// One cached value: every macro-block panel for the operand, plus the
/// per-protection-tile checksum sums fused into the pack (empty when
/// `PanelKey::prot == 0`). Both are shared immutably.
#[derive(Debug, Clone)]
pub struct PackedOperand {
    pub panels: Arc<Vec<Vec<f32>>>,
    pub sums: Arc<Vec<Vec<f32>>>,
}

impl PackedOperand {
    /// Heap footprint used against the cache's byte budget.
    pub fn bytes(&self) -> usize {
        let f = std::mem::size_of::<f32>();
        let panels: usize = self.panels.iter().map(|p| p.len() * f).sum();
        let sums: usize = self.sums.iter().map(|s| s.len() * f).sum();
        panels + sums
    }
}

/// Monotonic counters + a live-size snapshot, cheap enough to read on
/// every `metrics` verb hit.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PackCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub bytes: u64,
    pub entries: u64,
}

impl PackCacheStats {
    pub fn merge(&mut self, other: &PackCacheStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.evictions += other.evictions;
        self.bytes += other.bytes;
        self.entries += other.entries;
    }
}

struct Entry {
    value: PackedOperand,
    bytes: usize,
    tick: u64,
}

struct Inner {
    map: HashMap<PanelKey, Entry>,
    bytes: usize,
    tick: u64,
}

/// Byte-budget LRU cache of [`PackedOperand`]s, one per engine pool.
///
/// Shared across that pool's worker threads behind an `Arc`; the map
/// mutex is held only for lookup/insert bookkeeping (values are `Arc`
/// clones out), never across a pack.
pub struct PackCache {
    inner: Mutex<Inner>,
    budget: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl std::fmt::Debug for PackCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("PackCache").field("budget", &self.budget).field("stats", &s).finish()
    }
}

impl PackCache {
    /// A cache bounded to `budget_bytes` of packed f32 payload.
    pub fn new(budget_bytes: usize) -> Self {
        PackCache {
            inner: Mutex::new(Inner { map: HashMap::new(), bytes: 0, tick: 0 }),
            budget: budget_bytes,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Convenience constructor from the `pack_cache_mb` config knob;
    /// `None` when `mb == 0` (the cache is disabled, not merely empty).
    pub fn from_config_mb(mb: usize) -> Option<Arc<PackCache>> {
        if mb == 0 {
            None
        } else {
            Some(Arc::new(PackCache::new(mb * 1024 * 1024)))
        }
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget
    }

    /// Look up a packed operand, bumping its recency on a hit. Counts
    /// a hit or miss either way.
    pub fn get(&self, key: &PanelKey) -> Option<PackedOperand> {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get_mut(key) {
            Some(e) => {
                e.tick = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(e.value.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert a freshly-packed operand, evicting LRU entries until the
    /// byte budget holds. A value larger than the entire budget is not
    /// cached (it would only evict everything to then thrash).
    pub fn insert(&self, key: PanelKey, value: PackedOperand) {
        let bytes = value.bytes();
        if bytes > self.budget {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(old) = inner.map.remove(&key) {
            inner.bytes -= old.bytes;
        }
        while inner.bytes + bytes > self.budget && !inner.map.is_empty() {
            let victim = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.tick)
                .map(|(k, _)| *k)
                .expect("non-empty map has a minimum");
            if let Some(e) = inner.map.remove(&victim) {
                inner.bytes -= e.bytes;
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        inner.bytes += bytes;
        inner.map.insert(key, Entry { value, bytes, tick });
    }

    /// Counter + occupancy snapshot.
    pub fn stats(&self) -> PackCacheStats {
        let inner = self.inner.lock().unwrap();
        PackCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            bytes: inner.bytes as u64,
            entries: inner.map.len() as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(tag: u64, prot: usize) -> PanelKey {
        PanelKey {
            op: OperandKey::whole(OperandId::Seed { rows: 8, cols: 8, seed: tag }, 8, 8),
            role: PanelRole::A,
            block: 64,
            micro: 8,
            kc: 64,
            isa: KernelIsa::Scalar,
            prot,
        }
    }

    fn value(floats: usize) -> PackedOperand {
        PackedOperand {
            panels: Arc::new(vec![vec![0.5; floats]]),
            sums: Arc::new(Vec::new()),
        }
    }

    #[test]
    fn hit_returns_the_inserted_value_and_counts() {
        let c = PackCache::new(1 << 20);
        assert!(c.get(&key(1, 16)).is_none());
        c.insert(key(1, 16), value(100));
        let got = c.get(&key(1, 16)).expect("inserted key hits");
        assert_eq!(got.panels[0].len(), 100);
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert_eq!(s.bytes, 400);
    }

    #[test]
    fn distinct_geometry_is_a_distinct_entry() {
        let c = PackCache::new(1 << 20);
        c.insert(key(1, 16), value(10));
        assert!(c.get(&key(1, 32)).is_none(), "protection geometry is part of the key");
        let mut k2 = key(1, 16);
        k2.isa = KernelIsa::Avx2Fma;
        assert!(c.get(&k2).is_none(), "ISA is part of the key");
        let mut k3 = key(1, 16);
        k3.role = PanelRole::B;
        assert!(c.get(&k3).is_none(), "role is part of the key");
    }

    #[test]
    fn kc_is_part_of_the_key_so_cross_kc_collisions_are_impossible() {
        // Panels packed at KC=64 and KC=128 have different byte layouts;
        // a lookup at one depth must never serve the other's entry, for
        // any combination of the remaining fields.
        let c = PackCache::new(1 << 20);
        for prot in [0usize, 16] {
            c.insert(key(prot as u64, prot), value(100));
            let mut other = key(prot as u64, prot);
            other.kc = 128;
            assert!(c.get(&other).is_none(), "KC must partition entries (prot {prot})");
            other.kc = 64;
            assert!(c.get(&other).is_some(), "matching KC must still hit (prot {prot})");
        }
        // And hashing/equality treat kc symmetrically: inserting the
        // KC=128 twin creates a second live entry, not a replacement.
        let mut twin = key(0, 0);
        twin.kc = 128;
        c.insert(twin, value(100));
        assert!(c.get(&key(0, 0)).is_some());
        assert!(c.get(&twin).is_some());
        assert_eq!(c.stats().entries, 3);
    }

    #[test]
    fn lru_eviction_honors_the_byte_budget_under_pressure() {
        // Budget fits exactly two 100-float entries (400 bytes each).
        let c = PackCache::new(800);
        c.insert(key(1, 0), value(100));
        c.insert(key(2, 0), value(100));
        assert_eq!(c.stats().bytes, 800);
        // Touch 1 so 2 becomes the LRU victim.
        assert!(c.get(&key(1, 0)).is_some());
        c.insert(key(3, 0), value(100));
        let s = c.stats();
        assert_eq!(s.entries, 2);
        assert!(s.bytes <= 800, "budget violated: {} bytes", s.bytes);
        assert_eq!(s.evictions, 1);
        assert!(c.get(&key(1, 0)).is_some(), "recently-used entry evicted");
        assert!(c.get(&key(2, 0)).is_none(), "LRU entry survived past budget");
        assert!(c.get(&key(3, 0)).is_some());
    }

    #[test]
    fn oversized_value_is_not_cached_and_evicts_nothing() {
        let c = PackCache::new(400);
        c.insert(key(1, 0), value(100));
        c.insert(key(2, 0), value(1000)); // 4000 bytes > budget
        let s = c.stats();
        assert_eq!(s.entries, 1);
        assert_eq!(s.evictions, 0);
        assert!(c.get(&key(1, 0)).is_some());
        assert!(c.get(&key(2, 0)).is_none());
    }

    #[test]
    fn reinsert_replaces_without_double_counting_bytes() {
        let c = PackCache::new(10_000);
        c.insert(key(1, 0), value(100));
        c.insert(key(1, 0), value(200));
        let s = c.stats();
        assert_eq!(s.entries, 1);
        assert_eq!(s.bytes, 800);
    }

    #[test]
    fn zero_budget_config_disables_the_cache() {
        assert!(PackCache::from_config_mb(0).is_none());
        let c = PackCache::from_config_mb(1).expect("1 MB budget constructs");
        assert_eq!(c.budget_bytes(), 1024 * 1024);
    }
}
