//! Kernel-execution backends for the engine workers, and the registry
//! that names them.
//!
//! Each engine worker owns one [`Backend`] instance plus its compiled-
//! artifact cache. Implementations:
//!
//! * [`ReferenceBackend`] (`"reference"`, always available) — executes
//!   every artifact *semantically* on the host from its manifest metadata:
//!   the GEMM is the blocked CPU matmul, the fused FT kernels are emulated
//!   with the Huang–Abraham checksum algebra at the kernel's protection
//!   granularity (per sub-tile, per verification interval), and the
//!   Ding'11 stages follow the encoded outer-product contract. Same
//!   inputs, same output roles/shapes, same fault-tolerance observable
//!   behavior as the lowered kernels — so the whole serving stack (router,
//!   planner, scheduler, batcher, campaigns) runs in environments without
//!   PJRT or artifacts.
//! * [`BlockedBackend`](super::blocked::BlockedBackend) (`"blocked"`,
//!   plus `"blocked-scalar"` pinned to the portable micro-kernel) — the
//!   high-performance host engine: cache-blocked, register-tiled,
//!   multithreaded GEMM with SIMD micro-kernels dispatched once at
//!   construction ([`KernelIsa`]), checksum encoding fused into operand
//!   packing and per-tile verification fused into the block sweep (the
//!   paper's kernel-fusion strategy at host level). See
//!   `runtime/blocked.rs`.
//! * a PJRT backend — parses the AOT HLO text and executes it on a real
//!   `PjRtClient`. The `xla` bindings are not vendorable in this build
//!   environment; the integration point is this trait plus one
//!   [`BackendRegistry`] entry. See DESIGN.md "Substitutions".
//!
//! Backends are constructed *inside* the worker thread (PJRT handles are
//! `Rc`-based), which is why the trait has no `Send` bound and the
//! registry hands out `Send + Sync` **factories** rather than instances.

use std::collections::{BTreeMap, HashSet};
use std::sync::{Arc, OnceLock};

use anyhow::{anyhow, bail, Result};

use crate::abft::checksum::{self, ChecksumPair, Detection, Thresholds};
use crate::abft::injection::Injection;
use crate::abft::matrix::Matrix;

use super::engine::Tensor;
use super::manifest::{Artifact, ArtifactKind};
use super::simd::{sum8, KernelIsa};

/// One worker's kernel executor. `compile` is idempotent per artifact and
/// returns whether work happened (the engine meters compile time/counts).
pub trait Backend {
    fn name(&self) -> &'static str;
    fn compile(&mut self, art: &Artifact) -> Result<bool>;
    fn execute(&mut self, art: &Artifact, inputs: Vec<Tensor>) -> Result<Vec<Tensor>>;
}

/// Backend metadata the serving layers key decisions on (capability
/// resolution happens at plan time — see `coordinator::plan`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BackendInfo {
    pub name: &'static str,
    pub description: &'static str,
    /// Executes `FtGemm`/`FtDetect` artifacts with the checksum work fused
    /// into its GEMM loops (in-backend detect + correct). The planner
    /// routes `FtPolicy::Online` requests on backends without this
    /// capability to the detect-and-recompute strategy instead.
    pub fused_ft: bool,
    /// The micro-kernel ISA this backend dispatches to
    /// ([`KernelIsa::name`] for the blocked variants, `"portable"` for
    /// backends without runtime kernel dispatch). Surfaced in
    /// `ftgemm info`, bench JSON, and logs.
    pub kernel_isa: &'static str,
}

/// What a backend factory gets told about the engine constructing it.
#[derive(Debug, Clone)]
pub struct BackendCtx {
    /// Engine worker threads **per pool** — each gets its own backend
    /// instance.
    pub workers: usize,
    /// Engine pools (shards). Core division is per-pool-aware: a backend
    /// with internal parallelism should divide the machine by
    /// [`BackendCtx::total_workers`], not `workers`, or an N-pool engine
    /// oversubscribes cores by N× (the blocked backend does this).
    pub pools: usize,
    /// This pool's shared packed-operand & checksum cache, `None` when
    /// disabled (`pack_cache_mb = 0`). Backends that pack operands
    /// (the blocked family) consult it for key-bearing input tensors.
    pub pack_cache: Option<Arc<super::pack_cache::PackCache>>,
}

impl BackendCtx {
    /// Backend instances alive across the whole engine
    /// (`workers × pools`, both clamped to at least 1) — the denominator
    /// for machine-core division.
    pub fn total_workers(&self) -> usize {
        self.workers.max(1) * self.pools.max(1)
    }
}

/// Constructs one backend instance per engine worker. Factories are
/// `Send + Sync` so the engine can move them into worker threads; the
/// instances they build are thread-confined (no `Send` bound on
/// [`Backend`]).
pub type BackendFactory = Arc<dyn Fn(&BackendCtx) -> Box<dyn Backend> + Send + Sync>;

/// Named backend catalog: `EngineConfig::backend` / `--backend` strings
/// resolve here, and each engine worker constructs its executor from the
/// resolved factory. [`BackendRegistry::global`] carries the built-in
/// backends; embedders compose custom registries with
/// [`BackendRegistry::empty`] + [`BackendRegistry::register`] and serve
/// them via [`Engine::start_with`](super::engine::Engine::start_with).
pub struct BackendRegistry {
    entries: BTreeMap<&'static str, (BackendInfo, BackendFactory)>,
}

impl BackendRegistry {
    /// The name an empty/unset backend selection resolves to.
    pub const DEFAULT: &'static str = "reference";

    /// An empty registry (for tests/embedders composing their own set).
    pub fn empty() -> BackendRegistry {
        BackendRegistry { entries: BTreeMap::new() }
    }

    /// The built-in catalog: `reference`, `blocked` (SIMD micro-kernels
    /// picked once here via [`KernelIsa::detect`]) and `blocked-scalar`
    /// (the same engine pinned to the portable scalar kernel — the SIMD
    /// speedup baseline and a parity escape hatch).
    pub fn builtin() -> BackendRegistry {
        let isa = KernelIsa::detect();
        let mut reg = BackendRegistry::empty();
        reg.register(
            BackendInfo {
                name: "reference",
                description: "semantic host executor (naive-blocked GEMM, oracle for parity)",
                fused_ft: true,
                kernel_isa: "portable",
            },
            Arc::new(|_ctx: &BackendCtx| Box::new(ReferenceBackend::new()) as Box<dyn Backend>),
        );
        reg.register(
            BackendInfo {
                name: "blocked",
                description: "cache-blocked register-tiled multithreaded GEMM with fused ABFT \
                              (runtime-dispatched SIMD micro-kernels)",
                fused_ft: true,
                kernel_isa: isa.name(),
            },
            Arc::new(move |ctx: &BackendCtx| {
                Box::new(
                    super::blocked::BlockedBackend::for_engine_isa(ctx.total_workers(), isa)
                        .with_pack_cache(ctx.pack_cache.clone()),
                ) as Box<dyn Backend>
            }),
        );
        reg.register(
            BackendInfo {
                name: "blocked-scalar",
                description: "blocked backend pinned to the portable scalar micro-kernel \
                              (SIMD baseline / parity)",
                fused_ft: true,
                kernel_isa: "scalar",
            },
            Arc::new(|ctx: &BackendCtx| {
                Box::new(
                    super::blocked::BlockedBackend::for_engine_isa(
                        ctx.total_workers(),
                        KernelIsa::Scalar,
                    )
                    .with_name("blocked-scalar")
                    .with_pack_cache(ctx.pack_cache.clone()),
                ) as Box<dyn Backend>
            }),
        );
        reg
    }

    /// The process-wide registry of built-in backends.
    pub fn global() -> &'static BackendRegistry {
        static GLOBAL: OnceLock<BackendRegistry> = OnceLock::new();
        GLOBAL.get_or_init(BackendRegistry::builtin)
    }

    /// Register (or replace) a backend under `info.name`.
    pub fn register(&mut self, info: BackendInfo, factory: BackendFactory) {
        self.entries.insert(info.name, (info, factory));
    }

    /// Resolve a backend selection; `""` means [`BackendRegistry::DEFAULT`].
    pub fn resolve(&self, name: &str) -> Result<(BackendInfo, BackendFactory)> {
        let name = if name.is_empty() { Self::DEFAULT } else { name };
        self.entries
            .get(name)
            .map(|(info, factory)| (*info, Arc::clone(factory)))
            .ok_or_else(|| {
                anyhow!("unknown backend {name:?} (known: {})", self.names().join("|"))
            })
    }

    /// Metadata for one backend.
    pub fn info(&self, name: &str) -> Result<BackendInfo> {
        self.resolve(name).map(|(info, _)| info)
    }

    /// Registered names, sorted.
    pub fn names(&self) -> Vec<&'static str> {
        self.entries.keys().copied().collect()
    }
}

/// Maximum verify/correct passes per protection domain: a corrected
/// large-magnitude fault leaves an O(eps * magnitude) residue that the next
/// pass refines, exactly like the kernel's periodic re-verification.
pub(crate) const MAX_VERIFY_PASSES: usize = 4;

pub struct ReferenceBackend {
    compiled: HashSet<String>,
    thresholds: Thresholds,
}

impl ReferenceBackend {
    pub fn new() -> Self {
        ReferenceBackend { compiled: HashSet::new(), thresholds: Thresholds::default() }
    }
}

impl Default for ReferenceBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn compile(&mut self, art: &Artifact) -> Result<bool> {
        if self.compiled.contains(&art.name) {
            return Ok(false);
        }
        validate_artifact(art)?;
        self.compiled.insert(art.name.clone());
        Ok(true)
    }

    fn execute(&mut self, art: &Artifact, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        execute_semantic(art, inputs, self.thresholds, &|a, b| a.matmul(b))
    }
}

/// Structural validation standing in for real compilation — shared by
/// every host backend.
pub(crate) fn validate_artifact(art: &Artifact) -> Result<()> {
    match art.kind {
        ArtifactKind::Gemm | ArtifactKind::Stepwise => {
            ensure_role(art, "c")?;
        }
        ArtifactKind::FtGemm | ArtifactKind::FtDetect => {
            ensure_role(art, "c")?;
            ensure_role(art, "errcount")?;
            if art.inputs.len() != 3 {
                bail!("{}: FT kernels take (a, b, inj), got {} inputs", art.name, art.inputs.len());
            }
        }
        ArtifactKind::DingEncode => {
            ensure_role(art, "ac")?;
            ensure_role(art, "br")?;
        }
        ArtifactKind::DingStep => {
            ensure_role(art, "cf")?;
            if art.ks == 0 {
                bail!("{}: ding_step needs ks > 0", art.name);
            }
        }
        ArtifactKind::DingVerify => {
            ensure_role(art, "cf")?;
            ensure_role(art, "errcount")?;
        }
    }
    Ok(())
}

/// Execute one artifact semantically with a pluggable GEMM kernel — the
/// shared interpreter both host backends delegate to (the blocked backend
/// intercepts `FtGemm`/`FtDetect` with its fused path and routes the rest
/// here with its tiled kernel).
pub(crate) fn execute_semantic(
    art: &Artifact,
    inputs: Vec<Tensor>,
    thresholds: Thresholds,
    gemm: &dyn Fn(&Matrix, &Matrix) -> Matrix,
) -> Result<Vec<Tensor>> {
    match art.kind {
        ArtifactKind::Gemm | ArtifactKind::Stepwise => {
            let (a, b) = two_matrices(art, inputs)?;
            let c = gemm(&a, &b);
            build_outputs(art, [("c", c.into_data())].into_iter().collect())
        }
        ArtifactKind::FtGemm | ArtifactKind::FtDetect => {
            let correct = art.kind == ArtifactKind::FtGemm;
            let mut it = inputs.into_iter();
            let a = matrix_input(art, it.next())?;
            let b = matrix_input(art, it.next())?;
            let inj = it.next().ok_or_else(|| anyhow!("{}: missing inj input", art.name))?;
            let injections = decode_injections(&inj);
            let (c, cr, cc, errgrid) =
                semantic_ft_gemm(art, &a, &b, &injections, correct, thresholds, gemm)?;
            build_outputs(
                art,
                [
                    ("c", c.into_data()),
                    ("cr", cr),
                    ("cc", cc),
                    ("errcount", errgrid),
                ]
                .into_iter()
                .collect(),
            )
        }
        ArtifactKind::DingEncode => {
            let (a, b) = two_matrices(art, inputs)?;
            let (m, k, n) = (a.rows(), a.cols(), b.cols());
            let mut ac = Matrix::zeros(m + 1, k);
            for i in 0..m {
                ac.data_mut()[i * k..(i + 1) * k].copy_from_slice(a.row(i));
            }
            for (kk, s) in a.col_sums().into_iter().enumerate() {
                ac.set(m, kk, s);
            }
            let mut br = Matrix::zeros(k, n + 1);
            for kk in 0..k {
                br.data_mut()[kk * (n + 1)..kk * (n + 1) + n].copy_from_slice(b.row(kk));
                br.set(kk, n, b.row(kk).iter().sum());
            }
            build_outputs(
                art,
                [("ac", ac.into_data()), ("br", br.into_data())].into_iter().collect(),
            )
        }
        ArtifactKind::DingStep => {
            let mut it = inputs.into_iter();
            let mut cf = matrix_input(art, it.next())?;
            let acp = matrix_input(art, it.next())?;
            let brp = matrix_input(art, it.next())?;
            let update = gemm(&acp, &brp);
            if (update.rows(), update.cols()) != (cf.rows(), cf.cols()) {
                bail!("{}: panel update shape mismatch", art.name);
            }
            for (dst, src) in cf.data_mut().iter_mut().zip(update.data()) {
                *dst += src;
            }
            build_outputs(art, [("cf", cf.into_data())].into_iter().collect())
        }
        ArtifactKind::DingVerify => {
            let mut it = inputs.into_iter();
            let mut cf = matrix_input(art, it.next())?;
            let (m, n) = (cf.rows() - 1, cf.cols() - 1);
            let carried = ChecksumPair {
                cr: (0..m).map(|i| cf.at(i, n)).collect(),
                cc: (0..n).map(|j| cf.at(m, j)).collect(),
            };
            let mut inner = cf.slice_to(m, n);
            let corrected = verify_correct_loop(&mut inner, &carried, thresholds, true).0;
            for i in 0..m {
                for j in 0..n {
                    cf.set(i, j, inner.at(i, j));
                }
            }
            build_outputs(
                art,
                [("cf", cf.into_data()), ("errcount", vec![corrected as f32])]
                    .into_iter()
                    .collect(),
            )
        }
    }
}

/// The fused (FT-)GEMM contract: compute C, apply the injected faults
/// interval by interval, and run the checksum verify/correct sweep over
/// each affected protection sub-tile — detection and (for the fused
/// online kernel) correction at exactly the granularity the lowered
/// kernel would.
pub(crate) fn semantic_ft_gemm(
    art: &Artifact,
    a: &Matrix,
    b: &Matrix,
    injections: &[Injection],
    correct: bool,
    thresholds: Thresholds,
    gemm: &dyn Fn(&Matrix, &Matrix) -> Matrix,
) -> Result<(Matrix, Vec<f32>, Vec<f32>, Vec<f32>)> {
    let (m, n) = (a.rows(), b.cols());
    let (sub_m, sub_n) = protection_tile(art, m, n)?;
    let (gm, gn) = (m.div_ceil(sub_m), n.div_ceil(sub_n));
    let mut errgrid = vec![0.0f32; gm * gn];
    let mut c = gemm(a, b);

    check_injection_capacity(art, injections.len())?;

    run_injection_sweeps(art, m, n, sub_m, sub_n, &mut c, injections, &mut errgrid, |jobs| {
        jobs.into_iter()
            .map(|(ti, tj, mut tile)| {
                let (r0, r1) = (ti * sub_m, ((ti + 1) * sub_m).min(m));
                let (c0, c1) = (tj * sub_n, ((tj + 1) * sub_n).min(n));
                let carried = tile_carried_checksums(a, b, r0, r1, c0, c1);
                let (corrections, detections) =
                    verify_correct_loop(&mut tile, &carried, thresholds, correct);
                (ti, tj, tile, corrections, detections)
            })
            .collect()
    });

    let cr = c.row_sums();
    let cc = c.col_sums();
    Ok((c, cr, cc, errgrid))
}

/// One verified protection-tile snapshot handed to a sweep's verifier:
/// `(tile_row, tile_col, tile values with this interval's faults applied)`.
pub(crate) type TileJob = (usize, usize, Matrix);
/// A verifier's outcome per tile: the (possibly corrected) tile plus its
/// `(corrections, detections)` counts.
pub(crate) type TileVerdict = (usize, usize, Matrix, u64, u64);

/// The per-interval injection sweep both FT-GEMM implementations share:
/// group faults by verification interval ([`group_by_interval`] — the
/// kernel corrects each interval's damage before the next accumulates),
/// apply them to C, snapshot every touched protection sub-tile, hand the
/// batch to `verify_tiles` (sequential checksum recompute for the
/// reference backend; a pool fan-out over packed operand sums for the
/// blocked backend), then fold corrected tiles and the errcount grid
/// back in. Tiles within one interval are disjoint protection domains,
/// so the verifier may process them in any order or in parallel.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_injection_sweeps<F>(
    art: &Artifact,
    m: usize,
    n: usize,
    sub_m: usize,
    sub_n: usize,
    c: &mut Matrix,
    injections: &[Injection],
    errgrid: &mut [f32],
    mut verify_tiles: F,
) where
    F: FnMut(Vec<TileJob>) -> Vec<TileVerdict>,
{
    let gn = n.div_ceil(sub_n);
    for injs in group_by_interval(art, injections).values() {
        let mut touched: HashSet<(usize, usize)> = HashSet::new();
        for inj in injs {
            if inj.row < m && inj.col < n {
                c.add_at(inj.row, inj.col, inj.magnitude);
                touched.insert((inj.row / sub_m, inj.col / sub_n));
            }
        }
        if touched.is_empty() {
            continue;
        }
        let jobs: Vec<TileJob> = touched
            .into_iter()
            .map(|(ti, tj)| {
                let (r0, r1) = (ti * sub_m, ((ti + 1) * sub_m).min(m));
                let (c0, c1) = (tj * sub_n, ((tj + 1) * sub_n).min(n));
                let tile = Matrix::from_fn(r1 - r0, c1 - c0, |i, j| c.at(r0 + i, c0 + j));
                (ti, tj, tile)
            })
            .collect();
        for (ti, tj, tile, corrections, detections) in verify_tiles(jobs) {
            if corrections > 0 {
                let (r0, c0) = (ti * sub_m, tj * sub_n);
                for i in 0..tile.rows() {
                    for j in 0..tile.cols() {
                        c.set(r0 + i, c0 + j, tile.at(i, j));
                    }
                }
            }
            errgrid[ti * gn + tj] += (corrections + detections) as f32;
        }
    }
}

/// Enforce the kernel's injection-slot capacity.
pub(crate) fn check_injection_capacity(art: &Artifact, count: usize) -> Result<()> {
    if art.max_inj > 0 && count > art.max_inj {
        bail!("{}: {count} injections exceed kernel capacity {}", art.name, art.max_inj);
    }
    Ok(())
}

/// Faults land per verification interval; the kernel corrects each
/// interval's damage before the next accumulates (paper §4.1).
pub(crate) fn group_by_interval<'a>(
    art: &Artifact,
    injections: &'a [Injection],
) -> BTreeMap<usize, Vec<&'a Injection>> {
    let verify_every = art.verify_every.max(1);
    let mut by_interval: BTreeMap<usize, Vec<&Injection>> = BTreeMap::new();
    for inj in injections {
        by_interval.entry(inj.step / verify_every).or_default().push(inj);
    }
    by_interval
}

/// Checksum sub-tile of an FT artifact: explicit manifest metadata first,
/// then the Table-1 params for its level, then the whole output.
pub(crate) fn protection_tile(art: &Artifact, m: usize, n: usize) -> Result<(usize, usize)> {
    if art.sub_m > 0 && art.sub_n > 0 {
        return Ok((art.sub_m, art.sub_n));
    }
    if let (Some(p), Some(level)) = (&art.params, art.ft_level.as_deref()) {
        return p.sub_tile(level);
    }
    Ok((m.max(1), n.max(1)))
}

/// Carried (true-product) checksums of one output sub-tile, derived from
/// the operands: `cr = A_rows · (B · e_cols)`, `cc = (eᵀ A_rows) · B_cols`.
///
/// Fold orders are the crate-wide canon (see `runtime::simd`): the B
/// column-range sums use the lane-split [`sum8`] order so the blocked
/// backend's vectorized packing encode reproduces them bit-exactly; the
/// A row-range sums fold in ascending `i` (SIMD lanes run along `k`
/// there, preserving the order).
pub(crate) fn tile_carried_checksums(
    a: &Matrix,
    b: &Matrix,
    r0: usize,
    r1: usize,
    c0: usize,
    c1: usize,
) -> ChecksumPair {
    let k = a.cols();
    let mut be = vec![0.0f32; k];
    for (kk, s) in be.iter_mut().enumerate() {
        *s = sum8(&b.row(kk)[c0..c1]);
    }
    let mut ea = vec![0.0f32; k];
    for i in r0..r1 {
        for (s, v) in ea.iter_mut().zip(a.row(i)) {
            *s += v;
        }
    }
    carried_from_sums(a, b, r0, r1, c0, c1, &be, &ea)
}

/// Finish the carried checksums from precomputed operand sums: `be[k]` is
/// the column-range sum of B over `[c0, c1)` (canonical [`sum8`] lane
/// order) and `ea[k]` the row-range sum of A over `[r0, r1)` (ascending
/// index fold order). The blocked backend computes these during operand
/// packing — fused encoding — and lands here so both backends produce
/// bit-identical checksums.
#[allow(clippy::too_many_arguments)]
pub(crate) fn carried_from_sums(
    a: &Matrix,
    b: &Matrix,
    r0: usize,
    r1: usize,
    c0: usize,
    c1: usize,
    be: &[f32],
    ea: &[f32],
) -> ChecksumPair {
    let cr = (r0..r1)
        .map(|i| a.row(i).iter().zip(be).map(|(x, y)| x * y).sum())
        .collect();
    let mut cc = vec![0.0f32; c1 - c0];
    for (kk, &w) in ea.iter().enumerate() {
        if w == 0.0 {
            continue;
        }
        for (s, v) in cc.iter_mut().zip(&b.row(kk)[c0..c1]) {
            *s += w * v;
        }
    }
    ChecksumPair { cr, cc }
}

/// Repeated verify(+correct) passes over one matrix against carried
/// checksums. Returns (corrections, uncorrectable detections).
pub(crate) fn verify_correct_loop(
    c: &mut Matrix,
    carried: &ChecksumPair,
    th: Thresholds,
    correct: bool,
) -> (u64, u64) {
    let mut corrections = 0u64;
    for _ in 0..MAX_VERIFY_PASSES {
        match checksum::verify(c, carried, th) {
            Detection::Clean => return (corrections, 0),
            det @ Detection::Single { .. } => {
                if correct {
                    checksum::correct(c, &det);
                    corrections += 1;
                } else {
                    // Detect-only kernel: flag it, leave C corrupted.
                    return (0, 1);
                }
            }
            Detection::MultiError { .. } => {
                // SEU violated inside one protection domain: detected but
                // uncorrectable in-kernel.
                return (corrections, 1);
            }
        }
    }
    (corrections, 0)
}

fn ensure_role(art: &Artifact, role: &str) -> Result<()> {
    art.output_index(role)
        .map(|_| ())
        .ok_or_else(|| anyhow!("{}: no {role:?} output in manifest", art.name))
}

pub(crate) fn matrix_input(art: &Artifact, t: Option<Tensor>) -> Result<Matrix> {
    let t = t.ok_or_else(|| anyhow!("{}: missing input", art.name))?;
    if t.shape.len() != 2 {
        bail!("{}: expected a matrix input, got shape {:?}", art.name, t.shape);
    }
    Ok(Matrix::from_vec(t.shape[0], t.shape[1], t.data))
}

pub(crate) fn two_matrices(art: &Artifact, inputs: Vec<Tensor>) -> Result<(Matrix, Matrix)> {
    let mut it = inputs.into_iter();
    let a = matrix_input(art, it.next())?;
    let b = matrix_input(art, it.next())?;
    Ok((a, b))
}

/// Decode the kernels' `(max_inj, 4)` injection descriptor rows; zero
/// magnitude marks an unused slot.
pub(crate) fn decode_injections(t: &Tensor) -> Vec<Injection> {
    t.data
        .chunks(4)
        .filter(|r| r.len() == 4 && r[3] != 0.0)
        .map(|r| Injection {
            row: r[0] as usize,
            col: r[1] as usize,
            step: r[2] as usize,
            magnitude: r[3],
        })
        .collect()
}

/// Map output roles (as `role -> flat data`) onto the artifact's declared
/// output list. Semantically load-bearing roles must match the spec size
/// exactly; auxiliary checksum layouts this backend does not model (the
/// real kernels' tiled `cr`/`cc`) are zero-filled to spec.
pub(crate) fn build_outputs(
    art: &Artifact,
    mut values: BTreeMap<&'static str, Vec<f32>>,
) -> Result<Vec<Tensor>> {
    art.outputs
        .iter()
        .map(|spec| {
            let need = spec.elements();
            let data = match values.remove(spec.role.as_str()) {
                Some(d) if d.len() == need => d,
                Some(d) if matches!(spec.role.as_str(), "cr" | "cc") => {
                    let _ = d;
                    vec![0.0; need]
                }
                Some(d) => bail!(
                    "{}: output {:?} size {} != manifest {}",
                    art.name,
                    spec.role,
                    d.len(),
                    need
                ),
                None => vec![0.0; need],
            };
            Ok(Tensor::new(spec.shape.clone(), data))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn backend_and_manifest() -> (ReferenceBackend, Manifest) {
        (ReferenceBackend::new(), Manifest::builtin())
    }

    fn tensor2(m: &Matrix) -> Tensor {
        Tensor::new(vec![m.rows(), m.cols()], m.data().to_vec())
    }

    #[test]
    fn registry_lists_builtins_and_resolves_default() {
        let reg = BackendRegistry::global();
        assert_eq!(reg.names(), vec!["blocked", "blocked-scalar", "reference"]);
        let ctx = BackendCtx { workers: 2, pools: 1, pack_cache: None };
        let (info, factory) = reg.resolve("").unwrap();
        assert_eq!(info.name, "reference");
        assert_eq!(info.kernel_isa, "portable");
        assert_eq!((*factory)(&ctx).name(), "reference");
        let (info, factory) = reg.resolve("blocked").unwrap();
        assert!(info.fused_ft);
        assert!(!info.kernel_isa.is_empty());
        assert_eq!((*factory)(&ctx).name(), "blocked");
        let (info, factory) = reg.resolve("blocked-scalar").unwrap();
        assert!(info.fused_ft);
        assert_eq!(info.kernel_isa, "scalar");
        assert_eq!((*factory)(&ctx).name(), "blocked-scalar");
        let err = reg.resolve("pjrt").unwrap_err();
        assert!(err.to_string().contains("unknown backend"), "{err}");
        assert!(err.to_string().contains("blocked|blocked-scalar|reference"), "{err}");
    }

    #[test]
    fn backend_ctx_divides_cores_per_pool() {
        let ctx = |workers, pools| BackendCtx { workers, pools, pack_cache: None };
        assert_eq!(ctx(2, 3).total_workers(), 6);
        assert_eq!(ctx(4, 1).total_workers(), 4);
        // zero fields clamp instead of zeroing the division denominator
        assert_eq!(ctx(0, 0).total_workers(), 1);
    }

    #[test]
    fn custom_registry_entries_resolve() {
        let mut reg = BackendRegistry::empty();
        assert!(reg.resolve("").is_err(), "empty registry has no default");
        reg.register(
            BackendInfo {
                name: "custom",
                description: "test",
                fused_ft: false,
                kernel_isa: "portable",
            },
            Arc::new(|_ctx: &BackendCtx| Box::new(ReferenceBackend::new()) as Box<dyn Backend>),
        );
        assert!(!reg.info("custom").unwrap().fused_ft);
        assert_eq!(reg.names(), vec!["custom"]);
    }

    #[test]
    fn compile_is_idempotent() {
        let (mut be, man) = backend_and_manifest();
        let art = man.get("gemm_small").unwrap();
        assert!(be.compile(art).unwrap());
        assert!(!be.compile(art).unwrap());
    }

    #[test]
    fn gemm_matches_host_matmul() {
        let (mut be, man) = backend_and_manifest();
        let art = man.get("gemm_small").unwrap();
        let a = Matrix::rand_uniform(64, 64, 1);
        let b = Matrix::rand_uniform(64, 64, 2);
        let out = be.execute(art, vec![tensor2(&a), tensor2(&b)]).unwrap();
        let got = Matrix::from_vec(64, 64, out[0].data.clone());
        assert!(got.max_abs_diff(&a.matmul(&b)) < 1e-4);
    }

    #[test]
    fn ftgemm_corrects_and_counts() {
        let (mut be, man) = backend_and_manifest();
        let art = man.get("ftgemm_tb_medium").unwrap();
        let a = Matrix::rand_uniform(128, 128, 3);
        let b = Matrix::rand_uniform(128, 128, 4);
        let want = a.matmul(&b);
        let inj = crate::abft::injection::InjectionPlan {
            injections: vec![
                Injection { row: 5, col: 9, step: 0, magnitude: 300.0 },
                Injection { row: 77, col: 40, step: 6, magnitude: -1000.0 },
                Injection { row: 127, col: 127, step: 12, magnitude: 64.0 },
            ],
        };
        let out = be
            .execute(
                art,
                vec![
                    tensor2(&a),
                    tensor2(&b),
                    Tensor::new(vec![8, 4], inj.to_tensor(8)),
                ],
            )
            .unwrap();
        let c_idx = art.output_index("c").unwrap();
        let e_idx = art.output_index("errcount").unwrap();
        let got = Matrix::from_vec(128, 128, out[c_idx].data.clone());
        assert!(out[e_idx].scalar_sum().round() as u64 >= 3);
        assert!(got.max_abs_diff(&want) < 2e-2);
    }

    #[test]
    fn ftdetect_flags_but_does_not_correct() {
        let (mut be, man) = backend_and_manifest();
        let art = man.get("ftdetect_medium").unwrap();
        let a = Matrix::rand_uniform(128, 128, 5);
        let b = Matrix::rand_uniform(128, 128, 6);
        let want = a.matmul(&b);
        let inj = crate::abft::injection::InjectionPlan::single(10, 10, 3, 444.0);
        let out = be
            .execute(
                art,
                vec![
                    tensor2(&a),
                    tensor2(&b),
                    Tensor::new(vec![8, 4], inj.to_tensor(8)),
                ],
            )
            .unwrap();
        let c_idx = art.output_index("c").unwrap();
        let e_idx = art.output_index("errcount").unwrap();
        let got = Matrix::from_vec(128, 128, out[c_idx].data.clone());
        assert!(out[e_idx].scalar_sum() >= 1.0);
        // still corrupted: the offset survives
        assert!((got.at(10, 10) - want.at(10, 10) - 444.0).abs() < 1e-2);
    }

    #[test]
    fn ding_chain_reproduces_the_product() {
        let (mut be, man) = backend_and_manifest();
        let enc = man.get("ding_encode_medium").unwrap();
        let step = man.get("ding_step_medium").unwrap();
        let ver = man.get("ding_verify_medium").unwrap();
        let (m, n, k, ks) = (enc.m, enc.n, enc.k, step.ks);
        let a = Matrix::rand_uniform(m, k, 7);
        let b = Matrix::rand_uniform(k, n, 8);

        let out = be.execute(enc, vec![tensor2(&a), tensor2(&b)]).unwrap();
        let ac = Matrix::from_vec(m + 1, k, out[0].data.clone());
        let br = Matrix::from_vec(k, n + 1, out[1].data.clone());

        let mut cf = Matrix::zeros(m + 1, n + 1);
        let mut corrected = 0.0;
        for s in (0..k).step_by(ks) {
            let acp = Matrix::from_fn(m + 1, ks, |i, j| ac.at(i, s + j));
            let brp = Matrix::from_fn(ks, n + 1, |i, j| br.at(s + i, j));
            let out = be
                .execute(step, vec![tensor2(&cf), tensor2(&acp), tensor2(&brp)])
                .unwrap();
            cf = Matrix::from_vec(m + 1, n + 1, out[0].data.clone());
            // inject into the first panel's window only
            if s == 0 {
                cf.add_at(3, 4, 512.0);
            }
            let out = be.execute(ver, vec![tensor2(&cf)]).unwrap();
            cf = Matrix::from_vec(m + 1, n + 1, out[0].data.clone());
            corrected += out[1].scalar_sum();
        }
        assert!(corrected >= 1.0);
        assert!(cf.slice_to(m, n).max_abs_diff(&a.matmul(&b)) < 2e-2);
    }
}
