//! `BlockedBackend` — the high-performance host execution engine: a
//! cache-blocked, register-tiled, multithreaded f32 GEMM whose FT artifact
//! kinds fuse checksum encoding and per-tile verification into the
//! packing / macro-tile loops. This is the paper's kernel-fusion strategy
//! (§4) transplanted to host level:
//!
//! * **packing fuses encoding** — while operand panels are packed into
//!   the micro-kernel layout, the per-protection-tile operand sums
//!   (`e·A` row sums, `B·e` column sums) are accumulated in the same
//!   pass, so the checksums the verifier needs already exist when the
//!   compute sweep finishes (the §4.1 "checksum FMAs ride the prefetch"
//!   idea);
//! * **the block sweep fuses verification** — injected intervals are
//!   verified/corrected per protection sub-tile, in parallel over the
//!   touched tiles, at the granularity the artifact's FT level dictates:
//!   `thread` level maps to micro-tile-sized domains, `warp` to
//!   panel-sized, `tb` to block-sized — the same thread/warp/threadblock
//!   checksum placements as the lowered kernels.
//!
//! Tile parameters (MC/KC/NC/MR/NR) come from
//! [`codegen::select::host_tiles_for`](crate::codegen::select::host_tiles_for)
//! — the same shape-class heuristic that picks kernel templates picks
//! the host blocking, with the register micro-tile sized for the
//! micro-kernel ISA the instance dispatches to. The macro-tile sweep is
//! a true GotoBLAS-style three-loop nest: within each MC x NC macro
//! tile, `k` is swept in ascending `KC`-sized reduction panels, the
//! micro-kernels loading/storing their accumulator tiles from the macro
//! tile between panels, so the per-panel working set (MC x KC A block +
//! KC x NC B panel + the C tile) stays cache-resident at any `k` (see
//! DESIGN.md "Blocking hierarchy"; `FTGEMM_FORCE_KC` /
//! [`BlockedBackend::with_kc`] override the class-resolved depth). The
//! ISA ([`KernelIsa`]) is detected **once at construction** — AVX2+FMA
//! / AVX-512F (behind the `avx512` cargo feature) on x86-64, NEON on
//! aarch64, scalar otherwise or under `FTGEMM_FORCE_SCALAR` — and the
//! inner loops dispatch on the stored value, never per call. Threading
//! rides the existing [`ThreadPool`]; each engine worker owns one
//! instance, so the default width is available cores divided by the
//! engine worker count, capped at 8 (`FTGEMM_BLOCKED_THREADS`
//! overrides).
//!
//! Numerical contract (see DESIGN.md "Kernel dispatch" for the full
//! statement): every output element is accumulated as a single
//! ascending-`k` fold — the **same fold order as the reference
//! backend's host matmul** — regardless of `KC`: between panels the
//! accumulator tile round-trips through exact f32 stores/reloads, so
//! splitting the reduction changes nothing bitwise (C is
//! bit-identical across `KC` choices on a given ISA; the parity suite
//! pins this). The SIMD kernels keep that order and differ from the
//! reference only in FMA's fused rounding per term. Carried checksums
//! are **bit-identical** to the reference backend's on every ISA and
//! every `KC`: B-side operand sums use the crate-wide canonical
//! lane-split fold ([`simd::sum8`]) whether computed scalar,
//! vector-resident in the packing loops, or on demand — reduction
//! panels partition the per-`kk` entries, so per-panel encode passes
//! compose into the identical sums; A-side sums fold in ascending `i`
//! on every path. The verify/correct sweep shares the reference
//! implementation's checksum algebra verbatim, and on the aligned
//! fused path it is **pipelined per macro tile**: each pool job runs
//! its own injected-interval sweeps on its just-computed tile
//! (protection domains never span macro tiles there), overlapping
//! verification with the remaining compute — the paper's
//! fusion-overlap strategy. The parity property suite
//! (`tests/properties.rs`) holds every kernel variant element-wise
//! close to the reference backend — with exact errcount-grid equality
//! — clean and injected, at all three FT levels and across `KC`.

use std::collections::{BTreeMap, HashSet};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::abft::checksum::Thresholds;
use crate::abft::injection::Injection;
use crate::abft::matrix::Matrix;
use crate::codegen::select::{host_tiles_for, HostTiles};
use crate::util::pool::ThreadPool;

use super::backend::{self, Backend};
use super::engine::Tensor;
use super::manifest::{Artifact, ArtifactKind};
use super::pack_cache::{OperandKey, PackCache, PackedOperand, PanelKey, PanelRole};
use super::simd::{self, KernelIsa};

/// Below this FLOP count the pool fan-out costs more than it buys; the
/// kernel falls back to the reference host matmul (identical results).
const PARALLEL_FLOP_FLOOR: usize = 64 * 64 * 64;

pub struct BlockedBackend {
    compiled: HashSet<String>,
    thresholds: Thresholds,
    pool: ThreadPool,
    threads: usize,
    /// Micro-kernel ISA, fixed at construction — the inner loops
    /// dispatch on this value, never re-detect.
    isa: KernelIsa,
    /// Registry name this instance reports ("blocked", or
    /// "blocked-scalar" for the pinned-scalar registry entry).
    name: &'static str,
    /// The engine pool's shared packed-operand & checksum cache
    /// (`None` = pack per request). Consulted only for key-bearing
    /// input tensors; cached panels/sums are immutable — the
    /// verify/correct sweeps read them and write only the owned C
    /// tiles, so a shared panel stays bitwise identical forever.
    cache: Option<Arc<PackCache>>,
    /// Instance-level KC pin ([`BlockedBackend::with_kc`]); wins over
    /// the `FTGEMM_FORCE_KC` env and the shape-class cap. Tests use
    /// this instead of the env var so the parallel test harness stays
    /// race-free.
    force_kc: Option<usize>,
}

impl BlockedBackend {
    /// Pool width from `FTGEMM_BLOCKED_THREADS`, else available cores
    /// (capped at 8 — beyond that the packing bandwidth saturates first);
    /// micro-kernel ISA from [`KernelIsa::detect`].
    pub fn new() -> Self {
        Self::for_engine(1)
    }

    /// Sized for an engine running `engine_workers` backend instances
    /// side by side: the machine is divided between them, so an N-worker
    /// engine does not oversubscribe cores by N x pool width.
    /// `FTGEMM_BLOCKED_THREADS` overrides the per-instance width.
    pub fn for_engine(engine_workers: usize) -> Self {
        Self::for_engine_isa(engine_workers, KernelIsa::detect())
    }

    /// [`BlockedBackend::for_engine`] with the micro-kernel ISA pinned
    /// (the registry's `blocked-scalar` entry and the parity suite use
    /// this; an ISA the host cannot execute degrades to `Scalar`).
    pub fn for_engine_isa(engine_workers: usize, isa: KernelIsa) -> Self {
        let threads = std::env::var("FTGEMM_BLOCKED_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                let cores =
                    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
                (cores / engine_workers.max(1)).clamp(1, 8)
            });
        Self::with_threads_isa(threads, isa)
    }

    pub fn with_threads(threads: usize) -> Self {
        Self::with_threads_isa(threads, KernelIsa::detect())
    }

    /// Explicit pool width and micro-kernel ISA. Pinning an ISA the
    /// host cannot execute degrades to `Scalar` — the `unsafe` kernel
    /// invocations rely on construction having verified CPU support.
    pub fn with_threads_isa(threads: usize, isa: KernelIsa) -> Self {
        let threads = threads.max(1);
        let isa = if KernelIsa::supported().contains(&isa) { isa } else { KernelIsa::Scalar };
        BlockedBackend {
            compiled: HashSet::new(),
            thresholds: Thresholds::default(),
            pool: ThreadPool::new(threads),
            threads,
            isa,
            name: "blocked",
            cache: None,
            force_kc: None,
        }
    }

    /// Rename the instance (registry entries like `blocked-scalar`
    /// resolve to the same type under a different name).
    pub(crate) fn with_name(mut self, name: &'static str) -> Self {
        self.name = name;
        self
    }

    /// Attach the pool-shared packed-operand cache (`None` keeps
    /// pack-per-request behavior). The engine wires this from
    /// `BackendCtx::pack_cache` via the registry factories.
    pub fn with_pack_cache(mut self, cache: Option<Arc<PackCache>>) -> Self {
        self.cache = cache;
        self
    }

    /// Pin the KC reduction-panel depth for this instance (clamped to
    /// the actual `k` per request; `Some(0)` and `None` keep the
    /// class-/env-resolved depth). Purely a residency knob: C, carried
    /// checksums and errcount grids are bitwise independent of it —
    /// which is exactly what the KC parity tests use this to prove.
    pub fn with_kc(mut self, kc: Option<usize>) -> Self {
        self.force_kc = kc.filter(|&v| v > 0);
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The micro-kernel ISA this instance dispatches to.
    pub fn kernel_isa(&self) -> KernelIsa {
        self.isa
    }

    /// ISA-aware tile parameters for one problem shape, with the
    /// instance KC pin applied.
    fn tiles(&self, m: usize, n: usize, k: usize) -> HostTiles {
        let mut t = host_tiles_for(self.isa, m, n, k);
        if let Some(kc) = self.force_kc {
            t.kc = kc.min(k).max(1);
        }
        t
    }

    /// The multithreaded blocked GEMM (plain path and Ding panel updates).
    fn gemm(&self, a: &Matrix, b: &Matrix) -> Matrix {
        self.gemm_keyed(a, b, None, None)
    }

    /// [`BlockedBackend::gemm`] with pack-cache content addresses for the
    /// operands: a keyed operand's packed panels are fetched from /
    /// inserted into the pool cache (`prot = 0` entries, no fused sums).
    fn gemm_keyed(
        &self,
        a: &Matrix,
        b: &Matrix,
        key_a: Option<OperandKey>,
        key_b: Option<OperandKey>,
    ) -> Matrix {
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        assert_eq!(k, b.rows(), "inner dims");
        if m * n * k < PARALLEL_FLOP_FLOOR || m == 0 || n == 0 || k == 0 {
            return a.matmul(b);
        }
        let t = self.tiles(m, n, k);
        let (pa, _) = self.packed_a(a, key_a, t, 0);
        let (pb, _) = self.packed_b(b, key_b, t, 0);
        self.compute_blocks(pa, pb, m, n, k, t)
    }

    /// A-side pack with cache lookup: returns the macro-block panels and
    /// (for `prot > 0`) the per-protection-row-tile eᵀA sums, packed
    /// fresh on a miss and shared from the pool cache on a hit. The
    /// returned values are immutable — callers only read them.
    fn packed_a(
        &self,
        a: &Matrix,
        key: Option<OperandKey>,
        t: HostTiles,
        prot: usize,
    ) -> (Arc<Vec<Vec<f32>>>, Arc<Vec<Vec<f32>>>) {
        let slot = self.cache_slot(key, PanelRole::A, t.mc, t.mr, t.kc, prot);
        if let Some((cache, pk)) = &slot {
            if let Some(hit) = cache.get(pk) {
                return (hit.panels, hit.sums);
            }
        }
        let (m, k) = (a.rows(), a.cols());
        let mut ea: Vec<Vec<f32>> =
            if prot == 0 { Vec::new() } else { vec![vec![0.0f32; k]; m.div_ceil(prot)] };
        let mut pa = Vec::new();
        for (i0, mb) in row_blocks(m, t.mc) {
            pa.push(if prot == 0 {
                pack_a(a, i0, mb, t.mr, t.kc)
            } else {
                pack_a_encode(a, i0, mb, t.mr, t.kc, prot, &mut ea, self.isa)
            });
        }
        self.cache_fill(slot, Arc::new(pa), Arc::new(ea))
    }

    /// B-side counterpart of [`BlockedBackend::packed_a`]: column panels
    /// plus per-protection-column-tile Be sums.
    fn packed_b(
        &self,
        b: &Matrix,
        key: Option<OperandKey>,
        t: HostTiles,
        prot: usize,
    ) -> (Arc<Vec<Vec<f32>>>, Arc<Vec<Vec<f32>>>) {
        let slot = self.cache_slot(key, PanelRole::B, t.nc, t.nr, t.kc, prot);
        if let Some((cache, pk)) = &slot {
            if let Some(hit) = cache.get(pk) {
                return (hit.panels, hit.sums);
            }
        }
        let (k, n) = (b.rows(), b.cols());
        let mut be: Vec<Vec<f32>> =
            if prot == 0 { Vec::new() } else { vec![vec![0.0f32; k]; n.div_ceil(prot)] };
        let mut pb = Vec::new();
        for (j0, nb) in col_blocks(n, t.nc) {
            pb.push(if prot == 0 {
                pack_b(b, j0, nb, t.nr, t.kc)
            } else {
                pack_b_encode(b, j0, nb, t.nr, t.kc, prot, &mut be, self.isa)
            });
        }
        self.cache_fill(slot, Arc::new(pb), Arc::new(be))
    }

    /// The cache + full [`PanelKey`] pair for one operand, or `None`
    /// when either the cache is off or the operand carries no content
    /// address (then packing is neither looked up nor published).
    fn cache_slot(
        &self,
        key: Option<OperandKey>,
        role: PanelRole,
        block: usize,
        micro: usize,
        kc: usize,
        prot: usize,
    ) -> Option<(Arc<PackCache>, PanelKey)> {
        let cache = self.cache.as_ref()?;
        let op = key?;
        let pk = PanelKey { op, role, block, micro, kc, isa: self.isa, prot };
        Some((Arc::clone(cache), pk))
    }

    /// Publish a freshly-packed operand under its key (no-op without
    /// one) and hand the shared form back to the caller.
    fn cache_fill(
        &self,
        slot: Option<(Arc<PackCache>, PanelKey)>,
        panels: Arc<Vec<Vec<f32>>>,
        sums: Arc<Vec<Vec<f32>>>,
    ) -> (Arc<Vec<Vec<f32>>>, Arc<Vec<Vec<f32>>>) {
        if let Some((cache, pk)) = slot {
            cache.insert(
                pk,
                PackedOperand { panels: Arc::clone(&panels), sums: Arc::clone(&sums) },
            );
        }
        (panels, sums)
    }

    /// Fan the macro-tile jobs over the pool and assemble C. Tiles come
    /// back padded to whole micro-panels (row stride `nb.div_ceil(nr) *
    /// nr`); only the live `mb x nb` window is copied out.
    fn compute_blocks(
        &self,
        pa: Arc<Vec<Vec<f32>>>,
        pb: Arc<Vec<Vec<f32>>>,
        m: usize,
        n: usize,
        k: usize,
        t: HostTiles,
    ) -> Matrix {
        let rows: Vec<(usize, usize)> = row_blocks(m, t.mc).collect();
        let cols: Vec<(usize, usize)> = col_blocks(n, t.nc).collect();
        let jobs: Vec<(usize, usize)> = (0..rows.len())
            .flat_map(|ri| (0..cols.len()).map(move |ci| (ri, ci)))
            .collect();
        let (rows_c, cols_c) = (rows.clone(), cols.clone());
        let isa = self.isa;
        let tiles = self.pool.map(jobs.clone(), move |(ri, ci)| {
            let (_, mb) = rows_c[ri];
            let (_, nb) = cols_c[ci];
            compute_macro_tile(&pa[ri], &pb[ci], mb, nb, k, t, isa)
        });
        let mut c = Matrix::zeros(m, n);
        for ((ri, ci), tile) in jobs.into_iter().zip(tiles) {
            let (i0, mb) = rows[ri];
            let (j0, nb) = cols[ci];
            let np = nb.div_ceil(t.nr) * t.nr;
            for r in 0..mb {
                let dst = &mut c.data_mut()[(i0 + r) * n + j0..(i0 + r) * n + j0 + nb];
                dst.copy_from_slice(&tile[r * np..r * np + nb]);
            }
        }
        c
    }

    /// The fused FT-GEMM: checksum encoding rides the packing pass, the
    /// compute sweep runs over the pool, and each injected verification
    /// interval triggers a parallel verify/correct sweep over the touched
    /// protection sub-tiles. Observable behavior (C, errcount grid)
    /// matches [`backend::semantic_ft_gemm`] exactly.
    fn fused_ft(
        &self,
        art: &Artifact,
        a: Matrix,
        b: Matrix,
        key_a: Option<OperandKey>,
        key_b: Option<OperandKey>,
        injections: Vec<Injection>,
        correct: bool,
    ) -> Result<(Matrix, Vec<f32>, Vec<f32>, Vec<f32>)> {
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        let (sub_m, sub_n) = backend::protection_tile(art, m, n)?;
        let (gm, gn) = (m.div_ceil(sub_m), n.div_ceil(sub_n));
        backend::check_injection_capacity(art, injections.len())?;

        let t = self.tiles(m, n, k);
        // Fused encoding needs protection tiles that never span pack
        // blocks; the shape-class tile tables guarantee this for every
        // builtin artifact. Misaligned (custom-manifest) protection
        // geometry falls back to on-demand per-tile encoding — same
        // values, computed at verify time instead of pack time.
        let aligned = sub_m <= t.mc
            && t.mc % sub_m == 0
            && sub_n <= t.nc
            && t.nc % sub_n == 0
            && m * n * k >= PARALLEL_FLOP_FLOOR;

        let (c, errgrid) = if aligned {
            // Packing (with the encode fused in) flows through the pool
            // cache for keyed operands — a hit reuses another request's
            // panels *and* its per-tile operand sums, both immutable.
            let (pa, ea) = self.packed_a(&a, key_a, t, sub_m);
            let (pb, be) = self.packed_b(&b, key_b, t, sub_n);
            self.compute_blocks_ft(
                pa,
                pb,
                Arc::new(a),
                Arc::new(b),
                m,
                n,
                k,
                t,
                art.verify_every,
                sub_m,
                sub_n,
                &injections,
                ea,
                be,
                correct,
            )
        } else {
            // Misaligned (custom-manifest) protection geometry: compute
            // first, then drive the shared whole-matrix interval sweep,
            // fanning the touched tiles over the pool with on-demand
            // per-tile checksums — same values, computed at verify time
            // instead of pack time.
            let mut c = self.gemm_keyed(&a, &b, key_a, key_b);
            let mut errgrid = vec![0.0f32; gm * gn];
            let a = Arc::new(a);
            let b = Arc::new(b);
            backend::run_injection_sweeps(
                art,
                m,
                n,
                sub_m,
                sub_n,
                &mut c,
                &injections,
                &mut errgrid,
                |jobs| {
                    let th = self.thresholds;
                    let (a2, b2) = (Arc::clone(&a), Arc::clone(&b));
                    self.pool.map(jobs, move |(ti, tj, mut tile)| {
                        let (r0, r1) = (ti * sub_m, ((ti + 1) * sub_m).min(m));
                        let (c0, c1) = (tj * sub_n, ((tj + 1) * sub_n).min(n));
                        let carried = backend::tile_carried_checksums(&a2, &b2, r0, r1, c0, c1);
                        let (corrections, detections) =
                            backend::verify_correct_loop(&mut tile, &carried, th, correct);
                        (ti, tj, tile, corrections, detections)
                    })
                },
            );
            (c, errgrid)
        };

        let cr = c.row_sums();
        let cc = c.col_sums();
        Ok((c, cr, cc, errgrid))
    }

    /// The aligned fused path: one pool job per macro tile computes the
    /// tile with the blocked k-panel nest, then runs its own
    /// injected-interval verify/correct sweeps in place — verification
    /// of finished tiles overlaps compute of the remaining ones (the
    /// paper's fusion-overlap strategy) instead of whole-matrix passes
    /// after the full sweep. Valid because on this path protection
    /// domains never span macro tiles (`sub_m | mc`, `sub_n | nc`,
    /// blocks step uniformly), so each tile's local sweeps observe
    /// exactly the state the shared whole-matrix interval walker would;
    /// the (C, errcount grid) pair is identical to
    /// [`backend::run_injection_sweeps`] by construction.
    #[allow(clippy::too_many_arguments)]
    fn compute_blocks_ft(
        &self,
        pa: Arc<Vec<Vec<f32>>>,
        pb: Arc<Vec<Vec<f32>>>,
        a: Arc<Matrix>,
        b: Arc<Matrix>,
        m: usize,
        n: usize,
        k: usize,
        t: HostTiles,
        verify_every: usize,
        sub_m: usize,
        sub_n: usize,
        injections: &[Injection],
        ea: Arc<Vec<Vec<f32>>>,
        be: Arc<Vec<Vec<f32>>>,
        correct: bool,
    ) -> (Matrix, Vec<f32>) {
        let rows: Vec<(usize, usize)> = row_blocks(m, t.mc).collect();
        let cols: Vec<(usize, usize)> = col_blocks(n, t.nc).collect();
        let ncols = cols.len();
        // Bucket each in-bounds injection with the macro tile that owns
        // it (blocks step uniformly by mc/nc).
        let mut per_job: Vec<Vec<Injection>> = vec![Vec::new(); rows.len() * ncols];
        for inj in injections {
            if inj.row < m && inj.col < n {
                per_job[(inj.row / t.mc) * ncols + (inj.col / t.nc)].push(*inj);
            }
        }
        let per_job = Arc::new(per_job);
        let jobs: Vec<(usize, usize)> = (0..rows.len())
            .flat_map(|ri| (0..ncols).map(move |ci| (ri, ci)))
            .collect();
        let (rows_c, cols_c) = (rows.clone(), cols.clone());
        let isa = self.isa;
        let th = self.thresholds;
        let results = self.pool.map(jobs.clone(), move |(ri, ci)| {
            let (i0, mb) = rows_c[ri];
            let (j0, nb) = cols_c[ci];
            let mut tile = compute_macro_tile(&pa[ri], &pb[ci], mb, nb, k, t, isa);
            let np = nb.div_ceil(t.nr) * t.nr;
            let counts = sweep_macro_tile(
                &mut tile,
                np,
                i0,
                j0,
                m,
                n,
                sub_m,
                sub_n,
                verify_every,
                &per_job[ri * ncols + ci],
                &a,
                &b,
                &ea[..],
                &be[..],
                th,
                correct,
            );
            (tile, counts)
        });
        let gn = n.div_ceil(sub_n);
        let mut c = Matrix::zeros(m, n);
        let mut errgrid = vec![0.0f32; m.div_ceil(sub_m) * gn];
        for ((ri, ci), (tile, counts)) in jobs.into_iter().zip(results) {
            let (i0, mb) = rows[ri];
            let (j0, nb) = cols[ci];
            let np = nb.div_ceil(t.nr) * t.nr;
            for r in 0..mb {
                let dst = &mut c.data_mut()[(i0 + r) * n + j0..(i0 + r) * n + j0 + nb];
                dst.copy_from_slice(&tile[r * np..r * np + nb]);
            }
            for (ti, tj, cnt) in counts {
                errgrid[ti * gn + tj] += cnt as f32;
            }
        }
        (c, errgrid)
    }
}

impl Default for BlockedBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for BlockedBackend {
    fn name(&self) -> &'static str {
        self.name
    }

    fn compile(&mut self, art: &Artifact) -> Result<bool> {
        if self.compiled.contains(&art.name) {
            return Ok(false);
        }
        backend::validate_artifact(art)?;
        if art.m > 0 && art.n > 0 && art.k > 0 {
            let t = self.tiles(art.m, art.n, art.k);
            log::debug!(
                "blocked tiles for {}: MC={} KC={} NC={} MR={} NR={} ({} threads, {} kernel)",
                art.name,
                t.mc,
                t.kc,
                t.nc,
                t.mr,
                t.nr,
                self.threads,
                self.isa.name()
            );
        }
        self.compiled.insert(art.name.clone());
        Ok(true)
    }

    fn execute(&mut self, art: &Artifact, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let this: &BlockedBackend = self;
        match art.kind {
            ArtifactKind::FtGemm | ArtifactKind::FtDetect => {
                let correct = art.kind == ArtifactKind::FtGemm;
                let mut it = inputs.into_iter();
                let ta = it.next();
                let key_a = ta.as_ref().and_then(|t| t.key);
                let a = backend::matrix_input(art, ta)?;
                let tb = it.next();
                let key_b = tb.as_ref().and_then(|t| t.key);
                let b = backend::matrix_input(art, tb)?;
                let inj =
                    it.next().ok_or_else(|| anyhow!("{}: missing inj input", art.name))?;
                let injections = backend::decode_injections(&inj);
                let (c, cr, cc, errgrid) =
                    this.fused_ft(art, a, b, key_a, key_b, injections, correct)?;
                backend::build_outputs(
                    art,
                    [
                        ("c", c.into_data()),
                        ("cr", cr),
                        ("cc", cc),
                        ("errcount", errgrid),
                    ]
                    .into_iter()
                    .collect(),
                )
            }
            // Same semantics as `execute_semantic`'s Gemm arm, but with
            // the operands' content addresses preserved so the plain
            // GEMM path shares packed panels across requests too.
            ArtifactKind::Gemm | ArtifactKind::Stepwise => {
                let mut it = inputs.into_iter();
                let ta = it.next();
                let key_a = ta.as_ref().and_then(|t| t.key);
                let a = backend::matrix_input(art, ta)?;
                let tb = it.next();
                let key_b = tb.as_ref().and_then(|t| t.key);
                let b = backend::matrix_input(art, tb)?;
                let c = this.gemm_keyed(&a, &b, key_a, key_b);
                backend::build_outputs(art, [("c", c.into_data())].into_iter().collect())
            }
            _ => backend::execute_semantic(art, inputs, this.thresholds, &|a, b| {
                this.gemm(a, b)
            }),
        }
    }
}

// ---------------------------------------------------------------------
// Blocking geometry
// ---------------------------------------------------------------------

fn row_blocks(m: usize, mc: usize) -> impl Iterator<Item = (usize, usize)> {
    (0..m).step_by(mc.max(1)).map(move |i0| (i0, mc.min(m - i0)))
}

fn col_blocks(n: usize, nc: usize) -> impl Iterator<Item = (usize, usize)> {
    (0..n).step_by(nc.max(1)).map(move |j0| (j0, nc.min(n - j0)))
}

/// Ascending `(k0, kb)` reduction panels: `kb = kc` except possibly the
/// last. Ascending order is load-bearing — it is what lets carried
/// accumulators reproduce the reference backend's ascending-`k` fold.
fn k_panels(k: usize, kc: usize) -> impl Iterator<Item = (usize, usize)> {
    (0..k).step_by(kc.max(1)).map(move |k0| (k0, kc.max(1).min(k - k0)))
}

// ---------------------------------------------------------------------
// Packing (with optional fused checksum encoding)
// ---------------------------------------------------------------------

/// Pack rows `[i0, i0+mb)` of A into the k-panel-major micro-panel
/// layout, zero-padded to whole MR-row panels, feeding every stored
/// element to `sink(i, kk, v)` — the single source of truth for both the
/// A block layout and the encode fold order (ascending `i` per
/// `(tile, kk)`, which [`backend::tile_carried_checksums`] mirrors).
///
/// Layout: the buffer is ordered by `kc`-deep reduction panel first,
/// then MR-row micro-panel — panel `p` (covering `kk` in `[k0, k0+kb)`)
/// occupies `[ipanels*mr*k0, ipanels*mr*(k0+kb))`, and within it
/// micro-panel `ip` holds element `(kk_local, r) -> a[i0+ip*mr+r][k0 +
/// kk_local]` at `ip*kb*mr + kk_local*mr + r`. Each panel region is
/// exactly the PR-3 layout with `k` replaced by `kb`, so the macro-tile
/// sweep touches one contiguous MC x KC region per k-panel iteration.
fn pack_a_sink(
    a: &Matrix,
    i0: usize,
    mb: usize,
    mr: usize,
    kc: usize,
    mut sink: impl FnMut(usize, usize, f32),
) -> Vec<f32> {
    let k = a.cols();
    let ipanels = mb.div_ceil(mr);
    let mut out = vec![0.0f32; ipanels * k * mr];
    for (k0, kb) in k_panels(k, kc) {
        let pbase = ipanels * mr * k0;
        for ip in 0..ipanels {
            let base = pbase + ip * kb * mr;
            for r in 0..mr.min(mb - ip * mr) {
                let i = i0 + ip * mr + r;
                let row = &a.row(i)[k0..k0 + kb];
                for (kk, &v) in row.iter().enumerate() {
                    out[base + kk * mr + r] = v;
                    sink(i, k0 + kk, v);
                }
            }
        }
    }
    out
}

fn pack_a(a: &Matrix, i0: usize, mb: usize, mr: usize, kc: usize) -> Vec<f32> {
    pack_a_sink(a, i0, mb, mr, kc, |_i, _kk, _v| {})
}

/// [`pack_a`] with the encode fused in: row-range sums per protection row
/// tile (`ea[i / sub_m][kk] += a[i][kk]`).
///
/// On SIMD ISAs the encode runs vector-resident: per tile-bounded row
/// run, an 8-lane accumulator (lanes = adjacent `kk`) is loaded once,
/// carried across every row of the run, and stored once. Per `kk` lane
/// the adds land in ascending `i` — the scalar sink's fold order,
/// bit-exactly — so carried checksums do not depend on the ISA.
/// (Caller guarantees `i0 % sub_m == 0`; the `aligned` gate in
/// `fused_ft` enforces it.)
fn pack_a_encode(
    a: &Matrix,
    i0: usize,
    mb: usize,
    mr: usize,
    kc: usize,
    sub_m: usize,
    ea: &mut [Vec<f32>],
    isa: KernelIsa,
) -> Vec<f32> {
    if !isa.is_simd() {
        return pack_a_sink(a, i0, mb, mr, kc, |i, kk, v| ea[i / sub_m][kk] += v);
    }
    let out = pack_a(a, i0, mb, mr, kc);
    let k = a.cols();
    let mut i = i0;
    while i < i0 + mb {
        let ti = i / sub_m;
        let r1 = ((ti + 1) * sub_m).min(i0 + mb);
        // Reduction panels partition `kk`, so per-panel encode calls
        // compose into the identical full-k checksum row (each ea entry
        // is still one ascending-`i` fold).
        for (k0, kb) in k_panels(k, kc) {
            encode_rows(a, i, r1, k0, &mut ea[ti][k0..k0 + kb], isa);
        }
        i = r1;
    }
    out
}

/// Vector-resident A-side row-run encode dispatcher over one reduction
/// panel (`ea_seg[kk] += a[i][kk0 + kk]`; see [`pack_a_encode`]); the
/// portable arm replays the scalar sink's ascending-`i`-per-`kk` order
/// exactly.
fn encode_rows(a: &Matrix, r0: usize, r1: usize, kk0: usize, ea_seg: &mut [f32], isa: KernelIsa) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: construction verified AVX2 (Avx512 implies it — see
        // `KernelIsa::supported`).
        KernelIsa::Avx2Fma | KernelIsa::Avx512 => unsafe {
            simd::x86::encode_rows(a, r0, r1, kk0, ea_seg)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: construction verified NEON.
        KernelIsa::Neon => unsafe { simd::neon::encode_rows(a, r0, r1, kk0, ea_seg) },
        _ => {
            for i in r0..r1 {
                for (s, &v) in ea_seg.iter_mut().zip(&a.row(i)[kk0..]) {
                    *s += v;
                }
            }
        }
    }
}

/// Pack columns `[j0, j0+nb)` of B into the k-panel-major micro-panel
/// layout, zero-padded to whole NR-column panels, feeding every stored
/// element to `sink(j, kk, v)` — the single source of truth for both the
/// B panel layout and the encode fold order (ascending `j` per
/// `(tile, kk)`).
///
/// Layout mirrors [`pack_a_sink`]: reduction panel `p` (covering `kk` in
/// `[k0, k0+kb)`) occupies `[jpanels*nr*k0, jpanels*nr*(k0+kb))`, and
/// within it micro-panel `jp` holds element `(kk_local, c) ->
/// b[k0+kk_local][j0+jp*nr+c]` at `jp*kb*nr + kk_local*nr + c` — each
/// panel region is the PR-3 layout with `k` replaced by `kb`.
fn pack_b_sink(
    b: &Matrix,
    j0: usize,
    nb: usize,
    nr: usize,
    kc: usize,
    mut sink: impl FnMut(usize, usize, f32),
) -> Vec<f32> {
    let k = b.rows();
    let jpanels = nb.div_ceil(nr);
    let mut out = vec![0.0f32; jpanels * k * nr];
    for (k0, kb) in k_panels(k, kc) {
        let pbase = jpanels * nr * k0;
        for kk in 0..kb {
            let row = b.row(k0 + kk);
            for jp in 0..jpanels {
                let base = pbase + jp * kb * nr + kk * nr;
                for c in 0..nr.min(nb - jp * nr) {
                    let j = j0 + jp * nr + c;
                    out[base + c] = row[j];
                    sink(j, k0 + kk, row[j]);
                }
            }
        }
    }
    out
}

fn pack_b(b: &Matrix, j0: usize, nb: usize, nr: usize, kc: usize) -> Vec<f32> {
    pack_b_sink(b, j0, nb, nr, kc, |_j, _kk, _v| {})
}

/// [`pack_b`] with the encode fused in: column-range sums per protection
/// column tile, in the crate-wide canonical lane-split order
/// ([`simd::sum8`] — the same order [`backend::tile_carried_checksums`]
/// uses), walking each B row tile segment by tile segment while the
/// panel stores stream out inline.
///
/// When the ISA is SIMD and both `nr` and `sub_n` are lane-multiples,
/// each segment runs vector-resident: one 8-lane accumulator carried
/// across the whole tile segment, stores issued straight from the
/// loaded vectors (every aligned 8-chunk is contiguous in the panel
/// layout), reduced through the canonical [`simd::fold8`] tree —
/// bit-identical to the portable path by construction. (Caller
/// guarantees `j0 % sub_n == 0`; the `aligned` gate in `fused_ft`
/// enforces it.)
fn pack_b_encode(
    b: &Matrix,
    j0: usize,
    nb: usize,
    nr: usize,
    kc: usize,
    sub_n: usize,
    be: &mut [Vec<f32>],
    isa: KernelIsa,
) -> Vec<f32> {
    let k = b.rows();
    let jpanels = nb.div_ceil(nr);
    let mut out = vec![0.0f32; jpanels * k * nr];
    let vector_path =
        isa.is_simd() && nr % simd::LANES == 0 && sub_n % simd::LANES == 0;
    // Each per-(tile, kk) sum is computed entirely within the one
    // reduction panel that owns its `kk`, in the canonical segment
    // order — identical to the unpartitioned pass bit for bit. The
    // colsum helpers see the panel region with `k` standing in as `kb`
    // (each region is exactly the single-panel layout).
    for (k0, kb) in k_panels(k, kc) {
        let region = &mut out[jpanels * nr * k0..jpanels * nr * (k0 + kb)];
        for kk in 0..kb {
            let row = b.row(k0 + kk);
            let end = j0 + nb;
            let mut j = j0;
            while j < end {
                let tj = j / sub_n;
                let tend = ((tj + 1) * sub_n).min(end);
                let seg = &row[j..tend];
                let off0 = j - j0;
                be[tj][k0 + kk] += if vector_path {
                    pack_colsum(seg, region, off0, nr, kb, kk, isa)
                } else {
                    pack_colsum_portable(seg, region, off0, nr, kb, kk)
                };
                j = tend;
            }
        }
    }
    out
}

/// Portable arm of the fused B store+sum: lane `t % 8` accumulates
/// segment element `t` (exactly [`simd::sum8`]'s order), stores landing
/// at the [`pack_b_sink`] layout positions.
fn pack_colsum_portable(
    seg: &[f32],
    out: &mut [f32],
    off0: usize,
    nr: usize,
    k: usize,
    kk: usize,
) -> f32 {
    let mut lanes = [0.0f32; simd::LANES];
    for (t, &v) in seg.iter().enumerate() {
        let off = off0 + t;
        out[(off / nr) * k * nr + kk * nr + (off % nr)] = v;
        lanes[t % simd::LANES] += v;
    }
    simd::fold8(lanes)
}

/// Vector arm of the fused B store+sum (see [`pack_b_encode`]).
#[allow(clippy::too_many_arguments)]
fn pack_colsum(
    seg: &[f32],
    out: &mut [f32],
    off0: usize,
    nr: usize,
    k: usize,
    kk: usize,
    isa: KernelIsa,
) -> f32 {
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: construction verified AVX2 (Avx512 implies it).
        KernelIsa::Avx2Fma | KernelIsa::Avx512 => unsafe {
            simd::x86::pack_colsum(seg, out, off0, nr, k, kk)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: construction verified NEON.
        KernelIsa::Neon => unsafe { simd::neon::pack_colsum(seg, out, off0, nr, k, kk) },
        _ => pack_colsum_portable(seg, out, off0, nr, k, kk),
    }
}

// ---------------------------------------------------------------------
// Macro tile + micro kernel
// ---------------------------------------------------------------------

/// One macro tile from packed operands, as the GotoBLAS-style k-panel
/// nest: the outermost loop walks ascending `KC`-deep reduction panels,
/// and within each panel the jp/ip micro-panel sweep runs the
/// accumulate-into micro-kernels against that panel's contiguous MC x KC
/// / KC x NC pack regions. Accumulators round-trip through the tile
/// buffer between panels — exact f32 stores/reloads, so any `kc`
/// reproduces the full-`k` register-resident fold bitwise.
///
/// The returned buffer is padded to whole micro-panels:
/// `mb.div_ceil(mr)*mr` rows by `nb.div_ceil(nr)*nr` columns (row stride
/// = the latter). Padded lanes multiply packed zeros and stay `0.0`;
/// callers copy out the live `mb x nb` window.
fn compute_macro_tile(
    pa: &[f32],
    pb: &[f32],
    mb: usize,
    nb: usize,
    k: usize,
    t: HostTiles,
    isa: KernelIsa,
) -> Vec<f32> {
    let (mr, nr) = (t.mr, t.nr);
    let ipanels = mb.div_ceil(mr);
    let jpanels = nb.div_ceil(nr);
    let np = jpanels * nr;
    let mut out = vec![0.0f32; ipanels * mr * np];
    for (k0, kb) in k_panels(k, t.kc) {
        let pa_panel = &pa[ipanels * mr * k0..ipanels * mr * (k0 + kb)];
        let pb_panel = &pb[jpanels * nr * k0..jpanels * nr * (k0 + kb)];
        for jp in 0..jpanels {
            let pbp = &pb_panel[jp * kb * nr..(jp + 1) * kb * nr];
            for ip in 0..ipanels {
                let pap = &pa_panel[ip * kb * mr..(ip + 1) * kb * mr];
                let idx0 = ip * mr * np + jp * nr;
                dispatch_micro(kb, pap, pbp, &mut out, idx0, np, mr, nr, isa);
            }
        }
    }
    out
}

/// Route one micro-panel to the ISA's vector kernel when the micro-tile
/// geometry matches the kernel it was written for (always true for
/// tiles from [`host_tiles_for`]); anything else — scalar ISA, custom
/// geometry, or an ISA compiled out — takes the portable
/// [`micro_into`]/[`micro_generic`] path. All kernels accumulate into
/// the padded tile at `out[idx0 + r * stride ..]` (load, fold `kb`
/// terms, store back).
#[allow(clippy::too_many_arguments)]
fn dispatch_micro(
    kb: usize,
    pap: &[f32],
    pbp: &[f32],
    out: &mut [f32],
    idx0: usize,
    stride: usize,
    mr: usize,
    nr: usize,
    isa: KernelIsa,
) {
    match (isa, mr, nr) {
        #[cfg(target_arch = "x86_64")]
        (KernelIsa::Avx2Fma, 8, 8) => {
            // SAFETY: construction verified avx2+fma on this host.
            unsafe { simd::x86::micro_8x8(kb, pap, pbp, out, idx0, stride) }
        }
        #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
        (KernelIsa::Avx512, 8, 16) => {
            // SAFETY: construction verified avx512f on this host.
            unsafe { simd::x86::micro_8x16(kb, pap, pbp, out, idx0, stride) }
        }
        #[cfg(target_arch = "aarch64")]
        (KernelIsa::Neon, 8, 8) => {
            // SAFETY: construction verified NEON on this host.
            unsafe { simd::neon::micro_8x8(kb, pap, pbp, out, idx0, stride) }
        }
        _ => match (mr, nr) {
            (8, 8) => micro_into::<8, 8>(kb, pap, pbp, out, idx0, stride),
            (8, 4) => micro_into::<8, 4>(kb, pap, pbp, out, idx0, stride),
            (4, 8) => micro_into::<4, 8>(kb, pap, pbp, out, idx0, stride),
            (4, 4) => micro_into::<4, 4>(kb, pap, pbp, out, idx0, stride),
            (8, 16) => micro_into::<8, 16>(kb, pap, pbp, out, idx0, stride),
            _ => micro_generic(kb, mr, nr, pap, pbp, out, idx0, stride),
        },
    }
}

/// The register-tiled micro-kernel, panel-carried: load the MR x NR
/// accumulator array from the padded tile, fold one reduction panel on
/// top (ascending `kk` — chained panels reproduce the reference
/// backend's single ascending-k fold per element exactly), store back.
fn micro_into<const MR: usize, const NR: usize>(
    kb: usize,
    pap: &[f32],
    pbp: &[f32],
    out: &mut [f32],
    idx0: usize,
    stride: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for (r, acc_row) in acc.iter_mut().enumerate() {
        acc_row.copy_from_slice(&out[idx0 + r * stride..idx0 + r * stride + NR]);
    }
    for kk in 0..kb {
        let af = &pap[kk * MR..kk * MR + MR];
        let bf = &pbp[kk * NR..kk * NR + NR];
        for r in 0..MR {
            let ar = af[r];
            for c in 0..NR {
                acc[r][c] += ar * bf[c];
            }
        }
    }
    for (r, acc_row) in acc.iter().enumerate() {
        out[idx0 + r * stride..idx0 + r * stride + NR].copy_from_slice(acc_row);
    }
}

/// Fallback for tile tables outside the monomorphized MR/NR set.
#[allow(clippy::too_many_arguments)]
fn micro_generic(
    kb: usize,
    mr: usize,
    nr: usize,
    pap: &[f32],
    pbp: &[f32],
    out: &mut [f32],
    idx0: usize,
    stride: usize,
) {
    let mut acc = vec![0.0f32; mr * nr];
    for r in 0..mr {
        acc[r * nr..r * nr + nr].copy_from_slice(&out[idx0 + r * stride..idx0 + r * stride + nr]);
    }
    for kk in 0..kb {
        let af = &pap[kk * mr..kk * mr + mr];
        let bf = &pbp[kk * nr..kk * nr + nr];
        for r in 0..mr {
            let ar = af[r];
            let dst = &mut acc[r * nr..r * nr + nr];
            for (d, &bv) in dst.iter_mut().zip(bf) {
                *d += ar * bv;
            }
        }
    }
    for r in 0..mr {
        out[idx0 + r * stride..idx0 + r * stride + nr].copy_from_slice(&acc[r * nr..r * nr + nr]);
    }
}

// ---------------------------------------------------------------------
// Per-macro-tile verify pipelining
// ---------------------------------------------------------------------

/// Run one macro tile's injected-interval verify/correct sweeps in
/// place on its padded tile buffer (row stride `np`, tile origin
/// `(i0, j0)`): faults land per ascending verification interval, every
/// touched protection tile is verified against carried checksums
/// finished from the packed operand sums, and corrected values fold
/// back before the next interval's faults apply. Returns the per-tile
/// errcounts `(ti, tj, corrections + detections)` — exactly the
/// macro-local slice of [`backend::run_injection_sweeps`], since on the
/// aligned path protection domains never span macro tiles.
#[allow(clippy::too_many_arguments)]
fn sweep_macro_tile(
    tile: &mut [f32],
    np: usize,
    i0: usize,
    j0: usize,
    m: usize,
    n: usize,
    sub_m: usize,
    sub_n: usize,
    verify_every: usize,
    injections: &[Injection],
    a: &Matrix,
    b: &Matrix,
    ea: &[Vec<f32>],
    be: &[Vec<f32>],
    th: Thresholds,
    correct: bool,
) -> Vec<(usize, usize, usize)> {
    if injections.is_empty() {
        return Vec::new();
    }
    let ve = verify_every.max(1);
    let mut by_interval: BTreeMap<usize, Vec<Injection>> = BTreeMap::new();
    for inj in injections {
        by_interval.entry(inj.step / ve).or_default().push(*inj);
    }
    let mut out = Vec::new();
    for injs in by_interval.values() {
        let mut touched: HashSet<(usize, usize)> = HashSet::new();
        for inj in injs {
            tile[(inj.row - i0) * np + (inj.col - j0)] += inj.magnitude;
            touched.insert((inj.row / sub_m, inj.col / sub_n));
        }
        for (ti, tj) in touched {
            let (r0, r1) = (ti * sub_m, ((ti + 1) * sub_m).min(m));
            let (c0, c1) = (tj * sub_n, ((tj + 1) * sub_n).min(n));
            let mut snap = Matrix::from_fn(r1 - r0, c1 - c0, |i, j| {
                tile[(r0 - i0 + i) * np + (c0 - j0 + j)]
            });
            let carried = backend::carried_from_sums(a, b, r0, r1, c0, c1, &be[tj], &ea[ti]);
            let (corrections, detections) =
                backend::verify_correct_loop(&mut snap, &carried, th, correct);
            if corrections > 0 {
                for i in 0..r1 - r0 {
                    for j in 0..c1 - c0 {
                        tile[(r0 - i0 + i) * np + (c0 - j0 + j)] = snap.at(i, j);
                    }
                }
            }
            out.push((ti, tj, corrections + detections));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::abft::injection::InjectionPlan;
    use crate::codegen::select::host_tiles;
    use crate::runtime::backend::ReferenceBackend;
    use crate::runtime::manifest::Manifest;
    use crate::runtime::pack_cache::OperandId;

    fn tensor2(m: &Matrix) -> Tensor {
        Tensor::new(vec![m.rows(), m.cols()], m.data().to_vec())
    }

    #[test]
    fn blocked_gemm_matches_reference_on_bucket_and_odd_shapes() {
        for isa in KernelIsa::supported() {
            let be = BlockedBackend::with_threads_isa(4, isa);
            for (m, k, n, seed) in [
                (64usize, 64usize, 64usize, 1u64),
                (128, 128, 128, 2),
                (512, 512, 512, 3),
                (129, 64, 65, 4), // ding panel-update geometry
                (100, 70, 90, 5),
                (1, 300, 2, 6),
            ] {
                let a = Matrix::rand_uniform(m, k, seed);
                let b = Matrix::rand_uniform(k, n, seed + 100);
                let diff = be.gemm(&a, &b).max_abs_diff(&a.matmul(&b));
                // same fold order on every ISA; the slack over exact
                // equality is FMA's fused rounding per term
                let tol = 1e-4 + 1e-6 * k as f32;
                assert!(diff < tol, "{isa:?} ({m},{k},{n}) diff {diff}");
            }
        }
    }

    #[test]
    fn force_scalar_env_pins_the_scalar_kernel() {
        // The only test that touches FTGEMM_FORCE_SCALAR (keeps the
        // parallel test harness race-free); previous value restored so
        // a forced-scalar CI run stays forced after this test.
        let prev = std::env::var("FTGEMM_FORCE_SCALAR").ok();
        std::env::set_var("FTGEMM_FORCE_SCALAR", "1");
        let pinned = BlockedBackend::with_threads(1);
        let detected = KernelIsa::detect();
        std::env::set_var("FTGEMM_FORCE_SCALAR", "0");
        let unpinned = KernelIsa::detect();
        match prev {
            Some(v) => std::env::set_var("FTGEMM_FORCE_SCALAR", v),
            None => std::env::remove_var("FTGEMM_FORCE_SCALAR"),
        }
        assert_eq!(pinned.kernel_isa(), KernelIsa::Scalar);
        assert_eq!(detected, KernelIsa::Scalar);
        // "0" / unset mean no forcing: detection returns the widest
        // supported ISA (Scalar again on scalar-only hosts)
        assert_eq!(unpinned, *KernelIsa::supported().last().unwrap());
        // explicit ISA pinning bypasses detection entirely
        for isa in KernelIsa::supported() {
            assert_eq!(BlockedBackend::with_threads_isa(1, isa).kernel_isa(), isa);
        }
        // unsupported pins degrade to scalar rather than risking UB
        #[cfg(not(target_arch = "aarch64"))]
        assert_eq!(
            BlockedBackend::with_threads_isa(1, KernelIsa::Neon).kernel_isa(),
            KernelIsa::Scalar
        );
    }

    #[test]
    fn packed_encode_matches_on_demand_checksums_bitwise() {
        // The carried-checksum contract behind exact errcount parity:
        // operand sums accumulated during packing (scalar or
        // vector-resident) must equal the reference backend's on-demand
        // tile_carried_checksums BIT-exactly, for every supported ISA,
        // on both lane-multiple and narrow protection tiles.
        for (m, n, k, sub_m, sub_n) in
            [(128usize, 128usize, 128usize, 32usize, 32usize), (64, 64, 64, 4, 4)]
        {
            let a = Matrix::rand_uniform(m, k, 31);
            let b = Matrix::rand_uniform(k, n, 32);
            let (gm, gn) = (m / sub_m, n / sub_n);
            for isa in KernelIsa::supported() {
                let t = host_tiles_for(isa, m, n, k);
                // Reduction panels partition `kk`, so per-KC-panel encode
                // passes must compose into THE SAME sums bit for bit at
                // any KC — including a KC that divides nothing evenly.
                let encode = |kc: usize| {
                    let mut ea: Vec<Vec<f32>> = vec![vec![0.0f32; k]; gm];
                    let mut be: Vec<Vec<f32>> = vec![vec![0.0f32; k]; gn];
                    for (i0, mb) in row_blocks(m, t.mc) {
                        pack_a_encode(&a, i0, mb, t.mr, kc, sub_m, &mut ea, isa);
                    }
                    for (j0, nb) in col_blocks(n, t.nc) {
                        pack_b_encode(&b, j0, nb, t.nr, kc, sub_n, &mut be, isa);
                    }
                    (ea, be)
                };
                let (ea, be) = encode(t.kc);
                for kc in [24usize, 64, k] {
                    let (ea_kc, be_kc) = encode(kc);
                    assert_eq!(ea_kc, ea, "{isa:?} KC={kc}: eᵀA sums drifted across KC");
                    assert_eq!(be_kc, be, "{isa:?} KC={kc}: Be sums drifted across KC");
                }
                for ti in 0..gm {
                    for tj in 0..gn {
                        let (r0, r1) = (ti * sub_m, (ti + 1) * sub_m);
                        let (c0, c1) = (tj * sub_n, (tj + 1) * sub_n);
                        let want = backend::tile_carried_checksums(&a, &b, r0, r1, c0, c1);
                        let got = backend::carried_from_sums(
                            &a, &b, r0, r1, c0, c1, &be[tj], &ea[ti],
                        );
                        assert_eq!(got.cr, want.cr, "{isa:?} cr tile ({ti},{tj})");
                        assert_eq!(got.cc, want.cc, "{isa:?} cc tile ({ti},{tj})");
                    }
                }

                // Cached-vs-fresh: the same panels + sums served through
                // the pool cache (fill pass, then hit pass) must stay
                // BIT-identical to the freshly-encoded ones, per ISA —
                // this is what keeps detection decisions and errcount
                // grids unchanged when the cache is on.
                let cache = Arc::new(PackCache::new(64 * 1024 * 1024));
                let bk = BlockedBackend::with_threads_isa(1, isa)
                    .with_pack_cache(Some(Arc::clone(&cache)));
                let ka =
                    Some(OperandKey::whole(OperandId::Seed { rows: m, cols: k, seed: 31 }, m, k));
                let kb =
                    Some(OperandKey::whole(OperandId::Seed { rows: k, cols: n, seed: 32 }, k, n));
                let fresh_pa: Vec<Vec<f32>> =
                    row_blocks(m, t.mc).map(|(i0, mb)| pack_a(&a, i0, mb, t.mr, t.kc)).collect();
                for pass in ["fill", "hit"] {
                    let (pa_c, ea_c) = bk.packed_a(&a, ka, t, sub_m);
                    let (_, be_c) = bk.packed_b(&b, kb, t, sub_n);
                    assert_eq!(&*ea_c, &ea, "{isa:?} {pass}: cached eᵀA sums drifted");
                    assert_eq!(&*be_c, &be, "{isa:?} {pass}: cached Be sums drifted");
                    for (got_p, want_p) in pa_c.iter().zip(&fresh_pa) {
                        assert_eq!(got_p, want_p, "{isa:?} {pass}: cached A panel drifted");
                    }
                }
                let s = cache.stats();
                assert_eq!(s.hits, 2, "{isa:?}: second pass must hit both roles, {s:?}");
                assert_eq!(s.misses, 2, "{isa:?}: {s:?}");
            }
        }
    }

    #[test]
    fn cached_ft_runs_stay_bitwise_identical_and_count_hits() {
        // End-to-end pin of the cache's correctness contract: with the
        // pool cache on and content-addressed operands, a repeated
        // injected FT run reuses the packed panels + fused sums and
        // still produces byte-identical C, cr, cc and errcount outputs
        // (same instance, same ISA, so even C is bitwise stable).
        let man = Manifest::builtin();
        let cache = Arc::new(PackCache::new(256 * 1024 * 1024));
        let mut cached =
            BlockedBackend::with_threads(2).with_pack_cache(Some(Arc::clone(&cache)));
        let mut fresh = BlockedBackend::with_threads(2);
        let art = man.get("ftgemm_tb_medium").unwrap();
        let a = Matrix::rand_uniform(art.m, art.k, 77);
        let b = Matrix::rand_uniform(art.k, art.n, 78);
        let mut rng = crate::util::rng::Pcg32::seeded(79);
        let plan = InjectionPlan::random_seu(
            art.m,
            art.n,
            art.k / 8,
            art.verify_every,
            art.sub_m,
            art.sub_n,
            3,
            &mut rng,
        );
        let keyed = |mat: &Matrix, seed: u64| {
            let (rows, cols) = (mat.rows(), mat.cols());
            tensor2(mat)
                .with_key(Some(OperandKey::whole(OperandId::Seed { rows, cols, seed }, rows, cols)))
        };
        let inputs = || {
            vec![
                keyed(&a, 77),
                keyed(&b, 78),
                Tensor::new(vec![art.max_inj, 4], plan.to_tensor(art.max_inj)),
            ]
        };
        let want = fresh.execute(art, inputs()).unwrap();
        let first = cached.execute(art, inputs()).unwrap();
        let second = cached.execute(art, inputs()).unwrap();
        for (idx, spec) in art.outputs.iter().enumerate() {
            assert_eq!(first[idx].data, want[idx].data, "fill run drifted on {:?}", spec.role);
            assert_eq!(second[idx].data, want[idx].data, "hit run drifted on {:?}", spec.role);
        }
        let s = cache.stats();
        assert_eq!(s.hits, 2, "second run must hit both operands: {s:?}");
        assert_eq!(s.misses, 2, "{s:?}");
        assert!(s.entries == 2 && s.bytes > 0, "{s:?}");
        // Unkeyed inputs bypass the cache entirely (no spurious entries).
        let inj = Tensor::new(vec![art.max_inj, 4], plan.to_tensor(art.max_inj));
        let bare = cached.execute(art, vec![tensor2(&a), tensor2(&b), inj]).unwrap();
        for (idx, spec) in art.outputs.iter().enumerate() {
            assert_eq!(bare[idx].data, want[idx].data, "unkeyed run drifted on {:?}", spec.role);
        }
        assert_eq!(cache.stats().entries, 2, "unkeyed run must not populate the cache");
    }

    #[test]
    fn packing_layout_roundtrips() {
        let a = Matrix::from_fn(5, 3, |i, j| (i * 3 + j) as f32);
        // Full-depth KC (= k = 3): single reduction panel, the PR-3 layout.
        let pa = pack_a(&a, 1, 4, 4, 3);
        // micro-panel 0, kk=1, r=2 -> a[1 + 2][1] = a[3][1] = 10
        assert_eq!(pa[4 + 2], 10.0);
        let pb = pack_b(&a.transpose(), 1, 4, 4, 3);
        // transpose is 3x5; micro-panel 0, kk=1, c=2 -> bT[1][1 + 2] = a[3][1]
        assert_eq!(pb[4 + 2], 10.0);
        // KC=2 splits k=3 into panels [0,2) and [2,3). The second panel's
        // region starts at ipanels*mr*k0 = 1*4*2 = 8; element (kk_local=0,
        // r=2) -> a[3][2] = 11 at 8 + 0*4 + 2.
        let pa2 = pack_a(&a, 1, 4, 4, 2);
        assert_eq!(pa2[..8], pa[..8], "first panel must be the kk<2 prefix layout");
        assert_eq!(pa2[8 + 2], 11.0);
        // B side mirrors: panel base jpanels*nr*k0 = 8, (kk_local=0, c=2)
        // -> bT[2][1 + 2] = a[3][2] = 11.
        let pb2 = pack_b(&a.transpose(), 1, 4, 4, 2);
        assert_eq!(pb2[..8], pb[..8], "first panel must be the kk<2 prefix layout");
        assert_eq!(pb2[8 + 2], 11.0);
    }

    #[test]
    fn kc_blocking_is_bitwise_invariant_per_isa() {
        // The tentpole numerical contract: between reduction panels the
        // accumulator tile round-trips through exact f32 stores/reloads,
        // so ANY KC reproduces the full-k register-resident fold bitwise
        // — C must be byte-identical across KC choices on a given ISA.
        let (m, k, n) = (128usize, 300usize, 96usize); // above the flop floor, ragged k
        let a = Matrix::rand_uniform(m, k, 41);
        let b = Matrix::rand_uniform(k, n, 42);
        for isa in KernelIsa::supported() {
            let full = BlockedBackend::with_threads_isa(2, isa)
                .with_kc(Some(k))
                .gemm(&a, &b);
            for kc in [8usize, 64, 128, 300] {
                let got = BlockedBackend::with_threads_isa(2, isa)
                    .with_kc(Some(kc))
                    .gemm(&a, &b);
                assert_eq!(got.data(), full.data(), "{isa:?} KC={kc} drifted from KC=k");
            }
            // The class-resolved default depth is one of the same folds.
            let default = BlockedBackend::with_threads_isa(2, isa).gemm(&a, &b);
            assert_eq!(default.data(), full.data(), "{isa:?} default KC drifted");
        }
    }

    #[test]
    fn kc_partitioned_cache_matches_disabled_twin_under_eviction() {
        // Satellite: KC-partitioned cached panels through hit/miss/evict
        // churn stay bitwise identical to a cache-disabled twin, and
        // panels packed at different KC never serve each other (PanelKey
        // carries kc). The budget fits roughly one operand pair, so
        // cycling three seed pairs forces evictions and re-fills.
        let (m, k, n) = (96usize, 160usize, 96usize);
        let budget = 2 * (m * k + k * n) * 4; // ~one pair + slack, in bytes
        let cache = Arc::new(PackCache::new(budget));
        let kc64 = BlockedBackend::with_threads(1)
            .with_kc(Some(64))
            .with_pack_cache(Some(Arc::clone(&cache)));
        let twin64 = BlockedBackend::with_threads(1).with_kc(Some(64));
        let key = |rows: usize, cols: usize, seed: u64| {
            Some(OperandKey::whole(OperandId::Seed { rows, cols, seed }, rows, cols))
        };
        let pairs: Vec<(Matrix, Matrix, u64)> = (0..3)
            .map(|s| {
                let seed = 500 + s as u64 * 10;
                (Matrix::rand_uniform(m, k, seed), Matrix::rand_uniform(k, n, seed + 1), seed)
            })
            .collect();
        for round in 0..3 {
            for (a, b, seed) in &pairs {
                let got = kc64.gemm_keyed(a, b, key(m, k, *seed), key(k, n, *seed + 1));
                let want = twin64.gemm_keyed(a, b, None, None);
                assert_eq!(
                    got.data(),
                    want.data(),
                    "round {round} seed {seed}: cached KC=64 run drifted from twin"
                );
            }
        }
        let churn = cache.stats();
        assert!(churn.misses > 2, "eviction churn expected, stats {churn:?}");
        // Same operands, same cache, KC=128: must MISS (distinct PanelKey
        // kc), not reuse the KC=64 panels — and still match its twin.
        let before = cache.stats();
        let kc128 = BlockedBackend::with_threads(1)
            .with_kc(Some(128))
            .with_pack_cache(Some(Arc::clone(&cache)));
        let twin128 = BlockedBackend::with_threads(1).with_kc(Some(128));
        let (a, b, seed) = &pairs[2];
        let got = kc128.gemm_keyed(a, b, key(m, k, *seed), key(k, n, *seed + 1));
        assert_eq!(got.data(), twin128.gemm_keyed(a, b, None, None).data());
        let after = cache.stats();
        assert_eq!(after.hits, before.hits, "KC=128 must not hit KC=64 panels");
        assert_eq!(after.misses, before.misses + 2, "both operands must re-pack at KC=128");
    }

    #[test]
    fn fused_ft_parity_with_reference_backend() {
        let man = Manifest::builtin();
        let mut blocked = BlockedBackend::with_threads(4);
        let mut reference = ReferenceBackend::new();
        for name in ["ftgemm_tb_medium", "ftgemm_warp_medium", "ftgemm_thread_huge"] {
            let art = man.get(name).unwrap();
            // slack over exact equality is FMA rounding drift in C,
            // growing with the reduction depth
            let tol = 1e-3 + 4e-6 * art.k as f32;
            let a = Matrix::rand_uniform(art.m, art.k, 11);
            let b = Matrix::rand_uniform(art.k, art.n, 12);
            let mut rng = crate::util::rng::Pcg32::seeded(13);
            let plan = InjectionPlan::random_seu(
                art.m,
                art.n,
                art.k / 8,
                art.verify_every,
                art.sub_m,
                art.sub_n,
                3,
                &mut rng,
            );
            let inputs = || {
                vec![
                    tensor2(&a),
                    tensor2(&b),
                    Tensor::new(vec![art.max_inj, 4], plan.to_tensor(art.max_inj)),
                ]
            };
            let got = blocked.execute(art, inputs()).unwrap();
            let want = reference.execute(art, inputs()).unwrap();
            let c_idx = art.output_index("c").unwrap();
            let e_idx = art.output_index("errcount").unwrap();
            let gc = Matrix::from_vec(art.m, art.n, got[c_idx].data.clone());
            let wc = Matrix::from_vec(art.m, art.n, want[c_idx].data.clone());
            let diff = gc.max_abs_diff(&wc);
            assert!(diff < tol, "{name}: C diverged by {diff}");
            assert_eq!(
                got[e_idx].data, want[e_idx].data,
                "{name}: errcount grids diverged"
            );
        }
    }

    #[test]
    fn ding_chain_runs_on_the_blocked_backend() {
        let man = Manifest::builtin();
        let mut be = BlockedBackend::with_threads(2);
        let enc = man.get("ding_encode_medium").unwrap();
        let step = man.get("ding_step_medium").unwrap();
        let ver = man.get("ding_verify_medium").unwrap();
        let (m, n, k, ks) = (enc.m, enc.n, enc.k, step.ks);
        let a = Matrix::rand_uniform(m, k, 21);
        let b = Matrix::rand_uniform(k, n, 22);
        let out = be.execute(enc, vec![tensor2(&a), tensor2(&b)]).unwrap();
        let ac = Matrix::from_vec(m + 1, k, out[0].data.clone());
        let br = Matrix::from_vec(k, n + 1, out[1].data.clone());
        let mut cf = Matrix::zeros(m + 1, n + 1);
        for s in (0..k).step_by(ks) {
            let acp = Matrix::from_fn(m + 1, ks, |i, j| ac.at(i, s + j));
            let brp = Matrix::from_fn(ks, n + 1, |i, j| br.at(s + i, j));
            let out = be
                .execute(step, vec![tensor2(&cf), tensor2(&acp), tensor2(&brp)])
                .unwrap();
            cf = Matrix::from_vec(m + 1, n + 1, out[0].data.clone());
            let out = be.execute(ver, vec![tensor2(&cf)]).unwrap();
            cf = Matrix::from_vec(m + 1, n + 1, out[0].data.clone());
        }
        assert!(cf.slice_to(m, n).max_abs_diff(&a.matmul(&b)) < 2e-2);
    }

    #[test]
    fn builtin_ft_artifacts_get_fused_encode_alignment() {
        // every builtin FT artifact's protection tiles must sit whole
        // inside the pack blocks its shape class selects, or the fused
        // packing-time encode silently degrades to on-demand
        let man = Manifest::builtin();
        let mut seen = 0usize;
        for art in man.iter() {
            if !matches!(art.kind, ArtifactKind::FtGemm | ArtifactKind::FtDetect) {
                continue;
            }
            let t = host_tiles(art.m, art.n, art.k);
            assert!(
                art.sub_m <= t.mc && t.mc % art.sub_m == 0,
                "{}: sub_m {} vs mc {}",
                art.name,
                art.sub_m,
                t.mc
            );
            assert!(
                art.sub_n <= t.nc && t.nc % art.sub_n == 0,
                "{}: sub_n {} vs nc {}",
                art.name,
                art.sub_n,
                t.nc
            );
            seen += 1;
        }
        assert!(seen >= 10, "expected the FT artifact registry, saw {seen}");
    }

    #[test]
    fn compile_validates_and_is_idempotent() {
        let man = Manifest::builtin();
        let mut be = BlockedBackend::with_threads(1);
        let art = man.get("gemm_medium").unwrap();
        assert!(be.compile(art).unwrap());
        assert!(!be.compile(art).unwrap());
        assert_eq!(be.name(), "blocked");
        assert_eq!(be.threads(), 1);
    }
}
