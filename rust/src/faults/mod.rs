//! Fault models and injection campaign drivers.
//!
//! [`SeuModel`] turns an error *rate* into concrete injection plans
//! (Poisson arrivals over wall-clock or per-accumulation Bernoulli, the
//! paper's γ₀ model of §5.5); [`FaultCampaign`] runs a workload through
//! the coordinator while injecting per that model and tallies the ledger
//! the error-injection figures (16, 21) and the examples report.

pub mod campaign;
pub mod model;

pub use campaign::{CampaignReport, FaultCampaign};
pub use model::{expected_offline_runs, overall_error_rate, SeuModel};
