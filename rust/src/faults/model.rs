//! SEU arrival models + the paper's §5.5 online-vs-offline analytics.

use crate::abft::injection::{bitflip_magnitude, Injection, InjectionPlan};
use crate::util::rng::Pcg32;

/// Kernel geometry an SEU plan must respect: the protection domains are
/// (sub-tile, verification interval) pairs — one correctable error each
/// (paper §4.1).
#[derive(Debug, Clone, Copy)]
pub struct KernelGeom {
    /// Output extents served by the kernel.
    pub m: usize,
    pub n: usize,
    /// k-loop steps of the kernel grid.
    pub steps: usize,
    /// Verification fires every this many steps.
    pub verify_every: usize,
    /// Protection sub-tile (tb level: the threadblock tile itself).
    pub sub_m: usize,
    pub sub_n: usize,
}

impl KernelGeom {
    pub fn tiles(&self) -> usize {
        self.m.div_ceil(self.sub_m) * self.n.div_ceil(self.sub_n)
    }

    /// Geometry of the bucket kernel that would serve (m, n, k) at tb level.
    pub fn for_shape(m: usize, n: usize, k: usize) -> KernelGeom {
        let bucket = crate::codegen::select::select_bucket(m, n, k);
        match bucket {
            Some(b) => {
                let p = b.class.params();
                KernelGeom {
                    m,
                    n,
                    steps: b.k / p.k_tb,
                    verify_every: 8, // VERIFY_EVERY in the python template
                    sub_m: p.m_tb,
                    sub_n: p.n_tb,
                }
            }
            None => {
                // oversize requests split over the huge bucket
                let p = crate::codegen::ShapeClass::Huge.params();
                KernelGeom {
                    m,
                    n,
                    steps: 512 / p.k_tb,
                    verify_every: 8,
                    sub_m: p.m_tb,
                    sub_n: p.n_tb,
                }
            }
        }
    }
}

/// Single-event-upset model: how often compute errors strike.
#[derive(Debug, Clone, Copy)]
pub enum SeuModel {
    /// No faults (baseline runs).
    None,
    /// Exactly `count` errors per GEMM, spread evenly over the k-steps —
    /// the Fig 16/21 protocol ("1, 2, ..., 40 errors are injected ... for
    /// each outer-product sub-problem"). SEU-constrained placement.
    PerGemm { count: usize },
    /// Each threadblock-tile accumulation errs with probability γ₀ —
    /// the §5.5 analytical model (placement is per protection domain, so
    /// SEU holds by construction).
    PerTile { gamma0: f64 },
    /// Poisson arrivals at `rate_per_min` over wall-clock time (the
    /// "hundreds of errors injected per minute" abstract claim).
    PoissonPerMinute { rate_per_min: f64 },
}

impl SeuModel {
    /// Build an injection plan for one GEMM execution with the given
    /// kernel geometry; `elapsed_secs` feeds the Poisson model.
    pub fn plan(&self, geom: &KernelGeom, elapsed_secs: f64, rng: &mut Pcg32) -> InjectionPlan {
        match *self {
            SeuModel::None => InjectionPlan::none(),
            SeuModel::PerGemm { count } => InjectionPlan::random_seu(
                geom.m,
                geom.n,
                geom.steps,
                geom.verify_every,
                geom.sub_m,
                geom.sub_n,
                count,
                rng,
            ),
            SeuModel::PerTile { gamma0 } => {
                let mut plan = InjectionPlan::none();
                let tiles_m = geom.m.div_ceil(geom.sub_m);
                let tiles_n = geom.n.div_ceil(geom.sub_n);
                for ti in 0..tiles_m {
                    for tj in 0..tiles_n {
                        if rng.f64() < gamma0 {
                            let row = (ti * geom.sub_m
                                + rng.usize_below(geom.sub_m))
                            .min(geom.m - 1);
                            let col = (tj * geom.sub_n
                                + rng.usize_below(geom.sub_n))
                            .min(geom.n - 1);
                            plan.injections.push(Injection {
                                row,
                                col,
                                step: rng.usize_below(geom.steps.max(1)),
                                magnitude: bitflip_magnitude(rng),
                            });
                        }
                    }
                }
                plan
            }
            SeuModel::PoissonPerMinute { rate_per_min } => {
                let lambda_sec = rate_per_min / 60.0;
                let mut t = 0.0;
                let mut count = 0usize;
                loop {
                    t += rng.exponential(lambda_sec.max(1e-12));
                    if t >= elapsed_secs {
                        break;
                    }
                    count += 1;
                }
                // place the arrivals SEU-consistently (capped by domains)
                let domains =
                    geom.tiles() * geom.steps.div_ceil(geom.verify_every.max(1)).max(1);
                InjectionPlan::random_seu(
                    geom.m,
                    geom.n,
                    geom.steps,
                    geom.verify_every,
                    geom.sub_m,
                    geom.sub_n,
                    count.min(domains),
                    rng,
                )
            }
        }
    }
}

/// §5.5: overall error rate γ = 1 - (1-γ₀)^(M/m_tb · N/n_tb) — probability
/// that at least one tile of the GEMM errs.
pub fn overall_error_rate(gamma0: f64, m: usize, n: usize, m_tb: usize, n_tb: usize) -> f64 {
    let tiles = (m as f64 / m_tb as f64) * (n as f64 / n_tb as f64);
    1.0 - (1.0 - gamma0).powf(tiles)
}

/// §5.5: expected number of full executions for offline ABFT to produce a
/// correct result: (1-γ)/(1-2γ) — each detection triggers a restart which
/// may itself err (diverges as γ → 1/2).
pub fn expected_offline_runs(gamma: f64) -> f64 {
    assert!((0.0..0.5).contains(&gamma), "offline ABFT diverges at γ >= 1/2");
    (1.0 - gamma) / (1.0 - 2.0 * gamma)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom() -> KernelGeom {
        KernelGeom { m: 128, n: 128, steps: 16, verify_every: 8, sub_m: 32, sub_n: 32 }
    }

    #[test]
    fn per_gemm_plan_has_exact_count_and_respects_seu() {
        let mut rng = Pcg32::seeded(1);
        let g = geom();
        for count in [1, 4, 13, 32] {
            let plan = SeuModel::PerGemm { count }.plan(&g, 0.0, &mut rng);
            assert_eq!(plan.len(), count);
            // SEU: unique (tile, interval) domains
            let mut seen = std::collections::HashSet::new();
            for e in &plan.injections {
                assert!(seen.insert((e.row / 32, e.col / 32, e.step / 8)));
            }
        }
    }

    #[test]
    fn per_tile_rate_statistics() {
        let mut rng = Pcg32::seeded(2);
        let gamma0 = 0.1;
        let trials = 2000;
        let g = geom(); // 16 tiles
        let total: usize = (0..trials)
            .map(|_| SeuModel::PerTile { gamma0 }.plan(&g, 0.0, &mut rng).len())
            .sum();
        let mean = total as f64 / trials as f64;
        let expect = gamma0 * g.tiles() as f64;
        assert!((mean - expect).abs() < 0.1, "mean {mean} vs {expect}");
    }

    #[test]
    fn per_tile_places_inside_owner_tile() {
        let mut rng = Pcg32::seeded(7);
        let g = geom();
        for _ in 0..50 {
            let plan = SeuModel::PerTile { gamma0: 0.5 }.plan(&g, 0.0, &mut rng);
            for e in &plan.injections {
                assert!(e.row < g.m && e.col < g.n && e.step < g.steps);
            }
        }
    }

    #[test]
    fn poisson_rate_matches() {
        let mut rng = Pcg32::seeded(3);
        let model = SeuModel::PoissonPerMinute { rate_per_min: 600.0 }; // 10/sec
        let g = geom();
        let total: usize = (0..500).map(|_| model.plan(&g, 2.0, &mut rng).len()).sum();
        let mean = total as f64 / 500.0;
        assert!((mean - 20.0).abs() < 1.0, "mean {mean}");
    }

    #[test]
    fn geom_for_shape_uses_bucket_params() {
        let g = KernelGeom::for_shape(128, 128, 128);
        // medium bucket: k=128, k_tb=8 -> 16 steps; tiles 32x32
        assert_eq!(g.steps, 16);
        assert_eq!((g.sub_m, g.sub_n), (32, 32));
        assert_eq!(g.tiles(), 16);
    }

    #[test]
    fn gamma_formula_matches_paper() {
        // γ₀ = 1/256, 512^2 output with 128x128 tiles -> 16 tiles
        let g = overall_error_rate(1.0 / 256.0, 512, 512, 128, 128);
        let expect = 1.0 - (1.0 - 1.0 / 256.0f64).powi(16);
        assert!((g - expect).abs() < 1e-12);
        assert!(g > 0.0 && g < 1.0);
    }

    #[test]
    fn offline_runs_monotone_and_diverging() {
        assert!((expected_offline_runs(0.0) - 1.0).abs() < 1e-12);
        let a = expected_offline_runs(0.1);
        let b = expected_offline_runs(0.3);
        let c = expected_offline_runs(0.49);
        assert!(1.0 < a && a < b && b < c);
        assert!(c > 25.0);
    }

    #[test]
    #[should_panic]
    fn offline_runs_rejects_gamma_half() {
        expected_offline_runs(0.5);
    }
}
