//! Error-injection campaigns: run a workload through the coordinator under
//! an [`SeuModel`](super::SeuModel) and tally what happened — the driver
//! behind Figs 16/21 and `examples/error_storm.rs`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::abft::matrix::Matrix;
use crate::coordinator::{Coordinator, FtPolicy, GemmRequest};
use crate::util::rng::Pcg32;

use super::model::{KernelGeom, SeuModel};

/// Aggregate ledger of a campaign.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CampaignReport {
    pub gemms: u64,
    pub injected: u64,
    pub detected: u64,
    pub corrected: u64,
    pub recomputes: u64,
    pub kernel_launches: u64,
    pub wall_time: Duration,
    /// max |C - reference| observed across the campaign (correctness
    /// witness: should stay at roundoff when the policy corrects).
    pub max_error_vs_reference: f32,
}

impl CampaignReport {
    /// All injected faults accounted for (detected)?
    pub fn fully_detected(&self) -> bool {
        self.detected >= self.injected
    }

    pub fn errors_per_minute(&self) -> f64 {
        let mins = self.wall_time.as_secs_f64() / 60.0;
        if mins == 0.0 {
            0.0
        } else {
            self.injected as f64 / mins
        }
    }
}

/// A fault-injection campaign over repeated GEMMs of one shape.
pub struct FaultCampaign {
    pub coordinator: Coordinator,
    pub model: SeuModel,
    pub policy: FtPolicy,
    pub seed: u64,
    /// Kernel geometry override; derived from the serving bucket when `None`.
    pub geom_override: Option<KernelGeom>,
}

impl FaultCampaign {
    pub fn new(coordinator: Coordinator, model: SeuModel, policy: FtPolicy, seed: u64) -> Self {
        FaultCampaign { coordinator, model, policy, seed, geom_override: None }
    }

    /// Run `rounds` GEMMs of (m, n, k) with fresh random operands each
    /// round, injecting per the model, verifying each result against the
    /// host matmul.
    pub fn run(&self, m: usize, n: usize, k: usize, rounds: usize) -> Result<CampaignReport> {
        let mut rng = Pcg32::seeded(self.seed);
        let mut report = CampaignReport::default();
        let t0 = Instant::now();
        let geom = self.geom_override.unwrap_or_else(|| KernelGeom::for_shape(m, n, k));

        for round in 0..rounds {
            // Arc'd operands: the submitted request shares them (refcount
            // bump), and the reference matmul below reads the same data —
            // the hot loop never copies a matrix.
            let a = Arc::new(Matrix::rand_uniform(m, k, self.seed ^ (round as u64) << 1));
            let b = Arc::new(Matrix::rand_uniform(k, n, self.seed ^ ((round as u64) << 1 | 1)));
            let plan = self.model.plan(&geom, t0.elapsed().as_secs_f64(), &mut rng);
            report.injected += plan.len() as u64;
            let req = GemmRequest::new(Arc::clone(&a), Arc::clone(&b))
                .policy(self.policy)
                .inject(plan);
            let out = self.coordinator.submit(req)?.wait()?.result;
            report.gemms += 1;
            report.detected += out.errors_detected;
            report.corrected += out.errors_corrected;
            report.recomputes += out.recomputes;
            report.kernel_launches += out.kernel_launches;
            let want = a.matmul(&b);
            let diff = out.c.max_abs_diff(&want);
            report.max_error_vs_reference = report.max_error_vs_reference.max(diff);
        }
        report.wall_time = t0.elapsed();
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_accounting_helpers() {
        let mut r = CampaignReport::default();
        r.injected = 10;
        r.detected = 10;
        r.wall_time = Duration::from_secs(30);
        assert!(r.fully_detected());
        assert!((r.errors_per_minute() - 20.0).abs() < 1e-9);
    }
    // Live campaign tests (engine + artifacts) are in rust/tests/.
}
