//! The network serving gateway: a dependency-free TCP front door for the
//! coordinator's `submit() -> Ticket` surface.
//!
//! Structure:
//! * [`wire`] — newline framing ([`wire::FrameReader`]) and the iterative,
//!   depth-bounded, zero-allocation JSON pull parser ([`wire::PullParser`]).
//! * [`proto`] — the request protocol: one JSON object per line, every
//!   [`RequestOptions`](crate::coordinator::RequestOptions) field
//!   expressible on the wire, strict structured errors.
//! * [`Gateway`] (here) — the server: one non-blocking acceptor thread
//!   feeding accepted connections to a fixed pool of connection threads
//!   (`[serve] threads`), each running one connection at a time.
//!
//! ## Threading and ordering
//!
//! Per connection, a **reader** (the pool thread) decodes frames and
//! submits GEMMs without waiting for them, and a dedicated **writer**
//! thread settles tickets and streams responses back — so a client can
//! pipeline many requests over one connection and the submit queue's
//! priority/deadline machinery, not the socket, decides execution order.
//! Responses on one connection are delivered in request order (the writer
//! settles tickets FIFO); clients that want out-of-order completion open
//! more connections, and correlate via the echoed `id` either way.
//!
//! ## Backpressure contract
//!
//! The gateway adds **no** queueing of its own: every decoded GEMM goes
//! straight to [`Coordinator::submit`], so `max_inflight` (dispatcher
//! pool) and `max_queue` (admission bound) govern network traffic exactly
//! like in-process traffic. When admission control rejects, the client
//! gets a structured `admission-reject` error for that request — the
//! connection stays healthy. Frame size (`max_frame_bytes`) and JSON
//! depth ([`wire::DEFAULT_MAX_DEPTH`]) bound per-connection memory; a
//! frame over the size bound kills the connection (framing is lost), a
//! depth bomb or garbage frame only kills that request.
//!
//! ## Error taxonomy (`"error"` field of a `"ok": false` response)
//!
//! | kind               | meaning                                         |
//! |--------------------|-------------------------------------------------|
//! | `parse`            | malformed JSON / framing; bad frame discarded   |
//! | `validation`       | well-formed JSON violating the protocol         |
//! | `admission-reject` | `max_queue` admission control refused the GEMM  |
//! | `deadline-expired` | queue deadline passed before dispatch           |
//! | `canceled`         | request canceled before dispatch                |
//! | `failed`           | execution failed (or server shutting down)      |

pub mod proto;
pub mod wire;

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::{Coordinator, GemmResponse, Ticket, TicketStatus};
use crate::util::json::Json;

use proto::{ProtoError, WireRequest};
use wire::{FrameReader, DEFAULT_MAX_DEPTH};

/// `[serve]` configuration: where to listen and how much to accept.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServeConfig {
    /// `addr:port` to bind (port 0 = ephemeral, for tests).
    pub listen: String,
    /// Connection-thread pool size — concurrent connections served.
    pub threads: usize,
    /// Per-frame (and per-partial-frame) byte bound.
    pub max_frame_bytes: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            listen: "127.0.0.1:7421".to_string(),
            threads: 4,
            max_frame_bytes: 1 << 20,
        }
    }
}

impl ServeConfig {
    /// Validate at the config/CLI boundary — fail fast with field names,
    /// not deep inside `bind()`.
    pub fn validate(&self) -> Result<()> {
        if self.listen.is_empty() || !self.listen.contains(':') {
            anyhow::bail!("[serve] listen must be addr:port, got {:?}", self.listen);
        }
        if self.threads == 0 {
            anyhow::bail!("[serve] threads must be >= 1");
        }
        if self.max_frame_bytes < 256 {
            anyhow::bail!(
                "[serve] max_frame_bytes must be >= 256, got {}",
                self.max_frame_bytes
            );
        }
        Ok(())
    }
}

/// Gateway-level counters (the per-connection ones the `metrics` verb
/// adds on top of [`CoordinatorStats`](crate::coordinator::CoordinatorStats)).
#[derive(Debug, Default)]
struct GatewayCounters {
    /// Connections accepted over the gateway's lifetime.
    connections: AtomicU64,
    /// Connections currently being served.
    open: AtomicU64,
    /// Complete frames decoded (all verbs).
    frames: AtomicU64,
    /// GEMM requests submitted to the coordinator.
    gemms: AtomicU64,
    /// Response lines written back.
    responses: AtomicU64,
    /// Parse/validation errors returned to clients.
    protocol_errors: AtomicU64,
}

/// Point-in-time copy of the gateway counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct GatewaySnapshot {
    pub connections: u64,
    pub open: u64,
    pub frames: u64,
    pub gemms: u64,
    pub responses: u64,
    pub protocol_errors: u64,
}

impl GatewayCounters {
    fn snapshot(&self) -> GatewaySnapshot {
        GatewaySnapshot {
            connections: self.connections.load(Ordering::Relaxed),
            open: self.open.load(Ordering::Relaxed),
            frames: self.frames.load(Ordering::Relaxed),
            gemms: self.gemms.load(Ordering::Relaxed),
            responses: self.responses.load(Ordering::Relaxed),
            protocol_errors: self.protocol_errors.load(Ordering::Relaxed),
        }
    }
}

struct Shared {
    coord: Coordinator,
    counters: GatewayCounters,
    shutdown: AtomicBool,
    max_frame: usize,
    /// Seed→operand materialization cache (the wire-side half of the
    /// cross-request cache); `None` when `pack_cache_mb = 0`.
    seed_cache: Option<proto::SeedCache>,
}

/// The running TCP gateway. Dropping it stops accepting, lets in-flight
/// connections notice shutdown, and joins every thread.
pub struct Gateway {
    shared: Arc<Shared>,
    addr: SocketAddr,
    acceptor: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Gateway {
    /// Bind `cfg.listen` and start serving `coord` on `cfg.threads`
    /// connection threads.
    pub fn start(coord: Coordinator, cfg: ServeConfig) -> Result<Gateway> {
        cfg.validate()?;
        let listener = TcpListener::bind(&cfg.listen)
            .with_context(|| format!("bind {:?}", cfg.listen))?;
        let addr = listener.local_addr().context("local_addr")?;
        listener.set_nonblocking(true).context("set_nonblocking")?;

        let seed_cache = proto::SeedCache::with_budget(coord.engine().pack_cache_budget_bytes());
        let shared = Arc::new(Shared {
            coord,
            counters: GatewayCounters::default(),
            shutdown: AtomicBool::new(false),
            max_frame: cfg.max_frame_bytes,
            seed_cache,
        });

        let (tx, rx) = mpsc::channel::<TcpStream>();
        let rx = Arc::new(Mutex::new(rx));

        let acceptor = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("ftgemm-accept".to_string())
                .spawn(move || acceptor_loop(&listener, &shared, &tx))
                .context("spawn acceptor")?
        };
        let workers = (0..cfg.threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("ftgemm-conn-{i}"))
                    .spawn(move || worker_loop(&shared, &rx))
                    .context("spawn connection worker")
            })
            .collect::<Result<Vec<_>>>()?;

        Ok(Gateway { shared, addr, acceptor: Some(acceptor), workers })
    }

    /// The bound address (resolves port 0 for tests).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn snapshot(&self) -> GatewaySnapshot {
        self.shared.counters.snapshot()
    }
}

impl Drop for Gateway {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn acceptor_loop(listener: &TcpListener, shared: &Shared, tx: &mpsc::Sender<TcpStream>) {
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return; // tx drops; idle workers see Disconnected and exit
        }
        match listener.accept() {
            Ok((stream, _)) => {
                shared.counters.connections.fetch_add(1, Ordering::Relaxed);
                if tx.send(stream).is_err() {
                    return;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
}

fn worker_loop(shared: &Arc<Shared>, rx: &Arc<Mutex<mpsc::Receiver<TcpStream>>>) {
    loop {
        // Take the lock only to wait for the next connection; it is
        // released before serving, so other workers keep accepting.
        let next = rx.lock().unwrap().recv_timeout(Duration::from_millis(100));
        match next {
            Ok(stream) => {
                shared.counters.open.fetch_add(1, Ordering::Relaxed);
                serve_connection(shared, stream);
                shared.counters.open.fetch_sub(1, Ordering::Relaxed);
            }
            Err(RecvTimeoutError::Timeout) => {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
            }
            Err(RecvTimeoutError::Disconnected) => return,
        }
    }
}

/// What the reader hands the per-connection writer thread, in response
/// order: immediate lines (errors, ping, metrics) and tickets still being
/// served.
enum WriteItem {
    Line(String),
    Pending { id: u64, ticket: Ticket },
}

fn serve_connection(shared: &Arc<Shared>, stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    // Finite read timeout: the reader must keep noticing shutdown (and a
    // dead writer) even when the client goes quiet.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let Ok(write_half) = stream.try_clone() else { return };

    let closed = Arc::new(AtomicBool::new(false));
    let (tx, rx) = mpsc::channel::<WriteItem>();
    let writer = {
        let shared = Arc::clone(shared);
        let closed = Arc::clone(&closed);
        std::thread::Builder::new()
            .name("ftgemm-conn-writer".to_string())
            .spawn(move || writer_loop(&shared, &closed, write_half, &rx))
    };
    let Ok(writer) = writer else { return };

    reader_loop(shared, &closed, stream, &tx);

    drop(tx); // writer drains queued responses, then exits
    let _ = writer.join();
}

fn writer_loop(
    shared: &Arc<Shared>,
    closed: &AtomicBool,
    stream: TcpStream,
    rx: &mpsc::Receiver<WriteItem>,
) {
    let mut out = std::io::BufWriter::new(stream);
    // plain iteration: blocks until the reader hangs up, then drains
    for item in rx.iter() {
        let line = match item {
            WriteItem::Line(line) => line,
            WriteItem::Pending { id, ticket } => {
                let (status, outcome) = ticket.wait_outcome();
                match outcome {
                    Ok(resp) => gemm_ok_line(id, &resp),
                    Err(e) => {
                        let kind = match status {
                            TicketStatus::Expired => "deadline-expired",
                            TicketStatus::Canceled => "canceled",
                            _ => "failed",
                        };
                        error_line("gemm", Some(id), kind, &format!("{e:#}"))
                    }
                }
            }
        };
        if out.write_all(line.as_bytes()).is_err()
            || out.write_all(b"\n").is_err()
            || out.flush().is_err()
        {
            // Client is gone: tell the reader and stop. Remaining tickets
            // are dropped — their requests finish detached.
            closed.store(true, Ordering::SeqCst);
            return;
        }
        shared.counters.responses.fetch_add(1, Ordering::Relaxed);
    }
}

/// Per-connection counters reported by the `metrics` verb.
#[derive(Default)]
struct ConnStats {
    frames: u64,
    gemms: u64,
    errors: u64,
}

fn reader_loop(
    shared: &Arc<Shared>,
    closed: &AtomicBool,
    mut stream: TcpStream,
    tx: &mpsc::Sender<WriteItem>,
) {
    let mut fr = FrameReader::new(shared.max_frame);
    let mut conn = ConnStats::default();
    let mut buf = [0u8; 8192];
    loop {
        if shared.shutdown.load(Ordering::SeqCst) || closed.load(Ordering::SeqCst) {
            return;
        }
        let n = match stream.read(&mut buf) {
            Ok(0) => return, // client closed
            Ok(n) => n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue;
            }
            Err(_) => return,
        };
        if let Err(e) = fr.feed(&buf[..n]) {
            // Oversized frame: framing is lost, so the connection dies —
            // but with a structured goodbye first.
            shared.counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
            let _ = tx.send(WriteItem::Line(error_line(
                "frame",
                None,
                "parse",
                &e.to_string(),
            )));
            return;
        }
        while let Some(frame) = fr.next_frame() {
            shared.counters.frames.fetch_add(1, Ordering::Relaxed);
            conn.frames += 1;
            if !handle_frame(shared, &frame, &mut conn, tx) {
                return;
            }
        }
    }
}

/// Dispatch one decoded frame; returns `false` when the connection should
/// close (quit verb, or the writer is unreachable).
fn handle_frame(
    shared: &Arc<Shared>,
    frame: &[u8],
    conn: &mut ConnStats,
    tx: &mpsc::Sender<WriteItem>,
) -> bool {
    let item = match proto::decode(frame, DEFAULT_MAX_DEPTH) {
        Err(e) => {
            shared.counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
            conn.errors += 1;
            WriteItem::Line(proto_error_line(&e))
        }
        Ok(WireRequest::Ping) => WriteItem::Line(r#"{"ok": true, "op": "ping"}"#.to_string()),
        Ok(WireRequest::Quit) => {
            let _ = tx.send(WriteItem::Line(r#"{"ok": true, "op": "quit"}"#.to_string()));
            return false;
        }
        Ok(WireRequest::Metrics) => WriteItem::Line(metrics_line(shared, conn)),
        Ok(WireRequest::Gemm(spec)) => {
            let id = spec.id;
            shared.counters.gemms.fetch_add(1, Ordering::Relaxed);
            conn.gemms += 1;
            match shared.coord.submit(spec.into_request_with(shared.seed_cache.as_ref())) {
                Ok(ticket) => WriteItem::Pending { id, ticket },
                Err(e) => {
                    let msg = format!("{e:#}");
                    let kind = if msg.contains("admission control") {
                        "admission-reject"
                    } else {
                        "failed"
                    };
                    WriteItem::Line(error_line("gemm", Some(id), kind, &msg))
                }
            }
        }
    };
    tx.send(item).is_ok()
}

fn proto_error_line(e: &ProtoError) -> String {
    error_line("request", None, e.kind, &e.msg)
}

fn error_line(op: &str, id: Option<u64>, kind: &str, msg: &str) -> String {
    let mut o = Json::obj();
    o.set("ok", Json::Bool(false));
    o.set("op", Json::from(op));
    if let Some(id) = id {
        o.set("id", Json::Num(id as f64));
    }
    o.set("error", Json::from(kind));
    o.set("msg", Json::from(msg));
    o.to_string()
}

fn gemm_ok_line(id: u64, resp: &GemmResponse) -> String {
    let (out, meta) = (&resp.result, &resp.meta);
    let mut o = Json::obj();
    o.set("ok", Json::Bool(true));
    o.set("op", Json::from("gemm"));
    o.set("id", Json::Num(id as f64));
    o.set("req", Json::Num(meta.id as f64));
    o.set("priority", Json::from(meta.priority.as_str()));
    o.set("pool", Json::Num(meta.pool as f64));
    o.set("queued_us", Json::Num(meta.queued.as_micros() as f64));
    o.set("exec_us", Json::Num(out.exec_time.as_micros() as f64));
    o.set("detected", Json::Num(out.errors_detected as f64));
    o.set("corrected", Json::Num(out.errors_corrected as f64));
    o.set("recomputes", Json::Num(out.recomputes as f64));
    o.set("launches", Json::Num(out.kernel_launches as f64));
    o.set("buckets", Json::from(out.buckets.clone()));
    // content witness: seeded operands make this deterministic per spec
    let checksum: f64 = out.c.data().iter().map(|&x| x as f64).sum();
    o.set("checksum", Json::Num(checksum));
    o.to_string()
}

fn metrics_line(shared: &Shared, conn: &ConnStats) -> String {
    let mut o = Json::obj();
    o.set("ok", Json::Bool(true));
    o.set("op", Json::from("metrics"));
    o.set("coordinator", shared.coord.stats().to_json());
    let g = shared.counters.snapshot();
    let mut go = Json::obj();
    go.set("connections", Json::Num(g.connections as f64));
    go.set("open", Json::Num(g.open as f64));
    go.set("frames", Json::Num(g.frames as f64));
    go.set("gemms", Json::Num(g.gemms as f64));
    go.set("responses", Json::Num(g.responses as f64));
    go.set("protocol_errors", Json::Num(g.protocol_errors as f64));
    if let Some(c) = &shared.seed_cache {
        let (entries, bytes) = c.usage();
        let mut sc = Json::obj();
        sc.set("entries", Json::Num(entries as f64));
        sc.set("bytes", Json::Num(bytes as f64));
        go.set("seed_cache", sc);
    }
    o.set("gateway", go);
    let mut co = Json::obj();
    co.set("frames", Json::Num(conn.frames as f64));
    co.set("gemms", Json::Num(conn.gemms as f64));
    co.set("errors", Json::Num(conn.errors as f64));
    o.set("connection", co);
    o.to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader};

    #[test]
    fn config_defaults_are_valid() {
        let cfg = ServeConfig::default();
        assert!(cfg.validate().is_ok());
        assert_eq!(cfg.threads, 4);
        assert_eq!(cfg.max_frame_bytes, 1 << 20);
    }

    #[test]
    fn config_validation_names_the_field() {
        let bad = ServeConfig { listen: "nocolon".into(), ..Default::default() };
        assert!(bad.validate().unwrap_err().to_string().contains("listen"));
        let bad = ServeConfig { threads: 0, ..Default::default() };
        assert!(bad.validate().unwrap_err().to_string().contains("threads"));
        let bad = ServeConfig { max_frame_bytes: 10, ..Default::default() };
        assert!(bad.validate().unwrap_err().to_string().contains("max_frame_bytes"));
    }

    #[test]
    fn error_lines_are_valid_json() {
        let line = error_line("gemm", Some(7), "deadline-expired", "too late");
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("id").unwrap().as_usize(), Some(7));
        assert_eq!(v.get("error").unwrap().as_str(), Some("deadline-expired"));
    }

    /// Loopback smoke: ping, a bad frame (connection survives), metrics,
    /// one gemm, quit. The 16-client concurrency test lives in
    /// `tests/integration.rs`.
    #[test]
    fn gateway_serves_one_connection_end_to_end() {
        use crate::coordinator::{Coordinator, CoordinatorConfig};
        use crate::runtime::{Engine, EngineConfig};

        let engine = Engine::start(EngineConfig::default()).unwrap();
        let coord = Coordinator::new(engine, CoordinatorConfig::default());
        let gw = Gateway::start(
            coord,
            ServeConfig { listen: "127.0.0.1:0".into(), threads: 2, ..Default::default() },
        )
        .unwrap();

        let stream = TcpStream::connect(gw.local_addr()).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let send = |line: &str| {
            (&stream).write_all(line.as_bytes()).unwrap();
            (&stream).write_all(b"\n").unwrap();
        };
        let mut recv = || {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            Json::parse(line.trim()).unwrap()
        };

        send(r#"{"op": "ping"}"#);
        assert_eq!(recv().get("ok").unwrap().as_bool(), Some(true));

        send("this is not json");
        let v = recv();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("error").unwrap().as_str(), Some("parse"));

        send(r#"{"op": "gemm", "m": 32, "n": 32, "k": 32, "seed": 9}"#);
        let v = recv();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{v}");
        assert!(v.get("checksum").unwrap().as_f64().is_some());
        assert_eq!(v.get("pool").unwrap().as_usize(), Some(0), "single-pool engine");

        send(r#"{"op": "metrics"}"#);
        let v = recv();
        assert_eq!(v.path("gateway.protocol_errors").unwrap().as_usize(), Some(1));
        assert_eq!(v.path("connection.gemms").unwrap().as_usize(), Some(1));
        assert!(v.path("coordinator.backend.name").unwrap().as_str().is_some());
        // per-pool shard stats ride along (one entry per engine pool)
        let pools = match v.path("coordinator.pools") {
            Some(Json::Arr(a)) => a,
            other => panic!("metrics missing coordinator.pools array: {other:?}"),
        };
        assert_eq!(pools.len(), 1);
        assert_eq!(pools[0].get("dispatched").unwrap().as_usize(), Some(1));
        assert_eq!(pools[0].get("steals").unwrap().as_usize(), Some(0));

        send(r#"{"op": "quit"}"#);
        assert_eq!(recv().get("op").unwrap().as_str(), Some("quit"));

        let snap = gw.snapshot();
        assert_eq!(snap.connections, 1);
        assert_eq!(snap.protocol_errors, 1);
        assert!(snap.frames >= 5);
    }
}
