//! The gateway's request protocol: one JSON object per line.
//!
//! ```text
//! {"op": "gemm", "id": 1, "m": 128, "n": 128, "k": 128,
//!  "policy": "online", "seed": 7, "inject": 2,
//!  "injections": [{"row": 3, "col": 5, "step": 0, "magnitude": 4096.0}],
//!  "ft_level": "warp", "host_verify": "clean_only",
//!  "threshold_rel": 1e-4, "threshold_abs": 1e-3,
//!  "max_recomputes": 4, "priority": "high", "deadline_ms": 250}
//! {"op": "metrics"}
//! {"op": "ping"}
//! {"op": "quit"}
//! ```
//!
//! Every [`RequestOptions`] knob is expressible on the wire; only `op`
//! (and, for `gemm`, the shape) is required — everything else takes the
//! same defaults the in-process builder does. Operands travel as a `seed`
//! (the server materializes `rand_uniform` matrices), keeping frames tiny
//! and workloads reproducible; faults are either an explicit `injections`
//! list (exact §5.3 coordinates) or a `inject` count expanded through the
//! same [`SeuModel`] path the CLI uses. Decoding is **strict**: unknown
//! keys, wrong types, out-of-range shapes, and fields that don't belong
//! to the op are all structured `validation` errors, never silent drops —
//! a fault-tolerance service should not guess at what a client meant.
//!
//! [`SeuModel`]: crate::faults::SeuModel

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::abft::checksum::Thresholds;
use crate::abft::injection::{Injection, InjectionPlan};
use crate::abft::matrix::Matrix;
use crate::coordinator::{FtLevel, FtPolicy, GemmRequest, HostVerify, Priority, RequestOptions};
use crate::faults::model::KernelGeom;
use crate::faults::SeuModel;
use crate::runtime::pack_cache::OperandId;
use crate::util::json::Json;
use crate::util::rng::Pcg32;

use super::wire::{Event, PullParser, WireError};

/// Largest accepted value for each of m/n/k.
pub const MAX_DIM: usize = 1 << 16;
/// Largest accepted element count per operand/output matrix (64 Mi f32 =
/// 256 MiB — far above any benched shape, far below an allocation bomb).
pub const MAX_ELEMS: usize = 1 << 26;
/// Largest accepted explicit injection list / generated injection count.
pub const MAX_INJECTIONS: usize = 4096;

/// A structured protocol failure, classified for the wire error taxonomy.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProtoError {
    /// `"parse"` (malformed JSON / framing) or `"validation"` (well-formed
    /// JSON that violates the protocol).
    pub kind: &'static str,
    pub msg: String,
}

impl ProtoError {
    fn validation(msg: String) -> ProtoError {
        ProtoError { kind: "validation", msg }
    }
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.kind, self.msg)
    }
}

impl std::error::Error for ProtoError {}

impl From<WireError> for ProtoError {
    fn from(e: WireError) -> ProtoError {
        ProtoError { kind: "parse", msg: e.to_string() }
    }
}

/// One decoded wire frame.
#[derive(Debug, Clone, PartialEq)]
pub enum WireRequest {
    Gemm(Box<GemmSpec>),
    Metrics,
    Ping,
    Quit,
}

/// The wire form of a GEMM request: everything a [`GemmRequest`] carries,
/// in serializable clothes.
#[derive(Debug, Clone, PartialEq)]
pub struct GemmSpec {
    /// Client-chosen correlation id, echoed verbatim in the response.
    pub id: u64,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub policy: FtPolicy,
    /// Operand seed: the server materializes `A = rand_uniform(m, k, seed)`
    /// and `B = rand_uniform(k, n, seed + 1)`, same as the CLI.
    pub seed: u64,
    /// Generated-injection count (ignored when `injections` is non-empty).
    pub inject: usize,
    /// Explicit §5.3 injection coordinates; wins over `inject`.
    pub injections: Vec<Injection>,
    pub ft_level: Option<FtLevel>,
    pub host_verify: Option<HostVerify>,
    pub threshold_rel: Option<f32>,
    pub threshold_abs: Option<f32>,
    pub max_recomputes: Option<usize>,
    pub priority: Priority,
    /// Queue deadline in milliseconds; absent/0 = none.
    pub deadline_ms: Option<u64>,
}

impl GemmSpec {
    pub fn new(m: usize, n: usize, k: usize) -> GemmSpec {
        GemmSpec {
            id: 0,
            m,
            n,
            k,
            policy: FtPolicy::Online,
            seed: 1,
            inject: 0,
            injections: Vec::new(),
            ft_level: None,
            host_verify: None,
            threshold_rel: None,
            threshold_abs: None,
            max_recomputes: None,
            priority: Priority::Normal,
            deadline_ms: None,
        }
    }

    /// Encode as one single-line JSON frame (no trailing newline). Fields
    /// at their defaults are omitted — the decoder fills them back in, so
    /// `decode(spec.to_wire_json() + "\n") == spec`.
    pub fn to_wire_json(&self) -> String {
        let mut o = Json::obj();
        o.set("op", Json::from("gemm"));
        if self.id != 0 {
            o.set("id", Json::Num(self.id as f64));
        }
        o.set("m", Json::from(self.m));
        o.set("n", Json::from(self.n));
        o.set("k", Json::from(self.k));
        o.set("policy", Json::from(self.policy.name()));
        if self.seed != 1 {
            o.set("seed", Json::Num(self.seed as f64));
        }
        if self.inject != 0 {
            o.set("inject", Json::from(self.inject));
        }
        if !self.injections.is_empty() {
            let mut arr = Json::Arr(Vec::new());
            for inj in &self.injections {
                let mut io = Json::obj();
                io.set("row", Json::from(inj.row));
                io.set("col", Json::from(inj.col));
                io.set("step", Json::from(inj.step));
                io.set("magnitude", Json::Num(inj.magnitude as f64));
                arr.push(io);
            }
            o.set("injections", arr);
        }
        if let Some(level) = self.ft_level {
            o.set("ft_level", Json::from(level.as_str()));
        }
        if let Some(hv) = self.host_verify {
            o.set("host_verify", Json::from(hv.as_str()));
        }
        if let Some(rel) = self.threshold_rel {
            o.set("threshold_rel", Json::Num(rel as f64));
        }
        if let Some(abs) = self.threshold_abs {
            o.set("threshold_abs", Json::Num(abs as f64));
        }
        if let Some(nr) = self.max_recomputes {
            o.set("max_recomputes", Json::from(nr));
        }
        if self.priority != Priority::Normal {
            o.set("priority", Json::from(self.priority.as_str()));
        }
        if let Some(ms) = self.deadline_ms {
            o.set("deadline_ms", Json::Num(ms as f64));
        }
        o.to_string()
    }

    /// The B-operand seed (`A` uses `seed` itself). One definition, used
    /// by materialization *and* the pack-cache operand id, so the id
    /// always names exactly the content `rand_uniform` would produce.
    fn seed_b(&self) -> u64 {
        self.seed + 1
    }

    /// The injection plan this spec asks for (explicit list wins; a bare
    /// `inject` count expands through the same [`SeuModel`] path as the
    /// CLI, so a given `(seed, inject)` reproduces exactly).
    pub fn injection_plan(&self) -> InjectionPlan {
        if !self.injections.is_empty() {
            return InjectionPlan { injections: self.injections.clone() };
        }
        if self.inject == 0 {
            return InjectionPlan::none();
        }
        let geom = KernelGeom::for_shape(self.m, self.n, self.k);
        let mut rng = Pcg32::seeded(self.seed);
        SeuModel::PerGemm { count: self.inject }.plan(&geom, 0.0, &mut rng)
    }

    /// The single seed-derivation path: operands *and* injected
    /// coordinates for this spec, optionally through a [`SeedCache`]. A
    /// cache hit skips both `Matrix::rand_uniform` calls and the
    /// [`SeuModel`] expansion; hit or miss, the result is bit-identical
    /// to a fresh derivation (the cache stores exactly what this
    /// function would compute).
    pub fn derive(&self, cache: Option<&SeedCache>) -> (Arc<Matrix>, Arc<Matrix>, InjectionPlan) {
        match cache {
            Some(c) => (
                c.operand(self.m, self.k, self.seed),
                c.operand(self.k, self.n, self.seed_b()),
                c.plan(self),
            ),
            None => (
                Arc::new(Matrix::rand_uniform(self.m, self.k, self.seed)),
                Arc::new(Matrix::rand_uniform(self.k, self.n, self.seed_b())),
                self.injection_plan(),
            ),
        }
    }

    /// Materialize the server-side [`GemmRequest`]: seed-derived operands
    /// plus every option the frame carried. Operands are stamped with
    /// their wire-level `Seed` content addresses, so the engine's packed-
    /// operand cache recognizes repeat seeds with zero hashing of data.
    pub fn into_request(self) -> GemmRequest {
        self.into_request_with(None)
    }

    /// [`GemmSpec::into_request`] through an optional gateway
    /// [`SeedCache`] (a hit reuses the shared operand `Arc`s).
    pub fn into_request_with(self, cache: Option<&SeedCache>) -> GemmRequest {
        let (a, b, plan) = self.derive(cache);
        let key_a = OperandId::Seed { rows: self.m, cols: self.k, seed: self.seed };
        let key_b = OperandId::Seed { rows: self.k, cols: self.n, seed: self.seed_b() };
        let thresholds = match (self.threshold_rel, self.threshold_abs) {
            (None, None) => None,
            (rel, abs) => {
                let d = Thresholds::default();
                Some(Thresholds { rel: rel.unwrap_or(d.rel), abs: abs.unwrap_or(d.abs) })
            }
        };
        let opts = RequestOptions {
            ft_level: self.ft_level,
            thresholds,
            host_verify: self.host_verify,
            max_recomputes: self.max_recomputes,
            priority: self.priority,
            deadline: self.deadline_ms.map(std::time::Duration::from_millis),
        };
        GemmRequest::new(a, b)
            .policy(self.policy)
            .inject(plan)
            .options(opts)
            .operand_ids(Some(key_a), Some(key_b))
    }
}

/// Gateway-held LRU of seed-materialized operands plus memoized
/// seed-expanded injection plans — the wire-side half of the
/// cross-request cache. Keyed purely by wire content (`(rows, cols,
/// seed)` for operands, the full `(m, n, k, seed, inject)` tuple for
/// plans), so a repeated frame costs refcount bumps instead of
/// `rand_uniform` + `SeuModel` work. Sized off the engine's
/// `pack_cache_mb` budget: 0 disables it along with the engine half.
pub struct SeedCache {
    inner: Mutex<SeedCacheInner>,
    budget: usize,
}

struct SeedCacheInner {
    mats: HashMap<(usize, usize, u64), (Arc<Matrix>, u64)>,
    bytes: usize,
    tick: u64,
    /// Seed-expanded plans; tiny (≤ MAX_INJECTIONS coords each), bounded
    /// by entry count and cleared wholesale at capacity.
    plans: HashMap<(usize, usize, usize, u64, usize), InjectionPlan>,
}

/// Entry bound for the memoized plan map.
const MAX_CACHED_PLANS: usize = 4096;

impl SeedCache {
    /// `None` when `budget_bytes` is 0 — callers then derive fresh.
    pub fn with_budget(budget_bytes: usize) -> Option<SeedCache> {
        (budget_bytes > 0).then(|| SeedCache {
            inner: Mutex::new(SeedCacheInner {
                mats: HashMap::new(),
                bytes: 0,
                tick: 0,
                plans: HashMap::new(),
            }),
            budget: budget_bytes,
        })
    }

    /// `rand_uniform(rows, cols, seed)`, shared: materialized at most
    /// once while the entry stays resident. Oversized operands (bigger
    /// than the whole budget) are returned uncached.
    pub fn operand(&self, rows: usize, cols: usize, seed: u64) -> Arc<Matrix> {
        let key = (rows, cols, seed);
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some((m, stamp)) = inner.mats.get_mut(&key) {
            *stamp = tick;
            return Arc::clone(m);
        }
        // Materialized under the lock: concurrent connections asking for
        // the same seed must not race into a double fill, and holding it
        // briefly beats handing every caller its own copy.
        let mat = Arc::new(Matrix::rand_uniform(rows, cols, seed));
        let cost = rows * cols * std::mem::size_of::<f32>();
        if cost > self.budget {
            return mat;
        }
        while inner.bytes + cost > self.budget {
            let Some((&victim, _)) = inner.mats.iter().min_by_key(|(_, (_, t))| *t) else {
                break;
            };
            if let Some((m, _)) = inner.mats.remove(&victim) {
                inner.bytes -= m.rows() * m.cols() * std::mem::size_of::<f32>();
            }
        }
        inner.bytes += cost;
        inner.mats.insert(key, (Arc::clone(&mat), tick));
        mat
    }

    /// The spec's injection plan, memoized when it is seed-expanded
    /// (explicit lists and empty plans are trivial and bypass the map).
    pub fn plan(&self, spec: &GemmSpec) -> InjectionPlan {
        if !spec.injections.is_empty() || spec.inject == 0 {
            return spec.injection_plan();
        }
        let key = (spec.m, spec.n, spec.k, spec.seed, spec.inject);
        let mut inner = self.inner.lock().unwrap();
        if let Some(plan) = inner.plans.get(&key) {
            return plan.clone();
        }
        if inner.plans.len() >= MAX_CACHED_PLANS {
            inner.plans.clear();
        }
        let plan = spec.injection_plan();
        inner.plans.insert(key, plan.clone());
        plan
    }

    /// (resident operand entries, resident operand bytes).
    pub fn usage(&self) -> (usize, usize) {
        let inner = self.inner.lock().unwrap();
        (inner.mats.len(), inner.bytes)
    }
}

/// Decoder scratch: which fields the frame carried, op-agnostic until the
/// end so key order never matters.
#[derive(Default)]
struct Fields {
    op: Option<String>,
    spec: GemmSpec,
    /// First gemm-only key seen — a `metrics`/`ping`/`quit` frame carrying
    /// one is rejected instead of silently ignored.
    gemm_field: Option<&'static str>,
    saw_shape: (bool, bool, bool),
}

impl Default for GemmSpec {
    fn default() -> GemmSpec {
        GemmSpec::new(0, 0, 0)
    }
}

/// Decode one complete frame into a [`WireRequest`], streaming straight
/// off the pull parser (no intermediate tree).
pub fn decode(frame: &[u8], max_depth: usize) -> Result<WireRequest, ProtoError> {
    let mut p = PullParser::new(frame, max_depth);
    match p.next()? {
        Some(Event::ObjBegin) => {}
        _ => return Err(ProtoError::validation("frame must be a JSON object".into())),
    }
    let mut f = Fields::default();
    loop {
        match p.next()? {
            Some(Event::ObjEnd) => break,
            Some(Event::Key(key)) => decode_field(&mut p, &key.decode(), &mut f)?,
            // the parser only yields Key/ObjEnd at object level
            other => {
                return Err(ProtoError::validation(format!("unexpected event {other:?}")));
            }
        }
    }
    // drain: surfaces trailing-garbage errors after the closing brace
    if p.next()?.is_some() {
        return Err(ProtoError::validation("more than one value in frame".into()));
    }
    finish(f)
}

fn decode_field(p: &mut PullParser<'_>, key: &str, f: &mut Fields) -> Result<(), ProtoError> {
    if key != "op" && key != "injections" {
        f.gemm_field.get_or_insert(match key {
            "id" => "id",
            "m" => "m",
            "n" => "n",
            "k" => "k",
            "policy" => "policy",
            "seed" => "seed",
            "inject" => "inject",
            "ft_level" => "ft_level",
            "host_verify" => "host_verify",
            "threshold_rel" => "threshold_rel",
            "threshold_abs" => "threshold_abs",
            "max_recomputes" => "max_recomputes",
            "priority" => "priority",
            "deadline_ms" => "deadline_ms",
            other => return Err(ProtoError::validation(format!("unknown key {other:?}"))),
        });
    }
    match key {
        "op" => f.op = Some(take_str(p, key)?),
        "id" => f.spec.id = take_u64(p, key)?,
        "m" => {
            f.spec.m = take_dim(p, key)?;
            f.saw_shape.0 = true;
        }
        "n" => {
            f.spec.n = take_dim(p, key)?;
            f.saw_shape.1 = true;
        }
        "k" => {
            f.spec.k = take_dim(p, key)?;
            f.saw_shape.2 = true;
        }
        "policy" => f.spec.policy = parse_enum(&take_str(p, key)?, key)?,
        "seed" => f.spec.seed = take_u64(p, key)?,
        "inject" => {
            let n = take_usize(p, key, MAX_INJECTIONS)?;
            f.spec.inject = n;
        }
        "injections" => {
            f.gemm_field.get_or_insert("injections");
            f.spec.injections = take_injections(p)?;
        }
        "ft_level" => f.spec.ft_level = Some(parse_enum(&take_str(p, key)?, key)?),
        "host_verify" => f.spec.host_verify = Some(parse_enum(&take_str(p, key)?, key)?),
        "threshold_rel" => f.spec.threshold_rel = Some(take_f32(p, key)?),
        "threshold_abs" => f.spec.threshold_abs = Some(take_f32(p, key)?),
        "max_recomputes" => f.spec.max_recomputes = Some(take_usize(p, key, 1 << 20)?),
        "priority" => f.spec.priority = parse_enum(&take_str(p, key)?, key)?,
        "deadline_ms" => {
            let ms = take_u64(p, key)?;
            f.spec.deadline_ms = if ms == 0 { None } else { Some(ms) };
        }
        _ => unreachable!("unknown keys rejected above"),
    }
    Ok(())
}

fn finish(f: Fields) -> Result<WireRequest, ProtoError> {
    let op = f.op.ok_or_else(|| ProtoError::validation("missing \"op\"".into()))?;
    if op != "gemm" {
        if let Some(field) = f.gemm_field {
            return Err(ProtoError::validation(format!(
                "key {field:?} is not valid for op {op:?}"
            )));
        }
    }
    match op.as_str() {
        "metrics" => Ok(WireRequest::Metrics),
        "ping" => Ok(WireRequest::Ping),
        "quit" => Ok(WireRequest::Quit),
        "gemm" => {
            let spec = f.spec;
            match f.saw_shape {
                (true, true, true) => {}
                _ => {
                    return Err(ProtoError::validation(
                        "gemm requires \"m\", \"n\", and \"k\"".into(),
                    ))
                }
            }
            for (what, elems) in [
                ("A", spec.m * spec.k),
                ("B", spec.k * spec.n),
                ("C", spec.m * spec.n),
            ] {
                if elems > MAX_ELEMS {
                    return Err(ProtoError::validation(format!(
                        "operand {what} would have {elems} elements (max {MAX_ELEMS})"
                    )));
                }
            }
            for inj in &spec.injections {
                if inj.row >= spec.m || inj.col >= spec.n {
                    return Err(ProtoError::validation(format!(
                        "injection ({}, {}) outside the {}x{} output",
                        inj.row, inj.col, spec.m, spec.n
                    )));
                }
            }
            Ok(WireRequest::Gemm(Box::new(spec)))
        }
        other => Err(ProtoError::validation(format!(
            "unknown op {other:?} (gemm|metrics|ping|quit)"
        ))),
    }
}

fn parse_enum<T>(s: &str, key: &str) -> Result<T, ProtoError>
where
    T: std::str::FromStr,
    T::Err: std::fmt::Display,
{
    s.parse::<T>().map_err(|e| ProtoError::validation(format!("{key}: {e}")))
}

fn take_str(p: &mut PullParser<'_>, key: &str) -> Result<String, ProtoError> {
    match p.next()? {
        Some(Event::Str(t)) => Ok(t.decode().into_owned()),
        _ => Err(ProtoError::validation(format!("{key} must be a string"))),
    }
}

fn take_num(p: &mut PullParser<'_>, key: &str) -> Result<f64, ProtoError> {
    match p.next()? {
        Some(Event::Num(x)) => Ok(x),
        _ => Err(ProtoError::validation(format!("{key} must be a number"))),
    }
}

fn take_f32(p: &mut PullParser<'_>, key: &str) -> Result<f32, ProtoError> {
    let x = take_num(p, key)?;
    let y = x as f32;
    if !y.is_finite() {
        return Err(ProtoError::validation(format!("{key} out of f32 range")));
    }
    Ok(y)
}

fn take_u64(p: &mut PullParser<'_>, key: &str) -> Result<u64, ProtoError> {
    let x = take_num(p, key)?;
    // 2^53: the last f64 where every integer is exact
    if x < 0.0 || x.fract() != 0.0 || x > 9.007_199_254_740_992e15 {
        return Err(ProtoError::validation(format!("{key} must be a non-negative integer")));
    }
    Ok(x as u64)
}

fn take_usize(p: &mut PullParser<'_>, key: &str, max: usize) -> Result<usize, ProtoError> {
    let x = take_u64(p, key)?;
    if x > max as u64 {
        return Err(ProtoError::validation(format!("{key} too large (max {max})")));
    }
    Ok(x as usize)
}

fn take_dim(p: &mut PullParser<'_>, key: &str) -> Result<usize, ProtoError> {
    let x = take_usize(p, key, MAX_DIM)?;
    if x == 0 {
        return Err(ProtoError::validation(format!("{key} must be positive")));
    }
    Ok(x)
}

fn take_injections(p: &mut PullParser<'_>) -> Result<Vec<Injection>, ProtoError> {
    match p.next()? {
        Some(Event::ArrBegin) => {}
        _ => return Err(ProtoError::validation("injections must be an array".into())),
    }
    let mut out = Vec::new();
    loop {
        match p.next()? {
            Some(Event::ArrEnd) => return Ok(out),
            Some(Event::ObjBegin) => {
                if out.len() >= MAX_INJECTIONS {
                    return Err(ProtoError::validation(format!(
                        "too many injections (max {MAX_INJECTIONS})"
                    )));
                }
                out.push(take_injection(p)?);
            }
            _ => {
                return Err(ProtoError::validation(
                    "each injection must be an object".into(),
                ))
            }
        }
    }
}

fn take_injection(p: &mut PullParser<'_>) -> Result<Injection, ProtoError> {
    let (mut row, mut col, mut step, mut magnitude) = (None, None, None, None);
    loop {
        match p.next()? {
            Some(Event::ObjEnd) => break,
            Some(Event::Key(key)) => {
                if key.is("row") {
                    row = Some(take_usize(p, "row", MAX_DIM)?);
                } else if key.is("col") {
                    col = Some(take_usize(p, "col", MAX_DIM)?);
                } else if key.is("step") {
                    step = Some(take_usize(p, "step", MAX_DIM)?);
                } else if key.is("magnitude") {
                    let x = take_f32(p, "magnitude")?;
                    magnitude = Some(x);
                } else {
                    return Err(ProtoError::validation(format!(
                        "unknown injection key {:?}",
                        key.decode()
                    )));
                }
            }
            other => {
                return Err(ProtoError::validation(format!("unexpected event {other:?}")));
            }
        }
    }
    match (row, col, step, magnitude) {
        (Some(row), Some(col), Some(step), Some(magnitude)) => {
            Ok(Injection { row, col, step, magnitude })
        }
        _ => Err(ProtoError::validation(
            "injection requires row, col, step, and magnitude".into(),
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::wire::DEFAULT_MAX_DEPTH;

    fn dec(frame: &str) -> Result<WireRequest, ProtoError> {
        decode(frame.as_bytes(), DEFAULT_MAX_DEPTH)
    }

    #[test]
    fn decodes_a_minimal_gemm() {
        let req = dec(r#"{"op": "gemm", "m": 64, "n": 32, "k": 16}"#).unwrap();
        match req {
            WireRequest::Gemm(spec) => {
                assert_eq!((spec.m, spec.n, spec.k), (64, 32, 16));
                assert_eq!(spec.policy, FtPolicy::Online);
                assert_eq!(spec.priority, Priority::Normal);
                assert_eq!(spec.seed, 1);
                assert!(spec.deadline_ms.is_none());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn decodes_every_option_field() {
        let req = dec(concat!(
            r#"{"op": "gemm", "id": 9, "m": 8, "n": 8, "k": 8, "policy": "offline","#,
            r#" "seed": 3, "ft_level": "warp", "host_verify": "always","#,
            r#" "threshold_rel": 0.5, "threshold_abs": 0.25, "max_recomputes": 2,"#,
            r#" "priority": "high", "deadline_ms": 250,"#,
            r#" "injections": [{"row": 1, "col": 2, "step": 0, "magnitude": -64.0}]}"#
        ))
        .unwrap();
        let spec = match req {
            WireRequest::Gemm(spec) => spec,
            other => panic!("{other:?}"),
        };
        assert_eq!(spec.id, 9);
        assert_eq!(spec.policy, FtPolicy::Offline);
        assert_eq!(spec.ft_level, Some(FtLevel::Warp));
        assert_eq!(spec.host_verify, Some(HostVerify::Always));
        assert_eq!(spec.threshold_rel, Some(0.5));
        assert_eq!(spec.threshold_abs, Some(0.25));
        assert_eq!(spec.max_recomputes, Some(2));
        assert_eq!(spec.priority, Priority::High);
        assert_eq!(spec.deadline_ms, Some(250));
        assert_eq!(spec.injections.len(), 1);
        assert_eq!(spec.injections[0].magnitude, -64.0);
    }

    #[test]
    fn control_verbs_decode() {
        assert_eq!(dec(r#"{"op": "metrics"}"#).unwrap(), WireRequest::Metrics);
        assert_eq!(dec(r#"{"op": "ping"}"#).unwrap(), WireRequest::Ping);
        assert_eq!(dec(r#"{"op": "quit"}"#).unwrap(), WireRequest::Quit);
    }

    #[test]
    fn key_order_does_not_matter() {
        let req = dec(r#"{"k": 16, "m": 64, "op": "gemm", "n": 32}"#).unwrap();
        assert!(matches!(req, WireRequest::Gemm(s) if (s.m, s.n, s.k) == (64, 32, 16)));
    }

    #[test]
    fn malformed_corpus_yields_structured_errors() {
        // (frame, expected kind)
        let corpus: &[(&str, &str)] = &[
            // parse errors: broken JSON, truncation, depth bombs
            (r#"{"op": "gemm""#, "parse"),
            (r#"{"op": gemm}"#, "parse"),
            ("", "parse"),
            (r#"{"op": "ping"} extra"#, "parse"),
            (&format!("{}1{}", "[".repeat(300), "]".repeat(300)), "parse"),
            // validation errors: well-formed JSON, wrong protocol
            ("[1, 2, 3]", "validation"),
            (r#"{"verb": "gemm"}"#, "validation"),
            (r#"{"op": "nope"}"#, "validation"),
            (r#"{"op": "gemm", "m": 64, "n": 32}"#, "validation"),
            (r#"{"op": "gemm", "m": -1, "n": 1, "k": 1}"#, "validation"),
            (r#"{"op": "gemm", "m": 0, "n": 1, "k": 1}"#, "validation"),
            (r#"{"op": "gemm", "m": 1.5, "n": 1, "k": 1}"#, "validation"),
            (r#"{"op": "gemm", "m": "64", "n": 32, "k": 16}"#, "validation"),
            (r#"{"op": "gemm", "m": 99999999, "n": 1, "k": 1}"#, "validation"),
            (r#"{"op": "gemm", "m": 65536, "n": 65536, "k": 1}"#, "validation"),
            (r#"{"op": "gemm", "m": 8, "n": 8, "k": 8, "policy": "best"}"#, "validation"),
            (r#"{"op": "gemm", "m": 8, "n": 8, "k": 8, "priority": "urgent"}"#, "validation"),
            (r#"{"op": "gemm", "m": 8, "n": 8, "k": 8, "turbo": true}"#, "validation"),
            (r#"{"op": "ping", "m": 8}"#, "validation"),
            (r#"{"op": "gemm", "m": 8, "n": 8, "k": 8, "injections": [1]}"#, "validation"),
            (
                r#"{"op": "gemm", "m": 8, "n": 8, "k": 8, "injections": [{"row": 1}]}"#,
                "validation",
            ),
            (
                r#"{"op": "gemm", "m": 8, "n": 8, "k": 8,
                   "injections": [{"row": 9, "col": 0, "step": 0, "magnitude": 1.0}]}"#,
                "validation",
            ),
        ];
        for (frame, kind) in corpus {
            let err = dec(frame).expect_err(frame);
            assert_eq!(err.kind, *kind, "{frame}: {err}");
        }
    }

    #[test]
    fn bad_utf8_is_a_parse_error_not_a_panic() {
        let mut frame = br#"{"op": ""#.to_vec();
        frame.extend_from_slice(&[0xFF, 0xFE]);
        frame.extend_from_slice(br#""}"#);
        let err = decode(&frame, DEFAULT_MAX_DEPTH).unwrap_err();
        assert_eq!(err.kind, "parse");
    }

    #[test]
    fn wire_json_roundtrips_defaults_and_full_specs() {
        let minimal = GemmSpec::new(64, 32, 16);
        let frame = minimal.to_wire_json();
        assert_eq!(dec(&frame).unwrap(), WireRequest::Gemm(Box::new(minimal)));

        let full = GemmSpec {
            id: 77,
            seed: 5,
            policy: FtPolicy::Offline,
            injections: vec![Injection { row: 3, col: 5, step: 1, magnitude: 4096.0 }],
            ft_level: Some(FtLevel::Thread),
            host_verify: Some(HostVerify::CleanOnly),
            threshold_rel: Some(1e-4),
            threshold_abs: Some(2e-3),
            max_recomputes: Some(6),
            priority: Priority::Low,
            deadline_ms: Some(1500),
            ..GemmSpec::new(128, 96, 64)
        };
        let frame = full.to_wire_json();
        assert_eq!(dec(&frame).unwrap(), WireRequest::Gemm(Box::new(full)));
    }

    #[test]
    fn spec_materializes_a_request_with_all_options() {
        let spec = GemmSpec {
            inject: 2,
            ft_level: Some(FtLevel::Warp),
            priority: Priority::High,
            deadline_ms: Some(100),
            ..GemmSpec::new(32, 32, 32)
        };
        let plan = spec.injection_plan();
        assert_eq!(plan.len(), 2, "inject count expands through SeuModel");
        let req = spec.into_request();
        assert_eq!(req.shape(), (32, 32, 32));
        assert_eq!(req.get_options().priority, Priority::High);
        assert_eq!(req.get_options().ft_level, Some(FtLevel::Warp));
        assert_eq!(
            req.get_options().deadline,
            Some(std::time::Duration::from_millis(100))
        );
        assert_eq!(req.injections().len(), 2);
    }

    #[test]
    fn seed_derivation_is_shared_and_reproducible_through_the_cache() {
        let spec = GemmSpec { seed: 11, inject: 3, ..GemmSpec::new(48, 40, 32) };
        let cache = SeedCache::with_budget(16 << 20).unwrap();
        let (a0, b0, p0) = spec.derive(None);
        let (a1, b1, p1) = spec.derive(Some(&cache));
        let (a2, b2, p2) = spec.derive(Some(&cache));
        // one derivation path: cached and fresh agree exactly, so the
        // (seed, inject) tuple pins both operands and coordinates
        assert_eq!(a0.data(), a1.data());
        assert_eq!(b0.data(), b1.data());
        assert_eq!(p0.injections, p1.injections);
        assert_eq!(p0.injections.len(), 3);
        // a hit returns the same allocations — rand_uniform and the
        // SeuModel expansion both skipped
        assert!(Arc::ptr_eq(&a1, &a2));
        assert!(Arc::ptr_eq(&b1, &b2));
        assert_eq!(p1.injections, p2.injections);
        let (entries, bytes) = cache.usage();
        assert_eq!(entries, 2);
        assert_eq!(bytes, (48 * 32 + 32 * 40) * 4);
    }

    #[test]
    fn seed_cache_evicts_lru_under_its_byte_budget() {
        let mat_bytes = 8 * 8 * 4;
        let cache = SeedCache::with_budget(2 * mat_bytes).unwrap();
        let a = cache.operand(8, 8, 1);
        let _b = cache.operand(8, 8, 2);
        let _ = cache.operand(8, 8, 1); // touch: seed 2 becomes LRU
        let _c = cache.operand(8, 8, 3); // over budget: evicts seed 2
        let (entries, bytes) = cache.usage();
        assert_eq!(entries, 2);
        assert_eq!(bytes, 2 * mat_bytes);
        let a2 = cache.operand(8, 8, 1);
        assert!(Arc::ptr_eq(&a, &a2), "recently-touched seed stayed resident");
        assert!(SeedCache::with_budget(0).is_none(), "budget 0 disables");
    }

    #[test]
    fn wire_requests_carry_seed_operand_ids() {
        let spec = GemmSpec { seed: 7, ..GemmSpec::new(16, 8, 12) };
        let req = spec.into_request();
        assert_eq!(req.key_a, Some(OperandId::Seed { rows: 16, cols: 12, seed: 7 }));
        assert_eq!(req.key_b, Some(OperandId::Seed { rows: 12, cols: 8, seed: 8 }));
    }

    #[test]
    fn explicit_injections_win_over_inject_count() {
        let spec = GemmSpec {
            inject: 5,
            injections: vec![Injection { row: 0, col: 0, step: 0, magnitude: 99.0 }],
            ..GemmSpec::new(16, 16, 16)
        };
        let plan = spec.injection_plan();
        assert_eq!(plan.len(), 1);
        assert_eq!(plan.injections[0].magnitude, 99.0);
    }

    #[test]
    fn zero_deadline_means_none() {
        let req = dec(r#"{"op": "gemm", "m": 8, "n": 8, "k": 8, "deadline_ms": 0}"#).unwrap();
        assert!(matches!(req, WireRequest::Gemm(s) if s.deadline_ms.is_none()));
    }
}
