//! Streaming wire-format plumbing for the gateway: newline-delimited
//! frames and a zero-allocation JSON **pull parser**.
//!
//! The tree parser in [`util::json`](crate::util::json) is the right tool
//! for trusted files (manifests, bench output); a network front door has
//! different obligations:
//!
//! * **No recursion.** [`PullParser`] is iterative with an explicit
//!   container stack whose depth is bounded at construction
//!   ([`DEFAULT_MAX_DEPTH`]); a depth bomb returns
//!   [`WireErrorKind::TooDeep`] instead of overflowing the thread stack.
//!   The stack is pre-allocated to that bound, so parser memory is fixed
//!   regardless of input.
//! * **No allocation per event.** `next()` yields [`Event`]s that borrow
//!   spans of the input frame; strings are validated (UTF-8 + escape
//!   structure) during the scan but decoded lazily — [`Text::decode`]
//!   borrows unless the string actually contains escapes, which protocol
//!   identifiers never do.
//! * **Incremental feed.** [`FrameReader`] accumulates socket reads and
//!   splits complete `\n`-terminated frames off them, rejecting any frame
//!   — complete or still in flight — larger than its bound, so a slow or
//!   malicious client can neither hold a growing buffer hostage nor make
//!   the server parse an unbounded line.

use std::borrow::Cow;
use std::collections::VecDeque;
use std::fmt;

/// Default nesting bound for wire frames (matches `util::json`'s).
pub const DEFAULT_MAX_DEPTH: usize = 64;

/// A structured wire-level failure, positioned within the frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireError {
    /// Byte offset within the frame (0 for framing errors).
    pub pos: usize,
    pub kind: WireErrorKind,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireErrorKind {
    /// Malformed JSON at `pos`; the message names the expectation.
    Syntax(&'static str),
    /// Containers nested deeper than the configured bound.
    TooDeep(usize),
    /// A frame (or an unterminated partial frame) exceeded the byte bound.
    FrameTooLong(usize),
    /// A string carried bytes that are not valid UTF-8.
    BadUtf8,
    /// A `\x` or `\uXXXX` escape was malformed (including lone
    /// surrogates).
    BadEscape,
    /// A number token failed to parse as a finite f64.
    BadNumber,
    /// The frame ended in the middle of a value.
    UnexpectedEnd,
    /// Bytes after the top-level value.
    TrailingGarbage,
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.kind {
            WireErrorKind::Syntax(what) => {
                write!(f, "byte {}: expected {what}", self.pos)
            }
            WireErrorKind::TooDeep(max) => {
                write!(f, "byte {}: nesting exceeds the depth bound ({max})", self.pos)
            }
            WireErrorKind::FrameTooLong(max) => {
                write!(f, "frame exceeds the size bound ({max} bytes)")
            }
            WireErrorKind::BadUtf8 => write!(f, "byte {}: invalid UTF-8 in string", self.pos),
            WireErrorKind::BadEscape => write!(f, "byte {}: bad string escape", self.pos),
            WireErrorKind::BadNumber => write!(f, "byte {}: bad number", self.pos),
            WireErrorKind::UnexpectedEnd => write!(f, "byte {}: unexpected end of frame", self.pos),
            WireErrorKind::TrailingGarbage => {
                write!(f, "byte {}: trailing bytes after the value", self.pos)
            }
        }
    }
}

impl std::error::Error for WireError {}

/// A validated string span of the frame, escapes still intact.
/// Guaranteed valid UTF-8 with structurally sound escapes (the scanner
/// checked both), so decoding cannot fail.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Text<'a> {
    raw: &'a [u8],
    escaped: bool,
}

impl<'a> Text<'a> {
    /// Allocation-free comparison against a literal (protocol keys and
    /// enum values never carry escapes, so this is the hot path).
    pub fn is(&self, s: &str) -> bool {
        !self.escaped && self.raw == s.as_bytes()
    }

    /// Decode to a `&str`, borrowing unless the string contains escapes.
    pub fn decode(&self) -> Cow<'a, str> {
        if !self.escaped {
            // Scanner validated the UTF-8; lossy never actually replaces.
            return String::from_utf8_lossy(self.raw);
        }
        let mut out = Vec::with_capacity(self.raw.len());
        let mut i = 0;
        while i < self.raw.len() {
            let c = self.raw[i];
            if c != b'\\' {
                out.push(c);
                i += 1;
                continue;
            }
            i += 1;
            match self.raw[i] {
                b'"' => out.push(b'"'),
                b'\\' => out.push(b'\\'),
                b'/' => out.push(b'/'),
                b'b' => out.push(0x08),
                b'f' => out.push(0x0C),
                b'n' => out.push(b'\n'),
                b'r' => out.push(b'\r'),
                b't' => out.push(b'\t'),
                b'u' => {
                    let hi = hex4(&self.raw[i + 1..i + 5]);
                    i += 4;
                    let code = if (0xD800..0xDC00).contains(&hi) {
                        // validated surrogate pair: \uHHHH\uLLLL
                        let lo = hex4(&self.raw[i + 3..i + 7]);
                        i += 6;
                        0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                    } else {
                        hi
                    };
                    let ch = char::from_u32(code).unwrap_or(char::REPLACEMENT_CHARACTER);
                    let mut buf = [0u8; 4];
                    out.extend_from_slice(ch.encode_utf8(&mut buf).as_bytes());
                }
                _ => unreachable!("scanner validated escapes"),
            }
            i += 1;
        }
        Cow::Owned(String::from_utf8_lossy(&out).into_owned())
    }
}

fn hex4(b: &[u8]) -> u32 {
    b.iter().fold(0u32, |acc, &c| acc * 16 + (c as char).to_digit(16).unwrap_or(0))
}

/// One parse event. String events borrow the frame.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Event<'a> {
    ObjBegin,
    ObjEnd,
    ArrBegin,
    ArrEnd,
    /// An object key (the following event(s) are its value).
    Key(Text<'a>),
    Str(Text<'a>),
    Num(f64),
    Bool(bool),
    Null,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Frame {
    Obj,
    Arr,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// Expecting a value (top level, after `[`+comma, or after a colon).
    Value,
    /// Just opened an object: `}` or the first key.
    ObjFirst,
    /// Just opened an array: `]` or the first value.
    ArrFirst,
    /// A value just completed; expecting `,`, a closer, or frame end.
    AfterValue,
    /// Top-level value complete.
    Done,
}

/// Iterative, depth-bounded JSON pull parser over one complete frame.
///
/// ```
/// use ftgemm::serve::wire::{Event, PullParser};
///
/// let mut p = PullParser::new(br#"{"op": "ping"}"#, 64);
/// assert_eq!(p.next().unwrap(), Some(Event::ObjBegin));
/// match p.next().unwrap() {
///     Some(Event::Key(k)) => assert!(k.is("op")),
///     other => panic!("{other:?}"),
/// }
/// ```
pub struct PullParser<'a> {
    b: &'a [u8],
    pos: usize,
    stack: Vec<Frame>,
    max_depth: usize,
    phase: Phase,
}

impl<'a> PullParser<'a> {
    pub fn new(frame: &'a [u8], max_depth: usize) -> PullParser<'a> {
        let max_depth = max_depth.max(1);
        PullParser {
            b: frame,
            pos: 0,
            // pre-allocated to the bound: parser memory is fixed
            stack: Vec::with_capacity(max_depth),
            max_depth,
            phase: Phase::Value,
        }
    }

    /// Current nesting depth (open containers).
    pub fn depth(&self) -> usize {
        self.stack.len()
    }

    fn err(&self, kind: WireErrorKind) -> WireError {
        WireError { pos: self.pos, kind }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn push_frame(&mut self, f: Frame) -> Result<(), WireError> {
        if self.stack.len() >= self.max_depth {
            return Err(self.err(WireErrorKind::TooDeep(self.max_depth)));
        }
        self.stack.push(f);
        Ok(())
    }

    /// Pull the next event; `Ok(None)` exactly once the single top-level
    /// value has been consumed and the frame is exhausted.
    #[allow(clippy::should_implement_trait)] // fallible, not an Iterator
    pub fn next(&mut self) -> Result<Option<Event<'a>>, WireError> {
        loop {
            self.skip_ws();
            match self.phase {
                Phase::Done => {
                    if self.pos != self.b.len() {
                        return Err(self.err(WireErrorKind::TrailingGarbage));
                    }
                    return Ok(None);
                }
                Phase::AfterValue => match self.stack.last() {
                    None => {
                        self.phase = Phase::Done;
                    }
                    Some(Frame::Arr) => match self.bump() {
                        Some(b',') => self.phase = Phase::Value,
                        Some(b']') => {
                            self.stack.pop();
                            return Ok(Some(Event::ArrEnd));
                        }
                        _ => {
                            self.pos = self.pos.saturating_sub(1);
                            return Err(self.err(WireErrorKind::Syntax("',' or ']'")));
                        }
                    },
                    Some(Frame::Obj) => match self.bump() {
                        Some(b',') => {
                            self.skip_ws();
                            let key = self.scan_string()?;
                            self.skip_ws();
                            if self.bump() != Some(b':') {
                                self.pos = self.pos.saturating_sub(1);
                                return Err(self.err(WireErrorKind::Syntax("':'")));
                            }
                            self.phase = Phase::Value;
                            return Ok(Some(Event::Key(key)));
                        }
                        Some(b'}') => {
                            self.stack.pop();
                            return Ok(Some(Event::ObjEnd));
                        }
                        _ => {
                            self.pos = self.pos.saturating_sub(1);
                            return Err(self.err(WireErrorKind::Syntax("',' or '}'")));
                        }
                    },
                },
                Phase::ObjFirst => {
                    if self.peek() == Some(b'}') {
                        self.pos += 1;
                        self.stack.pop();
                        self.phase = Phase::AfterValue;
                        return Ok(Some(Event::ObjEnd));
                    }
                    let key = self.scan_string()?;
                    self.skip_ws();
                    if self.bump() != Some(b':') {
                        self.pos = self.pos.saturating_sub(1);
                        return Err(self.err(WireErrorKind::Syntax("':'")));
                    }
                    self.phase = Phase::Value;
                    return Ok(Some(Event::Key(key)));
                }
                Phase::ArrFirst => {
                    if self.peek() == Some(b']') {
                        self.pos += 1;
                        self.stack.pop();
                        self.phase = Phase::AfterValue;
                        return Ok(Some(Event::ArrEnd));
                    }
                    self.phase = Phase::Value;
                }
                Phase::Value => match self.peek() {
                    Some(b'{') => {
                        self.push_frame(Frame::Obj)?;
                        self.pos += 1;
                        self.phase = Phase::ObjFirst;
                        return Ok(Some(Event::ObjBegin));
                    }
                    Some(b'[') => {
                        self.push_frame(Frame::Arr)?;
                        self.pos += 1;
                        self.phase = Phase::ArrFirst;
                        return Ok(Some(Event::ArrBegin));
                    }
                    Some(b'"') => {
                        let t = self.scan_string()?;
                        self.phase = Phase::AfterValue;
                        return Ok(Some(Event::Str(t)));
                    }
                    Some(b't') => {
                        self.literal(b"true")?;
                        self.phase = Phase::AfterValue;
                        return Ok(Some(Event::Bool(true)));
                    }
                    Some(b'f') => {
                        self.literal(b"false")?;
                        self.phase = Phase::AfterValue;
                        return Ok(Some(Event::Bool(false)));
                    }
                    Some(b'n') => {
                        self.literal(b"null")?;
                        self.phase = Phase::AfterValue;
                        return Ok(Some(Event::Null));
                    }
                    Some(c) if c == b'-' || c.is_ascii_digit() => {
                        let x = self.scan_number()?;
                        self.phase = Phase::AfterValue;
                        return Ok(Some(Event::Num(x)));
                    }
                    Some(_) => return Err(self.err(WireErrorKind::Syntax("a JSON value"))),
                    None => return Err(self.err(WireErrorKind::UnexpectedEnd)),
                },
            }
        }
    }

    fn literal(&mut self, word: &[u8]) -> Result<(), WireError> {
        if self.b[self.pos..].starts_with(word) {
            self.pos += word.len();
            Ok(())
        } else {
            Err(self.err(WireErrorKind::Syntax("a JSON literal")))
        }
    }

    /// Scan (and fully validate) one string; the returned [`Text`] spans
    /// the bytes between the quotes with escapes intact.
    fn scan_string(&mut self) -> Result<Text<'a>, WireError> {
        if self.bump() != Some(b'"') {
            self.pos = self.pos.saturating_sub(1);
            return Err(self.err(WireErrorKind::Syntax("'\"'")));
        }
        let start = self.pos;
        let mut escaped = false;
        loop {
            match self.bump() {
                None => return Err(self.err(WireErrorKind::UnexpectedEnd)),
                Some(b'"') => {
                    let raw = &self.b[start..self.pos - 1];
                    if std::str::from_utf8(raw).is_err() {
                        return Err(self.err(WireErrorKind::BadUtf8));
                    }
                    return Ok(Text { raw, escaped });
                }
                Some(b'\\') => {
                    escaped = true;
                    self.scan_escape()?;
                }
                Some(c) if c < 0x20 => {
                    return Err(self.err(WireErrorKind::Syntax("no control chars in strings")));
                }
                Some(_) => {}
            }
        }
    }

    /// Validate one escape after the backslash. Full surrogate-pair
    /// checking here is what makes [`Text::decode`] infallible.
    fn scan_escape(&mut self) -> Result<(), WireError> {
        match self.bump() {
            Some(b'"' | b'\\' | b'/' | b'b' | b'f' | b'n' | b'r' | b't') => Ok(()),
            Some(b'u') => {
                let hi = self.scan_hex4()?;
                if (0xD800..0xDC00).contains(&hi) {
                    if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                        return Err(self.err(WireErrorKind::BadEscape));
                    }
                    let lo = self.scan_hex4()?;
                    if !(0xDC00..0xE000).contains(&lo) {
                        return Err(self.err(WireErrorKind::BadEscape));
                    }
                } else if (0xDC00..0xE000).contains(&hi) {
                    return Err(self.err(WireErrorKind::BadEscape));
                }
                Ok(())
            }
            _ => Err(self.err(WireErrorKind::BadEscape)),
        }
    }

    fn scan_hex4(&mut self) -> Result<u32, WireError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let d = self
                .bump()
                .and_then(|c| (c as char).to_digit(16))
                .ok_or_else(|| self.err(WireErrorKind::BadEscape))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn scan_number(&mut self) -> Result<f64, WireError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos])
            .map_err(|_| self.err(WireErrorKind::BadNumber))?;
        match text.parse::<f64>() {
            Ok(x) if x.is_finite() => Ok(x),
            _ => Err(self.err(WireErrorKind::BadNumber)),
        }
    }
}

/// Incremental newline-delimited framing with a hard per-frame byte
/// bound, applied to partial frames too: a client drip-feeding bytes
/// without ever sending `\n` is cut off at the same limit.
pub struct FrameReader {
    buf: Vec<u8>,
    ready: VecDeque<Vec<u8>>,
    max_frame: usize,
}

impl FrameReader {
    pub fn new(max_frame: usize) -> FrameReader {
        FrameReader { buf: Vec::new(), ready: VecDeque::new(), max_frame: max_frame.max(1) }
    }

    /// Feed one chunk of socket bytes; returns how many complete frames
    /// became ready. Blank frames (keep-alive newlines) are dropped.
    pub fn feed(&mut self, chunk: &[u8]) -> Result<usize, WireError> {
        self.buf.extend_from_slice(chunk);
        let mut n = 0;
        while let Some(i) = self.buf.iter().position(|&b| b == b'\n') {
            let mut frame: Vec<u8> = self.buf.drain(..=i).collect();
            frame.pop(); // the newline
            if frame.last() == Some(&b'\r') {
                frame.pop();
            }
            if frame.len() > self.max_frame {
                return Err(WireError { pos: 0, kind: WireErrorKind::FrameTooLong(self.max_frame) });
            }
            if frame.iter().all(|b| b.is_ascii_whitespace()) {
                continue;
            }
            self.ready.push_back(frame);
            n += 1;
        }
        if self.buf.len() > self.max_frame {
            return Err(WireError { pos: 0, kind: WireErrorKind::FrameTooLong(self.max_frame) });
        }
        Ok(n)
    }

    /// Next complete frame, FIFO.
    pub fn next_frame(&mut self) -> Option<Vec<u8>> {
        self.ready.pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drain a frame into events, for assertions.
    fn events(frame: &[u8]) -> Result<Vec<String>, WireError> {
        let mut p = PullParser::new(frame, DEFAULT_MAX_DEPTH);
        let mut out = Vec::new();
        while let Some(e) = p.next()? {
            out.push(match e {
                Event::ObjBegin => "{".into(),
                Event::ObjEnd => "}".into(),
                Event::ArrBegin => "[".into(),
                Event::ArrEnd => "]".into(),
                Event::Key(t) => format!("key:{}", t.decode()),
                Event::Str(t) => format!("str:{}", t.decode()),
                Event::Num(x) => format!("num:{x}"),
                Event::Bool(b) => format!("bool:{b}"),
                Event::Null => "null".into(),
            });
        }
        Ok(out)
    }

    #[test]
    fn pulls_nested_structure_in_order() {
        let got = events(br#"{"a": [1, true, null], "b": {"c": "x"}}"#).unwrap();
        assert_eq!(
            got,
            vec![
                "{", "key:a", "[", "num:1", "bool:true", "null", "]", "key:b", "{", "key:c",
                "str:x", "}", "}"
            ]
        );
    }

    #[test]
    fn scalar_top_level_values_parse() {
        assert_eq!(events(b"42").unwrap(), vec!["num:42"]);
        assert_eq!(events(b"\"hi\"").unwrap(), vec!["str:hi"]);
        assert_eq!(events(b"false").unwrap(), vec!["bool:false"]);
        assert_eq!(events(b"[]").unwrap(), vec!["[", "]"]);
        assert_eq!(events(b"{}").unwrap(), vec!["{", "}"]);
    }

    #[test]
    fn depth_bomb_returns_too_deep_not_overflow() {
        let mut bomb = Vec::new();
        for _ in 0..1000 {
            bomb.push(b'[');
        }
        bomb.push(b'1');
        for _ in 0..1000 {
            bomb.push(b']');
        }
        let mut p = PullParser::new(&bomb, DEFAULT_MAX_DEPTH);
        let err = loop {
            match p.next() {
                Ok(Some(_)) => continue,
                Ok(None) => panic!("depth bomb accepted"),
                Err(e) => break e,
            }
        };
        assert_eq!(err.kind, WireErrorKind::TooDeep(DEFAULT_MAX_DEPTH));
        // mixed object/array nesting trips the same bound
        let bomb: Vec<u8> = br#"{"a":"#
            .iter()
            .copied()
            .cycle()
            .take(5 * 200)
            .chain(*b"1")
            .collect();
        let mut p = PullParser::new(&bomb, 64);
        let err = loop {
            match p.next() {
                Ok(Some(_)) => continue,
                Ok(None) => panic!("depth bomb accepted"),
                Err(e) => break e,
            }
        };
        assert_eq!(err.kind, WireErrorKind::TooDeep(64));
    }

    #[test]
    fn depth_within_bound_is_fine() {
        let mut deep = Vec::new();
        for _ in 0..DEFAULT_MAX_DEPTH {
            deep.push(b'[');
        }
        for _ in 0..DEFAULT_MAX_DEPTH {
            deep.push(b']');
        }
        assert!(events(&deep).is_ok());
    }

    #[test]
    fn malformed_frames_return_structured_errors() {
        for bad in [
            &b"{"[..],
            b"[1,",
            b"\"unterminated",
            b"{\"a\" 1}",
            b"[1] junk",
            b"{,}",
            b"[1,,2]",
            b"nul",
            b"+1",
            b"1e999",
            b"{\"a\": \"\\q\"}",
            b"\"\\ud800\"",
            b"\"\\ud800\\u0020\"",
        ] {
            let mut p = PullParser::new(bad, DEFAULT_MAX_DEPTH);
            let r = loop {
                match p.next() {
                    Ok(Some(_)) => continue,
                    other => break other,
                }
            };
            assert!(r.is_err(), "{:?} should fail", String::from_utf8_lossy(bad));
        }
    }

    #[test]
    fn bad_utf8_in_strings_is_rejected() {
        let frame = [b'"', 0xFF, 0xFE, b'"'];
        let mut p = PullParser::new(&frame, DEFAULT_MAX_DEPTH);
        assert_eq!(p.next().unwrap_err().kind, WireErrorKind::BadUtf8);
    }

    #[test]
    fn text_decodes_escapes_and_borrows_plain_strings() {
        let mut p = PullParser::new(br#""plain""#, 8);
        match p.next().unwrap() {
            Some(Event::Str(t)) => {
                assert!(matches!(t.decode(), Cow::Borrowed("plain")));
                assert!(t.is("plain"));
            }
            other => panic!("{other:?}"),
        }
        let mut p = PullParser::new(br#""a\"b\nc \u00e9 \ud83d\ude00""#, 8);
        match p.next().unwrap() {
            Some(Event::Str(t)) => {
                assert_eq!(t.decode(), "a\"b\nc \u{e9} \u{1F600}");
                assert!(!t.is("a\"b"), "escaped text never fast-path matches");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn frame_reader_splits_and_buffers_partials() {
        let mut fr = FrameReader::new(1024);
        assert_eq!(fr.feed(b"{\"op\":\"ping\"}\n{\"op\":").unwrap(), 1);
        assert_eq!(fr.next_frame().unwrap(), b"{\"op\":\"ping\"}");
        assert!(fr.next_frame().is_none());
        assert_eq!(fr.feed(b"\"quit\"}\r\n\n").unwrap(), 1, "blank keep-alive line dropped");
        assert_eq!(fr.next_frame().unwrap(), b"{\"op\":\"quit\"}");
    }

    #[test]
    fn frame_reader_bounds_complete_and_partial_frames() {
        let mut fr = FrameReader::new(8);
        let err = fr.feed(b"0123456789\n").unwrap_err();
        assert_eq!(err.kind, WireErrorKind::FrameTooLong(8));
        // a drip-fed frame with no newline trips the same bound
        let mut fr = FrameReader::new(8);
        assert_eq!(fr.feed(b"0123").unwrap(), 0);
        let err = fr.feed(b"456789").unwrap_err();
        assert_eq!(err.kind, WireErrorKind::FrameTooLong(8));
    }

    #[test]
    fn numbers_parse_with_signs_and_exponents() {
        assert_eq!(events(b"[-3.5e2, 0.25, 1e3]").unwrap()[1], "num:-350");
        assert_eq!(events(b"[-3.5e2, 0.25, 1e3]").unwrap()[2], "num:0.25");
    }
}
