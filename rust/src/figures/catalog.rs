//! Figure catalog: id → generator, plus the writer that emits
//! markdown / CSV / JSON bundles into an output directory.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::metrics::report::Table;
use crate::util::json::Json;

/// Every regenerable experiment, keyed by the paper's numbering.
pub const FIGURE_IDS: [&str; 15] = [
    "table1", "9", "10", "11", "12", "13", "14", "15", "16", "17", "18", "19", "20", "21", "22",
];

/// Generate the tables for one figure id.
pub fn generate(id: &str) -> Result<Vec<Table>> {
    Ok(match id {
        "table1" => vec![super::table1()],
        "9" => vec![super::fig9()],
        "10" => vec![super::fig10()],
        "11" => vec![super::fig11()],
        "12" => super::fig12(),
        "13" => super::fig13(),
        "14" => vec![super::fig14()],
        "15" => vec![super::fig15()],
        "16" => vec![super::fig16()],
        "17" => super::fig17(),
        "18" => super::fig18(),
        "19" => vec![super::fig19()],
        "20" => vec![super::fig20()],
        "21" => vec![super::fig21()],
        "22" => vec![super::fig22()],
        other => bail!("unknown figure id {other:?} (try one of {FIGURE_IDS:?})"),
    })
}

/// Write one figure's tables into `<out>/fig<id>.{md,csv,json}`.
pub fn write(id: &str, out_dir: &Path) -> Result<Vec<String>> {
    let tables = generate(id)?;
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating {out_dir:?}"))?;
    let mut written = Vec::new();
    let stem = if id == "table1" { "table1".to_string() } else { format!("fig{id}") };
    let mut md = String::new();
    let mut csv = String::new();
    let mut json_tables = Vec::new();
    for t in &tables {
        md.push_str(&t.to_markdown());
        md.push('\n');
        csv.push_str(&format!("# {}\n", t.title));
        csv.push_str(&t.to_csv());
        json_tables.push(t.to_json());
    }
    for (ext, content) in [
        ("md", md),
        ("csv", csv),
        ("json", Json::Arr(json_tables).to_string_pretty()),
    ] {
        let path = out_dir.join(format!("{stem}.{ext}"));
        std::fs::write(&path, content).with_context(|| format!("writing {path:?}"))?;
        written.push(path.display().to_string());
    }
    Ok(written)
}

/// Write everything.
pub fn write_all(out_dir: &Path) -> Result<Vec<String>> {
    let mut all = Vec::new();
    for id in FIGURE_IDS {
        all.extend(write(id, out_dir)?);
    }
    Ok(all)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_id_generates() {
        for id in FIGURE_IDS {
            let tables = generate(id).unwrap();
            assert!(!tables.is_empty(), "{id}");
        }
    }

    #[test]
    fn unknown_id_rejected() {
        assert!(generate("99").is_err());
    }

    #[test]
    fn write_roundtrip() {
        let dir = std::env::temp_dir().join("ftgemm_figtest");
        let _ = std::fs::remove_dir_all(&dir);
        let files = write("22", &dir).unwrap();
        assert_eq!(files.len(), 3);
        let md = std::fs::read_to_string(dir.join("fig22.md")).unwrap();
        assert!(md.contains("online_abft"));
        let json = std::fs::read_to_string(dir.join("fig22.json")).unwrap();
        assert!(Json::parse(&json).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
