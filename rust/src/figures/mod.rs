//! Figure harness: regenerates the data series behind **every** table and
//! figure in the paper's evaluation (§5), from the gpusim analytical model
//! (see DESIGN.md "Substitutions" — no GPU in this environment).
//!
//! `ftgemm figures --all --out figures_out` writes one markdown + CSV +
//! JSON per figure; `--fig 12` selects one. The per-experiment index in
//! DESIGN.md maps each figure to its modules.

pub mod catalog;

use crate::codegen::params::{KernelParams, ShapeClass};
use crate::codegen::select::select_class;
use crate::gpusim::cublas::cublas_gflops;
use crate::gpusim::device::{DeviceSpec, A100, T4};
use crate::gpusim::ft_model::{predict_ft, FtLevel, FtVariant};
use crate::gpusim::kernel_model::{predict, KernelConfig};
use crate::gpusim::{analytic, stepwise};
use crate::metrics::report::{Series, Table};

/// The paper's square-size sweep (Figs 9, 12, 13, 17, 18).
pub const SQUARE_SIZES: [usize; 6] = [1024, 2048, 3072, 4096, 5120, 6144];

/// The irregular-shape sweep of Figs 10/11/14/15: M=N from 64 to 490-ish
/// (step 32), K fixed at 256.
pub fn irregular_sizes() -> Vec<usize> {
    (64..=490).step_by(32).collect()
}

fn round_up(x: usize, to: usize) -> usize {
    x.div_ceil(to) * to
}

/// Model GFLOPS of one preset on a (possibly non-divisible) shape: the
/// kernel runs on the padded shape, useful FLOPs stay the original's.
pub fn preset_gflops(dev: &DeviceSpec, p: KernelParams, m: usize, n: usize, k: usize) -> f64 {
    let (pm, pn, pk) = (round_up(m, p.m_tb), round_up(n, p.n_tb), round_up(k, p.k_tb));
    let pred = predict(dev, &KernelConfig::optimized(p), pm, pn, pk);
    2.0 * m as f64 * n as f64 * k as f64 / pred.time_s / 1e9
}

/// The code generator's pick: the heuristic class (§3.2.2).
pub fn generated_gflops(dev: &DeviceSpec, m: usize, n: usize, k: usize) -> f64 {
    preset_gflops(dev, select_class(m, n, k).params(), m, n, k)
}

/// FT variant on a padded shape.
pub fn preset_ft_gflops(
    dev: &DeviceSpec,
    p: KernelParams,
    m: usize,
    n: usize,
    k: usize,
    v: FtVariant,
) -> f64 {
    let (pm, pn, pk) = (round_up(m, p.m_tb), round_up(n, p.n_tb), round_up(k, p.k_tb));
    let pred = predict_ft(dev, p, pm, pn, pk, v);
    2.0 * m as f64 * n as f64 * k as f64 / pred.time_s / 1e9
}

fn hardcoded() -> KernelParams {
    ShapeClass::Huge.params()
}

// ---------------------------------------------------------------------
// Table 1
// ---------------------------------------------------------------------

pub fn table1() -> Table {
    let mut t = Table::new(
        "Table 1: SGEMM kernel parameter setup on a Tesla T4 GPU",
        "class",
        "tile parameters",
    );
    t.note("columns: m_tb n_tb k_tb m_w n_w m_t n_t (verbatim from the paper)");
    for cls in ShapeClass::ALL {
        let p = cls.params();
        let mut s = Series::new(cls.name());
        for (i, v) in [p.m_tb, p.n_tb, p.k_tb, p.m_w, p.n_w, p.m_t, p.n_t]
            .into_iter()
            .enumerate()
        {
            s.push(i as f64, v as f64);
        }
        t.add(s);
    }
    t
}

// ---------------------------------------------------------------------
// Fig 9: step-wise SGEMM optimization (T4)
// ---------------------------------------------------------------------

pub fn fig9() -> Table {
    let mut t = Table::new(
        "Fig 9: Step-wise SGEMM optimization (T4)",
        "M=N=K",
        "GFLOPS",
    );
    t.note("paper-measured averages: 611 / 679 / 3822 / 4331 / 4381 / 4625 / 4654");
    for step in stepwise::ladder() {
        let mut s = Series::new(step.name);
        for &size in &SQUARE_SIZES {
            s.push(size as f64, predict(&T4, &step.config, size, size, size).gflops);
        }
        t.add(s);
    }
    let mut cb = Series::new("cublas");
    for &size in &SQUARE_SIZES {
        cb.push(size as f64, cublas_gflops(&T4, size, size, size));
    }
    t.add(cb);
    t
}

// ---------------------------------------------------------------------
// Figs 10/11: codegen for irregular shapes, non-FT (T4)
// ---------------------------------------------------------------------

pub fn fig10() -> Table {
    let mut t = Table::new(
        "Fig 10: Auto-generated SGEMM vs cuBLAS vs hard-coded, irregular inputs (T4, K=256)",
        "M=N",
        "GFLOPS",
    );
    t.note("paper: generated beats hard-coded by up to 230.96%, cuBLAS by 18.21% avg");
    let k = 256;
    let (mut gen, mut hard, mut cb) = (
        Series::new("generated"),
        Series::new("hardcoded"),
        Series::new("cublas"),
    );
    for m in irregular_sizes() {
        gen.push(m as f64, generated_gflops(&T4, m, m, k));
        hard.push(m as f64, preset_gflops(&T4, hardcoded(), m, m, k));
        cb.push(m as f64, cublas_gflops(&T4, m, m, k));
    }
    t.add(gen);
    t.add(hard);
    t.add(cb);
    t
}

pub fn fig11() -> Table {
    let mut t = Table::new(
        "Fig 11: Performance of generated SGEMM kernels by class (T4, K=256)",
        "M=N",
        "GFLOPS",
    );
    t.note("one series per Table-1 preset; `selected` = the heuristic's pick");
    let k = 256;
    for cls in ShapeClass::ALL {
        let mut s = Series::new(cls.name());
        for m in irregular_sizes() {
            s.push(m as f64, preset_gflops(&T4, cls.params(), m, m, k));
        }
        t.add(s);
    }
    let mut sel = Series::new("selected");
    let mut cb = Series::new("cublas");
    for m in irregular_sizes() {
        sel.push(m as f64, generated_gflops(&T4, m, m, k));
        cb.push(m as f64, cublas_gflops(&T4, m, m, k));
    }
    t.add(sel);
    t.add(cb);
    t
}

// ---------------------------------------------------------------------
// Figs 12/13: FT schemes + on/off comparison (T4); Figs 17/18 A100 twins
// ---------------------------------------------------------------------

fn ft_schemes(dev: &DeviceSpec, k_fixed: Option<usize>, title: &str) -> Table {
    let mut t = Table::new(title, if k_fixed.is_some() { "M=N" } else { "M=N=K" }, "GFLOPS");
    let p = hardcoded();
    let variants: [(&str, FtVariant); 4] = [
        ("nonfused", FtVariant::NonFused { ks: 256 }),
        ("thread", FtVariant::Fused(FtLevel::Thread)),
        ("warp", FtVariant::Fused(FtLevel::Warp)),
        ("tb", FtVariant::Fused(FtLevel::Tb)),
    ];
    for (name, v) in variants {
        let mut s = Series::new(name);
        for &size in &SQUARE_SIZES {
            let k = k_fixed.unwrap_or(size);
            s.push(size as f64, preset_ft_gflops(dev, p, size, size, k, v));
        }
        t.add(s);
    }
    t
}

pub fn fig12() -> Vec<Table> {
    vec![
        ft_schemes(&T4, None, "Fig 12a: FT-SGEMM schemes (T4, M=N=K)"),
        ft_schemes(&T4, Some(1024), "Fig 12b: FT-SGEMM schemes (T4, K=1024)"),
    ]
}

fn ft_on_off(dev: &DeviceSpec, k_fixed: Option<usize>, title: &str) -> Table {
    let mut t = Table::new(title, if k_fixed.is_some() { "M=N" } else { "M=N=K" }, "GFLOPS");
    let p = hardcoded();
    let mut cb = Series::new("cublas");
    let mut off = Series::new("fused_ft_off");
    let mut on = Series::new("fused_ft_on");
    let mut nf = Series::new("nonfused_ft");
    for &size in &SQUARE_SIZES {
        let k = k_fixed.unwrap_or(size);
        cb.push(size as f64, cublas_gflops(dev, size, size, k));
        off.push(size as f64, preset_ft_gflops(dev, p, size, size, k, FtVariant::None));
        on.push(size as f64, preset_ft_gflops(dev, p, size, size, k, FtVariant::Fused(FtLevel::Tb)));
        nf.push(size as f64, preset_ft_gflops(dev, p, size, size, k, FtVariant::NonFused { ks: 256 }));
    }
    t.add(cb);
    t.add(off);
    t.add(on);
    t.add(nf);
    t
}

pub fn fig13() -> Vec<Table> {
    vec![
        ft_on_off(&T4, None, "Fig 13a: FT on/off vs cuBLAS (T4, M=N=K)"),
        ft_on_off(&T4, Some(1024), "Fig 13b: FT on/off vs cuBLAS (T4, K=1024)"),
    ]
}

// ---------------------------------------------------------------------
// Figs 14/15: codegen with FT (T4)
// ---------------------------------------------------------------------

pub fn fig14() -> Table {
    let mut t = Table::new(
        "Fig 14: Auto-generated fused FT-SGEMM vs original (T4, K=256)",
        "M=N",
        "GFLOPS",
    );
    t.note("paper: generated FT beats original FT by 165.12%, overhead vs cuBLAS drops 59.23% -> 4.88%");
    let k = 256;
    let tb = FtVariant::Fused(FtLevel::Tb);
    let (mut gen_on, mut hard_on, mut gen_off, mut cb) = (
        Series::new("generated_ft_on"),
        Series::new("hardcoded_ft_on"),
        Series::new("generated_ft_off"),
        Series::new("cublas"),
    );
    for m in irregular_sizes() {
        let cls = select_class(m, m, k);
        gen_on.push(m as f64, preset_ft_gflops(&T4, cls.params(), m, m, k, tb));
        hard_on.push(m as f64, preset_ft_gflops(&T4, hardcoded(), m, m, k, tb));
        gen_off.push(m as f64, preset_ft_gflops(&T4, cls.params(), m, m, k, FtVariant::None));
        cb.push(m as f64, cublas_gflops(&T4, m, m, k));
    }
    t.add(gen_on);
    t.add(hard_on);
    t.add(gen_off);
    t.add(cb);
    t
}

pub fn fig15() -> Table {
    let mut t = Table::new(
        "Fig 15: Generated fused FT-SGEMM kernels by class (T4, K=256)",
        "M=N",
        "GFLOPS",
    );
    t.note("paper: FT generated beats cuBLAS by 7.22-81.95%, non-fused FT by 64.69-287.06%");
    let k = 256;
    let tb = FtVariant::Fused(FtLevel::Tb);
    for cls in ShapeClass::ALL {
        let mut s = Series::new(cls.name());
        for m in irregular_sizes() {
            s.push(m as f64, preset_ft_gflops(&T4, cls.params(), m, m, k, tb));
        }
        t.add(s);
    }
    let (mut cb, mut nf) = (Series::new("cublas"), Series::new("nonfused_ft"));
    for m in irregular_sizes() {
        cb.push(m as f64, cublas_gflops(&T4, m, m, k));
        nf.push(
            m as f64,
            preset_ft_gflops(&T4, hardcoded(), m, m, k, FtVariant::NonFused { ks: 256 }),
        );
    }
    t.add(cb);
    t.add(nf);
    t
}

// ---------------------------------------------------------------------
// Fig 16 / Fig 21: error injection sweeps
// ---------------------------------------------------------------------

fn error_injection(dev: &DeviceSpec, title: &str) -> Table {
    let mut t = Table::new(title, "K (errors = K/256)", "GFLOPS");
    t.note("one SEU injected+corrected per K_s=256 panel, M=N=4096 (the Fig 16 protocol)");
    let (m, n) = (4096, 4096);
    let p = hardcoded();
    // per-corrected-error in-kernel cost: one extra verification sweep's
    // worth of work (~hundreds of cycles) — negligible by design.
    let per_error_s = 2.0e-7;
    let mut cb = Series::new("cublas_no_ft");
    let mut fused = Series::new("fused_ft_inject");
    let mut detect = Series::new("detect_only_inject");
    let mut ding = Series::new("nonfused_ding_inject");
    for k in (256..=10240).step_by(1024) {
        let errors = (k / 256) as f64;
        let flops = 2.0 * m as f64 * n as f64 * k as f64;
        cb.push(k as f64, cublas_gflops(dev, m, n, k));
        let tf = predict_ft(dev, p, m, n, k, FtVariant::Fused(FtLevel::Tb)).time_s
            + errors * per_error_s;
        fused.push(k as f64, flops / tf / 1e9);
        // detect-only must RECOMPUTE on each detection: with one error per
        // panel the naive restart policy would never finish; the paper's
        // offline scheme instead pays a full re-run per detection window.
        let td = predict_ft(dev, p, m, n, k, FtVariant::DetectOnly).time_s * (1.0 + errors.min(1.0));
        detect.push(k as f64, flops / td / 1e9);
        let tn = predict_ft(dev, p, m, n, k, FtVariant::NonFused { ks: 256 }).time_s
            + errors * per_error_s;
        ding.push(k as f64, flops / tn / 1e9);
    }
    t.add(cb);
    t.add(fused);
    t.add(detect);
    t.add(ding);
    t
}

pub fn fig16() -> Table {
    error_injection(&T4, "Fig 16: FT-SGEMM under error injection (T4)")
}

pub fn fig17() -> Vec<Table> {
    vec![
        ft_schemes(&A100, None, "Fig 17a: FT-SGEMM schemes (A100, M=N=K)"),
        ft_schemes(&A100, Some(1024), "Fig 17b: FT-SGEMM schemes (A100, K=1024)"),
    ]
}

pub fn fig18() -> Vec<Table> {
    vec![
        ft_on_off(&A100, None, "Fig 18a: FT on/off vs cuBLAS (A100, M=N=K)"),
        ft_on_off(&A100, Some(1024), "Fig 18b: FT on/off vs cuBLAS (A100, K=1024)"),
    ]
}

pub fn fig19() -> Table {
    let mut t = Table::new(
        "Fig 19: Code generation on an A100 GPU (K=256)",
        "M=N",
        "GFLOPS",
    );
    t.note("paper: generated beats cuBLAS by 22.45% and original by 197.78% at K=256");
    let k = 256;
    let tb = FtVariant::Fused(FtLevel::Tb);
    let (mut gen, mut hard, mut gen_ft, mut hard_ft, mut cb) = (
        Series::new("generated"),
        Series::new("hardcoded"),
        Series::new("generated_ft"),
        Series::new("hardcoded_ft"),
        Series::new("cublas"),
    );
    for m in irregular_sizes() {
        let cls = select_class(m, m, k);
        gen.push(m as f64, preset_gflops(&A100, cls.params(), m, m, k));
        hard.push(m as f64, preset_gflops(&A100, hardcoded(), m, m, k));
        gen_ft.push(m as f64, preset_ft_gflops(&A100, cls.params(), m, m, k, tb));
        hard_ft.push(m as f64, preset_ft_gflops(&A100, hardcoded(), m, m, k, tb));
        cb.push(m as f64, cublas_gflops(&A100, m, m, k));
    }
    t.add(gen);
    t.add(hard);
    t.add(gen_ft);
    t.add(hard_ft);
    t.add(cb);
    t
}

pub fn fig20() -> Table {
    let mut t = Table::new(
        "Fig 20: Generated kernels by class on an A100 GPU (K=256)",
        "M=N",
        "GFLOPS",
    );
    t.note("paper: fused beats non-fused ABFT by 462.56% avg for small-to-huge shapes");
    let k = 256;
    let tb = FtVariant::Fused(FtLevel::Tb);
    for cls in ShapeClass::ALL {
        let mut s = Series::new(cls.name());
        for m in irregular_sizes() {
            s.push(m as f64, preset_ft_gflops(&A100, cls.params(), m, m, k, tb));
        }
        t.add(s);
    }
    let (mut cb, mut nf) = (Series::new("cublas"), Series::new("nonfused_ft"));
    for m in irregular_sizes() {
        cb.push(m as f64, cublas_gflops(&A100, m, m, k));
        nf.push(
            m as f64,
            preset_ft_gflops(&A100, hardcoded(), m, m, k, FtVariant::NonFused { ks: 256 }),
        );
    }
    t.add(cb);
    t.add(nf);
    t
}

pub fn fig21() -> Table {
    error_injection(&A100, "Fig 21: FT-SGEMM under error injection (A100)")
}

// ---------------------------------------------------------------------
// Fig 22: online vs offline ABFT
// ---------------------------------------------------------------------

pub fn fig22() -> Table {
    let mut t = Table::new(
        "Fig 22: Online vs offline ABFT overhead (T4, gamma0 = 1/256)",
        "M=N=K",
        "overhead vs unprotected (%)",
    );
    let p = hardcoded();
    let gamma0 = 1.0 / 256.0;
    let mut on = Series::new("online_abft");
    let mut off = Series::new("offline_abft");
    for s in (256..=6144).step_by(256) {
        on.push(s as f64, analytic::online_overhead_pct(&T4, p, s, s, s));
        off.push(s as f64, analytic::offline_overhead_pct(&T4, p, s, s, s, gamma0));
    }
    if let Some(x) = analytic::crossover_size(&T4, p, gamma0) {
        t.note(format!("online becomes cheaper than offline at M=N=K ≈ {x}"));
    }
    t.add(on);
    t.add(off);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_figure_produces_nonempty_series() {
        let singles: Vec<Table> = vec![
            table1(),
            fig9(),
            fig10(),
            fig11(),
            fig14(),
            fig15(),
            fig16(),
            fig19(),
            fig20(),
            fig21(),
            fig22(),
        ];
        for t in singles.iter().chain(fig12().iter()).chain(fig13().iter())
            .chain(fig17().iter()).chain(fig18().iter())
        {
            assert!(!t.series.is_empty(), "{}", t.title);
            for s in &t.series {
                assert!(!s.x.is_empty(), "{}/{}", t.title, s.name);
                assert!(s.y.iter().all(|y| y.is_finite()), "{}/{}", t.title, s.name);
            }
        }
    }

    #[test]
    fn fig10_generated_dominates_hardcoded_on_small() {
        let t = fig10();
        let gen = t.get("generated").unwrap();
        let hard = t.get("hardcoded").unwrap();
        // at the smallest sizes the generated kernel must win big
        assert!(gen.y[0] > 1.5 * hard.y[0], "{} vs {}", gen.y[0], hard.y[0]);
        // paper: generated beats cuBLAS by 18.21% on average
        let cb = t.get("cublas").unwrap();
        let mean_ratio: f64 = gen
            .y
            .iter()
            .zip(&cb.y)
            .map(|(g, c)| g / c)
            .sum::<f64>()
            / gen.y.len() as f64;
        assert!(mean_ratio > 1.05, "generated/cublas avg {mean_ratio:.3}");
    }

    #[test]
    fn fig12_tb_wins_every_size() {
        for t in fig12() {
            let tb = t.get("tb").unwrap();
            for other in ["nonfused", "thread", "warp"] {
                let o = t.get(other).unwrap();
                for (a, b) in tb.y.iter().zip(&o.y) {
                    assert!(a >= b, "{}: tb {a} < {other} {b}", t.title);
                }
            }
        }
    }

    #[test]
    fn fig16_fused_beats_ding_by_paper_margin() {
        let t = fig16();
        let fused = t.get("fused_ft_inject").unwrap();
        let ding = t.get("nonfused_ding_inject").unwrap();
        let mean_speedup: f64 = fused
            .y
            .iter()
            .zip(&ding.y)
            .map(|(f, d)| f / d - 1.0)
            .sum::<f64>()
            / fused.y.len() as f64;
        // paper: 38.8% average speedup
        assert!((0.20..0.65).contains(&mean_speedup), "{mean_speedup:.3}");
    }

    #[test]
    fn fig22_crossover_in_plausible_range() {
        let t = fig22();
        let on = t.get("online_abft").unwrap();
        let off = t.get("offline_abft").unwrap();
        // offline starts cheaper, ends drastically worse
        assert!(off.y[0] < on.y[0]);
        assert!(off.y.last().unwrap() > on.y.last().unwrap());
    }

    #[test]
    fn fig18_a100_overheads_match_paper_ballpark() {
        let t = &fig18()[0];
        let cb = t.get("cublas").unwrap();
        let ours = t.get("fused_ft_off").unwrap();
        let ft = t.get("fused_ft_on").unwrap();
        // paper: ours 6.29% behind cuBLAS; FT 15.32% behind cuBLAS (M=N=K)
        let ours_gap: f64 =
            cb.y.iter().zip(&ours.y).map(|(c, o)| c / o - 1.0).sum::<f64>() / cb.y.len() as f64;
        let ft_gap: f64 =
            cb.y.iter().zip(&ft.y).map(|(c, o)| c / o - 1.0).sum::<f64>() / cb.y.len() as f64;
        assert!((0.00..0.20).contains(&ours_gap), "{ours_gap:.3}");
        assert!(ft_gap > ours_gap, "{ft_gap:.3} vs {ours_gap:.3}");
    }
}
