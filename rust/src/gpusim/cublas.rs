//! Calibrated stand-in for the closed-source cuBLAS SGEMM.
//!
//! cuBLAS cannot run in this environment (and is a black box in the paper
//! too); it appears in every figure as a baseline curve. We model it as a
//! fraction-of-peak efficiency curve vs effective problem size
//! `(m·n·k)^(1/3)`, log-interpolated over anchor points placed so the
//! *relative* positions the paper reports hold:
//!
//! * T4, large squares: our optimized SGEMM is comparable-or-faster
//!   (Fig 9/13), and the generated kernels beat cuBLAS by 18-28% on
//!   small/irregular shapes (Figs 10/11) — cuBLAS pays its own kernel-
//!   selection and quantization penalties down there.
//! * A100, large squares: cuBLAS leads our SGEMM by 6.29% (Fig 18).

use super::device::DeviceSpec;

/// Anchor table: (effective cube size, fraction of device peak).
///
/// Small/medium anchors are set from the paper's reported margins against
/// the generated kernels (Fig 11: cuBLAS loses 27.23% at 64-112, 76.72%
/// at 160, 7.22% at >=384); large-square anchors from the Fig 9/13
/// relation to our optimized kernel (comparable, we lead slightly).
const T4_CURVE: &[(f64, f64)] = &[
    (16.0, 0.004),
    (32.0, 0.010),
    (64.0, 0.020),
    (100.0, 0.033),
    (133.0, 0.056),
    (161.0, 0.072),
    (187.0, 0.092), // the paper's medium dip: poor internal kernel pick
    (210.0, 0.132),
    (233.0, 0.168),
    (254.0, 0.196),
    (275.0, 0.257),
    (317.0, 0.330),
    (334.0, 0.385),
    (371.0, 0.410),
    (512.0, 0.450),
    (768.0, 0.510),
    (1024.0, 0.540),
    (2048.0, 0.556),
    (4096.0, 0.560),
    (8192.0, 0.560),
];

/// A100: Fig 19 margins (generated +22.45% at K=256 sweeps; cuBLAS leads
/// our SGEMM by 6.29% at full squares).
const A100_CURVE: &[(f64, f64)] = &[
    (16.0, 0.002),
    (32.0, 0.005),
    (64.0, 0.015),
    (96.0, 0.030),
    (128.0, 0.052),
    (160.0, 0.060),
    (192.0, 0.090),
    (256.0, 0.140),
    (384.0, 0.230),
    (512.0, 0.330),
    (768.0, 0.500),
    (1024.0, 0.600),
    (2048.0, 0.700),
    (4096.0, 0.740),
    (8192.0, 0.745),
];

/// Effective cube size of a GEMM.
pub fn effective_size(m: usize, n: usize, k: usize) -> f64 {
    (m as f64 * n as f64 * k as f64).cbrt()
}

fn interp(curve: &[(f64, f64)], x: f64) -> f64 {
    if x <= curve[0].0 {
        return curve[0].1;
    }
    if x >= curve[curve.len() - 1].0 {
        return curve[curve.len() - 1].1;
    }
    for w in curve.windows(2) {
        let (x0, y0) = w[0];
        let (x1, y1) = w[1];
        if x <= x1 {
            // log-x interpolation: sizes span decades
            let t = (x.ln() - x0.ln()) / (x1.ln() - x0.ln());
            return y0 + t * (y1 - y0);
        }
    }
    unreachable!()
}

/// Modeled cuBLAS SGEMM GFLOPS on `dev` for C = A(m,k)·B(k,n).
pub fn cublas_gflops(dev: &DeviceSpec, m: usize, n: usize, k: usize) -> f64 {
    let curve = match dev.name {
        "T4" => T4_CURVE,
        "A100" => A100_CURVE,
        _ => T4_CURVE,
    };
    let eff = interp(curve, effective_size(m, n, k));
    dev.peak_gflops() * eff
}

/// Modeled cuBLAS execution time.
pub fn cublas_time(dev: &DeviceSpec, m: usize, n: usize, k: usize) -> f64 {
    let g = cublas_gflops(dev, m, n, k);
    2.0 * m as f64 * n as f64 * k as f64 / (g * 1e9) + dev.launch_overhead_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::device::{A100, T4};

    #[test]
    fn monotone_over_large_sizes() {
        let mut last = 0.0;
        for s in [256, 512, 1024, 2048, 4096] {
            let g = cublas_gflops(&T4, s, s, s);
            assert!(g > last, "{s}: {g}");
            last = g;
        }
    }

    #[test]
    fn t4_plateau_near_4500() {
        let g = cublas_gflops(&T4, 4096, 4096, 4096);
        assert!((4300.0..4700.0).contains(&g), "{g}");
    }

    #[test]
    fn a100_large_square_leads_t4_by_3x_plus() {
        let t = cublas_gflops(&T4, 4096, 4096, 4096);
        let a = cublas_gflops(&A100, 4096, 4096, 4096);
        assert!(a > 3.0 * t);
    }

    #[test]
    fn small_sizes_are_heavily_penalized() {
        let small = cublas_gflops(&T4, 64, 64, 256);
        let big = cublas_gflops(&T4, 4096, 4096, 4096);
        assert!(small < 0.2 * big);
    }

    #[test]
    fn effective_size_of_cube_is_side() {
        assert!((effective_size(128, 128, 128) - 128.0).abs() < 1e-9);
    }

    #[test]
    fn interpolation_continuous_at_anchors() {
        for &(x, y) in T4_CURVE {
            let g = cublas_gflops(&T4, x as usize, x as usize, x as usize);
            assert!((g - T4.peak_gflops() * y).abs() / (T4.peak_gflops() * y) < 0.05);
        }
    }
}
