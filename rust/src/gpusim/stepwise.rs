//! The §3.1 step-wise optimization ladder as model configurations (Fig 9).
//!
//! Seven steps, each adding one optimization. Paper-measured averages on a
//! Tesla T4 over square sizes 1024..6144 (GFLOPS): 611 → 679 → 3822 →
//! 4331 → 4381 → 4625 → 4654. The calibration test pins the model to
//! those within tolerance; the figure harness regenerates the whole series.

use crate::codegen::params::KernelParams;
use crate::codegen::ShapeClass;

use super::device::DeviceSpec;
use super::kernel_model::{predict, KernelConfig};

/// One rung of the ladder.
#[derive(Debug, Clone, Copy)]
pub struct Step {
    pub name: &'static str,
    pub desc: &'static str,
    /// Paper-measured average GFLOPS on the T4 (Fig 9).
    pub paper_t4_gflops: f64,
    pub config: KernelConfig,
}

/// The naive kernel's launch geometry: one thread per output element in a
/// 16x16 block, full-K streaming (no smem, no k-blocking).
fn naive_params() -> KernelParams {
    KernelParams::new(16, 16, 16, 8, 16, 1, 1)
}

/// One-element-per-thread tiled kernel (§3.1.2 uses a 32x32 tile).
fn tbtile_params() -> KernelParams {
    KernelParams::new(32, 32, 8, 16, 32, 1, 1)
}

/// Build the seven-step ladder for the `huge` preset.
pub fn ladder() -> Vec<Step> {
    let huge = ShapeClass::Huge.params();
    let base = |params, smem, thread, warp, vect, pre_r, pre_s| KernelConfig {
        params,
        smem_tiled: smem,
        thread_tiled: thread,
        warp_tiled: warp,
        vectorized: vect,
        prefetch_reg: pre_r,
        prefetch_smem: pre_s,
    };
    vec![
        Step {
            name: "naive",
            desc: "one thread per element, global-memory streaming",
            paper_t4_gflops: 611.0,
            config: base(naive_params(), false, false, false, false, false, false),
        },
        Step {
            name: "tbtile",
            desc: "threadblock tiling via shared memory",
            paper_t4_gflops: 679.0,
            config: base(tbtile_params(), true, false, false, false, false, false),
        },
        Step {
            name: "threadtile",
            desc: "thread-level (register) tiling, 8x8 micro-tile",
            paper_t4_gflops: 3822.0,
            config: base(huge, true, true, false, false, false, false),
        },
        Step {
            name: "warptile",
            desc: "warp-level tiling: conflict-free smem broadcast",
            paper_t4_gflops: 4331.0,
            config: base(huge, true, true, true, false, false, false),
        },
        Step {
            name: "vectorized",
            desc: "128-bit vectorized load/store",
            paper_t4_gflops: 4381.0,
            config: base(huge, true, true, true, true, false, false),
        },
        Step {
            name: "prefetch_reg",
            desc: "shared->register prefetch pipeline",
            paper_t4_gflops: 4625.0,
            config: base(huge, true, true, true, true, true, false),
        },
        Step {
            name: "prefetch_smem",
            desc: "global->shared double-buffer prefetch",
            paper_t4_gflops: 4654.0,
            config: base(huge, true, true, true, true, true, true),
        },
    ]
}

/// Model average GFLOPS over the paper's size sweep (square 1024..6144).
pub fn average_gflops(dev: &DeviceSpec, cfg: &KernelConfig) -> f64 {
    let sizes = [1024usize, 2048, 3072, 4096, 5120, 6144];
    sizes.iter().map(|&s| predict(dev, cfg, s, s, s).gflops).sum::<f64>() / sizes.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::device::T4;

    #[test]
    fn ladder_is_monotone_on_t4() {
        let mut last = 0.0;
        for step in ladder() {
            let g = average_gflops(&T4, &step.config);
            assert!(
                g > last,
                "{} ({g:.0}) must beat the previous step ({last:.0})",
                step.name
            );
            last = g;
        }
    }

    #[test]
    fn ladder_matches_paper_within_tolerance() {
        // The calibration contract: every step within 12% of the paper's
        // measured average, and the big jump (thread tiling) reproduced.
        for step in ladder() {
            let g = average_gflops(&T4, &step.config);
            let rel = (g - step.paper_t4_gflops).abs() / step.paper_t4_gflops;
            assert!(
                rel < 0.12,
                "{}: model {g:.0} vs paper {:.0} ({:+.1}%)",
                step.name,
                step.paper_t4_gflops,
                rel * 100.0
            );
        }
    }

    #[test]
    fn thread_tiling_is_the_big_jump() {
        let steps = ladder();
        let tb = average_gflops(&T4, &steps[1].config);
        let tt = average_gflops(&T4, &steps[2].config);
        assert!(tt / tb > 4.0, "paper: 4.62x; model {:.2}x", tt / tb);
    }

    #[test]
    fn endpoint_speedup_over_naive_matches_paper() {
        let steps = ladder();
        let first = average_gflops(&T4, &steps[0].config);
        let last = average_gflops(&T4, &steps[6].config);
        let speedup = last / first;
        // paper: 7.62x
        assert!((speedup - 7.62).abs() / 7.62 < 0.2, "{speedup:.2}x");
    }
}
