//! Analytical GPU performance simulator.
//!
//! This environment has no NVIDIA GPU, so every *performance* number in
//! the paper's evaluation is regenerated from a first-principles model of
//! the two devices it used (Tesla T4, A100):
//!
//! * [`device`] — device specs: SM count, clock, FP32 lanes, DRAM/shared
//!   bandwidth, register file, occupancy limits, plus a small set of
//!   per-architecture cost constants **calibrated against the paper's
//!   measured step-wise ladder** (Fig 9) — the model structure is physical
//!   (instruction-issue + bandwidth + occupancy roofline), the constants
//!   are fitted, and `stepwise` tests pin the fit.
//! * [`kernel_model`] — time/GFLOPS prediction for one codegen kernel
//!   configuration: instruction-issue efficiency from the micro-tile shape,
//!   global-memory roofline from the tiling, occupancy and wave
//!   quantization (the effect the Table-1 presets exploit for small
//!   shapes), pipeline-stall factors for the prefetch variants.
//! * [`stepwise`] — the seven §3.1 variants as model configurations
//!   (Fig 9).
//! * [`ft_model`] — overhead model for the fused FT kernels (thread /
//!   warp / threadblock), the detect-only kernel, and the non-fused
//!   Ding'11 baseline with its per-panel kernel launches and C^f
//!   re-read/re-write traffic (Figs 12-21).
//! * [`cublas`] — calibrated fraction-of-peak curves standing in for the
//!   closed-source cuBLAS (the paper also treats it as a black box).
//! * [`analytic`] — the §5.5 online-vs-offline expected-cost model
//!   (Fig 22).
//! * [`serving`] — the worker-count axis: what the engine worker pool buys
//!   on split (oversize) requests served through the plan → schedule →
//!   execute pipeline (BENCH_pipeline.json's model series).

pub mod analytic;
pub mod cublas;
pub mod device;
pub mod ft_model;
pub mod kernel_model;
pub mod serving;
pub mod stepwise;

pub use device::{DeviceSpec, A100, T4};
pub use ft_model::{predict_ft, FtVariant};
pub use kernel_model::{predict, KernelConfig, Prediction};
pub use serving::{pipeline_speedup, pipeline_wall, ServingCost};
