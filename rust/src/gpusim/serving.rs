//! Serving-pipeline model: the worker-count axis of the analytic
//! simulator.
//!
//! The plan → schedule → execute refactor makes split (oversize) GEMMs a
//! set of independent block nodes; this module predicts what the engine
//! worker pool buys on such a request. The model mirrors the scheduler's
//! actual structure:
//!
//! * each block costs one bucket-shaped kernel execution
//!   ([`predict_ft`]) plus the host-side operand extraction that rides on
//!   the dispatching pool thread — these overlap across workers, so they
//!   batch into `ceil(blocks / workers)` **waves**;
//! * partial accumulation happens on the scheduler's completion loop and
//!   serializes, so it scales with `blocks` regardless of pool width.
//!
//! `wall(W) = waves(W) · (t_block + t_extract) + blocks · t_accum`
//!
//! The `hotpath` bench prints this model next to live 1-vs-N-worker
//! measurements (BENCH_pipeline.json); the gap between ideal wave scaling
//! (`blocks / waves`) and the live curve is the host-side serial fraction.

use crate::coordinator::router;

use super::device::DeviceSpec;
use super::ft_model::{predict_ft, FtLevel, FtVariant};

/// Effective host copy bandwidth for extraction/accumulation traffic
/// (GB/s) — a deliberately conservative single-channel memcpy figure.
pub const HOST_COPY_GBS: f64 = 20.0;

/// Cost breakdown of serving one (possibly split) GEMM through the
/// pipeline with a given worker count.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServingCost {
    pub blocks: usize,
    /// Effective parallel width: min(workers, blocks).
    pub width: usize,
    /// ceil(blocks / width) kernel waves.
    pub waves: usize,
    /// Device time of one bucket-shaped block execution.
    pub t_block_s: f64,
    /// Host-side operand extraction per block (overlaps across workers).
    pub t_extract_s: f64,
    /// Host-side partial accumulation per block (serial).
    pub t_accum_s: f64,
    /// Modeled end-to-end wall time.
    pub wall_s: f64,
}

impl ServingCost {
    /// Upper bound on the pool speedup: pure wave scaling.
    pub fn ideal_speedup(&self) -> f64 {
        self.blocks as f64 / self.waves as f64
    }
}

/// Model one request at (m, n, k) with `workers` engine workers.
pub fn pipeline_wall(
    dev: &DeviceSpec,
    m: usize,
    n: usize,
    k: usize,
    online_ft: bool,
    workers: usize,
) -> ServingCost {
    let plan = router::route(m, n, k);
    let blocks = plan.blocks.len();
    let bucket = plan.blocks[0].bucket;
    let params = bucket.class.params();
    let variant = if online_ft { FtVariant::Fused(FtLevel::Tb) } else { FtVariant::None };
    let t_block_s = predict_ft(dev, params, bucket.m, bucket.n, bucket.k, variant).time_s
        + dev.launch_overhead_s;

    let host_bps = HOST_COPY_GBS * 1e9;
    let t_extract_s = ((bucket.m * bucket.k + bucket.k * bucket.n) * 4) as f64 / host_bps;
    // read-modify-write of the output region per k-partial
    let t_accum_s = (2 * bucket.m * bucket.n * 4) as f64 / host_bps;

    let width = workers.max(1).min(blocks);
    let waves = blocks.div_ceil(width);
    let wall_s = waves as f64 * (t_block_s + t_extract_s) + blocks as f64 * t_accum_s;
    ServingCost { blocks, width, waves, t_block_s, t_extract_s, t_accum_s, wall_s }
}

/// Modeled speedup of `workers` over a single worker for one request.
pub fn pipeline_speedup(
    dev: &DeviceSpec,
    m: usize,
    n: usize,
    k: usize,
    online_ft: bool,
    workers: usize,
) -> f64 {
    let one = pipeline_wall(dev, m, n, k, online_ft, 1).wall_s;
    let w = pipeline_wall(dev, m, n, k, online_ft, workers).wall_s;
    one / w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::device::{A100, T4};

    #[test]
    fn single_block_requests_do_not_scale() {
        let c = pipeline_wall(&T4, 128, 128, 128, true, 8);
        assert_eq!((c.blocks, c.waves, c.width), (1, 1, 1));
        assert!((pipeline_speedup(&T4, 128, 128, 128, true, 8) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn split_1024_has_8_blocks_and_near_wave_scaling() {
        let c1 = pipeline_wall(&T4, 1024, 1024, 1024, true, 1);
        let c4 = pipeline_wall(&T4, 1024, 1024, 1024, true, 4);
        assert_eq!(c1.blocks, 8);
        assert_eq!(c1.waves, 8);
        assert_eq!(c4.waves, 2);
        assert!((c4.ideal_speedup() - 4.0).abs() < 1e-12);
        // the serial host accumulation keeps the modeled curve well under
        // the 4x wave bound on a device this fast
        let s = pipeline_speedup(&T4, 1024, 1024, 1024, true, 4);
        assert!(s > 1.3 && s < 4.0, "modeled speedup {s:.2}");
    }

    #[test]
    fn speedup_monotone_and_bounded() {
        let mut last = 0.0;
        for w in [1usize, 2, 3, 4, 8, 16, 64] {
            let s = pipeline_speedup(&A100, 1536, 1536, 1536, false, w);
            assert!(s >= last - 1e-12, "w={w}: {s} < {last}");
            let blocks = pipeline_wall(&A100, 1536, 1536, 1536, false, w).blocks;
            assert!(s <= w.min(blocks) as f64 + 1e-9);
            last = s;
        }
        // 27 blocks cap the pool benefit at 27x
        assert_eq!(pipeline_wall(&A100, 1536, 1536, 1536, false, 64).width, 27);
    }

    #[test]
    fn serial_accumulation_keeps_speedup_below_ideal() {
        let c = pipeline_wall(&T4, 1024, 1024, 1024, true, 8);
        let s = pipeline_speedup(&T4, 1024, 1024, 1024, true, 8);
        assert_eq!(c.waves, 1);
        assert!(s < c.ideal_speedup());
        assert!(c.t_accum_s > 0.0 && c.t_extract_s > 0.0);
    }
}
