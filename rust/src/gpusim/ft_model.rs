//! Overhead model for the fault-tolerant kernel variants (Figs 12-21).
//!
//! The fused schemes cost extra *issue slots* per k-iteration (checksum
//! updates, amortized verification) — modeled through the same
//! instruction-budget formula as the base kernel, with per-level extras:
//!
//! * threadblock level: everything fused into prefetch; a flat, fitted
//!   per-iteration cost (`cal.ft_tb_instr`) covering the online checksum
//!   FMAs + the amortized verification sweep.
//! * warp level: + the two extra shared-memory reads per C_w update the
//!   paper calls out (§4.2.2), `cal.ft_warp_instr`.
//! * thread level: + the *physical* redundant-encoding cost — the paper's
//!   own ratio (4·n_t)/(2·n_t²) = 2/n_t of the FMA budget (§4.2.2) — on
//!   top of the verification cost.
//!
//! The non-fused Ding baseline pays no in-kernel cost but re-reads and
//! re-writes C^f from DRAM every K_s panel and launches 2 extra kernels
//! per panel — pure memory/launch overhead, which is exactly why fusion
//! wins (§2.2, §4).

use crate::codegen::params::KernelParams;

use super::device::DeviceSpec;
use super::kernel_model::{predict_with_extras, KernelConfig, Prediction};

/// The shared FT-granularity enum (re-exported from [`crate::abft`]) —
/// the same type the coordinator's request surface uses, so model
/// predictions and served requests agree on what "warp level" means.
pub use crate::abft::FtLevel;

/// Which protection scheme a prediction is for.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FtVariant {
    /// Unprotected baseline.
    None,
    /// Fused online ABFT (detect + correct in kernel).
    Fused(FtLevel),
    /// Fused detection only (offline ABFT's fast path, §5.5).
    DetectOnly,
    /// Non-fused Ding'11: encoded outer product with K_s panels.
    NonFused { ks: usize },
}

/// Extra issue slots per k-iteration for a fused level.
fn fused_extra_instr(dev: &DeviceSpec, p: &KernelParams, level: FtLevel) -> f64 {
    let c = &dev.cal;
    let fma = (p.m_t * p.n_t) as f64;
    match level {
        FtLevel::Tb => c.ft_tb_instr,
        FtLevel::Warp => c.ft_tb_instr + c.ft_warp_instr,
        FtLevel::Thread => {
            // the paper's own overhead ratio: 2/n_t of the compute
            let physical = fma * 2.0 / p.n_t.min(p.m_t) as f64;
            physical + c.ft_thread_instr
        }
    }
}

/// Checksum maintenance FLOPs for a granularity (adds to the FLOP total;
/// small next to the instruction cost but kept for roofline honesty).
pub fn checksum_flops(m: usize, n: usize, k: usize, sub_m: usize, sub_n: usize) -> f64 {
    let (m, n, k) = (m as f64, n as f64, k as f64);
    let enc = k * (n / sub_n as f64) + k * (m / sub_m as f64);
    let acc = 2.0 * m * k * (n / sub_n as f64) + 2.0 * n * k * (m / sub_m as f64);
    enc + acc
}

/// Predict a protected GEMM on `dev` for tile preset `params`.
pub fn predict_ft(
    dev: &DeviceSpec,
    params: KernelParams,
    m: usize,
    n: usize,
    k: usize,
    variant: FtVariant,
) -> Prediction {
    let cfg = KernelConfig::optimized(params);
    match variant {
        FtVariant::None => predict_with_extras(dev, &cfg, m, n, k, 0.0, 0.0, 0.0),
        FtVariant::Fused(level) => {
            // The checksum work is already counted as issue slots
            // (`fused_extra_instr`) — adding its FLOPs too would double
            // count; `checksum_flops` stays available for roofline reports.
            let extra_i = fused_extra_instr(dev, &params, level);
            predict_with_extras(dev, &cfg, m, n, k, extra_i, 0.0, 0.0)
        }
        FtVariant::DetectOnly => {
            // §5.5: registers for correction released; ~1% residual cost.
            let base = predict_with_extras(dev, &cfg, m, n, k, 0.0, 0.0, 0.0);
            scaled(base, 1.01, m, n, k)
        }
        FtVariant::NonFused { ks } => {
            let ks = ks.max(1).min(k);
            let panels = k.div_ceil(ks);
            // encode kernels: read A and B, write A^c / B^r
            let enc_bytes = 2.0 * ((m * k + k * n) * 4) as f64;
            let t_encode =
                enc_bytes / (dev.dram_bytes_per_sec() * dev.cal.bw_eff_scalar)
                    + dev.launch_overhead_s;
            // The baseline's GEMM itself (Ding '11-era kernel): pays the
            // architecture-gap penalty on newer devices (no LDGSTS / async
            // pipelines — the A100 gap in Fig 17 is dominated by this).
            let base = predict_with_extras(dev, &cfg, m, n, k, 0.0, 0.0, 0.0);
            let t_gemm = base.time_s * dev.cal.ding_kernel_penalty;
            // Non-fused extras are SEPARATE kernels — their C^f traffic
            // (step re-read + re-write, verify re-read) cannot overlap the
            // GEMM, so it adds serially, plus 2 launches per panel.
            let cf_bytes = ((m + 1) * (n + 1) * 4) as f64;
            let extra_traffic = panels as f64 * 3.0 * cf_bytes
                / (dev.dram_bytes_per_sec() * dev.cal.bw_eff_scalar);
            let t = t_gemm
                + t_encode
                + extra_traffic
                + (2 * panels) as f64 * dev.launch_overhead_s;
            Prediction {
                time_s: t,
                gflops: 2.0 * m as f64 * n as f64 * k as f64 / t / 1e9,
                ..base
            }
        }
    }
}

fn scaled(p: Prediction, factor: f64, m: usize, n: usize, k: usize) -> Prediction {
    let t = p.time_s * factor;
    Prediction {
        time_s: t,
        gflops: 2.0 * m as f64 * n as f64 * k as f64 / t / 1e9,
        ..p
    }
}

/// Convenience: relative overhead (%) of a variant vs the unprotected base.
pub fn overhead_pct(
    dev: &DeviceSpec,
    params: KernelParams,
    m: usize,
    n: usize,
    k: usize,
    variant: FtVariant,
) -> f64 {
    let base = predict_ft(dev, params, m, n, k, FtVariant::None);
    let ft = predict_ft(dev, params, m, n, k, variant);
    (ft.time_s / base.time_s - 1.0) * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::ShapeClass;
    use crate::gpusim::device::{A100, T4};

    fn huge() -> KernelParams {
        ShapeClass::Huge.params()
    }

    fn avg_overhead(dev: &DeviceSpec, v: FtVariant) -> f64 {
        let sizes = [1024usize, 2048, 3072, 4096, 5120, 6144];
        sizes.iter().map(|&s| overhead_pct(dev, huge(), s, s, s, v)).sum::<f64>()
            / sizes.len() as f64
    }

    #[test]
    fn t4_level_ordering_matches_paper() {
        // Fig 12: threadblock < warp < thread < non-fused
        let tb = avg_overhead(&T4, FtVariant::Fused(FtLevel::Tb));
        let warp = avg_overhead(&T4, FtVariant::Fused(FtLevel::Warp));
        let thread = avg_overhead(&T4, FtVariant::Fused(FtLevel::Thread));
        let ding = avg_overhead(&T4, FtVariant::NonFused { ks: 256 });
        assert!(tb < warp && warp < thread && thread < ding,
            "tb {tb:.1} warp {warp:.1} thread {thread:.1} ding {ding:.1}");
    }

    #[test]
    fn t4_tb_overhead_near_paper() {
        // Fig 13: FT on/off overhead 11.31% average (8.55-14.85% by shape)
        let tb = avg_overhead(&T4, FtVariant::Fused(FtLevel::Tb));
        assert!((8.0..16.0).contains(&tb), "{tb:.1}%");
    }

    #[test]
    fn t4_tb_beats_nonfused_like_paper() {
        // Fig 12: +25.98% (M=N=K) for tb over non-fused
        let sizes = [1024usize, 2048, 3072, 4096, 5120, 6144];
        let ratio: f64 = sizes
            .iter()
            .map(|&s| {
                let tb = predict_ft(&T4, huge(), s, s, s, FtVariant::Fused(FtLevel::Tb));
                let nf = predict_ft(&T4, huge(), s, s, s, FtVariant::NonFused { ks: 256 });
                nf.time_s / tb.time_s
            })
            .sum::<f64>()
            / sizes.len() as f64;
        assert!((1.15..1.45).contains(&ratio), "{ratio:.3}");
    }

    #[test]
    fn t4_thread_level_overhead_near_25pct() {
        // §4.2.1: thread-level ABFT ≈ 25% average overhead on T4
        let t = avg_overhead(&T4, FtVariant::Fused(FtLevel::Thread));
        assert!((18.0..40.0).contains(&t), "{t:.1}%");
    }

    #[test]
    fn a100_warp_is_nearly_free() {
        // Fig 17: warp within ~1% of tb on A100
        let tb = avg_overhead(&A100, FtVariant::Fused(FtLevel::Tb));
        let warp = avg_overhead(&A100, FtVariant::Fused(FtLevel::Warp));
        assert!(warp - tb < 3.0, "tb {tb:.1} warp {warp:.1}");
    }

    #[test]
    fn a100_nonfused_gap_is_larger_than_t4() {
        // Fig 17: tb beats non-fused by 52.39% on A100 (vs 25.98% on T4):
        // the bandwidth-rich A100 suffers relatively more from the extra
        // passes... no — it suffers more from launch overhead + shorter
        // kernels. Either way the gap must grow.
        let t4_gap = avg_overhead(&T4, FtVariant::NonFused { ks: 256 })
            - avg_overhead(&T4, FtVariant::Fused(FtLevel::Tb));
        let a100_gap = avg_overhead(&A100, FtVariant::NonFused { ks: 256 })
            - avg_overhead(&A100, FtVariant::Fused(FtLevel::Tb));
        assert!(a100_gap > 0.0 && t4_gap > 0.0);
    }

    #[test]
    fn detect_only_is_cheapest() {
        let det = avg_overhead(&T4, FtVariant::DetectOnly);
        let tb = avg_overhead(&T4, FtVariant::Fused(FtLevel::Tb));
        assert!(det < 2.0, "{det:.2}%");
        assert!(det < tb);
    }
}
