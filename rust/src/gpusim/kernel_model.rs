//! Time/GFLOPS prediction for one codegen kernel configuration.
//!
//! The model is a three-component roofline with occupancy:
//!
//! 1. **instruction issue** — per k-iteration a thread executes
//!    `m_t·n_t` FMAs, `m_t+n_t` operand loads (scaled by cost constants,
//!    bank-conflict and vectorization factors), plus loop bookkeeping;
//!    issue efficiency = FMA share of the slot budget, degraded by
//!    pipeline-stall factors when the prefetch stages are disabled.
//! 2. **DRAM roofline** — per-block operand traffic `(m_tb + n_tb)·K·4`
//!    bytes (the reuse the paper's threadblock tiling buys), plus the
//!    C write-back; naive (no-smem) kernels pay a calibrated traffic
//!    multiplier instead.
//! 3. **occupancy / wave quantization** — blocks per SM bounded by shared
//!    memory, registers and thread slots; the final partial wave runs at
//!    reduced utilization. This term is what the Table-1 small-shape
//!    presets optimize (Figs 10/11/14/15/19/20).
//!
//! `t = max(t_issue / wave_eff, t_dram) + launch overhead`.

use crate::codegen::params::KernelParams;

use super::device::DeviceSpec;

/// A concrete kernel configuration the code generator could emit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelConfig {
    pub params: KernelParams,
    /// Operands staged through shared memory (§3.1.2). False = naive.
    pub smem_tiled: bool,
    /// Each thread owns an m_t x n_t micro-tile (§3.1.3). False = 1 elem.
    pub thread_tiled: bool,
    /// Warp tile organized for broadcast/conflict-free smem (§3.1.4).
    pub warp_tiled: bool,
    /// 128-bit vectorized loads/stores (§3.1.5).
    pub vectorized: bool,
    /// Shared→register prefetch pipeline (§3.1.6).
    pub prefetch_reg: bool,
    /// Global→shared double-buffer prefetch (§3.1.7).
    pub prefetch_smem: bool,
}

impl KernelConfig {
    /// The fully-optimized §3.1 endpoint for a parameter preset.
    pub fn optimized(params: KernelParams) -> Self {
        KernelConfig {
            params,
            smem_tiled: true,
            thread_tiled: true,
            warp_tiled: true,
            vectorized: true,
            prefetch_reg: true,
            prefetch_smem: true,
        }
    }
}

/// Model output.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Prediction {
    pub time_s: f64,
    pub gflops: f64,
    /// Issue-limited time (occupancy-adjusted).
    pub t_issue: f64,
    /// DRAM-limited time.
    pub t_dram: f64,
    pub issue_efficiency: f64,
    pub blocks: usize,
    pub blocks_per_sm: usize,
    pub wave_efficiency: f64,
}

/// Occupancy: resident blocks per SM under the three hardware limits.
pub fn blocks_per_sm(dev: &DeviceSpec, cfg: &KernelConfig) -> usize {
    let p = &cfg.params;
    let threads = if cfg.thread_tiled {
        p.threads_per_block()
    } else {
        p.m_tb * p.n_tb
    };
    let threads = threads.max(32);
    let smem = if cfg.smem_tiled {
        let buffers = if cfg.prefetch_smem { 2 } else { 1 };
        buffers * (p.m_tb * p.k_tb + p.k_tb * p.n_tb) * 4
    } else {
        0
    };
    let regs_per_thread = if cfg.thread_tiled { p.regs_per_thread() } else { 24 };
    let by_threads = dev.max_threads_per_sm / threads;
    let by_smem = if smem == 0 { usize::MAX } else { dev.smem_per_sm / smem };
    let by_regs = dev.regs_per_sm / (regs_per_thread * threads);
    by_threads
        .min(by_smem)
        .min(by_regs)
        .min(dev.max_blocks_per_sm)
        .max(1)
}

/// Issue efficiency: FMA share of the per-iteration slot budget, including
/// FT extras via `extra_instr` (0.0 for plain kernels).
pub fn issue_efficiency(dev: &DeviceSpec, cfg: &KernelConfig, extra_instr: f64) -> f64 {
    let c = &dev.cal;
    let p = &cfg.params;
    let (mt, nt) = if cfg.thread_tiled { (p.m_t, p.n_t) } else { (1, 1) };
    let fma = (mt * nt) as f64;
    // 128-bit vectorization does not reduce *data* moved per FMA — its win
    // is pipeline utilization (modeled via stall_no_vectorized below), so
    // the slot count stays per-element.
    let loads = (mt + nt) as f64;
    let ld_cost = if cfg.smem_tiled { c.ld_smem } else { c.ld_global };
    // Bank conflicts bite when threads stride over multi-element fragments
    // without the warp-level layout; the 1-elem/thread kernel's reads are
    // warp-broadcast and conflict-free by construction.
    let conflict = if cfg.smem_tiled && cfg.thread_tiled && !cfg.warp_tiled {
        c.conflict
    } else {
        1.0
    };
    let denom = fma + loads * ld_cost * conflict + c.loop_overhead + extra_instr;
    let mut eff = fma / denom;
    if !cfg.prefetch_reg {
        eff *= c.stall_no_prefetch_reg;
    }
    if !cfg.prefetch_smem {
        eff *= c.stall_no_prefetch_smem;
    }
    if !cfg.vectorized {
        eff *= c.stall_no_vectorized;
    }
    (eff * c.issue_bonus).min(0.95)
}

/// Predict execution of C += A·B with `extra_flops` / `extra_instr` /
/// `extra_bytes` hooks for the FT models.
#[allow(clippy::too_many_arguments)]
pub fn predict_with_extras(
    dev: &DeviceSpec,
    cfg: &KernelConfig,
    m: usize,
    n: usize,
    k: usize,
    extra_instr: f64,
    extra_flops: f64,
    extra_bytes: f64,
) -> Prediction {
    let p = &cfg.params;
    let flops = 2.0 * m as f64 * n as f64 * k as f64 + extra_flops;
    let peak = dev.peak_gflops() * 1e9;

    // --- issue-limited time
    let eff = issue_efficiency(dev, cfg, extra_instr);
    let t_compute = flops / (peak * eff);

    // --- occupancy / waves
    let blocks = m.div_ceil(p.m_tb) * n.div_ceil(p.n_tb);
    let bpsm = blocks_per_sm(dev, cfg);
    // Residency can't exceed the grid itself: a 64-block grid with 8
    // blocks/SM of headroom still only occupies ceil(64/sms) per SM.
    let resident = bpsm.min(blocks.div_ceil(dev.sms)).max(1);
    let concurrent = resident * dev.sms;
    let waves = blocks.div_ceil(concurrent).max(1);
    // Wave quantization, two regimes:
    // * grid smaller than the SM count — whole SMs sit idle; penalty is
    //   near-linear in the busy fraction (this is what the Table-1
    //   small-shape presets fix: more, smaller blocks).
    // * grid covers the SMs — only the final partial wave hurts, and
    //   trailing blocks overlap the next wave's start, so the cliff is
    //   soft (0.3 exponent, fitted).
    let wave_eff = if blocks < dev.sms {
        (blocks as f64 / dev.sms as f64).powf(0.7)
    } else {
        (blocks as f64 / (waves * concurrent) as f64).powf(0.3)
    };
    let t_issue = t_compute / wave_eff;

    // --- DRAM roofline: per-block operand panels; naive kernels stream
    // without smem reuse but the L2 still catches a calibrated fraction.
    let panel_bytes = (blocks * (p.m_tb + p.n_tb) * k * 4) as f64;
    let operand_bytes =
        if cfg.smem_tiled { panel_bytes } else { panel_bytes / dev.cal.naive_traffic };
    let total_bytes = operand_bytes + (m * n * 4) as f64 + extra_bytes;
    let bw_eff = if cfg.vectorized { dev.cal.bw_eff_vector } else { dev.cal.bw_eff_scalar };
    let t_dram = total_bytes / (dev.dram_bytes_per_sec() * bw_eff);

    let time_s = t_issue.max(t_dram) + dev.launch_overhead_s;
    Prediction {
        time_s,
        gflops: 2.0 * m as f64 * n as f64 * k as f64 / time_s / 1e9,
        t_issue,
        t_dram,
        issue_efficiency: eff,
        blocks,
        blocks_per_sm: bpsm,
        wave_efficiency: wave_eff,
    }
}

/// Predict a plain (non-FT) kernel.
pub fn predict(dev: &DeviceSpec, cfg: &KernelConfig, m: usize, n: usize, k: usize) -> Prediction {
    predict_with_extras(dev, cfg, m, n, k, 0.0, 0.0, 0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::ShapeClass;
    use crate::gpusim::device::{A100, T4};

    fn huge() -> KernelConfig {
        KernelConfig::optimized(ShapeClass::Huge.params())
    }

    #[test]
    fn optimized_huge_hits_paper_ballpark_on_t4() {
        // Fig 9 endpoint: 4654 GFLOPS average over 1024^2..6144^2.
        let sizes = [1024, 2048, 3072, 4096, 5120, 6144];
        let avg: f64 = sizes
            .iter()
            .map(|&s| predict(&T4, &huge(), s, s, s).gflops)
            .sum::<f64>()
            / sizes.len() as f64;
        assert!((avg - 4654.0).abs() / 4654.0 < 0.10, "avg {avg}");
    }

    #[test]
    fn occupancy_limits_respected() {
        let b = blocks_per_sm(&T4, &huge());
        // huge: 256 threads, 16 KiB double-buffered smem, 112 regs/thread
        // -> register-bound at 2 blocks/SM
        assert_eq!(b, 2);
        assert!(blocks_per_sm(&A100, &huge()) >= 2);
    }

    #[test]
    fn small_matrices_suffer_wave_quantization() {
        // a 128^2 output is a single 128x128 block: 1 block on 40 SMs
        let p = predict(&T4, &huge(), 128, 128, 256);
        assert!(p.wave_efficiency < 0.3, "{}", p.wave_efficiency);
        let small_cfg = KernelConfig::optimized(ShapeClass::Small.params());
        let q = predict(&T4, &small_cfg, 128, 128, 256);
        assert!(q.wave_efficiency > 1.5 * p.wave_efficiency);
        assert!(q.gflops > p.gflops, "small preset must win on small shapes");
    }

    #[test]
    fn issue_efficiency_monotone_in_microtile() {
        let p = ShapeClass::Huge.params();
        let mut cfg1 = KernelConfig::optimized(p);
        cfg1.thread_tiled = false;
        let e1 = issue_efficiency(&T4, &cfg1, 0.0);
        let e64 = issue_efficiency(&T4, &KernelConfig::optimized(p), 0.0);
        assert!(e64 > 3.0 * e1);
    }

    #[test]
    fn bigger_k_amortizes_launch_overhead() {
        let a = predict(&T4, &huge(), 2048, 2048, 256);
        let b = predict(&T4, &huge(), 2048, 2048, 2048);
        assert!(b.gflops > a.gflops);
    }

    #[test]
    fn a100_beats_t4_everywhere() {
        // small grids can't fill the A100's 108 SMs with huge tiles, so
        // the margin grows with size but never inverts
        for (s, margin) in [(512, 1.0), (1024, 1.3), (4096, 2.0)] {
            let t = predict(&T4, &huge(), s, s, s).gflops;
            let a = predict(&A100, &huge(), s, s, s).gflops;
            assert!(a > margin * t, "{s}: {a} vs {t}");
        }
    }

    #[test]
    fn ft_extra_instr_costs_throughput() {
        let base = predict_with_extras(&T4, &huge(), 4096, 4096, 4096, 0.0, 0.0, 0.0);
        let ft = predict_with_extras(&T4, &huge(), 4096, 4096, 4096, 3.0, 0.0, 0.0);
        assert!(ft.gflops < base.gflops);
        assert!(ft.gflops > 0.8 * base.gflops);
    }
}
