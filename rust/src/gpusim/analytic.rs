//! §5.5 analytics: online vs offline ABFT under an error rate (Fig 22).
//!
//! Offline (detect-only) ABFT is nearly free when nothing goes wrong
//! (~1%), but every detection forces a full recompute, and the recompute
//! itself may fault: expected executions = (1-γ)/(1-2γ) with
//! γ = 1-(1-γ₀)^(tiles). Online ABFT pays a flat in-kernel premium but
//! always finishes in one pass. The crossover in matrix size (for fixed
//! γ₀) is the figure's punchline.

use crate::codegen::params::KernelParams;
use crate::faults::model::{expected_offline_runs, overall_error_rate};

use super::device::DeviceSpec;
use super::ft_model::{predict_ft, FtLevel, FtVariant};

/// Expected relative overhead (%) of ONLINE ABFT vs the unprotected base
/// at (m, n, k) — flat in the error rate (by design).
pub fn online_overhead_pct(
    dev: &DeviceSpec,
    params: KernelParams,
    m: usize,
    n: usize,
    k: usize,
) -> f64 {
    let base = predict_ft(dev, params, m, n, k, FtVariant::None);
    let on = predict_ft(dev, params, m, n, k, FtVariant::Fused(FtLevel::Tb));
    (on.time_s / base.time_s - 1.0) * 100.0
}

/// Expected relative overhead (%) of OFFLINE (detect-only + recompute)
/// ABFT vs base, under per-tile error rate γ₀.
pub fn offline_overhead_pct(
    dev: &DeviceSpec,
    params: KernelParams,
    m: usize,
    n: usize,
    k: usize,
    gamma0: f64,
) -> f64 {
    let base = predict_ft(dev, params, m, n, k, FtVariant::None);
    let det = predict_ft(dev, params, m, n, k, FtVariant::DetectOnly);
    let gamma = overall_error_rate(gamma0, m, n, params.m_tb, params.n_tb);
    // Past γ = 1/2 the restart recursion diverges; cap at a large finite
    // value so figures/JSON stay well-formed (the curve is off the chart
    // either way).
    let runs = if gamma < 0.499 {
        expected_offline_runs(gamma).min(100.0)
    } else {
        100.0
    };
    (det.time_s * runs / base.time_s - 1.0) * 100.0
}

/// The Fig 22 crossover: smallest square size where online beats offline.
pub fn crossover_size(dev: &DeviceSpec, params: KernelParams, gamma0: f64) -> Option<usize> {
    for s in (128..=8192).step_by(128) {
        let on = online_overhead_pct(dev, params, s, s, s);
        let off = offline_overhead_pct(dev, params, s, s, s, gamma0);
        if on < off {
            return Some(s);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::ShapeClass;
    use crate::gpusim::device::T4;

    const GAMMA0: f64 = 1.0 / 256.0; // the paper's Fig 22 setting

    #[test]
    fn online_overhead_is_flat_in_error_rate() {
        let p = ShapeClass::Huge.params();
        let a = online_overhead_pct(&T4, p, 2048, 2048, 2048);
        assert!((5.0..20.0).contains(&a), "{a}");
    }

    #[test]
    fn offline_cheap_when_small_expensive_when_big() {
        let p = ShapeClass::Huge.params();
        let small = offline_overhead_pct(&T4, p, 256, 256, 256, GAMMA0);
        let big = offline_overhead_pct(&T4, p, 6144, 6144, 6144, GAMMA0);
        assert!(small < 5.0, "small {small:.2}%");
        assert!(big > 50.0, "big {big:.2}%");
    }

    #[test]
    fn crossover_exists_at_paper_error_rate() {
        let p = ShapeClass::Huge.params();
        let x = crossover_size(&T4, p, GAMMA0).expect("crossover must exist");
        // offline wins below ~a few hundred, online above
        assert!((128..4096).contains(&x), "{x}");
        let before = offline_overhead_pct(&T4, p, x - 128, x - 128, x - 128, GAMMA0);
        let on_before = online_overhead_pct(&T4, p, x - 128, x - 128, x - 128);
        assert!(before <= on_before + 1e-9);
    }

    #[test]
    fn offline_diverges_at_gamma_half() {
        let p = ShapeClass::Huge.params();
        // γ₀ high enough that a big grid pushes γ past 1/2
        let off = offline_overhead_pct(&T4, p, 8192, 8192, 1024, 0.05);
        assert!(off.is_infinite() || off > 1000.0);
    }
}
