//! Device specifications for the paper's two testbeds.
//!
//! Hardware numbers are public datasheet values; the `cal` block holds the
//! fitted cost constants of the instruction-issue model (calibrated so the
//! Fig 9 step-wise ladder lands within tolerance — see
//! `stepwise::tests::ladder_matches_paper`).

/// Fitted per-architecture cost constants.
#[derive(Debug, Clone, Copy)]
pub struct CostCal {
    /// Issue-slot cost of one shared-memory load (relative to one FMA).
    pub ld_smem: f64,
    /// Issue-slot cost of one global load in a non-tiled (naive) kernel.
    pub ld_global: f64,
    /// Bank-conflict multiplier on smem loads when the warp tile is NOT
    /// organized for broadcast (paper §3.1.4).
    pub conflict: f64,
    /// Per-k-iteration loop/bookkeeping instruction cost.
    pub loop_overhead: f64,
    /// Throughput factor lost to load-use stalls without the
    /// shared→register prefetch (§3.1.6).
    pub stall_no_prefetch_reg: f64,
    /// ... without the global→shared double buffer (§3.1.7).
    pub stall_no_prefetch_smem: f64,
    /// ... without 128-bit vectorized access (§3.1.5).
    pub stall_no_vectorized: f64,
    /// Architecture-wide issue bonus (dual-issue, LDGSTS, etc.).
    pub issue_bonus: f64,
    /// Effective DRAM bandwidth fraction for scalar / vectorized access.
    pub bw_eff_scalar: f64,
    pub bw_eff_vector: f64,
    /// Traffic multiplier for the naive (no-smem) kernel after L2 reuse.
    pub naive_traffic: f64,
    // --- fused-ABFT instruction costs (per k-iteration, issue slots) ---
    /// Checksum-update FMA/reduction cost at threadblock granularity.
    pub ft_tb_instr: f64,
    /// Additional per-iteration cost at warp granularity (the two extra
    /// smem reads per C_w update, §4.2.2).
    pub ft_warp_instr: f64,
    /// Additional per-iteration cost at thread granularity (per-thread
    /// redundant encodings, §4.2.1).
    pub ft_thread_instr: f64,
    /// Slowdown of the Ding'11-era baseline GEMM kernel on this
    /// architecture (legacy kernels don't exploit newer pipelines).
    pub ding_kernel_penalty: f64,
}

/// One GPU model.
#[derive(Debug, Clone, Copy)]
pub struct DeviceSpec {
    pub name: &'static str,
    pub sms: usize,
    pub clock_ghz: f64,
    /// FP32 lanes (CUDA cores) per SM.
    pub fp32_per_sm: usize,
    pub dram_gbs: f64,
    /// Shared-memory bytes per SM usable by one kernel.
    pub smem_per_sm: usize,
    pub regs_per_sm: usize,
    pub max_threads_per_sm: usize,
    pub max_blocks_per_sm: usize,
    /// Kernel launch overhead, seconds.
    pub launch_overhead_s: f64,
    pub cal: CostCal,
}

impl DeviceSpec {
    /// Peak FP32 GFLOPS (FMA = 2 flops).
    pub fn peak_gflops(&self) -> f64 {
        self.sms as f64 * self.fp32_per_sm as f64 * 2.0 * self.clock_ghz
    }

    pub fn dram_bytes_per_sec(&self) -> f64 {
        self.dram_gbs * 1e9
    }
}

/// NVIDIA Tesla T4 (Turing TU104): 40 SMs @ 1.59 GHz, 64 FP32/SM
/// → 8.1 TFLOPS peak; 320 GB/s GDDR6.
pub const T4: DeviceSpec = DeviceSpec {
    name: "T4",
    sms: 40,
    clock_ghz: 1.59,
    fp32_per_sm: 64,
    dram_gbs: 320.0,
    smem_per_sm: 64 * 1024,
    regs_per_sm: 65536,
    max_threads_per_sm: 1024,
    max_blocks_per_sm: 16,
    launch_overhead_s: 4.0e-6,
    cal: CostCal {
        ld_smem: 1.1,
        ld_global: 1.6,
        conflict: 1.9,
        loop_overhead: 6.0,
        stall_no_prefetch_reg: 0.9472,
        stall_no_prefetch_smem: 0.9937,
        stall_no_vectorized: 0.9887,
        issue_bonus: 0.817,
        bw_eff_scalar: 0.78,
        bw_eff_vector: 0.92,
        naive_traffic: 0.60,
        ft_tb_instr: 8.5,
        ft_warp_instr: 5.5,
        ft_thread_instr: 10.0,
        ding_kernel_penalty: 1.0,
    },
};

/// NVIDIA A100 (Ampere GA100, 40 GB SXM): 108 SMs @ 1.41 GHz, 64 FP32/SM
/// → 19.5 TFLOPS peak; 1555 GB/s HBM2.
pub const A100: DeviceSpec = DeviceSpec {
    name: "A100",
    sms: 108,
    clock_ghz: 1.41,
    fp32_per_sm: 64,
    dram_gbs: 1555.0,
    smem_per_sm: 164 * 1024,
    regs_per_sm: 65536,
    max_threads_per_sm: 2048,
    max_blocks_per_sm: 32,
    launch_overhead_s: 3.5e-6,
    cal: CostCal {
        // Ampere: LDGSTS + wider LSU make loads cheaper; warp-level FT is
        // nearly free (Fig 17: warp within 1% of tb), thread-level still
        // pays its redundant encodings.
        ld_smem: 1.05,
        ld_global: 1.5,
        conflict: 1.8,
        loop_overhead: 5.0,
        stall_no_prefetch_reg: 0.950,
        stall_no_prefetch_smem: 0.994,
        stall_no_vectorized: 0.989,
        issue_bonus: 0.98,
        bw_eff_scalar: 0.80,
        bw_eff_vector: 0.93,
        naive_traffic: 0.55,
        ft_tb_instr: 7.3,
        ft_warp_instr: 0.7,
        ft_thread_instr: 29.3,
        ding_kernel_penalty: 1.35,
    },
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peaks_match_datasheets() {
        assert!((T4.peak_gflops() - 8140.8).abs() < 1.0);
        assert!((A100.peak_gflops() - 19491.8).abs() < 1.0);
    }

    #[test]
    fn a100_is_strictly_bigger() {
        assert!(A100.peak_gflops() > 2.0 * T4.peak_gflops());
        assert!(A100.dram_gbs > 4.0 * T4.dram_gbs);
        assert!(A100.smem_per_sm > T4.smem_per_sm);
    }

    #[test]
    fn calibration_constants_sane() {
        for d in [T4, A100] {
            let c = d.cal;
            assert!(c.ld_smem < c.ld_global, "{}", d.name);
            assert!(c.conflict >= 1.0);
            assert!((0.5..=1.0).contains(&c.issue_bonus));
            assert!(c.stall_no_prefetch_reg < 1.0);
            // warp adds cost on top of tb; thread-level is the priciest
            assert!(c.ft_warp_instr > 0.0);
            assert!(c.ft_thread_instr > c.ft_warp_instr);
        }
    }
}
