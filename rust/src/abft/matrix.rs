//! Row-major dense f32 matrix with a blocked CPU GEMM.
//!
//! This is the host-side numeric substrate: the recompute path of offline
//! ABFT, the oracle for integration tests, and the padding/slicing helper
//! the router uses to fit requests into artifact buckets.

use crate::util::rng::Pcg32;

#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Uniform in [-0.5, 0.5) — the distribution the python tests use.
    pub fn rand_uniform(rows: usize, cols: usize, seed: u64) -> Self {
        let mut rng = Pcg32::seeded(seed);
        let data = (0..rows * cols).map(|_| rng.f32() - 0.5).collect();
        Matrix { rows, cols, data }
    }

    /// Standard-normal entries.
    pub fn randn(rows: usize, cols: usize, seed: u64) -> Self {
        let mut rng = Pcg32::seeded(seed);
        let data = (0..rows * cols).map(|_| rng.normal()).collect();
        Matrix { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn add_at(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] += v;
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    // ------------------------------------------------------------------
    // GEMM: naive witness + cache-blocked production version
    // ------------------------------------------------------------------

    /// Textbook triple loop — the unarguable oracle (tests only).
    pub fn matmul_naive(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.rows, "inner dims");
        let mut c = Matrix::zeros(self.rows, b.cols);
        for i in 0..self.rows {
            for j in 0..b.cols {
                let mut acc = 0.0f32;
                for k in 0..self.cols {
                    acc += self.at(i, k) * b.at(k, j);
                }
                c.set(i, j, acc);
            }
        }
        c
    }

    /// Cache-blocked i-k-j GEMM — the host recompute path. Blocking keeps
    /// the B panel hot in L1/L2; the k-inner accumulation order matches the
    /// kernels' (panel sums), keeping drift comparable.
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.rows, "inner dims");
        const BK: usize = 64;
        const BJ: usize = 256;
        let (m, k, n) = (self.rows, self.cols, b.cols);
        let mut c = Matrix::zeros(m, n);
        for k0 in (0..k).step_by(BK) {
            let k1 = (k0 + BK).min(k);
            for j0 in (0..n).step_by(BJ) {
                let j1 = (j0 + BJ).min(n);
                for i in 0..m {
                    let crow = &mut c.data[i * n..(i + 1) * n];
                    for kk in k0..k1 {
                        let aik = self.data[i * k + kk];
                        if aik == 0.0 {
                            continue;
                        }
                        let brow = &b.data[kk * n..kk * n + n];
                        for j in j0..j1 {
                            crow[j] += aik * brow[j];
                        }
                    }
                }
            }
        }
        c
    }

    // ------------------------------------------------------------------
    // Shape plumbing for the router
    // ------------------------------------------------------------------

    /// Zero-pad to `(rows, cols)` (no-op when already that shape).
    /// Zero padding is exact for GEMM and for checksum algebra (padded
    /// rows/cols contribute 0 to every sum).
    pub fn pad_to(&self, rows: usize, cols: usize) -> Matrix {
        assert!(rows >= self.rows && cols >= self.cols, "pad must grow");
        if rows == self.rows && cols == self.cols {
            return self.clone();
        }
        let mut out = Matrix::zeros(rows, cols);
        for i in 0..self.rows {
            out.data[i * cols..i * cols + self.cols]
                .copy_from_slice(self.row(i));
        }
        out
    }

    /// Extract the top-left `(rows, cols)` block.
    pub fn slice_to(&self, rows: usize, cols: usize) -> Matrix {
        assert!(rows <= self.rows && cols <= self.cols, "slice must shrink");
        if rows == self.rows && cols == self.cols {
            return self.clone();
        }
        let mut out = Matrix::zeros(rows, cols);
        for i in 0..rows {
            out.data[i * cols..(i + 1) * cols]
                .copy_from_slice(&self.data[i * self.cols..i * self.cols + cols]);
        }
        out
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.set(j, i, self.at(i, j));
            }
        }
        out
    }

    pub fn row_sums(&self) -> Vec<f32> {
        (0..self.rows).map(|i| self.row(i).iter().sum()).collect()
    }

    pub fn col_sums(&self) -> Vec<f32> {
        let mut sums = vec![0.0f32; self.cols];
        for i in 0..self.rows {
            for (s, v) in sums.iter_mut().zip(self.row(i)) {
                *s += v;
            }
        }
        sums
    }

    /// max |a - b| over all elements (shape-checked).
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn naive_matmul_identity() {
        let a = Matrix::rand_uniform(5, 5, 1);
        let id = Matrix::from_fn(5, 5, |i, j| (i == j) as u8 as f32);
        assert_eq!(a.matmul_naive(&id), a);
    }

    #[test]
    fn blocked_matches_naive() {
        for (m, k, n, seed) in [(7, 13, 9, 1), (64, 64, 64, 2), (33, 100, 65, 3), (1, 300, 2, 4)] {
            let a = Matrix::rand_uniform(m, k, seed);
            let b = Matrix::rand_uniform(k, n, seed + 100);
            let diff = a.matmul(&b).max_abs_diff(&a.matmul_naive(&b));
            assert!(diff < 1e-3, "({m},{k},{n}) diff {diff}");
        }
    }

    #[test]
    fn pad_then_matmul_equals_matmul_then_pad() {
        let a = Matrix::rand_uniform(10, 12, 5);
        let b = Matrix::rand_uniform(12, 8, 6);
        let c = a.matmul(&b);
        let cp = a.pad_to(16, 16).matmul(&b.pad_to(16, 16));
        assert!(cp.slice_to(10, 8).max_abs_diff(&c) < 1e-4);
        // padded region must be exactly zero
        for i in 0..16 {
            for j in 0..16 {
                if i >= 10 || j >= 8 {
                    assert_eq!(cp.at(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn slice_inverts_pad() {
        let a = Matrix::rand_uniform(9, 11, 7);
        assert_eq!(a.pad_to(20, 30).slice_to(9, 11), a);
    }

    #[test]
    #[should_panic]
    fn pad_cannot_shrink() {
        Matrix::zeros(4, 4).pad_to(2, 8);
    }

    #[test]
    fn sums_and_transpose() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.row_sums(), vec![6.0, 15.0]);
        assert_eq!(a.col_sums(), vec![5.0, 7.0, 9.0]);
        assert_eq!(a.transpose().at(2, 1), 6.0);
    }

    #[test]
    fn deterministic_rand() {
        assert_eq!(Matrix::rand_uniform(4, 4, 9), Matrix::rand_uniform(4, 4, 9));
        assert_ne!(Matrix::rand_uniform(4, 4, 9), Matrix::rand_uniform(4, 4, 10));
    }
}
