//! Host-side ABFT library: dense matrices, the Huang–Abraham checksum
//! algebra (encode / verify / locate / correct), and SEU injection.
//!
//! Two consumers:
//! * the coordinator's **offline path** — detect-only kernels report a
//!   fault, the host verifies/recomputes here;
//! * **defense in depth** — after every FT execution the host can re-verify
//!   the returned `C` against the kernel's carried checksums (the `cr`/`cc`
//!   outputs) without touching the operands again.
//!
//! Everything is plain rust over row-major `Vec<f32>`; the pure-rust GEMM
//! in [`matrix`] is the CPU witness used by tests and the recompute path.
//!
//! This module also owns [`FtLevel`] — the paper's three checksum
//! placements — as the single shared type: the coordinator's request
//! surface, the gpusim overhead model, and the execution backends all
//! re-export it from here.

pub mod checksum;
pub mod injection;
pub mod matrix;

pub use checksum::{ChecksumPair, Detection, Thresholds};
pub use injection::{Injection, InjectionPlan};
pub use matrix::Matrix;

use std::fmt;
use std::str::FromStr;

use anyhow::anyhow;

/// FT granularity of a fused kernel (the paper's three checksum
/// placements). Buckets lowered without the requested level fall back to
/// [`FtLevel::Tb`], which every FT bucket carries.
///
/// The one `Tb`/`Warp`/`Thread` enum of the system: the coordinator
/// (request options, config, CLI), the gpusim overhead model
/// ([`crate::gpusim::ft_model::FtVariant`]) and the host backends'
/// checksum-granularity mapping all share this type.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum FtLevel {
    /// Thread-block-level checksums (always present).
    #[default]
    Tb,
    /// Warp-level checksums.
    Warp,
    /// Thread-level checksums.
    Thread,
}

impl FtLevel {
    pub const ALL: [FtLevel; 3] = [FtLevel::Tb, FtLevel::Warp, FtLevel::Thread];

    /// The manifest/artifact spelling (`"tb" | "warp" | "thread"`).
    pub fn as_str(&self) -> &'static str {
        match self {
            FtLevel::Tb => "tb",
            FtLevel::Warp => "warp",
            FtLevel::Thread => "thread",
        }
    }

    /// Alias for [`FtLevel::as_str`] (the gpusim model's historical
    /// spelling).
    pub fn name(&self) -> &'static str {
        self.as_str()
    }
}

impl fmt::Display for FtLevel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl FromStr for FtLevel {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<FtLevel> {
        match s {
            "tb" => Ok(FtLevel::Tb),
            "warp" => Ok(FtLevel::Warp),
            "thread" => Ok(FtLevel::Thread),
            other => Err(anyhow!("unknown FT level {other:?} (tb|warp|thread)")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ft_level_is_the_shared_type() {
        for level in FtLevel::ALL {
            assert_eq!(level.as_str().parse::<FtLevel>().unwrap(), level);
            assert_eq!(level.name(), level.as_str());
            assert_eq!(format!("{level}"), level.as_str());
        }
        assert_eq!(FtLevel::default(), FtLevel::Tb);
        assert!("threadblock".parse::<FtLevel>().is_err());
    }
}
