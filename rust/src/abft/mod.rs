//! Host-side ABFT library: dense matrices, the Huang–Abraham checksum
//! algebra (encode / verify / locate / correct), and SEU injection.
//!
//! Two consumers:
//! * the coordinator's **offline path** — detect-only kernels report a
//!   fault, the host verifies/recomputes here;
//! * **defense in depth** — after every FT execution the host can re-verify
//!   the returned `C` against the kernel's carried checksums (the `cr`/`cc`
//!   outputs) without touching the operands again.
//!
//! Everything is plain rust over row-major `Vec<f32>`; the pure-rust GEMM
//! in [`matrix`] is the CPU witness used by tests and the recompute path.

pub mod checksum;
pub mod injection;
pub mod matrix;

pub use checksum::{ChecksumPair, Detection, Thresholds};
pub use injection::{Injection, InjectionPlan};
pub use matrix::Matrix;
