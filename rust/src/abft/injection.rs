//! SEU injection plans — the §5.3 protocol as data.
//!
//! An [`Injection`] is one additive offset at a global (row, col) of the
//! output, applied at a given k-step of the accumulation. Plans are
//! marshalled into the fused kernels' `(MAX_INJ, 4)` input tensor, or
//! applied host-side for the non-fused Ding baseline.

use crate::util::rng::Pcg32;

use super::matrix::Matrix;

/// Matches the kernel-side descriptor row `[row, col, step, magnitude]`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Injection {
    pub row: usize,
    pub col: usize,
    pub step: usize,
    pub magnitude: f32,
}

/// A batch of injections for one GEMM execution.
#[derive(Debug, Clone, Default)]
pub struct InjectionPlan {
    pub injections: Vec<Injection>,
}

impl InjectionPlan {
    pub fn none() -> Self {
        Self::default()
    }

    pub fn single(row: usize, col: usize, step: usize, magnitude: f32) -> Self {
        InjectionPlan { injections: vec![Injection { row, col, step, magnitude }] }
    }

    pub fn len(&self) -> usize {
        self.injections.len()
    }

    pub fn is_empty(&self) -> bool {
        self.injections.is_empty()
    }

    /// Random plan: `count` errors spread over the k dimension, emulating
    /// the paper's "errors evenly injected into random threads throughout
    /// the computation". Magnitudes are bit-flip-like: large, either sign.
    ///
    /// NOTE: positions are unconstrained — two errors may share a (tile,
    /// verification interval), violating SEU. Use [`Self::random_seu`]
    /// when the protection scheme must be able to correct everything.
    pub fn random(
        m: usize,
        n: usize,
        steps: usize,
        count: usize,
        rng: &mut Pcg32,
    ) -> Self {
        let mut injections = Vec::with_capacity(count);
        for i in 0..count {
            let base = (i * steps) / count.max(1);
            let step = base.min(steps.saturating_sub(1));
            injections.push(Injection {
                row: rng.usize_below(m),
                col: rng.usize_below(n),
                step,
                magnitude: bitflip_magnitude(rng),
            });
        }
        InjectionPlan { injections }
    }

    /// Random plan honoring the SEU fault model (paper §4.1): at most one
    /// error per (protection sub-tile, verification interval), so an
    /// online scheme at granularity `(sub_m, sub_n)` with interval
    /// `verify_every` can correct every injected fault. Positions are
    /// rejection-sampled against that constraint.
    #[allow(clippy::too_many_arguments)]
    pub fn random_seu(
        m: usize,
        n: usize,
        steps: usize,
        verify_every: usize,
        sub_m: usize,
        sub_n: usize,
        count: usize,
        rng: &mut Pcg32,
    ) -> Self {
        let intervals = steps.div_ceil(verify_every.max(1)).max(1);
        let domains = (m.div_ceil(sub_m)) * (n.div_ceil(sub_n)) * intervals;
        assert!(
            count <= domains,
            "cannot place {count} SEUs in {domains} (tile x interval) domains"
        );
        let mut used = std::collections::HashSet::new();
        let mut injections = Vec::with_capacity(count);
        for i in 0..count {
            let mut tries = 0usize;
            loop {
                // even k-spacing first; fall back to random steps if the
                // preferred interval's tiles are exhausted
                let step = if tries < 64 {
                    ((i * steps) / count.max(1)).min(steps.saturating_sub(1))
                } else {
                    rng.usize_below(steps.max(1))
                };
                let row = rng.usize_below(m);
                let col = rng.usize_below(n);
                let key = (row / sub_m, col / sub_n, step / verify_every.max(1));
                if used.insert(key) {
                    injections.push(Injection {
                        row,
                        col,
                        step,
                        magnitude: bitflip_magnitude(rng),
                    });
                    break;
                }
                tries += 1;
            }
        }
        InjectionPlan { injections }
    }

    /// Serialize to the kernel input layout: `(max_inj, 4)` f32, zero-padded.
    /// Panics if the plan exceeds `max_inj` (callers chunk instead).
    pub fn to_tensor(&self, max_inj: usize) -> Vec<f32> {
        assert!(
            self.injections.len() <= max_inj,
            "plan ({}) exceeds kernel capacity ({max_inj})",
            self.injections.len()
        );
        let mut t = vec![0.0f32; max_inj * 4];
        for (i, inj) in self.injections.iter().enumerate() {
            t[i * 4] = inj.row as f32;
            t[i * 4 + 1] = inj.col as f32;
            t[i * 4 + 2] = inj.step as f32;
            t[i * 4 + 3] = inj.magnitude;
        }
        t
    }

    /// Apply all offsets directly to a result matrix (host-side injection
    /// for the non-fused baseline, where the fault hits C^f between
    /// launches).
    pub fn apply_to(&self, c: &mut Matrix) {
        for inj in &self.injections {
            c.add_at(inj.row, inj.col, inj.magnitude);
        }
    }

    /// Split into chunks of at most `max_inj` (the kernel capacity), one
    /// chunk per execution.
    pub fn chunks(&self, max_inj: usize) -> Vec<InjectionPlan> {
        self.injections
            .chunks(max_inj)
            .map(|c| InjectionPlan { injections: c.to_vec() })
            .collect()
    }
}

/// Bit-flip-emulating magnitude: log-uniform in [16, 2^20), random sign —
/// a flipped mantissa/exponent bit yields offsets across orders of
/// magnitude, always far above the detection threshold.
pub fn bitflip_magnitude(rng: &mut Pcg32) -> f32 {
    let exp = rng.range_f32(4.0, 20.0);
    let sign = if rng.below(2) == 0 { 1.0 } else { -1.0 };
    sign * 2f32.powf(exp)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_layout_roundtrips() {
        let plan = InjectionPlan::single(3, 7, 2, -64.0);
        let t = plan.to_tensor(8);
        assert_eq!(t.len(), 32);
        assert_eq!(&t[0..4], &[3.0, 7.0, 2.0, -64.0]);
        assert!(t[4..].iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic]
    fn overflowing_plan_panics() {
        let plan = InjectionPlan {
            injections: vec![Injection { row: 0, col: 0, step: 0, magnitude: 1.0 }; 9],
        };
        plan.to_tensor(8);
    }

    #[test]
    fn random_plan_in_bounds_and_spread() {
        let mut rng = Pcg32::seeded(1);
        let plan = InjectionPlan::random(100, 50, 16, 8, &mut rng);
        assert_eq!(plan.len(), 8);
        for inj in &plan.injections {
            assert!(inj.row < 100 && inj.col < 50 && inj.step < 16);
            assert!(inj.magnitude.abs() >= 16.0);
        }
        // even spacing => steps non-decreasing and covering the range
        let steps: Vec<_> = plan.injections.iter().map(|i| i.step).collect();
        assert!(steps.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn apply_to_adds_offsets() {
        let mut c = Matrix::zeros(4, 4);
        InjectionPlan::single(1, 2, 0, 5.0).apply_to(&mut c);
        assert_eq!(c.at(1, 2), 5.0);
        assert_eq!(c.at(2, 1), 0.0);
    }

    #[test]
    fn chunking_preserves_order_and_content() {
        let plan = InjectionPlan {
            injections: (0..19)
                .map(|i| Injection { row: i, col: i, step: i, magnitude: i as f32 + 1.0 })
                .collect(),
        };
        let chunks = plan.chunks(8);
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[0].len(), 8);
        assert_eq!(chunks[2].len(), 3);
        let flat: Vec<_> = chunks.iter().flat_map(|c| c.injections.clone()).collect();
        assert_eq!(flat, plan.injections);
    }

    #[test]
    fn bitflip_magnitudes_are_large_both_signs() {
        let mut rng = Pcg32::seeded(2);
        let mags: Vec<f32> = (0..200).map(|_| bitflip_magnitude(&mut rng)).collect();
        assert!(mags.iter().all(|m| m.abs() >= 16.0 && m.abs() < 2f32.powi(20)));
        assert!(mags.iter().any(|m| *m > 0.0) && mags.iter().any(|m| *m < 0.0));
    }
}
