//! The Huang–Abraham checksum algebra on the host (paper §2.2).
//!
//! Used by the offline-ABFT policy (verify a detect-only kernel's output),
//! by the host re-verification of fused-kernel results, and as the oracle
//! in integration tests.

use super::matrix::Matrix;

/// Row/column checksums of a (true) product: `cr = C·e`, `cc = eᵀ·C`.
#[derive(Debug, Clone)]
pub struct ChecksumPair {
    pub cr: Vec<f32>,
    pub cc: Vec<f32>,
}

impl ChecksumPair {
    /// Compute both checksums of a matrix directly.
    pub fn of(c: &Matrix) -> Self {
        ChecksumPair { cr: c.row_sums(), cc: c.col_sums() }
    }

    /// Derive the product checksums from the *operands* without forming C:
    /// `C·e = A·(B·e)`, `eᵀ·C = (eᵀ·A)·B` — O(mk + kn) instead of O(mkn).
    /// This is exactly what the fused kernels maintain online.
    pub fn of_product(a: &Matrix, b: &Matrix) -> Self {
        assert_eq!(a.cols(), b.rows());
        let be = b.row_sums(); // (k)
        let ea = a.col_sums(); // (k)
        let mut cr = vec![0.0f32; a.rows()];
        for i in 0..a.rows() {
            cr[i] = a.row(i).iter().zip(&be).map(|(x, y)| x * y).sum();
        }
        let mut cc = vec![0.0f32; b.cols()];
        for (k, &w) in ea.iter().enumerate() {
            if w == 0.0 {
                continue;
            }
            for (c, v) in cc.iter_mut().zip(b.row(k)) {
                *c += w * v;
            }
        }
        ChecksumPair { cr, cc }
    }
}

/// Detection thresholds: residuals compared against
/// `rel * (|recomputed| + |carried|) + abs` (matches the kernel template).
#[derive(Debug, Clone, Copy)]
pub struct Thresholds {
    pub rel: f32,
    pub abs: f32,
}

impl Default for Thresholds {
    fn default() -> Self {
        Thresholds { rel: 1e-4, abs: 1e-3 }
    }
}

/// Outcome of a verification pass.
#[derive(Debug, Clone, PartialEq)]
pub enum Detection {
    /// Checksums consistent — no error.
    Clean,
    /// A single error located at (row, col) with the given magnitude
    /// (subtract it to correct).
    Single { row: usize, col: usize, magnitude: f32 },
    /// Residuals inconsistent with the single-error model (multiple faults
    /// in one verification interval — SEU assumption violated).
    MultiError { bad_rows: usize, bad_cols: usize },
}

/// Verify `c` against carried checksums; locate a single error if present.
pub fn verify(c: &Matrix, carried: &ChecksumPair, th: Thresholds) -> Detection {
    assert_eq!(c.rows(), carried.cr.len());
    assert_eq!(c.cols(), carried.cc.len());
    let rs = c.row_sums();
    let cs = c.col_sums();
    let mut bad_rows = Vec::new();
    for i in 0..c.rows() {
        let resid = rs[i] - carried.cr[i];
        let scale: f32 = c.row(i).iter().map(|x| x.abs()).sum::<f32>() + carried.cr[i].abs();
        if resid.abs() > th.rel * scale + th.abs {
            bad_rows.push((i, resid));
        }
    }
    let mut abs_col = vec![0.0f32; c.cols()];
    for i in 0..c.rows() {
        for (s, v) in abs_col.iter_mut().zip(c.row(i)) {
            *s += v.abs();
        }
    }
    let mut bad_cols = Vec::new();
    for j in 0..c.cols() {
        let resid = cs[j] - carried.cc[j];
        if resid.abs() > th.rel * (abs_col[j] + carried.cc[j].abs()) + th.abs {
            bad_cols.push((j, resid));
        }
    }
    match (bad_rows.len(), bad_cols.len()) {
        (0, 0) => Detection::Clean,
        (1, 1) => Detection::Single {
            row: bad_rows[0].0,
            col: bad_cols[0].0,
            magnitude: bad_rows[0].1,
        },
        (r, c_) => {
            // Column residual might be sub-threshold while the row fires
            // (or vice versa) on a borderline offset — treat any (>=1, 0)
            // pattern as multi/inconsistent so callers recompute.
            Detection::MultiError { bad_rows: r, bad_cols: c_ }
        }
    }
}

/// Correct a located single error in place. Returns the corrected value.
pub fn correct(c: &mut Matrix, det: &Detection) -> Option<f32> {
    if let Detection::Single { row, col, magnitude } = det {
        c.add_at(*row, *col, -magnitude);
        Some(c.at(*row, *col))
    } else {
        None
    }
}

/// Full offline pass: verify, correct if a single error, report.
/// Returns (corrected count, residual detection state after the pass).
pub fn verify_and_correct(c: &mut Matrix, carried: &ChecksumPair, th: Thresholds) -> (usize, Detection) {
    match verify(c, carried, th) {
        Detection::Clean => (0, Detection::Clean),
        det @ Detection::Single { .. } => {
            correct(c, &det);
            (1, verify(c, carried, th))
        }
        det @ Detection::MultiError { .. } => (0, det),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn product_fixture(seed: u64) -> (Matrix, ChecksumPair) {
        let a = Matrix::rand_uniform(24, 32, seed);
        let b = Matrix::rand_uniform(32, 20, seed + 1);
        let c = a.matmul(&b);
        let pair = ChecksumPair::of_product(&a, &b);
        (c, pair)
    }

    #[test]
    fn operand_checksums_match_product_checksums() {
        let a = Matrix::rand_uniform(16, 40, 3);
        let b = Matrix::rand_uniform(40, 12, 4);
        let c = a.matmul(&b);
        let fast = ChecksumPair::of_product(&a, &b);
        let direct = ChecksumPair::of(&c);
        for (x, y) in fast.cr.iter().zip(&direct.cr) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
        for (x, y) in fast.cc.iter().zip(&direct.cc) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn clean_product_verifies_clean() {
        let (c, pair) = product_fixture(10);
        assert_eq!(verify(&c, &pair, Thresholds::default()), Detection::Clean);
    }

    #[test]
    fn single_error_located_exactly() {
        let (mut c, pair) = product_fixture(11);
        c.add_at(7, 13, 99.0);
        match verify(&c, &pair, Thresholds::default()) {
            Detection::Single { row, col, magnitude } => {
                assert_eq!((row, col), (7, 13));
                assert!((magnitude - 99.0).abs() < 0.01);
            }
            other => panic!("expected Single, got {other:?}"),
        }
    }

    #[test]
    fn correction_restores_the_product() {
        let (mut c, pair) = product_fixture(12);
        let orig = c.clone();
        c.add_at(3, 3, -250.0);
        let (n, after) = verify_and_correct(&mut c, &pair, Thresholds::default());
        assert_eq!(n, 1);
        assert_eq!(after, Detection::Clean);
        assert!(c.max_abs_diff(&orig) < 1e-2);
    }

    #[test]
    fn two_errors_in_distinct_rows_cols_flagged_multi() {
        let (mut c, pair) = product_fixture(13);
        c.add_at(1, 2, 77.0);
        c.add_at(9, 15, -55.0);
        match verify(&c, &pair, Thresholds::default()) {
            Detection::MultiError { bad_rows, bad_cols } => {
                assert_eq!((bad_rows, bad_cols), (2, 2));
            }
            other => panic!("expected MultiError, got {other:?}"),
        }
    }

    #[test]
    fn sub_threshold_offset_ignored() {
        let (mut c, pair) = product_fixture(14);
        c.add_at(0, 0, 1e-6);
        assert_eq!(verify(&c, &pair, Thresholds::default()), Detection::Clean);
    }

    #[test]
    fn correct_is_noop_on_clean_and_multi() {
        let (mut c, _) = product_fixture(15);
        assert!(correct(&mut c, &Detection::Clean).is_none());
        assert!(correct(&mut c, &Detection::MultiError { bad_rows: 2, bad_cols: 2 }).is_none());
    }
}
