//! Rust-side mirror of the kernel code-generation scheme (paper §3.2/§4.3).
//!
//! The python side *generates* kernels; this side *selects* them: Table-1
//! parameter presets, the shape-class heuristic, bucket geometry for the
//! router, and validity checks shared with the gpusim cost model.
//! `python/compile/kernels/params.py` is the twin of [`params`] — keep the
//! tables in sync (test `table1_matches_manifest` cross-checks via the
//! manifest).

pub mod params;
pub mod select;

pub use params::{KernelParams, ShapeClass, TABLE1};
pub use select::{host_tiles, select_class, select_params, Bucket, HostTiles, BUCKETS};
