//! Shape-class selection heuristic + artifact bucket geometry.
//!
//! This is the runtime half of the paper's code-generation story: given a
//! request's (m, n, k), pick the Table-1 parameter class (§3.2.2) and the
//! fixed-shape artifact bucket the router pads into.

use crate::runtime::simd::KernelIsa;

use super::params::{KernelParams, ShapeClass};

/// The paper's semi-empirical heuristic (mirrors
/// `python/compile/kernels/params.py::select_class`): square-ish shapes
/// split at 128/256/512; strongly rectangular outputs go to `tall`.
pub fn select_class(m: usize, n: usize, _k: usize) -> ShapeClass {
    let (lo, hi) = if m <= n { (m, n) } else { (n, m) };
    if hi >= 4 * lo && hi >= 128 {
        return ShapeClass::Tall;
    }
    let size = hi;
    if size <= 128 {
        ShapeClass::Small
    } else if size <= 256 {
        ShapeClass::Medium
    } else if size <= 512 {
        ShapeClass::Large
    } else {
        ShapeClass::Huge
    }
}

pub fn select_params(m: usize, n: usize, k: usize) -> KernelParams {
    select_class(m, n, k).params()
}

/// Concrete artifact bucket shapes (mirror of python `BUCKETS`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Bucket {
    pub class: ShapeClass,
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl Bucket {
    pub fn name(&self) -> &'static str {
        self.class.name()
    }

    /// Does (m, n, k) fit inside this bucket (with padding)?
    pub fn fits(&self, m: usize, n: usize, k: usize) -> bool {
        m <= self.m && n <= self.n && k <= self.k
    }

    /// Wasted FLOP ratio when padding (m,n,k) into this bucket.
    pub fn waste(&self, m: usize, n: usize, k: usize) -> f64 {
        let useful = (m * n * k) as f64;
        let padded = (self.m * self.n * self.k) as f64;
        (padded - useful) / padded
    }
}

pub const BUCKETS: [Bucket; 5] = [
    Bucket { class: ShapeClass::Small, m: 64, n: 64, k: 64 },
    Bucket { class: ShapeClass::Medium, m: 128, n: 128, k: 128 },
    Bucket { class: ShapeClass::Large, m: 256, n: 256, k: 256 },
    Bucket { class: ShapeClass::Tall, m: 128, n: 512, k: 256 },
    Bucket { class: ShapeClass::Huge, m: 512, n: 512, k: 512 },
];

/// Blocked-host-backend tile parameters — the CPU analogue of the Table-1
/// kernel template parameters. `mc`/`nc` bound the macro tile a pool job
/// computes (L2/L3 residency of the packed panels), `mr`/`nr` are the
/// register micro-tile, and `kc` is the reduction-panel depth: the blocked
/// backend sweeps k in ascending `kc`-sized panels, accumulating into the
/// macro tile between panels, so the mc x kc A block + kc x nc B panel
/// stay cache-resident at any `k`.
///
/// Invariants (checked by [`HostTiles::validate`]):
/// * all dimensions are positive powers of two and `mr | mc`, `nr | nc`,
///   mirroring the GPU template's warp/thread divisibility rules;
/// * `mc`/`nc` are multiples of every protection sub-tile the shape
///   class's FT artifacts use (`sub_m <= m_tb <= mc`), so fused checksum
///   encoding never splits a protection domain across pack blocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HostTiles {
    pub mc: usize,
    pub kc: usize,
    pub nc: usize,
    pub mr: usize,
    pub nr: usize,
}

impl HostTiles {
    /// Same spirit as [`KernelParams::validate`]: positive powers of two,
    /// micro tile divides macro tile.
    pub fn validate(&self) -> anyhow::Result<()> {
        let all = [self.mc, self.kc, self.nc, self.mr, self.nr];
        if all.iter().any(|&v| v == 0) {
            anyhow::bail!("host tile sizes must be positive: {self:?}");
        }
        if [self.mc, self.nc, self.mr, self.nr].iter().any(|&v| !v.is_power_of_two()) {
            anyhow::bail!("host macro/micro tiles must be powers of two: {self:?}");
        }
        if self.mc % self.mr != 0 || self.nc % self.nr != 0 {
            anyhow::bail!("micro tile must divide macro tile: {self:?}");
        }
        Ok(())
    }
}

/// Per-shape-class host blocking presets. The table `kc` is the class's
/// reduction-panel *cap*; [`host_tiles`] clamps it to the actual `k` (and
/// applies the `FTGEMM_FORCE_KC` override). Caps keep the per-panel
/// working set (mc x kc A block + kc x nc B panel + mc x nc C tile)
/// around the 256–512 KiB an L2 slice holds. Small/Medium/Large/Tall caps
/// match their bucket `k`, so in-bucket shapes run as a single panel; the
/// huge bucket (k = 512) deliberately runs two 256-deep panels — its full
/// panels would not fit L2.
///
/// Mind the class/bucket offset: the heuristic maps a 512-wide shape to
/// `Large` (splits at <= 512) while the artifact serving it is the
/// *huge* bucket with 128x128 protection tiles — so the `Large` entry
/// keeps `mc`/`nc` at 128 to preserve fused-encode alignment for the
/// flagship 512^3 FT artifacts (checked by the blocked backend's
/// alignment test).
const HOST_TILE_TABLE: [(ShapeClass, HostTiles); 5] = [
    (ShapeClass::Small, HostTiles { mc: 64, kc: 64, nc: 64, mr: 4, nr: 4 }),
    (ShapeClass::Medium, HostTiles { mc: 64, kc: 128, nc: 64, mr: 8, nr: 4 }),
    (ShapeClass::Large, HostTiles { mc: 128, kc: 256, nc: 128, mr: 8, nr: 8 }),
    (ShapeClass::Tall, HostTiles { mc: 64, kc: 256, nc: 128, mr: 4, nr: 8 }),
    (ShapeClass::Huge, HostTiles { mc: 128, kc: 256, nc: 128, mr: 8, nr: 8 }),
];

/// `FTGEMM_FORCE_KC`, parsed fresh per call (a positive integer; anything
/// else is ignored). Read per call so a test-suite-wide env pin (CI's
/// forced-KC leg) applies to every backend the suite constructs.
fn force_kc_env() -> Option<usize> {
    std::env::var("FTGEMM_FORCE_KC").ok()?.parse::<usize>().ok().filter(|&v| v > 0)
}

/// `FTGEMM_FORCE_NC`: accepted only when it keeps the [`HostTiles`]
/// invariants for every dispatched micro-tile width — a power of two and
/// a multiple of the widest register tile (16 columns, avx512) — else
/// silently ignored.
fn force_nc_env() -> Option<usize> {
    std::env::var("FTGEMM_FORCE_NC")
        .ok()?
        .parse::<usize>()
        .ok()
        .filter(|&v| v.is_power_of_two() && v >= 16)
}

/// Pick blocked-backend tile parameters from the problem shape — the same
/// shape-class heuristic that picks kernel templates picks the host
/// blocking. `kc` resolves as: `FTGEMM_FORCE_KC` override if set, else the
/// class cap from [`HOST_TILE_TABLE`]; either way clamped to `k`. Any
/// `kc` produces the same per-element ascending-k fold (the blocked
/// backend accumulates the C tile across panels through exact f32
/// stores/reloads), so this is purely a residency knob — the parity suite
/// pins bitwise-identical C across `kc` choices per ISA.
pub fn host_tiles(m: usize, n: usize, k: usize) -> HostTiles {
    let class = select_class(m, n, k);
    let mut t = HOST_TILE_TABLE[class as usize].1;
    t.kc = force_kc_env().unwrap_or(t.kc).min(k).max(1);
    if let Some(nc) = force_nc_env() {
        t.nc = nc;
    }
    t
}

/// ISA-aware micro-tile (mr, nr) rows layered over [`HOST_TILE_TABLE`]:
/// the macro tiles (`mc`/`nc`, and thus fused-encode alignment) are
/// class-driven and ISA-independent, but the register tile must match
/// the vector width the dispatched micro-kernel was written for.
///
/// | ISA        | mr x nr | accumulator layout                |
/// |------------|---------|-----------------------------------|
/// | `scalar`   | table   | `[[f32; NR]; MR]` (autovectorized)|
/// | `avx2`     | 8 x 8   | 8 x `__m256`                      |
/// | `avx512`   | 8 x 16  | 8 x `__m512`                      |
/// | `neon`     | 8 x 8   | 8 x 2 x `float32x4_t`             |
///
/// `mc`/`nc` stay powers of two >= 64, so the widened micro tiles keep
/// every [`HostTiles::validate`] invariant.
pub fn host_tiles_for(isa: KernelIsa, m: usize, n: usize, k: usize) -> HostTiles {
    let mut t = host_tiles(m, n, k);
    match isa {
        KernelIsa::Scalar => {}
        KernelIsa::Avx2Fma | KernelIsa::Neon => {
            t.mr = 8;
            t.nr = 8;
        }
        KernelIsa::Avx512 => {
            t.mr = 8;
            t.nr = 16;
        }
    }
    t
}

/// Route a request shape to the artifact bucket that minimizes padding
/// waste among the buckets that fit. `None` when the request exceeds every
/// bucket (the coordinator then splits the GEMM — see
/// `coordinator::router::plan_oversize`).
pub fn select_bucket(m: usize, n: usize, k: usize) -> Option<Bucket> {
    BUCKETS
        .iter()
        .filter(|b| b.fits(m, n, k))
        .min_by(|a, b| {
            a.waste(m, n, k)
                .partial_cmp(&b.waste(m, n, k))
                .unwrap()
        })
        .copied()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_heuristic_matches_python_twin() {
        // cases mirrored from python/tests/test_template.py
        assert_eq!(select_class(64, 64, 64), ShapeClass::Small);
        assert_eq!(select_class(128, 128, 512), ShapeClass::Small);
        assert_eq!(select_class(160, 160, 256), ShapeClass::Medium);
        assert_eq!(select_class(384, 384, 256), ShapeClass::Large);
        assert_eq!(select_class(1024, 1024, 1024), ShapeClass::Huge);
        assert_eq!(select_class(64, 1024, 256), ShapeClass::Tall);
        assert_eq!(select_class(2048, 128, 1024), ShapeClass::Tall);
    }

    #[test]
    fn buckets_divisible_by_their_params() {
        for b in BUCKETS {
            let p = b.class.params();
            assert_eq!(b.m % p.m_tb, 0, "{}", b.name());
            assert_eq!(b.n % p.n_tb, 0, "{}", b.name());
            assert_eq!(b.k % p.k_tb, 0, "{}", b.name());
        }
    }

    #[test]
    fn bucket_selection_minimizes_waste() {
        // 60x60x60 fits everything; small wastes least.
        assert_eq!(select_bucket(60, 60, 60).unwrap().class, ShapeClass::Small);
        // 100x500x200 fits tall (and huge); tall wastes less.
        assert_eq!(select_bucket(100, 500, 200).unwrap().class, ShapeClass::Tall);
        // 300^3 only fits huge.
        assert_eq!(select_bucket(300, 300, 300).unwrap().class, ShapeClass::Huge);
        // oversize
        assert!(select_bucket(1000, 1000, 1000).is_none());
    }

    #[test]
    fn host_tile_table_validates_and_covers_ft_granularities() {
        for (class, entry) in HOST_TILE_TABLE {
            let p = class.params();
            entry.validate().unwrap();
            // the class kc cap never forces multi-panel sweeps on shapes
            // that fit the class's own bucket
            let bucket = BUCKETS.iter().find(|b| b.class == class).unwrap();
            assert!(entry.kc >= bucket.k.min(256), "{}", class.name());
            // fused encoding alignment: every protection sub-tile of this
            // class fits whole inside a pack block
            assert_eq!(entry.mc % p.m_tb, 0, "{}", class.name());
            assert_eq!(entry.nc % p.n_tb, 0, "{}", class.name());
        }
    }

    #[test]
    fn host_tiles_follow_the_class_heuristic() {
        assert_eq!(host_tiles(64, 64, 64).mr, 4);
        // the huge class caps kc at 256: a 512^3 request runs two k-panels
        let huge = HostTiles { mc: 128, kc: 256, nc: 128, mr: 8, nr: 8 };
        assert_eq!(host_tiles(512, 512, 512), huge);
        // kc is clamped to the actual reduction depth
        assert_eq!(host_tiles(512, 512, 77).kc, 77);
        // ... and stays at the class cap however large k grows
        assert_eq!(host_tiles(256, 256, 8192).kc, 128, "medium cap");
        assert_eq!(host_tiles(384, 384, 8192).kc, 256, "large cap");
        assert_eq!(host_tiles(64, 1024, 256).nr, 8, "tall class");
    }

    #[test]
    fn isa_rows_override_micro_tiles_and_stay_valid() {
        // scalar row is the plain table
        assert_eq!(host_tiles_for(KernelIsa::Scalar, 64, 64, 64), host_tiles(64, 64, 64));
        for (m, n, k) in [(64, 64, 64), (128, 128, 128), (512, 512, 512), (64, 1024, 256)] {
            for isa in [KernelIsa::Avx2Fma, KernelIsa::Neon] {
                let t = host_tiles_for(isa, m, n, k);
                assert_eq!((t.mr, t.nr), (8, 8), "{isa:?} ({m},{n},{k})");
                t.validate().unwrap();
                // macro tiles (fused-encode alignment) never change
                let s = host_tiles(m, n, k);
                assert_eq!((t.mc, t.nc, t.kc), (s.mc, s.nc, s.kc));
            }
            let t = host_tiles_for(KernelIsa::Avx512, m, n, k);
            assert_eq!((t.mr, t.nr), (8, 16), "avx512 ({m},{n},{k})");
            t.validate().unwrap();
        }
    }

    #[test]
    fn waste_is_zero_for_exact_fit() {
        let b = BUCKETS[0];
        assert_eq!(b.waste(64, 64, 64), 0.0);
        assert!(b.waste(32, 64, 64) > 0.49 && b.waste(32, 64, 64) < 0.51);
    }
}
