//! Shape-class selection heuristic + artifact bucket geometry.
//!
//! This is the runtime half of the paper's code-generation story: given a
//! request's (m, n, k), pick the Table-1 parameter class (§3.2.2) and the
//! fixed-shape artifact bucket the router pads into.

use super::params::{KernelParams, ShapeClass};

/// The paper's semi-empirical heuristic (mirrors
/// `python/compile/kernels/params.py::select_class`): square-ish shapes
/// split at 128/256/512; strongly rectangular outputs go to `tall`.
pub fn select_class(m: usize, n: usize, _k: usize) -> ShapeClass {
    let (lo, hi) = if m <= n { (m, n) } else { (n, m) };
    if hi >= 4 * lo && hi >= 128 {
        return ShapeClass::Tall;
    }
    let size = hi;
    if size <= 128 {
        ShapeClass::Small
    } else if size <= 256 {
        ShapeClass::Medium
    } else if size <= 512 {
        ShapeClass::Large
    } else {
        ShapeClass::Huge
    }
}

pub fn select_params(m: usize, n: usize, k: usize) -> KernelParams {
    select_class(m, n, k).params()
}

/// Concrete artifact bucket shapes (mirror of python `BUCKETS`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Bucket {
    pub class: ShapeClass,
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl Bucket {
    pub fn name(&self) -> &'static str {
        self.class.name()
    }

    /// Does (m, n, k) fit inside this bucket (with padding)?
    pub fn fits(&self, m: usize, n: usize, k: usize) -> bool {
        m <= self.m && n <= self.n && k <= self.k
    }

    /// Wasted FLOP ratio when padding (m,n,k) into this bucket.
    pub fn waste(&self, m: usize, n: usize, k: usize) -> f64 {
        let useful = (m * n * k) as f64;
        let padded = (self.m * self.n * self.k) as f64;
        (padded - useful) / padded
    }
}

pub const BUCKETS: [Bucket; 5] = [
    Bucket { class: ShapeClass::Small, m: 64, n: 64, k: 64 },
    Bucket { class: ShapeClass::Medium, m: 128, n: 128, k: 128 },
    Bucket { class: ShapeClass::Large, m: 256, n: 256, k: 256 },
    Bucket { class: ShapeClass::Tall, m: 128, n: 512, k: 256 },
    Bucket { class: ShapeClass::Huge, m: 512, n: 512, k: 512 },
];

/// Route a request shape to the artifact bucket that minimizes padding
/// waste among the buckets that fit. `None` when the request exceeds every
/// bucket (the coordinator then splits the GEMM — see
/// `coordinator::router::plan_oversize`).
pub fn select_bucket(m: usize, n: usize, k: usize) -> Option<Bucket> {
    BUCKETS
        .iter()
        .filter(|b| b.fits(m, n, k))
        .min_by(|a, b| {
            a.waste(m, n, k)
                .partial_cmp(&b.waste(m, n, k))
                .unwrap()
        })
        .copied()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_heuristic_matches_python_twin() {
        // cases mirrored from python/tests/test_template.py
        assert_eq!(select_class(64, 64, 64), ShapeClass::Small);
        assert_eq!(select_class(128, 128, 512), ShapeClass::Small);
        assert_eq!(select_class(160, 160, 256), ShapeClass::Medium);
        assert_eq!(select_class(384, 384, 256), ShapeClass::Large);
        assert_eq!(select_class(1024, 1024, 1024), ShapeClass::Huge);
        assert_eq!(select_class(64, 1024, 256), ShapeClass::Tall);
        assert_eq!(select_class(2048, 128, 1024), ShapeClass::Tall);
    }

    #[test]
    fn buckets_divisible_by_their_params() {
        for b in BUCKETS {
            let p = b.class.params();
            assert_eq!(b.m % p.m_tb, 0, "{}", b.name());
            assert_eq!(b.n % p.n_tb, 0, "{}", b.name());
            assert_eq!(b.k % p.k_tb, 0, "{}", b.name());
        }
    }

    #[test]
    fn bucket_selection_minimizes_waste() {
        // 60x60x60 fits everything; small wastes least.
        assert_eq!(select_bucket(60, 60, 60).unwrap().class, ShapeClass::Small);
        // 100x500x200 fits tall (and huge); tall wastes less.
        assert_eq!(select_bucket(100, 500, 200).unwrap().class, ShapeClass::Tall);
        // 300^3 only fits huge.
        assert_eq!(select_bucket(300, 300, 300).unwrap().class, ShapeClass::Huge);
        // oversize
        assert!(select_bucket(1000, 1000, 1000).is_none());
    }

    #[test]
    fn waste_is_zero_for_exact_fit() {
        let b = BUCKETS[0];
        assert_eq!(b.waste(64, 64, 64), 0.0);
        assert!(b.waste(32, 64, 64) > 0.49 && b.waste(32, 64, 64) < 0.51);
    }
}
