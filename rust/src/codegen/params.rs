//! Table 1 — the paper's SGEMM kernel parameter presets — plus validation.

use anyhow::{anyhow, bail, Result};

use crate::util::json::Json;

/// The 7 codegen parameters of the paper's template (§3.2.1): tile sizes at
/// threadblock (`_tb`), warp (`_w`) and thread (`_t`) level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelParams {
    pub m_tb: usize,
    pub n_tb: usize,
    pub k_tb: usize,
    pub m_w: usize,
    pub n_w: usize,
    pub m_t: usize,
    pub n_t: usize,
}

impl KernelParams {
    pub const fn new(
        m_tb: usize,
        n_tb: usize,
        k_tb: usize,
        m_w: usize,
        n_w: usize,
        m_t: usize,
        n_t: usize,
    ) -> Self {
        KernelParams { m_tb, n_tb, k_tb, m_w, n_w, m_t, n_t }
    }

    /// Same divisibility/power-of-two constraints as the python template.
    pub fn validate(&self) -> Result<()> {
        let all = [self.m_tb, self.n_tb, self.k_tb, self.m_w, self.n_w, self.m_t, self.n_t];
        if all.iter().any(|&v| v == 0 || !v.is_power_of_two()) {
            bail!("tile sizes must be positive powers of two: {self:?}");
        }
        if self.m_tb % self.m_w != 0 || self.n_tb % self.n_w != 0 {
            bail!("warp tile must divide threadblock tile: {self:?}");
        }
        if self.m_w % self.m_t != 0 || self.n_w % self.n_t != 0 {
            bail!("thread tile must divide warp tile: {self:?}");
        }
        Ok(())
    }

    /// CUDA-view occupancy quantities (used by gpusim).
    pub fn threads_per_block(&self) -> usize {
        (self.m_tb / self.m_t) * (self.n_tb / self.n_t)
    }

    pub fn warps_per_block(&self) -> usize {
        (self.m_tb / self.m_w) * (self.n_tb / self.n_w)
    }

    /// Registers per thread: the accumulator micro-tile + two operand
    /// fragments (double-buffered) + addressing — the model the paper's
    /// §3.1.3/§3.1.6 analysis implies.
    pub fn regs_per_thread(&self) -> usize {
        let acc = self.m_t * self.n_t;
        let frags = 2 * (self.m_t + self.n_t);
        acc + frags + 16
    }

    /// Shared memory per block in bytes: double-buffered A and B tiles, f32.
    pub fn smem_bytes(&self) -> usize {
        2 * (self.m_tb * self.k_tb + self.k_tb * self.n_tb) * 4
    }

    /// Checksum sub-tile for an FT level ("thread" | "warp" | "tb").
    pub fn sub_tile(&self, level: &str) -> Result<(usize, usize)> {
        Ok(match level {
            "thread" => (self.m_t, self.n_t),
            "warp" => (self.m_w, self.n_w),
            "tb" => (self.m_tb, self.n_tb),
            other => bail!("unknown FT level {other:?}"),
        })
    }

    /// Parse the manifest's `params` object.
    pub fn from_json(j: &Json) -> Result<KernelParams> {
        let g = |k: &str| {
            j.path(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("params missing {k}"))
        };
        let p = KernelParams {
            m_tb: g("m_tb")?,
            n_tb: g("n_tb")?,
            k_tb: g("k_tb")?,
            m_w: g("m_w")?,
            n_w: g("n_w")?,
            m_t: g("m_t")?,
            n_t: g("n_t")?,
        };
        p.validate()?;
        Ok(p)
    }
}

/// The five shape classes of Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ShapeClass {
    Small,
    Medium,
    Large,
    Tall,
    Huge,
}

impl ShapeClass {
    pub const ALL: [ShapeClass; 5] = [
        ShapeClass::Small,
        ShapeClass::Medium,
        ShapeClass::Large,
        ShapeClass::Tall,
        ShapeClass::Huge,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            ShapeClass::Small => "small",
            ShapeClass::Medium => "medium",
            ShapeClass::Large => "large",
            ShapeClass::Tall => "tall",
            ShapeClass::Huge => "huge",
        }
    }

    pub fn from_name(s: &str) -> Result<Self> {
        Ok(match s {
            "small" => ShapeClass::Small,
            "medium" => ShapeClass::Medium,
            "large" => ShapeClass::Large,
            "tall" => ShapeClass::Tall,
            "huge" => ShapeClass::Huge,
            other => bail!("unknown shape class {other:?}"),
        })
    }

    pub fn params(&self) -> KernelParams {
        TABLE1[*self as usize].1
    }
}

/// Table 1 verbatim (T4 presets). Order matches [`ShapeClass`].
pub const TABLE1: [(ShapeClass, KernelParams); 5] = [
    (ShapeClass::Small, KernelParams::new(16, 16, 16, 8, 16, 2, 2)),
    (ShapeClass::Medium, KernelParams::new(32, 32, 8, 16, 32, 4, 4)),
    (ShapeClass::Large, KernelParams::new(64, 64, 8, 32, 64, 8, 8)),
    (ShapeClass::Tall, KernelParams::new(32, 128, 8, 16, 64, 4, 8)),
    (ShapeClass::Huge, KernelParams::new(128, 128, 8, 32, 64, 8, 8)),
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_presets_validate() {
        for (cls, p) in TABLE1 {
            p.validate().unwrap_or_else(|e| panic!("{}: {e}", cls.name()));
        }
    }

    #[test]
    fn huge_preset_matches_paper_text() {
        // §3.1.4: 128x128 threadblock, 256 threads (8 warps), 64x32 warp
        // tile... our Table-1 huge row: threads = (128/8)*(128/8) = 256.
        let p = ShapeClass::Huge.params();
        assert_eq!(p.threads_per_block(), 256);
        assert_eq!(p.warps_per_block(), 8);
    }

    #[test]
    fn smem_fits_t4_per_block_budget() {
        // T4: 64 KiB shared memory per SM; every preset must fit at least
        // one block.
        for (cls, p) in TABLE1 {
            assert!(p.smem_bytes() <= 64 * 1024, "{}: {}", cls.name(), p.smem_bytes());
        }
    }

    #[test]
    fn from_json_roundtrip() {
        let j = Json::parse(
            r#"{"m_tb":32,"n_tb":32,"k_tb":8,"m_w":16,"n_w":32,"m_t":4,"n_t":4}"#,
        )
        .unwrap();
        let p = KernelParams::from_json(&j).unwrap();
        assert_eq!(p, ShapeClass::Medium.params());
    }

    #[test]
    fn from_json_rejects_invalid() {
        let j = Json::parse(
            r#"{"m_tb":32,"n_tb":32,"k_tb":8,"m_w":5,"n_w":32,"m_t":4,"n_t":4}"#,
        )
        .unwrap();
        assert!(KernelParams::from_json(&j).is_err());
    }

    #[test]
    fn sub_tile_levels() {
        let p = ShapeClass::Huge.params();
        assert_eq!(p.sub_tile("thread").unwrap(), (8, 8));
        assert_eq!(p.sub_tile("warp").unwrap(), (32, 64));
        assert_eq!(p.sub_tile("tb").unwrap(), (128, 128));
        assert!(p.sub_tile("block").is_err());
    }

    #[test]
    fn class_name_roundtrip() {
        for cls in ShapeClass::ALL {
            assert_eq!(ShapeClass::from_name(cls.name()).unwrap(), cls);
        }
    }
}
