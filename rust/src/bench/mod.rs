//! Bench harness (criterion replacement — the vendored crate set has no
//! criterion). Warmup + timed iterations + robust statistics, and a
//! markdown summary compatible with EXPERIMENTS.md.

pub mod mix;

use std::time::{Duration, Instant};

use crate::util::stats::{Quantiles, Running};

/// One benchmark's configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub target_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 1000,
            target_time: Duration::from_secs(2),
        }
    }
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
    pub std_dev: Duration,
}

impl BenchResult {
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.mean.as_secs_f64()
    }

    pub fn row(&self) -> String {
        format!(
            "| {} | {} | {:?} | {:?} | {:?} | {:?} |",
            self.name, self.iters, self.mean, self.median, self.p99, self.max
        )
    }
}

/// A named collection of benchmarks with a shared config.
pub struct Harness {
    config: BenchConfig,
    results: Vec<BenchResult>,
}

impl Default for Harness {
    fn default() -> Self {
        Self::new(BenchConfig::default())
    }
}

impl Harness {
    pub fn new(config: BenchConfig) -> Self {
        Harness { config, results: Vec::new() }
    }

    /// Quick config for expensive end-to-end benches.
    pub fn quick() -> Self {
        Harness::new(BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 30,
            target_time: Duration::from_millis(800),
        })
    }

    /// Run one benchmark. The closure is timed per call; use
    /// `std::hint::black_box` inside to defeat dead-code elimination.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        for _ in 0..self.config.warmup_iters {
            f();
        }
        let mut running = Running::new();
        let mut q = Quantiles::default();
        let start = Instant::now();
        let mut iters = 0usize;
        while iters < self.config.min_iters
            || (start.elapsed() < self.config.target_time && iters < self.config.max_iters)
        {
            let t0 = Instant::now();
            f();
            let dt = t0.elapsed().as_secs_f64();
            running.push(dt);
            q.push(dt);
            iters += 1;
        }
        let d = Duration::from_secs_f64;
        let result = BenchResult {
            name: name.to_string(),
            iters,
            mean: d(running.mean()),
            median: d(q.median()),
            p99: d(q.p99()),
            min: d(running.min()),
            max: d(running.max()),
            std_dev: d(running.std_dev()),
        };
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Markdown summary of everything run so far.
    pub fn summary(&self) -> String {
        let mut s = String::from(
            "| bench | iters | mean | median | p99 | max |\n|---|---|---|---|---|---|\n",
        );
        for r in &self.results {
            s.push_str(&r.row());
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut h = Harness::new(BenchConfig {
            warmup_iters: 1,
            min_iters: 5,
            max_iters: 10,
            target_time: Duration::from_millis(10),
        });
        let r = h.bench("spin", || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.iters >= 5);
        assert!(r.mean > Duration::ZERO);
        assert!(r.min <= r.median && r.median <= r.max);
        assert!(h.summary().contains("spin"));
    }

    #[test]
    fn throughput_computation() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean: Duration::from_secs(2),
            median: Duration::from_secs(2),
            p99: Duration::from_secs(2),
            min: Duration::from_secs(2),
            max: Duration::from_secs(2),
            std_dev: Duration::ZERO,
        };
        assert!((r.throughput(10.0) - 5.0).abs() < 1e-12);
    }
}
