//! Named workload-mix presets shared by the `loadgen` harness and the CI
//! pipelines.
//!
//! The gateway smoke job and the scheduled full-bench workflow used to
//! spell the same client mix twice as raw flag strings in two YAML files;
//! a typo in one silently made the gate measure a different workload than
//! the one the committed baseline was recorded against. This table is the
//! single source of truth: CI passes `loadgen --preset <name>` and the
//! flag strings live here, next to a test that pins them.
//!
//! Presets only *default* the mix knobs — an explicit `--mix`/`--policies`/
//! `--priorities`/`--inject` flag still wins, so ad-hoc experiments can
//! start from a preset and override one axis.

/// One named workload mix. Fields mirror the `loadgen` flags of the same
/// name and use the same comma-separated wire syntax so the preset can be
/// echoed verbatim into logs and reproduced by hand.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MixPreset {
    pub name: &'static str,
    pub description: &'static str,
    /// Shape classes to cycle (`small|medium|large|huge`), comma-separated.
    pub shapes: &'static str,
    /// FT policies to cycle (`none|online|offline`), comma-separated.
    pub policies: &'static str,
    /// Priorities to cycle (`low|normal|high`), comma-separated.
    pub priorities: &'static str,
    /// Correctable SEUs injected per request server-side.
    pub inject: usize,
    /// Percentage (0–100) of requests that reuse the workload's base
    /// seed instead of a per-request one. Repeated seeds are pack-cache
    /// hits server-side: the operands and their packed panels/checksums
    /// are shared across those requests.
    pub seed_reuse_pct: usize,
}

/// The preset registry. Order is the display order of `--preset help`.
pub const PRESETS: &[MixPreset] = &[
    MixPreset {
        name: "ci-smoke",
        description: "gateway-smoke gate mix: small/medium, online+none, two priorities, 1 SEU",
        shapes: "small,medium",
        policies: "online,none",
        priorities: "normal,high",
        inject: 1,
        seed_reuse_pct: 50,
    },
    MixPreset {
        name: "latency",
        description: "single-class latency floor: small, no FT, one priority, clean",
        shapes: "small",
        policies: "none",
        priorities: "normal",
        inject: 0,
        seed_reuse_pct: 0,
    },
    MixPreset {
        name: "stress",
        description: "wide mix for soak runs: all four classes, every policy and priority, 1 SEU",
        shapes: "small,medium,large,huge",
        policies: "none,online,offline",
        priorities: "low,normal,high",
        inject: 1,
        seed_reuse_pct: 25,
    },
];

/// Look up a preset by name.
pub fn preset(name: &str) -> Option<&'static MixPreset> {
    PRESETS.iter().find(|p| p.name == name)
}

/// One line per preset, for `--preset help` / error messages.
pub fn describe_presets() -> String {
    let mut s = String::new();
    for p in PRESETS {
        s.push_str(&format!(
            "  {:<9} {} (--mix {} --policies {} --priorities {} --inject {} --seed-reuse {})\n",
            p.name, p.description, p.shapes, p.policies, p.priorities, p.inject, p.seed_reuse_pct
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_finds_every_preset_and_rejects_unknowns() {
        for p in PRESETS {
            assert_eq!(preset(p.name), Some(p));
        }
        assert!(preset("nope").is_none());
        assert!(preset("").is_none());
    }

    /// The gate mix is what the committed serving baselines were recorded
    /// against; changing it silently invalidates them. Change this test
    /// only together with a baseline regeneration.
    #[test]
    fn ci_smoke_mix_is_pinned() {
        let p = preset("ci-smoke").expect("ci-smoke preset must exist");
        assert_eq!(p.shapes, "small,medium");
        assert_eq!(p.policies, "online,none");
        assert_eq!(p.priorities, "normal,high");
        assert_eq!(p.inject, 1);
        assert_eq!(p.seed_reuse_pct, 50, "half the smoke mix exercises the pack cache");
    }

    #[test]
    fn seed_reuse_is_a_percentage() {
        for p in PRESETS {
            assert!(p.seed_reuse_pct <= 100, "{}: bad seed_reuse_pct", p.name);
        }
    }

    #[test]
    fn preset_names_are_unique_and_described() {
        let mut names: Vec<&str> = PRESETS.iter().map(|p| p.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), PRESETS.len(), "duplicate preset name");
        let listing = describe_presets();
        for p in PRESETS {
            assert!(listing.contains(p.name));
        }
    }
}
