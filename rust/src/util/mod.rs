//! Self-built infrastructure substrates.
//!
//! The build environment is fully offline and the vendored crate set is
//! minimal (no serde, clap, tokio, rand, criterion), so the pieces a
//! production service would normally pull from crates.io are implemented
//! here from scratch: a JSON parser/serializer ([`json`]), a CLI argument
//! parser ([`cli`]), deterministic PRNGs ([`rng`]), a thread pool and
//! oneshot channels ([`pool`]), and simple numeric stats ([`stats`]).

pub mod cli;
pub mod config;
pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;
