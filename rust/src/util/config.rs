//! File-based configuration: a TOML-subset parser (sections, `key = value`
//! with strings / numbers / booleans, `#` comments) and typed loaders for
//! the system's config structs — the deployment-facing entry point
//! (`ftgemm serve --config ftgemm.toml`).
//!
//! Grammar intentionally small (no nested tables, arrays, or multi-line
//! strings): enough for service configuration, zero dependencies.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::abft::checksum::Thresholds;
use crate::coordinator::batcher::BatcherConfig;
use crate::coordinator::{CoordinatorConfig, FtLevel, HostVerify};
use crate::runtime::EngineConfig;
use crate::serve::ServeConfig;

/// Parsed config: `section.key -> raw value`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    values: BTreeMap<String, Value>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
}

impl Value {
    fn type_name(&self) -> &'static str {
        match self {
            Value::Str(_) => "string",
            Value::Num(_) => "number",
            Value::Bool(_) => "boolean",
        }
    }
}

impl Config {
    pub fn parse(text: &str) -> Result<Config> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unterminated section", lineno + 1))?
                    .trim();
                if name.is_empty() || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
                {
                    bail!("line {}: bad section name {name:?}", lineno + 1);
                }
                section = name.to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() || !key.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
                bail!("line {}: bad key {key:?}", lineno + 1);
            }
            let full =
                if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            let parsed = parse_value(val.trim())
                .with_context(|| format!("line {}: value for {full}", lineno + 1))?;
            if values.insert(full.clone(), parsed).is_some() {
                bail!("line {}: duplicate key {full}", lineno + 1);
            }
        }
        Ok(Config { values })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Config> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        Self::parse(&text)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn str(&self, key: &str) -> Result<Option<&str>> {
        match self.values.get(key) {
            None => Ok(None),
            Some(Value::Str(s)) => Ok(Some(s)),
            Some(v) => bail!("{key}: expected string, got {}", v.type_name()),
        }
    }

    pub fn num(&self, key: &str) -> Result<Option<f64>> {
        match self.values.get(key) {
            None => Ok(None),
            Some(Value::Num(x)) => Ok(Some(*x)),
            Some(v) => bail!("{key}: expected number, got {}", v.type_name()),
        }
    }

    pub fn usize(&self, key: &str) -> Result<Option<usize>> {
        match self.num(key)? {
            None => Ok(None),
            Some(x) if x >= 0.0 && x.fract() == 0.0 => Ok(Some(x as usize)),
            Some(x) => bail!("{key}: expected non-negative integer, got {x}"),
        }
    }

    pub fn bool(&self, key: &str) -> Result<Option<bool>> {
        match self.values.get(key) {
            None => Ok(None),
            Some(Value::Bool(b)) => Ok(Some(*b)),
            Some(v) => bail!("{key}: expected boolean, got {}", v.type_name()),
        }
    }

    // ------------------------------------------------------------------
    // Typed loaders
    // ------------------------------------------------------------------

    /// `[coordinator]` section → [`CoordinatorConfig`]; unset keys keep
    /// defaults. This is the boundary where the stringly config becomes
    /// typed: `ft_level` parses into [`FtLevel`] (rejecting unknown
    /// levels) and `host_verify` accepts a boolean (`true` =
    /// [`HostVerify::CleanOnly`] — injected runs are deliberately not
    /// re-verified) or one of `"off" | "clean_only" | "always"`.
    pub fn coordinator(&self) -> Result<CoordinatorConfig> {
        let mut cfg = CoordinatorConfig::default();
        if let Some(level) = self.str("coordinator.ft_level")? {
            cfg.ft_level = level.parse::<FtLevel>().map_err(|_| {
                anyhow!("coordinator.ft_level must be tb|warp|thread, got {level:?}")
            })?;
        }
        cfg.host_verify = match self.get("coordinator.host_verify") {
            None => cfg.host_verify,
            Some(Value::Bool(true)) => HostVerify::CleanOnly,
            Some(Value::Bool(false)) => HostVerify::Off,
            Some(Value::Str(mode)) => mode.parse::<HostVerify>().map_err(|_| {
                anyhow!(
                    "coordinator.host_verify must be a boolean or off|clean_only|always, \
                     got {mode:?}"
                )
            })?,
            Some(v) => bail!(
                "coordinator.host_verify: expected boolean or string, got {}",
                v.type_name()
            ),
        };
        if let Some(n) = self.usize("coordinator.max_recomputes")? {
            cfg.max_recomputes = n;
        }
        if let Some(n) = self.usize("coordinator.scheduler_threads")? {
            cfg.scheduler_threads = n;
        }
        if let Some(n) = self.usize("coordinator.max_inflight")? {
            cfg.max_inflight = n;
        }
        if let Some(n) = self.usize("coordinator.max_queue")? {
            cfg.max_queue = n;
        }
        if let Some(n) = self.usize("coordinator.steal_threshold")? {
            cfg.steal_threshold = n;
        }
        let mut th = Thresholds::default();
        if let Some(x) = self.num("coordinator.threshold_rel")? {
            th.rel = x as f32;
        }
        if let Some(x) = self.num("coordinator.threshold_abs")? {
            th.abs = x as f32;
        }
        cfg.thresholds = th;
        Ok(cfg)
    }

    /// `[engine]` section → [`EngineConfig`]. The `backend` key is a
    /// [`BackendRegistry`](crate::runtime::BackendRegistry) name
    /// (`"reference"` | `"blocked"` | `"blocked-scalar"`, or a custom
    /// entry); it is carried
    /// verbatim and resolved when the engine starts — against the global
    /// registry for `Engine::start`, or the caller's for
    /// `Engine::start_with` — so config files can name embedder-registered
    /// backends too.
    pub fn engine(&self) -> Result<EngineConfig> {
        let mut cfg = EngineConfig::default();
        if let Some(dir) = self.str("engine.artifacts_dir")? {
            cfg.artifacts_dir = Some(dir.into());
        }
        if let Some(name) = self.str("engine.backend")? {
            cfg.backend = name.to_string();
        }
        if let Some(list) = self.str("engine.precompile")? {
            cfg.precompile = list
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
        }
        if let Some(n) = self.usize("engine.workers")? {
            if n == 0 {
                bail!("engine.workers must be >= 1");
            }
            cfg.workers = n;
        }
        if let Some(n) = self.usize("engine.pools")? {
            if n == 0 {
                bail!("engine.pools must be >= 1");
            }
            cfg.pools = n;
        }
        if let Some(mb) = self.usize("engine.pack_cache_mb")? {
            cfg.pack_cache_mb = Some(mb);
        }
        Ok(cfg)
    }

    /// `[serve]` section → [`ServeConfig`]: the gateway's listen address,
    /// connection-thread count, and frame-size bound. Validated here (the
    /// config/CLI boundary) so a bad deployment file fails with field
    /// names before any socket is bound.
    pub fn serve(&self) -> Result<ServeConfig> {
        let mut cfg = ServeConfig::default();
        if let Some(listen) = self.str("serve.listen")? {
            cfg.listen = listen.to_string();
        }
        if let Some(n) = self.usize("serve.threads")? {
            cfg.threads = n;
        }
        if let Some(n) = self.usize("serve.max_frame_bytes")? {
            cfg.max_frame_bytes = n;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Whether the config carries a `[serve]` section at all (the CLI uses
    /// this to decide between TCP and stdin mode when `--listen` is absent).
    pub fn has_serve_section(&self) -> bool {
        self.keys().any(|k| k.starts_with("serve."))
    }

    /// `[batcher]` section → [`BatcherConfig`].
    pub fn batcher(&self) -> Result<BatcherConfig> {
        let mut cfg = BatcherConfig::default();
        if let Some(n) = self.usize("batcher.max_batch")? {
            if n == 0 {
                bail!("batcher.max_batch must be >= 1");
            }
            cfg.max_batch = n;
        }
        if let Some(us) = self.usize("batcher.batch_window_us")? {
            cfg.batch_window = std::time::Duration::from_micros(us as u64);
        }
        Ok(cfg)
    }
}

fn strip_comment(line: &str) -> &str {
    // naive '#' handling is wrong inside quoted strings; scan properly
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if let Some(body) = s.strip_prefix('"') {
        let body = body
            .strip_suffix('"')
            .ok_or_else(|| anyhow!("unterminated string"))?;
        if body.contains('"') {
            bail!("embedded quotes not supported");
        }
        return Ok(Value::Str(body.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    s.parse::<f64>()
        .map(Value::Num)
        .map_err(|_| anyhow!("not a string/number/boolean: {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# ftgemm service config
[engine]
artifacts_dir = "artifacts"          # where make artifacts wrote
precompile = "gemm_medium, ftgemm_tb_medium"
workers = 4
pools = 2
backend = "blocked"
pack_cache_mb = 128                  # packed-operand cache per pool; 0 disables

[coordinator]
ft_level = "warp"
host_verify = true
max_recomputes = 3
threshold_rel = 2e-4
scheduler_threads = 6
max_inflight = 8
max_queue = 256
steal_threshold = 3

[batcher]
max_batch = 32
batch_window_us = 500

[serve]
listen = "127.0.0.1:7500"
threads = 8
max_frame_bytes = 65536
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str("engine.artifacts_dir").unwrap(), Some("artifacts"));
        assert_eq!(c.bool("coordinator.host_verify").unwrap(), Some(true));
        assert_eq!(c.usize("batcher.max_batch").unwrap(), Some(32));
        assert_eq!(c.num("coordinator.threshold_rel").unwrap(), Some(2e-4));
    }

    #[test]
    fn typed_loaders_build_configs() {
        let c = Config::parse(SAMPLE).unwrap();
        let coord = c.coordinator().unwrap();
        assert_eq!(coord.ft_level, FtLevel::Warp);
        assert_eq!(coord.host_verify, HostVerify::CleanOnly, "true maps to clean-only");
        assert_eq!(coord.max_recomputes, 3);
        assert_eq!(coord.scheduler_threads, 6);
        assert_eq!(coord.max_inflight, 8);
        assert_eq!(coord.max_queue, 256);
        assert_eq!(coord.steal_threshold, 3);
        assert!((coord.thresholds.rel - 2e-4).abs() < 1e-9);
        let eng = c.engine().unwrap();
        assert_eq!(eng.precompile, vec!["gemm_medium", "ftgemm_tb_medium"]);
        assert_eq!(eng.workers, 4);
        assert_eq!(eng.pools, 2);
        assert_eq!(eng.backend, "blocked");
        assert_eq!(eng.pack_cache_mb, Some(128));
        let b = c.batcher().unwrap();
        assert_eq!(b.max_batch, 32);
        assert_eq!(b.batch_window, std::time::Duration::from_micros(500));
        let s = c.serve().unwrap();
        assert_eq!(s.listen, "127.0.0.1:7500");
        assert_eq!(s.threads, 8);
        assert_eq!(s.max_frame_bytes, 65536);
        assert!(c.has_serve_section());
    }

    #[test]
    fn serve_section_defaults_and_validation() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.serve().unwrap(), ServeConfig::default());
        assert!(!c.has_serve_section());
        // partial sections keep the other defaults
        let c = Config::parse("[serve]\nthreads = 2").unwrap();
        let s = c.serve().unwrap();
        assert_eq!(s.threads, 2);
        assert_eq!(s.listen, ServeConfig::default().listen);
        assert!(c.has_serve_section());
        // validation fires at the config boundary with field names
        let c = Config::parse("[serve]\nthreads = 0").unwrap();
        assert!(c.serve().unwrap_err().to_string().contains("threads"));
        let c = Config::parse("[serve]\nlisten = \"no-port\"").unwrap();
        assert!(c.serve().unwrap_err().to_string().contains("listen"));
        let c = Config::parse("[serve]\nmax_frame_bytes = 64").unwrap();
        assert!(c.serve().unwrap_err().to_string().contains("max_frame_bytes"));
        let c = Config::parse("[serve]\nlisten = 7421").unwrap();
        assert!(c.serve().is_err(), "listen must be a string");
    }

    #[test]
    fn defaults_when_unset() {
        let c = Config::parse("").unwrap();
        let coord = c.coordinator().unwrap();
        assert_eq!(coord.ft_level, FtLevel::Tb);
        assert_eq!(coord.host_verify, HostVerify::Off);
        assert_eq!(coord.max_inflight, 0, "0 = autosize to the engine pool");
        assert_eq!(coord.max_queue, 0, "0 = unbounded");
        assert_eq!(coord.steal_threshold, 4);
    }

    #[test]
    fn host_verify_accepts_bool_or_mode_string() {
        let c = Config::parse("[coordinator]\nhost_verify = false").unwrap();
        assert_eq!(c.coordinator().unwrap().host_verify, HostVerify::Off);
        let c = Config::parse("[coordinator]\nhost_verify = \"always\"").unwrap();
        assert_eq!(c.coordinator().unwrap().host_verify, HostVerify::Always);
        let c = Config::parse("[coordinator]\nhost_verify = \"clean_only\"").unwrap();
        assert_eq!(c.coordinator().unwrap().host_verify, HostVerify::CleanOnly);
        let c = Config::parse("[coordinator]\nhost_verify = \"maybe\"").unwrap();
        assert!(c.coordinator().is_err());
        let c = Config::parse("[coordinator]\nhost_verify = 1").unwrap();
        assert!(c.coordinator().is_err());
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "[unterminated",
            "key without equals",
            "k = ",
            "k = \"open",
            "a = 1\na = 2",
            "bad key! = 1",
            "[coordinator]\nft_level = \"bogus\"",
        ] {
            let parsed = Config::parse(bad);
            let failed = match parsed {
                Err(_) => true,
                Ok(c) => c.coordinator().is_err(),
            };
            assert!(failed, "{bad:?} should fail");
        }
    }

    #[test]
    fn validates_value_types() {
        let c = Config::parse("[coordinator]\nmax_recomputes = \"three\"").unwrap();
        assert!(c.coordinator().is_err());
        let c = Config::parse("[coordinator]\nmax_recomputes = 2.5").unwrap();
        assert!(c.coordinator().is_err());
        let c = Config::parse("[batcher]\nmax_batch = 0").unwrap();
        assert!(c.batcher().is_err());
        let c = Config::parse("[engine]\nworkers = 0").unwrap();
        assert!(c.engine().is_err());
        let c = Config::parse("[engine]\npools = 0").unwrap();
        assert!(c.engine().is_err());
        let c = Config::parse("[engine]\npools = 4").unwrap();
        assert_eq!(c.engine().unwrap().pools, 4);
        // 0 is a *valid* pack-cache budget: it means "disabled", distinct
        // from the unset default
        let c = Config::parse("[engine]\npack_cache_mb = 0").unwrap();
        assert_eq!(c.engine().unwrap().pack_cache_mb, Some(0));
        let c = Config::parse("").unwrap();
        assert_eq!(c.engine().unwrap().pack_cache_mb, None, "unset keeps the default budget");
        let c = Config::parse("[engine]\npack_cache_mb = \"big\"").unwrap();
        assert!(c.engine().is_err());
        // backend names are carried verbatim (resolution happens at
        // Engine::start, against whichever registry serves the config)
        let c = Config::parse("[engine]\nbackend = \"custom_embedder\"").unwrap();
        assert_eq!(c.engine().unwrap().backend, "custom_embedder");
        let c = Config::parse("[engine]\nbackend = \"reference\"").unwrap();
        assert_eq!(c.engine().unwrap().backend, "reference");
    }

    #[test]
    fn comments_respect_strings() {
        let c = Config::parse("k = \"a#b\" # trailing").unwrap();
        assert_eq!(c.str("k").unwrap(), Some("a#b"));
    }
}
