//! Thread pool + oneshot channel (no tokio in the vendored crate set).
//!
//! The coordinator's leader loop and the fault-campaign drivers need
//! fan-out/fan-in concurrency; [`ThreadPool`] provides bounded worker
//! threads over `std::sync::mpsc`, and [`oneshot`] provides the one-value
//! rendezvous used for engine request/response pairing.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool. Jobs are `FnOnce() + Send`; panics in jobs are
/// caught and counted rather than tearing down the worker.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    panics: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let panics = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let panics = Arc::clone(&panics);
                std::thread::Builder::new()
                    .name(format!("ftgemm-pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                                    panics.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                            Err(_) => break, // all senders dropped
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, panics }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(f))
            .expect("pool workers gone");
    }

    /// Number of jobs that panicked since construction.
    pub fn panic_count(&self) -> usize {
        self.panics.load(Ordering::Relaxed)
    }

    /// Run a closure over each item, in parallel, and collect results in
    /// input order — the pool's fan-out/fan-in primitive.
    ///
    /// A job that panics still counts down the join latch (via a drop
    /// guard), so `map` never deadlocks on a panicking closure; the panic
    /// is re-raised on the calling thread once every job has settled.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        /// Counts the latch down even when the job unwinds, so the
        /// waiting caller is never stranded (the pool worker's
        /// `catch_unwind` would otherwise swallow the panic after the
        /// count-down was skipped).
        struct CountDown(Arc<Latch>);
        impl Drop for CountDown {
            fn drop(&mut self) {
                self.0.count_down();
            }
        }

        let f = Arc::new(f);
        let n = items.len();
        let results = Arc::new(Mutex::new(Vec::from_iter((0..n).map(|_| None))));
        let latch = Arc::new(Latch::new(n));
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let results = Arc::clone(&results);
            let latch = Arc::clone(&latch);
            self.execute(move || {
                let _armed = CountDown(latch);
                let r = f(item);
                results.lock().unwrap()[i] = Some(r);
            });
        }
        latch.wait();
        // Take the slots through the mutex rather than unwrapping the Arc:
        // the last job counts the latch down *before* its closure (and the
        // `results` clone it captured) is destroyed, so unique ownership
        // here would be a transient race.
        let mut slots = results.lock().unwrap();
        slots
            .iter_mut()
            .map(|r| r.take().expect("pool job panicked before producing a result"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Countdown latch for fan-in.
pub struct Latch {
    remaining: Mutex<usize>,
    cv: Condvar,
}

impl Latch {
    pub fn new(n: usize) -> Self {
        Latch { remaining: Mutex::new(n), cv: Condvar::new() }
    }

    pub fn count_down(&self) {
        let mut rem = self.remaining.lock().unwrap();
        *rem = rem.saturating_sub(1);
        if *rem == 0 {
            self.cv.notify_all();
        }
    }

    pub fn wait(&self) {
        let mut rem = self.remaining.lock().unwrap();
        while *rem > 0 {
            rem = self.cv.wait(rem).unwrap();
        }
    }
}

/// One-value rendezvous channel (`tokio::sync::oneshot` replacement).
pub mod oneshot {
    use std::sync::mpsc::{sync_channel, Receiver, SyncSender};

    pub struct OneSender<T>(SyncSender<T>);
    pub struct OneReceiver<T>(Receiver<T>);

    pub fn channel<T>() -> (OneSender<T>, OneReceiver<T>) {
        let (tx, rx) = sync_channel(1);
        (OneSender(tx), OneReceiver(rx))
    }

    impl<T> OneSender<T> {
        /// Send the value; returns Err(value) if the receiver is gone.
        pub fn send(self, value: T) -> Result<(), T> {
            self.0.send(value).map_err(|e| e.0)
        }
    }

    impl<T> OneReceiver<T> {
        /// Block until the value arrives; Err if the sender was dropped.
        pub fn recv(self) -> Result<T, RecvError> {
            self.0.recv().map_err(|_| RecvError)
        }

        /// Non-blocking probe: `Ok(None)` while the value is pending,
        /// `Ok(Some(v))` exactly once when it lands, `Err` if the sender
        /// was dropped (or the value was already taken).
        pub fn try_recv(&self) -> Result<Option<T>, RecvError> {
            match self.0.try_recv() {
                Ok(v) => Ok(Some(v)),
                Err(std::sync::mpsc::TryRecvError::Empty) => Ok(None),
                Err(std::sync::mpsc::TryRecvError::Disconnected) => Err(RecvError),
            }
        }

        pub fn recv_timeout(self, d: std::time::Duration) -> Result<T, RecvError> {
            self.0.recv_timeout(d).map_err(|_| RecvError)
        }
    }

    #[derive(Debug, PartialEq, Eq)]
    pub struct RecvError;
}

/// Multi-producer channel pair helper used by the engine loop.
pub fn request_channel<T>() -> (Sender<T>, Receiver<T>) {
    channel()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        let latch = Arc::new(Latch::new(100));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let l = Arc::clone(&latch);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                l.count_down();
            });
        }
        latch.wait();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.map((0..64).collect(), |x: i32| x * x);
        assert_eq!(out, (0..64).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn panics_are_contained_and_counted() {
        let pool = ThreadPool::new(2);
        let latch = Arc::new(Latch::new(1));
        let l2 = Arc::clone(&latch);
        pool.execute(|| panic!("boom"));
        pool.execute(move || l2.count_down());
        latch.wait();
        // the panicking job may still be unwinding; poll briefly
        for _ in 0..100 {
            if pool.panic_count() == 1 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(pool.panic_count(), 1);
    }

    #[test]
    #[should_panic(expected = "pool job panicked")]
    fn map_panics_loudly_instead_of_deadlocking() {
        let pool = ThreadPool::new(2);
        // a panicking job must still count the latch down (drop guard) so
        // map surfaces the failure instead of blocking forever
        let _ = pool.map(vec![0usize, 1, 2], |x| {
            assert!(x != 1, "boom");
            x
        });
    }

    #[test]
    fn oneshot_roundtrip() {
        let (tx, rx) = oneshot::channel();
        std::thread::spawn(move || tx.send(123).unwrap());
        assert_eq!(rx.recv().unwrap(), 123);
    }

    #[test]
    fn oneshot_sender_drop_errors() {
        let (tx, rx) = oneshot::channel::<i32>();
        drop(tx);
        assert!(rx.recv().is_err());
    }

    #[test]
    fn oneshot_try_recv_probes_without_blocking() {
        let (tx, rx) = oneshot::channel::<i32>();
        assert_eq!(rx.try_recv(), Ok(None));
        tx.send(5).unwrap();
        assert_eq!(rx.try_recv(), Ok(Some(5)));
        // value already taken: the channel reports disconnection
        assert!(rx.try_recv().is_err());
    }
}
