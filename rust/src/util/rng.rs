//! Deterministic PRNGs (no `rand` in the vendored crate set).
//!
//! [`SplitMix64`] seeds everything; [`Pcg32`] is the workhorse generator
//! (O'Neill 2014, `PCG-XSH-RR 64/32`). Both are tiny, fast, and — most
//! importantly for reproducing fault-injection experiments — fully
//! deterministic given a seed, so every campaign in EXPERIMENTS.md can be
//! replayed bit-exactly.

/// SplitMix64: used to expand a single `u64` seed into stream seeds.
#[derive(Debug, Clone)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32 — small-state, statistically solid generator.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Seed a generator; `stream` selects one of 2^63 independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience: derive both state and stream from one seed.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64(seed);
        let s = sm.next_u64();
        let st = sm.next_u64();
        Pcg32::new(s, st)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire's method).
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u32() as u64;
            let m = x * bound as u64;
            let l = m as u32;
            if l >= bound || l >= (u32::MAX - bound + 1) % bound {
                return (m >> 32) as u32;
            }
        }
    }

    pub fn usize_below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0 && bound <= u32::MAX as usize);
        self.below(bound as u32) as usize
    }

    /// Uniform f32 in `[0, 1)` with 24 bits of mantissa.
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in `[0, 1)` with 53 bits.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller (pairs discarded for simplicity).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Exponential with rate `lambda` (inter-arrival times of the Poisson
    /// SEU process in the fault campaigns).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3);
    }

    #[test]
    fn f32_is_in_unit_interval_and_roughly_uniform() {
        let mut rng = Pcg32::seeded(7);
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let x = rng.f32();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_respects_bound_and_hits_all_values() {
        let mut rng = Pcg32::seeded(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[rng.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut rng = Pcg32::seeded(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = rng.normal() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut rng = Pcg32::seeded(13);
        let lambda = 4.0;
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Pcg32::seeded(5);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
