//! Tiny declarative CLI parser (no clap in the vendored crate set).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, typed
//! accessors with defaults, and auto-generated `--help` text — enough for
//! the `ftgemm` binary and the bench harnesses.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Declares one option for help text + validation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// A parsed command line: subcommand, options, and positional args.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    Unknown(String),
    MissingValue(String),
    BadValue { key: String, value: String, hint: String },
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(name) => write!(f, "unknown option --{name}"),
            CliError::MissingValue(name) => write!(f, "option --{name} requires a value"),
            CliError::BadValue { key, value, hint } => {
                write!(f, "invalid value for --{key}: {value:?} ({hint})")
            }
        }
    }
}

impl std::error::Error for CliError {}

/// A command definition: name, options, and help blurb.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, opts: Vec::new() }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: false, default: None });
        self
    }

    pub fn opt(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: true, default });
        self
    }

    /// Parse `argv` (without the program name / subcommand itself).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut args = Args { command: Some(self.name.to_string()), ..Default::default() };
        for spec in &self.opts {
            if let (true, Some(d)) = (spec.takes_value, spec.default) {
                args.opts.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                let (key, inline) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| CliError::Unknown(key.clone()))?;
                if spec.takes_value {
                    let val = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| CliError::MissingValue(key.clone()))?,
                    };
                    args.opts.insert(key, val);
                } else {
                    args.flags.push(key);
                }
            } else {
                args.positional.push(tok.clone());
            }
        }
        Ok(args)
    }

    pub fn help(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}\n", self.name, self.about);
        let _ = writeln!(s, "OPTIONS:");
        for o in &self.opts {
            let meta = if o.takes_value { " <value>" } else { "" };
            let dflt = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            let _ = writeln!(s, "  --{}{meta}\n      {}{dflt}", o.name, o.help);
        }
        s
    }
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, CliError> {
        match self.opts.get(name) {
            None => Ok(None),
            Some(v) => v.parse::<T>().map(Some).map_err(|_| CliError::BadValue {
                key: name.to_string(),
                value: v.clone(),
                hint: std::any::type_name::<T>().to_string(),
            }),
        }
    }

    pub fn usize_or(&self, name: &str, dflt: usize) -> usize {
        self.get_parsed(name).ok().flatten().unwrap_or(dflt)
    }

    pub fn f64_or(&self, name: &str, dflt: f64) -> f64 {
        self.get_parsed(name).ok().flatten().unwrap_or(dflt)
    }

    pub fn str_or<'a>(&'a self, name: &str, dflt: &'a str) -> &'a str {
        self.get(name).unwrap_or(dflt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("serve", "TCP GEMM serving gateway")
            .opt("size", "matrix size", Some("128"))
            .opt("policy", "ft policy", Some("online"))
            .flag("verbose", "log more")
    }

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&sv(&[])).unwrap();
        assert_eq!(a.usize_or("size", 0), 128);
        assert_eq!(a.str_or("policy", ""), "online");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = cmd().parse(&sv(&["--size", "256", "--policy=offline", "--verbose"])).unwrap();
        assert_eq!(a.usize_or("size", 0), 256);
        assert_eq!(a.str_or("policy", ""), "offline");
        assert!(a.flag("verbose"));
    }

    #[test]
    fn positional_args_collected() {
        let a = cmd().parse(&sv(&["input.bin", "--size", "64", "out.bin"])).unwrap();
        assert_eq!(a.positional, vec!["input.bin", "out.bin"]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(matches!(cmd().parse(&sv(&["--nope"])), Err(CliError::Unknown(_))));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(
            cmd().parse(&sv(&["--size"])),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn bad_typed_value_reported() {
        let a = cmd().parse(&sv(&["--size", "abc"])).unwrap();
        assert!(a.get_parsed::<usize>("size").is_err());
    }

    #[test]
    fn help_mentions_every_option() {
        let h = cmd().help();
        for name in ["size", "policy", "verbose"] {
            assert!(h.contains(name));
        }
    }
}
