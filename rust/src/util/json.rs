//! Minimal, dependency-free JSON: a recursive-descent parser and a
//! serializer. Covers the full JSON grammar (RFC 8259) minus exotic float
//! edge cases; used for `artifacts/manifest.json` and the figures output.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in a `BTreeMap` for deterministic
/// serialization (stable diffs in figures_out/).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ---- constructors ----------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs<I: IntoIterator<Item = (String, Json)>>(it: I) -> Json {
        Json::Obj(it.into_iter().collect())
    }

    // ---- accessors -------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `a.b.c` path lookup.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|x| *x >= 0.0 && x.fract() == 0.0).map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn set(&mut self, key: &str, val: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        }
    }

    pub fn push(&mut self, val: Json) {
        if let Json::Arr(v) = self {
            v.push(val);
        }
    }

    // ---- parsing ---------------------------------------------------------

    /// Parse with the default nesting bound ([`Json::DEFAULT_MAX_DEPTH`]).
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        Json::parse_with_max_depth(text, Json::DEFAULT_MAX_DEPTH)
    }

    /// Containers nested deeper than this return a [`ParseError`] instead
    /// of recursing — `value()` is recursive descent, so unbounded input
    /// depth would otherwise overflow the thread stack.
    pub const DEFAULT_MAX_DEPTH: usize = 64;

    /// [`Json::parse`] with an explicit nesting bound (min 1).
    pub fn parse_with_max_depth(text: &str, max_depth: usize) -> Result<Json, ParseError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0, depth: 0, max_depth: max_depth.max(1) };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // ---- serialization ---------------------------------------------------
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(0));
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if !x.is_finite() {
                    // JSON has no Inf/NaN; null is the least-bad encoding
                    out.push_str("null");
                } else if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat((ind + 1) * 2));
                        item.write(out, Some(ind + 1));
                    } else {
                        item.write(out, None);
                    }
                }
                if let (Some(ind), false) = (indent, v.is_empty()) {
                    out.push('\n');
                    out.push_str(&" ".repeat(ind * 2));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat((ind + 1) * 2));
                        write_escaped(out, k);
                        out.push_str(": ");
                        val.write(out, Some(ind + 1));
                    } else {
                        write_escaped(out, k);
                        out.push(':');
                        val.write(out, None);
                    }
                }
                if let (Some(ind), false) = (indent, m.is_empty()) {
                    out.push('\n');
                    out.push_str(&" ".repeat(ind * 2));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None);
        f.write_str(&s)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
    /// Open containers around the current position.
    depth: usize,
    /// Bound on `depth` (stack-overflow guard for hostile input).
    max_depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn enter(&mut self) -> Result<(), ParseError> {
        if self.depth >= self.max_depth {
            return Err(self.err(&format!("nesting deeper than {}", self.max_depth)));
        }
        self.depth += 1;
        Ok(())
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        self.enter()?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => {
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        self.enter()?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => {
                    self.depth -= 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let hex = self
                            .b
                            .get(self.pos..self.pos + 4)
                            .ok_or_else(|| self.err("truncated \\u escape"))?;
                        let code = u32::from_str_radix(
                            std::str::from_utf8(hex)
                                .map_err(|_| self.err("bad \\u escape"))?,
                            16,
                        )
                        .map_err(|_| self.err("bad \\u escape"))?;
                        self.pos += 4;
                        // Surrogate pairs: JSON encodes astral chars as two \u escapes.
                        let ch = if (0xD800..0xDC00).contains(&code) {
                            if self.b.get(self.pos) == Some(&b'\\')
                                && self.b.get(self.pos + 1) == Some(&b'u')
                            {
                                let hex2 = self
                                    .b
                                    .get(self.pos + 2..self.pos + 6)
                                    .ok_or_else(|| self.err("truncated surrogate"))?;
                                let low = u32::from_str_radix(
                                    std::str::from_utf8(hex2)
                                        .map_err(|_| self.err("bad surrogate"))?,
                                    16,
                                )
                                .map_err(|_| self.err("bad surrogate"))?;
                                self.pos += 6;
                                0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00)
                            } else {
                                return Err(self.err("lone high surrogate"));
                            }
                        } else {
                            code
                        };
                        s.push(
                            char::from_u32(ch).ok_or_else(|| self.err("invalid codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let bytes = self
                            .b
                            .get(start..start + len)
                            .ok_or_else(|| self.err("truncated utf-8"))?;
                        let st = std::str::from_utf8(bytes)
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(st);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.path("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.path("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn roundtrips_escapes_and_unicode() {
        let cases = [r#""a\"b\\c\nd""#, r#""é€""#, r#""😀""#];
        for c in cases {
            let v = Json::parse(c).unwrap();
            let again = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, again, "{c}");
        }
    }

    #[test]
    fn rejects_malformed_input() {
        for bad in ["{", "[1,", "\"unterminated", "{\"a\" 1}", "01x", "[1] junk"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn pretty_print_roundtrips() {
        let v = Json::parse(r#"{"m": 128, "list": [1.5, true, null]}"#).unwrap();
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn integer_formatting_has_no_decimal_point() {
        assert_eq!(Json::Num(128.0).to_string(), "128");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn depth_1000_input_errors_instead_of_overflowing() {
        for bomb in [
            format!("{}1{}", "[".repeat(1000), "]".repeat(1000)),
            format!("{}1{}", "{\"a\":".repeat(1000), "}".repeat(1000)),
            // unclosed: must die at the bound, not at the missing closers
            "[".repeat(1000),
        ] {
            let err = Json::parse(&bomb).expect_err("depth bomb accepted");
            assert!(err.msg.contains("nesting deeper than 64"), "{err}");
        }
    }

    #[test]
    fn max_depth_is_configurable_and_inclusive() {
        let nested = |d: usize| format!("{}1{}", "[".repeat(d), "]".repeat(d));
        // depth == bound parses; depth == bound + 1 fails
        assert!(Json::parse_with_max_depth(&nested(64), 64).is_ok());
        assert!(Json::parse_with_max_depth(&nested(65), 64).is_err());
        assert!(Json::parse_with_max_depth(&nested(3), 2).is_err());
        assert!(Json::parse_with_max_depth(&nested(1000), 1000).is_ok());
        // default entrypoint uses DEFAULT_MAX_DEPTH
        assert!(Json::parse(&nested(Json::DEFAULT_MAX_DEPTH)).is_ok());
        assert!(Json::parse(&nested(Json::DEFAULT_MAX_DEPTH + 1)).is_err());
    }
}
