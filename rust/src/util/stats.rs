//! Numeric summary statistics for the bench harness and metrics
//! (criterion replacement lives on top of these).

/// Online mean/variance (Welford) + min/max.
#[derive(Debug, Clone)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Default for Running {
    fn default() -> Self {
        Running::new()
    }
}

impl Running {
    pub fn new() -> Self {
        Running { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact quantiles over a retained sample (fine at bench scale).
#[derive(Debug, Clone, Default)]
pub struct Quantiles {
    xs: Vec<f64>,
    sorted: bool,
}

impl Quantiles {
    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// q in [0,1]; linear interpolation between order statistics.
    pub fn quantile(&mut self, q: f64) -> f64 {
        assert!(!self.xs.is_empty());
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let pos = q.clamp(0.0, 1.0) * (self.xs.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            self.xs[lo]
        } else {
            let w = pos - lo as f64;
            self.xs[lo] * (1.0 - w) + self.xs[hi] * w
        }
    }

    pub fn median(&mut self) -> f64 {
        self.quantile(0.5)
    }

    pub fn p99(&mut self) -> f64 {
        self.quantile(0.99)
    }
}

/// Relative change in percent, the unit every EXPERIMENTS.md row uses.
pub fn pct_change(baseline: f64, value: f64) -> f64 {
    (value - baseline) / baseline * 100.0
}

/// Speedup of `fast` over `slow` in percent (paper convention: "X% faster").
pub fn speedup_pct(slow: f64, fast: f64) -> f64 {
    (slow / fast - 1.0) * 100.0
}

/// Overhead of `value` versus `baseline` in percent.
pub fn overhead_pct(baseline: f64, value: f64) -> f64 {
    (value / baseline - 1.0) * 100.0
}

/// Geometric mean (the right average for GFLOPS ratios across sizes).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_closed_form() {
        let mut r = Running::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            r.push(x);
        }
        assert_eq!(r.count(), 8);
        assert!((r.mean() - 5.0).abs() < 1e-12);
        assert!((r.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(r.min(), 2.0);
        assert_eq!(r.max(), 9.0);
    }

    #[test]
    fn quantiles_interpolate() {
        let mut q = Quantiles::default();
        for x in 0..101 {
            q.push(x as f64);
        }
        assert_eq!(q.median(), 50.0);
        assert_eq!(q.quantile(0.0), 0.0);
        assert_eq!(q.quantile(1.0), 100.0);
        assert!((q.quantile(0.25) - 25.0).abs() < 1e-9);
    }

    #[test]
    fn pct_helpers() {
        assert!((speedup_pct(2.0, 1.0) - 100.0).abs() < 1e-12);
        assert!((overhead_pct(1.0, 1.0889) - 8.89).abs() < 1e-9);
        assert!((pct_change(100.0, 150.0) - 50.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_equal_values_is_value() {
        assert!((geomean(&[3.0, 3.0, 3.0]) - 3.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }
}
