//! `ftgemm` — CLI for the fault-tolerant GEMM serving system.
//!
//! Subcommands:
//!   info      — artifact manifest + modeled device summary
//!   gemm      — run one GEMM through the coordinator (optionally injected)
//!   campaign  — run an SEU fault-injection campaign
//!   figures   — regenerate the paper's tables/figures from gpusim
//!   table1    — print the kernel-parameter presets

use std::path::PathBuf;
use std::process::ExitCode;

use ftgemm::abft::matrix::Matrix;
use ftgemm::coordinator::{
    Coordinator, CoordinatorConfig, FtLevel, FtPolicy, GemmRequest, Priority,
};
use ftgemm::faults::{FaultCampaign, SeuModel};
use ftgemm::figures::catalog;
use ftgemm::gpusim::device::{A100, T4};
use ftgemm::runtime::{Engine, EngineConfig};
use ftgemm::util::cli::Command;

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, rest)) => (c.as_str(), rest.to_vec()),
        None => {
            print_usage();
            return ExitCode::FAILURE;
        }
    };
    let result = match cmd {
        "info" => cmd_info(&rest),
        "gemm" => cmd_gemm(&rest),
        "campaign" => cmd_campaign(&rest),
        "figures" => cmd_figures(&rest),
        "serve" => cmd_serve(&rest),
        "table1" => cmd_table1(),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n");
            print_usage();
            return ExitCode::FAILURE;
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn print_usage() {
    println!(
        "ftgemm — high-performance GEMM with online fault tolerance (ICS'23 reproduction)\n\n\
         USAGE: ftgemm <command> [options]\n\n\
         COMMANDS:\n\
           info       artifact manifest + device model summary\n\
           gemm       run one GEMM (--m --n --k --policy none|online|offline --inject N\n\
                      --workers W --pools P --backend reference|blocked|blocked-scalar\n\
                      --priority low|normal|high\n\
                      --deadline-ms D --pack-cache-mb MB)\n\
           campaign   SEU injection campaign (--rounds --errors --policy --workers W\n\
                      --backend B)\n\
           figures    regenerate paper figures (--fig 9..22|table1 | --all) --out DIR\n\
           serve      GEMM serving gateway: TCP with a JSON wire protocol\n\
                      (--listen addr:port --threads N --max-frame-bytes B), or the\n\
                      legacy stdin line protocol when no listen address is given\n\
                      (--config FILE --backend B --workers W --pools P\n\
                      --pack-cache-mb MB)\n\
           table1     print Table 1 kernel parameters\n\
           help       this text"
    );
}

/// The CLI boundary of [`FtPolicy`]: same `FromStr` the wire protocol uses.
fn parse_policy(s: &str) -> anyhow::Result<FtPolicy> {
    s.parse::<FtPolicy>()
}

/// The CLI boundary of the typed [`FtLevel`]: parse or die with the
/// accepted spellings.
fn parse_level(s: &str) -> anyhow::Result<FtLevel> {
    s.parse::<FtLevel>()
}

fn parse_priority(s: &str) -> anyhow::Result<Priority> {
    s.parse::<Priority>()
}

fn start_coordinator(
    ft_level: FtLevel,
    workers: usize,
    pools: usize,
    backend: &str,
    pack_cache_mb: Option<usize>,
) -> anyhow::Result<Coordinator> {
    let engine = Engine::start(EngineConfig {
        workers,
        pools,
        backend: backend.to_string(),
        pack_cache_mb,
        ..Default::default()
    })?;
    let cfg = CoordinatorConfig { ft_level, ..Default::default() };
    Ok(Coordinator::new(engine, cfg))
}

/// Parse an optional `--pack-cache-mb` override (None = keep the config
/// or built-in default; 0 = disable the cache).
fn parse_pack_cache_mb(arg: Option<&str>) -> anyhow::Result<Option<usize>> {
    match arg {
        None => Ok(None),
        Some(s) => s
            .parse::<usize>()
            .map(Some)
            .map_err(|_| anyhow::anyhow!("--pack-cache-mb: bad integer {s:?}")),
    }
}

fn cmd_info(rest: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("info", "manifest + device summary");
    cmd.parse(rest)?;
    match ftgemm::runtime::Manifest::discover() {
        Ok(m) => {
            println!("artifacts: {} in {:?}", m.len(), m.dir);
            for a in m.iter() {
                println!(
                    "  {:28} {:10} {}x{}x{} {}",
                    a.name,
                    format!("{:?}", a.kind),
                    a.m,
                    a.n,
                    a.k,
                    a.ft_level.as_deref().unwrap_or("-")
                );
            }
        }
        Err(e) => println!(
            "artifacts: not built ({e}); serving falls back to the built-in manifest \
             + reference backend"
        ),
    }
    for d in [T4, A100] {
        println!(
            "device model {}: {} SMs @ {:.2} GHz, peak {:.0} GFLOPS, {:.0} GB/s",
            d.name,
            d.sms,
            d.clock_ghz,
            d.peak_gflops(),
            d.dram_gbs
        );
    }
    let reg = ftgemm::runtime::BackendRegistry::global();
    println!("backends:");
    for name in reg.names() {
        let info = reg.info(name)?;
        println!(
            "  {:14} kernel={:8} fused_ft={}  {}",
            info.name, info.kernel_isa, info.fused_ft, info.description
        );
    }
    // Resolved host blocking: what the blocked backend would actually use
    // for each shape-class bucket on each kernel ISA this host supports —
    // including any FTGEMM_FORCE_KC/FTGEMM_FORCE_NC override in effect,
    // since `host_tiles_for` reads them fresh per call.
    println!("host blocking (macro MCxKCxNC, micro MRxNR per shape-class bucket):");
    for (var, note) in [
        ("FTGEMM_FORCE_KC", "overrides every class KC cap below (clamped to k)"),
        ("FTGEMM_FORCE_NC", "overrides every class NC below (power of two >= 16)"),
    ] {
        if let Ok(v) = std::env::var(var) {
            println!("  {var}={v} ({note})");
        }
    }
    for b in ftgemm::codegen::select::BUCKETS {
        for isa in ftgemm::runtime::KernelIsa::supported() {
            let t = ftgemm::codegen::select::host_tiles_for(isa, b.m, b.n, b.k);
            println!(
                "  {:6} {:>4}x{:<4} k={:<4} [{:6}] MC={:<3} KC={:<3} NC={:<3} micro {}x{}",
                b.name(),
                b.m,
                b.n,
                b.k,
                isa.name(),
                t.mc,
                t.kc,
                t.nc,
                t.mr,
                t.nr
            );
        }
    }
    // one CoordinatorStats snapshot — the same struct the gateway's
    // `metrics` verb reports
    let engine = Engine::start(EngineConfig::default())?;
    let coord = Coordinator::new(engine, CoordinatorConfig::default());
    let s = coord.stats();
    println!(
        "coordinator (default engine): backend={} isa={} workers={} max_inflight={} \
         queue_depth={} engine_inflight={}",
        s.backend.name,
        s.backend.kernel_isa,
        s.workers,
        s.max_inflight,
        s.queue_depth,
        s.engine_inflight
    );
    for (p, ps) in s.pools.iter().enumerate() {
        println!(
            "  pool {p}: queue_depth={} engine_inflight={} routed={} dispatched={} steals={} \
             affinity_hits={} steal_wait_us={}",
            ps.queue_depth,
            ps.engine_inflight,
            ps.routed,
            ps.dispatched,
            ps.steals,
            ps.affinity_hits,
            ps.steal_wait_us
        );
        if let Some(pc) = &ps.pack_cache {
            println!(
                "    pack cache: hits={} misses={} evictions={} entries={} bytes={}",
                pc.hits, pc.misses, pc.evictions, pc.entries, pc.bytes
            );
        }
    }
    match &s.pack_cache {
        Some(pc) => println!(
            "pack cache (all pools): hits={} misses={} evictions={} entries={} bytes={}",
            pc.hits, pc.misses, pc.evictions, pc.entries, pc.bytes
        ),
        None => println!("pack cache: disabled (pack_cache_mb = 0)"),
    }
    Ok(())
}

fn cmd_gemm(rest: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("gemm", "run one GEMM through the coordinator")
        .opt("m", "rows of A/C", Some("128"))
        .opt("n", "cols of B/C", Some("128"))
        .opt("k", "inner dimension", Some("128"))
        .opt("policy", "none|online|offline", Some("online"))
        .opt("inject", "number of SEUs to inject", Some("0"))
        .opt("level", "online FT granularity tb|warp|thread", Some("tb"))
        .opt("workers", "engine workers per pool", Some("1"))
        .opt("pools", "engine pools (shards)", Some("1"))
        .opt("backend", "execution backend reference|blocked|blocked-scalar", Some("reference"))
        .opt("priority", "dispatch priority low|normal|high", Some("normal"))
        .opt("deadline-ms", "fail if still queued after this long; 0 = none", Some("0"))
        .opt("pack-cache-mb", "packed-operand cache MiB per pool; 0 disables", None)
        .opt("seed", "rng seed", Some("42"));
    let args = cmd.parse(rest)?;
    let (m, n, k) = (args.usize_or("m", 128), args.usize_or("n", 128), args.usize_or("k", 128));
    let policy = parse_policy(args.str_or("policy", "online"))?;
    let inject = args.usize_or("inject", 0);
    let seed = args.usize_or("seed", 42) as u64;
    let priority = parse_priority(args.str_or("priority", "normal"))?;
    let deadline_ms = args.usize_or("deadline-ms", 0);

    let level = parse_level(args.str_or("level", "tb"))?;
    let coord = start_coordinator(
        level,
        args.usize_or("workers", 1),
        args.usize_or("pools", 1),
        args.str_or("backend", "reference"),
        parse_pack_cache_mb(args.get("pack-cache-mb"))?,
    )?;
    let a = Matrix::rand_uniform(m, k, seed);
    let b = Matrix::rand_uniform(k, n, seed + 1);
    let want = a.matmul(&b);
    let geom = ftgemm::faults::model::KernelGeom::for_shape(m, n, k);
    let mut rng = ftgemm::util::rng::Pcg32::seeded(seed);
    let plan = SeuModel::PerGemm { count: inject }.plan(&geom, 0.0, &mut rng);

    let mut req = GemmRequest::new(a, b).policy(policy).inject(plan.clone()).priority(priority);
    if deadline_ms > 0 {
        req = req.deadline(std::time::Duration::from_millis(deadline_ms as u64));
    }
    let resp = coord.submit(req)?.wait()?;
    let (out, meta) = (resp.result, resp.meta);
    println!(
        "C = A({m}x{k}) * B({k}x{n})  policy={}  buckets={:?}  request id={} priority={} \
         queued={:?}",
        policy.name(),
        out.buckets,
        meta.id,
        meta.priority.as_str(),
        meta.queued
    );
    println!(
        "injected {}  detected {}  corrected {}  recomputes {}  launches {}",
        plan.len(),
        out.errors_detected,
        out.errors_corrected,
        out.recomputes,
        out.kernel_launches
    );
    println!(
        "exec {:?}  max|C - ref| = {:.3e}",
        out.exec_time,
        out.c.max_abs_diff(&want)
    );
    Ok(())
}

fn cmd_campaign(rest: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("campaign", "SEU fault-injection campaign")
        .opt("m", "rows", Some("128"))
        .opt("n", "cols", Some("128"))
        .opt("k", "inner", Some("128"))
        .opt("rounds", "number of GEMMs", Some("10"))
        .opt("errors", "SEUs per GEMM", Some("4"))
        .opt("policy", "online|offline", Some("online"))
        .opt("workers", "engine worker pool size", Some("1"))
        .opt("backend", "execution backend reference|blocked|blocked-scalar", Some("reference"))
        .opt("seed", "rng seed", Some("7"));
    let args = cmd.parse(rest)?;
    let coord = start_coordinator(
        FtLevel::Tb,
        args.usize_or("workers", 1),
        1,
        args.str_or("backend", "reference"),
        None,
    )?;
    let campaign = FaultCampaign::new(
        coord,
        SeuModel::PerGemm { count: args.usize_or("errors", 4) },
        parse_policy(args.str_or("policy", "online"))?,
        args.usize_or("seed", 7) as u64,
    );
    let report = campaign.run(
        args.usize_or("m", 128),
        args.usize_or("n", 128),
        args.usize_or("k", 128),
        args.usize_or("rounds", 10),
    )?;
    println!("campaign: {report:#?}");
    println!("errors/minute: {:.1}", report.errors_per_minute());
    anyhow::ensure!(report.fully_detected(), "some injected errors went undetected!");
    Ok(())
}

fn cmd_figures(rest: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("figures", "regenerate paper figures")
        .opt("fig", "figure id: table1, 9..22", None)
        .opt("out", "output directory", Some("figures_out"))
        .flag("all", "regenerate everything")
        .flag("print", "also print markdown to stdout");
    let args = cmd.parse(rest)?;
    let out = PathBuf::from(args.str_or("out", "figures_out"));
    let ids: Vec<String> = if args.flag("all") {
        catalog::FIGURE_IDS.iter().map(|s| s.to_string()).collect()
    } else {
        match args.get("fig") {
            Some(f) => vec![f.to_string()],
            None => anyhow::bail!("pass --fig <id> or --all"),
        }
    };
    for id in &ids {
        let files = catalog::write(id, &out)?;
        println!("fig {id}: {}", files.join(", "));
        if args.flag("print") {
            for t in catalog::generate(id)? {
                println!("{}", t.to_markdown());
            }
        }
    }
    Ok(())
}

fn cmd_table1() -> anyhow::Result<()> {
    println!("{}", ftgemm::figures::table1().to_markdown());
    Ok(())
}

/// The launcher for both serving front-ends:
///
/// * **TCP gateway** (`--listen addr:port`, or a `[serve]` config
///   section): the newline-delimited JSON protocol of `ftgemm::serve`
///   dispatched straight onto `Coordinator::submit` — see DESIGN.md
///   "Serving gateway" for the wire grammar and error taxonomy.
/// * **stdin line protocol** (no listen address): the original
///   single-process harness driving the batcher. Protocol (one request
///   per line):
///
///       GEMM <m> <n> <k> <policy> [seed] [inject] [priority]
///       STATS
///       QUIT
///
///   Responses are single lines: `OK ...` / `ERR <msg>`.
///
/// Config comes from `--config <file>`
/// ([engine]/[coordinator]/[batcher]/[serve] sections — see
/// `util::config`); `--listen/--threads/--max-frame-bytes` override the
/// `[serve]` keys.
fn cmd_serve(rest: &[String]) -> anyhow::Result<()> {
    use ftgemm::coordinator::batcher::Batcher;
    use std::io::BufRead;

    let cmd = Command::new("serve", "TCP GEMM serving gateway (or stdin line protocol)")
        .opt("config", "config file (TOML subset)", None)
        .opt("backend", "override [engine].backend (reference|blocked|blocked-scalar)", None)
        .opt("workers", "override [engine].workers (workers per pool)", None)
        .opt("pools", "override [engine].pools (shard count)", None)
        .opt("pack-cache-mb", "override [engine].pack_cache_mb (0 disables)", None)
        .opt("listen", "bind addr:port and serve the TCP wire protocol", None)
        .opt("threads", "connection-thread pool size (TCP mode)", None)
        .opt("max-frame-bytes", "per-frame byte bound (TCP mode)", None);
    let args = cmd.parse(rest)?;
    let cfg = match args.get("config") {
        Some(path) => ftgemm::util::config::Config::load(path)?,
        None => ftgemm::util::config::Config::default(),
    };
    let mut engine_cfg = cfg.engine()?;
    if let Some(backend) = args.get("backend") {
        engine_cfg.backend = backend.to_string();
    }
    if let Some(workers) = args.get("workers") {
        engine_cfg.workers = workers
            .parse()
            .map_err(|_| anyhow::anyhow!("--workers: bad integer {workers:?}"))?;
    }
    if let Some(pools) = args.get("pools") {
        engine_cfg.pools = pools
            .parse()
            .map_err(|_| anyhow::anyhow!("--pools: bad integer {pools:?}"))?;
        anyhow::ensure!(engine_cfg.pools >= 1, "--pools must be >= 1");
    }
    if let Some(mb) = parse_pack_cache_mb(args.get("pack-cache-mb"))? {
        engine_cfg.pack_cache_mb = Some(mb);
    }
    let engine = Engine::start(engine_cfg)?;
    let coord = Coordinator::new(engine, cfg.coordinator()?);

    if args.get("listen").is_some() || cfg.has_serve_section() {
        let mut serve_cfg = cfg.serve()?;
        if let Some(listen) = args.get("listen") {
            serve_cfg.listen = listen.to_string();
        }
        if let Some(threads) = args.get("threads") {
            serve_cfg.threads = threads
                .parse()
                .map_err(|_| anyhow::anyhow!("--threads: bad integer {threads:?}"))?;
        }
        if let Some(bytes) = args.get("max-frame-bytes") {
            serve_cfg.max_frame_bytes = bytes
                .parse()
                .map_err(|_| anyhow::anyhow!("--max-frame-bytes: bad integer {bytes:?}"))?;
        }
        let gateway = ftgemm::serve::Gateway::start(coord, serve_cfg)?;
        // stdout so harnesses can wait for readiness by reading one line
        println!("ftgemm serve: listening on {}", gateway.local_addr());
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }

    let batcher = Batcher::start(coord.clone(), cfg.batcher()?);

    eprintln!("ftgemm serve: ready (GEMM m n k policy [seed] [inject] [priority] | STATS | QUIT)");
    let stdin = std::io::stdin();
    let mut id = 0u64;
    for line in stdin.lock().lines() {
        let line = line?;
        let toks: Vec<&str> = line.split_whitespace().collect();
        match toks.as_slice() {
            [] => continue,
            ["QUIT"] | ["quit"] => break,
            ["STATS"] | ["stats"] => {
                println!(
                    "OK stats counters={:?} batch={:?} mean_latency_s={:.6} queued={} \
                     max_inflight={} engine_inflight={}",
                    coord.counters().snapshot(),
                    batcher.stats(),
                    coord.latency().mean_secs(),
                    coord.queue_depth(),
                    coord.max_inflight(),
                    coord.engine().inflight()
                );
            }
            ["GEMM", m, n, k, policy, tail @ ..] | ["gemm", m, n, k, policy, tail @ ..] => {
                id += 1;
                match serve_one(&batcher, m, n, k, policy, tail) {
                    Ok(msg) => println!("OK gemm id={id} {msg}"),
                    Err(e) => println!("ERR gemm id={id} {e:#}"),
                }
            }
            _ => println!("ERR unknown request {line:?}"),
        }
    }
    println!("OK bye");
    Ok(())
}

fn serve_one(
    batcher: &ftgemm::coordinator::batcher::Batcher,
    m: &str,
    n: &str,
    k: &str,
    policy: &str,
    tail: &[&str],
) -> anyhow::Result<String> {
    let parse = |s: &str| -> anyhow::Result<usize> {
        s.parse().map_err(|_| anyhow::anyhow!("bad integer {s:?}"))
    };
    let (m, n, k) = (parse(m)?, parse(n)?, parse(k)?);
    let policy = parse_policy(policy)?;
    let seed: u64 = tail.first().and_then(|s| s.parse().ok()).unwrap_or(1);
    let inject: usize = tail.get(1).and_then(|s| s.parse().ok()).unwrap_or(0);
    let priority = match tail.get(2) {
        Some(p) => parse_priority(p)?,
        None => Priority::Normal,
    };
    let a = Matrix::rand_uniform(m, k, seed);
    let b = Matrix::rand_uniform(k, n, seed + 1);
    let geom = ftgemm::faults::model::KernelGeom::for_shape(m, n, k);
    let mut rng = ftgemm::util::rng::Pcg32::seeded(seed);
    let plan = SeuModel::PerGemm { count: inject }.plan(&geom, 0.0, &mut rng);
    let req = GemmRequest::new(a, b).policy(policy).inject(plan).priority(priority);
    let resp = batcher.submit(req)?.wait()?;
    let out = resp.result;
    Ok(format!(
        "buckets={:?} detected={} corrected={} recomputes={} launches={} time_us={} queued_us={}",
        out.buckets,
        out.errors_detected,
        out.errors_corrected,
        out.recomputes,
        out.kernel_launches,
        out.exec_time.as_micros(),
        resp.meta.queued.as_micros()
    ))
}
