//! `loadgen` — closed-loop (and open-loop) load harness for the TCP
//! serving gateway.
//!
//!     cargo run --release --bin loadgen -- --addr 127.0.0.1:7421 \
//!         --clients 8 --requests 200 [--mode closed|open] [--rate R] \
//!         [--preset ci-smoke] [--mix small,medium] [--policies online,none] \
//!         [--priorities normal,high] [--deadline-ms D] [--inject N] \
//!         [--seed-reuse PCT] [--sweep-clients 1,2,4,8] [--duration-cap 60s] [--pools P] \
//!         [--max-p99-ms P] [--bench-out BENCH_pipeline.json] \
//!         [--append-serving]
//!
//! Each client opens one connection and drives the newline-delimited JSON
//! protocol of `ftgemm::serve`:
//!
//! * **closed** loop (default): send one GEMM, wait for its response,
//!   repeat — concurrency equals `--clients`, latency is send-to-response.
//! * **open** loop: each client issues at a fixed schedule (`--rate`
//!   requests/s total across clients) without waiting, a reader thread
//!   settles responses; latency is *scheduled*-send-to-response, so queue
//!   buildup shows up as latency, not as reduced throughput.
//!
//! The workload cycles deterministically through shape classes
//! (`small`=64, `medium`=128, `large`=256, `huge`=512, cube GEMMs) ×
//! `--policies` × `--priorities`; `--inject N` plants N correctable SEUs
//! per request server-side; `--seed-reuse PCT` makes that percentage of
//! requests reuse the base `--seed` instead of a per-request one, so the
//! server's packed-operand cache sees repeat operands like a production
//! mix would. `--preset NAME` defaults the mix knobs from
//! the shared table in `ftgemm::bench::mix` (explicit flags still win) so
//! CI and by-hand runs measure the same workload. Per run it reports
//! ok/expired/rejected/canceled/failed/protocol-error counts, p50/p95/p99
//! latency, and throughput; `--sweep-clients` repeats the run per client
//! count to trace the throughput-vs-inflight curve.
//!
//! `--bench-out FILE` merges a `serving` series into an existing
//! schema-/5 `BENCH_pipeline.json` (written by `cargo bench --bench
//! hotpath`), which CI's `bench-check --require-serving` then validates.
//! `--pools P` labels every entry with the server's shard count, and
//! `--append-serving` appends to the existing series instead of replacing
//! it — run once against a `--pools 1` server and again (appending)
//! against a multi-pool server, and the merge derives a `pool_scaling`
//! block (baseline vs top rps at the widest common client count) that
//! `bench-check --require-scaling` gates on.
//!
//! Exit is nonzero when any run saw a protocol error, produced zero OK
//! responses, or missed `--max-p99-ms`.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::process::ExitCode;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};
use ftgemm::bench::mix;
use ftgemm::coordinator::{FtPolicy, Priority};
use ftgemm::serve::proto::GemmSpec;
use ftgemm::util::cli::Command;
use ftgemm::util::json::Json;
use ftgemm::util::stats::Quantiles;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    Closed,
    Open,
}

impl Mode {
    fn as_str(self) -> &'static str {
        match self {
            Mode::Closed => "closed",
            Mode::Open => "open",
        }
    }
}

/// The parsed workload: everything a run needs except the client count.
struct Workload {
    addr: String,
    mode: Mode,
    requests: usize,
    /// Open-loop total request rate across all clients (requests/s).
    rate: f64,
    /// Cube GEMM sizes, one per shape class in the `--mix`.
    shapes: Vec<usize>,
    policies: Vec<FtPolicy>,
    priorities: Vec<Priority>,
    deadline_ms: u64,
    inject: usize,
    seed: u64,
    /// Percentage (0–100) of requests that reuse `seed` verbatim instead
    /// of `seed + seq` — repeat operands for the server's pack cache.
    seed_reuse_pct: usize,
    duration_cap: Duration,
}

impl Workload {
    /// The deterministic request stream: global sequence number -> spec.
    fn spec_for(&self, id: u64, seq: u64) -> GemmSpec {
        let s = seq as usize;
        let size = self.shapes[s % self.shapes.len()];
        let mut spec = GemmSpec::new(size, size, size);
        spec.id = id;
        spec.policy = self.policies[(s / self.shapes.len()) % self.policies.len()];
        let cycle = self.shapes.len() * self.policies.len();
        spec.priority = self.priorities[(s / cycle) % self.priorities.len()];
        // Deterministic reuse pattern: seq·61 mod 100 visits every
        // residue (gcd(61, 100) = 1), so a PCT threshold selects exactly
        // PCT% of any 100 consecutive requests, spread evenly rather
        // than front-loaded.
        spec.seed = if (seq.wrapping_mul(61) % 100) < self.seed_reuse_pct as u64 {
            self.seed
        } else {
            self.seed.wrapping_add(seq)
        };
        spec.inject = self.inject;
        if self.deadline_ms > 0 {
            spec.deadline_ms = Some(self.deadline_ms);
        }
        spec
    }
}

/// Per-run outcome counters + retained latency sample.
#[derive(Default)]
struct Tally {
    ok: u64,
    expired: u64,
    rejected: u64,
    canceled: u64,
    failed: u64,
    protocol_errors: u64,
    sent: u64,
    lat_ms: Vec<f64>,
}

impl Tally {
    fn absorb(&mut self, other: Tally) {
        self.ok += other.ok;
        self.expired += other.expired;
        self.rejected += other.rejected;
        self.canceled += other.canceled;
        self.failed += other.failed;
        self.protocol_errors += other.protocol_errors;
        self.sent += other.sent;
        self.lat_ms.extend(other.lat_ms);
    }

    /// Sort one response line into the error taxonomy (DESIGN.md
    /// "Serving gateway"); `lat_ms` is recorded only for OK responses.
    fn classify(&mut self, line: &str, lat_ms: Option<f64>) {
        let Ok(v) = Json::parse(line.trim()) else {
            self.protocol_errors += 1;
            return;
        };
        if v.get("ok").and_then(Json::as_bool) == Some(true) {
            self.ok += 1;
            if let Some(ms) = lat_ms {
                self.lat_ms.push(ms);
            }
            return;
        }
        match v.get("error").and_then(Json::as_str) {
            Some("deadline-expired") => self.expired += 1,
            Some("admission-reject") => self.rejected += 1,
            Some("canceled") => self.canceled += 1,
            Some("parse") | Some("validation") => self.protocol_errors += 1,
            _ => self.failed += 1,
        }
    }
}

/// One completed run (one point on the throughput-vs-inflight curve).
struct RunResult {
    mode: Mode,
    clients: usize,
    /// Server shard count this run measured (`--pools` label).
    pools: usize,
    tally: Tally,
    wall_s: f64,
}

impl RunResult {
    fn percentiles(&self) -> Option<(f64, f64, f64, f64)> {
        if self.tally.lat_ms.is_empty() {
            return None;
        }
        let mut q = Quantiles::default();
        let mut sum = 0.0;
        for &ms in &self.tally.lat_ms {
            q.push(ms);
            sum += ms;
        }
        let mean = sum / q.len() as f64;
        Some((q.quantile(0.50), q.quantile(0.95), q.quantile(0.99), mean))
    }

    fn rps(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.tally.ok as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// One `serving[]` entry of the BENCH_pipeline.json /4 schema.
    fn to_json(&self) -> Option<Json> {
        let (p50, p95, p99, mean) = self.percentiles()?;
        let t = &self.tally;
        let mut e = Json::obj();
        e.set("mode", Json::from(self.mode.as_str()));
        e.set("clients", Json::Num(self.clients as f64));
        e.set("pools", Json::Num(self.pools as f64));
        e.set("inflight", Json::Num(self.clients as f64));
        e.set("requests", Json::Num(t.sent as f64));
        e.set("ok", Json::Num(t.ok as f64));
        e.set("expired", Json::Num(t.expired as f64));
        e.set("rejected", Json::Num(t.rejected as f64));
        e.set("canceled", Json::Num(t.canceled as f64));
        e.set("failed", Json::Num(t.failed as f64));
        e.set("protocol_errors", Json::Num(t.protocol_errors as f64));
        e.set("p50_ms", Json::Num(p50));
        e.set("p95_ms", Json::Num(p95));
        e.set("p99_ms", Json::Num(p99));
        e.set("mean_ms", Json::Num(mean));
        e.set("rps", Json::Num(self.rps()));
        Some(e)
    }
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = Command::new("loadgen", "closed-loop load harness for the TCP serving gateway")
        .opt("addr", "gateway address", Some("127.0.0.1:7421"))
        .opt("clients", "concurrent client connections", Some("8"))
        .opt("requests", "total requests per run (split across clients)", Some("200"))
        .opt("mode", "closed (send-wait-repeat) or open (fixed schedule)", Some("closed"))
        .opt("rate", "open-loop total requests/s across clients", Some("50"))
        .opt("preset", "named mix preset (see ftgemm::bench::mix); flags below override", None)
        .opt("mix", "shape classes to cycle (small|medium|large|huge) [default: small,medium]", None)
        .opt("policies", "FT policies to cycle (none|online|offline) [default: online]", None)
        .opt("priorities", "priorities to cycle (low|normal|high) [default: normal]", None)
        .opt("deadline-ms", "per-request queue deadline (0 = none)", Some("0"))
        .opt("inject", "SEUs injected per request server-side [default: 0]", None)
        .opt("seed", "base operand seed (seq is added per request)", Some("42"))
        .opt("seed-reuse", "percent of requests reusing the base seed verbatim [default: 0]", None)
        .opt("duration-cap", "stop issuing after this long, e.g. 60s", Some("60s"))
        .opt("sweep-clients", "comma list: one run per client count", None)
        .opt("pools", "server [engine].pools label recorded in serving entries", Some("1"))
        .opt("bench-out", "merge a `serving` series into this schema-/5 file", None)
        .flag("append-serving", "append to the file's serving series instead of replacing it")
        .opt("max-p99-ms", "fail the run if p99 exceeds this (0 = off)", Some("0"));
    let args = match cmd.parse(&argv) {
        Ok(args) => args,
        Err(e) => {
            eprintln!("loadgen: {e}\n\n{}", cmd.help());
            return ExitCode::FAILURE;
        }
    };
    match run(&args) {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::FAILURE,
        Err(e) => {
            eprintln!("loadgen FAILED: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn run(args: &ftgemm::util::cli::Args) -> Result<bool> {
    let workload = parse_workload(args)?;
    let sweep = match args.get("sweep-clients") {
        Some(list) => parse_list(list, "sweep-clients", |s| {
            s.parse::<usize>().map_err(|_| anyhow!("bad client count {s:?}"))
        })?,
        None => vec![args.usize_or("clients", 8)],
    };
    let max_p99_ms = args.f64_or("max-p99-ms", 0.0);
    let pools = args.usize_or("pools", 1);
    if pools == 0 {
        bail!("--pools must be >= 1");
    }

    let mut entries = Json::Arr(Vec::new());
    let mut all_ok = true;
    for &clients in &sweep {
        if clients == 0 {
            bail!("--sweep-clients/--clients entries must be >= 1");
        }
        let result = run_once(&workload, clients, pools)?;
        all_ok &= report(&result, max_p99_ms);
        if let Some(entry) = result.to_json() {
            entries.push(entry);
        }
    }

    if let Some(path) = args.get("bench-out") {
        merge_serving(path, entries, args.flag("append-serving"))?;
        println!("merged serving series into {path}");
    }
    Ok(all_ok)
}

/// Print the run summary and apply the pass/fail gates.
fn report(r: &RunResult, max_p99_ms: f64) -> bool {
    let t = &r.tally;
    println!(
        "{} loop, {} clients: {} sent in {:.2}s — ok {} expired {} rejected {} canceled {} \
         failed {} protocol-errors {}",
        r.mode.as_str(),
        r.clients,
        t.sent,
        r.wall_s,
        t.ok,
        t.expired,
        t.rejected,
        t.canceled,
        t.failed,
        t.protocol_errors,
    );
    let mut ok = true;
    match r.percentiles() {
        Some((p50, p95, p99, mean)) => {
            println!(
                "  latency ms: p50 {p50:.2} p95 {p95:.2} p99 {p99:.2} mean {mean:.2}; \
                 throughput {:.1} ok/s",
                r.rps()
            );
            if max_p99_ms > 0.0 && p99 > max_p99_ms {
                eprintln!("  GATE FAILED: p99 {p99:.2}ms > --max-p99-ms {max_p99_ms:.2}ms");
                ok = false;
            }
        }
        None => {
            eprintln!("  GATE FAILED: no OK responses — nothing to measure");
            ok = false;
        }
    }
    if t.protocol_errors > 0 {
        eprintln!("  GATE FAILED: {} protocol errors (want 0)", t.protocol_errors);
        ok = false;
    }
    ok
}

fn parse_workload(args: &ftgemm::util::cli::Args) -> Result<Workload> {
    let mode = match args.str_or("mode", "closed") {
        "closed" => Mode::Closed,
        "open" => Mode::Open,
        other => bail!("--mode must be closed|open, got {other:?}"),
    };
    // resolution order for the mix knobs: explicit flag > preset > built-in
    let preset = match args.get("preset") {
        Some(name) => Some(mix::preset(name).ok_or_else(|| {
            anyhow!("unknown --preset {name:?}; known presets:\n{}", mix::describe_presets())
        })?),
        None => None,
    };
    let mix_csv = args.get("mix").or(preset.map(|p| p.shapes)).unwrap_or("small,medium");
    let shapes = parse_list(mix_csv, "mix", |s| match s {
        "small" => Ok(64),
        "medium" => Ok(128),
        "large" => Ok(256),
        "huge" => Ok(512),
        other => Err(anyhow!("unknown shape class {other:?} (small|medium|large|huge)")),
    })?;
    let policies_csv = args.get("policies").or(preset.map(|p| p.policies)).unwrap_or("online");
    let policies = parse_list(policies_csv, "policies", str::parse)?;
    let prio_csv = args.get("priorities").or(preset.map(|p| p.priorities)).unwrap_or("normal");
    let priorities = parse_list(prio_csv, "priorities", str::parse)?;
    let inject = match args.get("inject") {
        Some(v) => v.parse().map_err(|_| anyhow!("--inject: bad integer {v:?}"))?,
        None => preset.map(|p| p.inject).unwrap_or(0),
    };
    let seed_reuse_pct: usize = match args.get("seed-reuse") {
        Some(v) => v.parse().map_err(|_| anyhow!("--seed-reuse: bad integer {v:?}"))?,
        None => preset.map(|p| p.seed_reuse_pct).unwrap_or(0),
    };
    if seed_reuse_pct > 100 {
        bail!("--seed-reuse is a percentage (0-100), got {seed_reuse_pct}");
    }
    let rate = args.f64_or("rate", 50.0);
    if mode == Mode::Open && !(rate.is_finite() && rate > 0.0) {
        bail!("--rate must be a positive rate in open mode, got {rate}");
    }
    Ok(Workload {
        addr: args.str_or("addr", "127.0.0.1:7421").to_string(),
        mode,
        requests: args.usize_or("requests", 200),
        rate,
        shapes,
        policies,
        priorities,
        deadline_ms: args.usize_or("deadline-ms", 0) as u64,
        inject,
        seed: args.usize_or("seed", 42) as u64,
        seed_reuse_pct,
        duration_cap: parse_duration(args.str_or("duration-cap", "60s"))?,
    })
}

fn parse_list<T>(csv: &str, opt: &str, parse: impl Fn(&str) -> Result<T>) -> Result<Vec<T>> {
    let out = csv
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(parse)
        .collect::<Result<Vec<T>>>()
        .with_context(|| format!("--{opt} {csv:?}"))?;
    if out.is_empty() {
        bail!("--{opt} must name at least one entry, got {csv:?}");
    }
    Ok(out)
}

/// `"60s"`, `"500ms"`, or a bare number of seconds.
fn parse_duration(s: &str) -> Result<Duration> {
    let (digits, scale_ms) = match s.strip_suffix("ms") {
        Some(d) => (d, 1u64),
        None => (s.strip_suffix('s').unwrap_or(s), 1000u64),
    };
    let n: u64 = digits.parse().map_err(|_| anyhow!("bad duration {s:?} (e.g. 60s, 500ms)"))?;
    Ok(Duration::from_millis(n * scale_ms))
}

/// Execute one run at `clients` connections and aggregate the tallies.
fn run_once(w: &Workload, clients: usize, pools: usize) -> Result<RunResult> {
    let shared = Arc::new(Mutex::new(Tally::default()));
    let start = Instant::now();
    let cap = start + w.duration_cap;
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            // split `requests` across clients; early clients take the
            // remainder so every request is issued exactly once
            let n = w.requests / clients + usize::from(c < w.requests % clients);
            let shared = Arc::clone(&shared);
            let client = ClientCfg {
                addr: w.addr.clone(),
                index: c,
                stride: clients,
                count: n,
                cap,
            };
            std::thread::Builder::new()
                .name(format!("loadgen-{c}"))
                .spawn({
                    let w = clone_workload(w);
                    move || {
                        let tally = match w.mode {
                            Mode::Closed => client_closed(&w, &client),
                            Mode::Open => client_open(&w, &client),
                        };
                        match tally {
                            Ok(t) => shared.lock().unwrap().absorb(t),
                            Err(e) => {
                                eprintln!("loadgen client {c}: {e:#}");
                                shared.lock().unwrap().protocol_errors += 1;
                            }
                        }
                    }
                })
                .context("spawn client thread")
        })
        .collect::<Result<Vec<_>>>()?;
    for h in handles {
        let _ = h.join();
    }
    let wall_s = start.elapsed().as_secs_f64();
    let tally = Arc::try_unwrap(shared)
        .map_err(|_| anyhow!("client thread leaked its tally handle"))?
        .into_inner()
        .unwrap();
    Ok(RunResult { mode: w.mode, clients, pools, tally, wall_s })
}

// Workload is only read by the clients; a manual clone keeps the struct
// free of a Clone bound on every future field.
fn clone_workload(w: &Workload) -> Workload {
    Workload {
        addr: w.addr.clone(),
        shapes: w.shapes.clone(),
        policies: w.policies.clone(),
        priorities: w.priorities.clone(),
        ..*w
    }
}

struct ClientCfg {
    addr: String,
    /// This client's index — interleaves the global request sequence.
    index: usize,
    /// Total client count (the sequence stride).
    stride: usize,
    /// Requests this client issues.
    count: usize,
    /// Hard wall-clock stop for issuing and for reads.
    cap: Instant,
}

/// Connect with retry: CI starts the server concurrently, so the first
/// connects may race the bind.
fn connect(addr: &str, cap: Instant) -> Result<TcpStream> {
    let window = Duration::from_secs(10);
    let start = Instant::now();
    loop {
        match TcpStream::connect(addr) {
            Ok(stream) => {
                let _ = stream.set_nodelay(true);
                return Ok(stream);
            }
            Err(e) => {
                if start.elapsed() >= window || Instant::now() >= cap {
                    return Err(e).with_context(|| format!("connect {addr}"));
                }
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
}

fn send_line(stream: &mut TcpStream, line: &str) -> std::io::Result<()> {
    stream.write_all(line.as_bytes())?;
    stream.write_all(b"\n")
}

/// Closed loop: send one request, block on its response, repeat.
fn client_closed(w: &Workload, c: &ClientCfg) -> Result<Tally> {
    let mut stream = connect(&c.addr, c.cap)?;
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let mut reader = BufReader::new(stream.try_clone().context("clone stream")?);
    let mut tally = Tally::default();
    let mut line = String::new();
    for i in 0..c.count {
        if Instant::now() >= c.cap {
            break;
        }
        let seq = (i * c.stride + c.index) as u64;
        let spec = w.spec_for(seq, seq);
        let sent_at = Instant::now();
        if send_line(&mut stream, &spec.to_wire_json()).is_err() {
            tally.protocol_errors += 1;
            break;
        }
        tally.sent += 1;
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => {
                // server hung up (or read timed out) mid-conversation
                tally.protocol_errors += 1;
                break;
            }
            Ok(_) => {
                let ms = sent_at.elapsed().as_secs_f64() * 1e3;
                tally.classify(&line, Some(ms));
            }
        }
    }
    let _ = send_line(&mut stream, r#"{"op": "quit"}"#);
    line.clear();
    let _ = reader.read_line(&mut line); // best-effort goodbye
    Ok(tally)
}

/// Open loop: issue on a fixed schedule without waiting; a reader thread
/// settles responses. Latency counts from the *scheduled* send instant,
/// so queue buildup shows up as latency (the closed loop would instead
/// slow its own arrival rate).
fn client_open(w: &Workload, c: &ClientCfg) -> Result<Tally> {
    let mut stream = connect(&c.addr, c.cap)?;
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let read_half = stream.try_clone().context("clone stream")?;

    let pending: Arc<Mutex<HashMap<u64, Instant>>> = Arc::new(Mutex::new(HashMap::new()));
    let reader = {
        let pending = Arc::clone(&pending);
        let cap = c.cap;
        std::thread::Builder::new()
            .name("loadgen-read".to_string())
            .spawn(move || open_reader(read_half, &pending, cap))
            .context("spawn reader")?
    };

    // per-client interval so the aggregate arrival rate is `--rate`;
    // stagger the start so clients do not send in lockstep
    let interval = Duration::from_secs_f64(c.stride as f64 / w.rate);
    let start = Instant::now() + interval.mul_f64(c.index as f64 / c.stride as f64);
    let mut sent = 0u64;
    for i in 0..c.count {
        let due = start + interval.mul_f64(i as f64);
        if due >= c.cap {
            break;
        }
        if let Some(wait) = due.checked_duration_since(Instant::now()) {
            std::thread::sleep(wait);
        }
        let seq = (i * c.stride + c.index) as u64;
        let spec = w.spec_for(seq, seq);
        pending.lock().unwrap().insert(seq, due);
        if send_line(&mut stream, &spec.to_wire_json()).is_err() {
            pending.lock().unwrap().remove(&seq);
            break;
        }
        sent += 1;
    }
    let _ = send_line(&mut stream, r#"{"op": "quit"}"#);
    let mut tally = reader.join().unwrap_or_default();
    tally.sent += sent;
    // whatever never came back before the cap is a protocol error: the
    // server claims it answers every frame
    tally.protocol_errors += pending.lock().unwrap().len() as u64;
    Ok(tally)
}

/// Reader half of the open loop: settle responses against the pending
/// map until the quit acknowledgement, EOF, or the wall-clock cap.
fn open_reader(stream: TcpStream, pending: &Mutex<HashMap<u64, Instant>>, cap: Instant) -> Tally {
    let mut tally = Tally::default();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if Instant::now() >= cap {
            return tally;
        }
        match reader.read_line(&mut line) {
            Ok(0) => return tally,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // read timed out mid-line: `line` keeps the partial data,
                // the next read_line appends the rest — framing holds
                continue;
            }
            Err(_) => return tally,
            Ok(_) => {}
        }
        let parsed = Json::parse(line.trim()).ok();
        if parsed.as_ref().and_then(|v| v.get("op")).and_then(Json::as_str) == Some("quit") {
            return tally;
        }
        let ms = parsed
            .as_ref()
            .and_then(|v| v.get("id"))
            .and_then(Json::as_usize)
            .and_then(|id| pending.lock().unwrap().remove(&(id as u64)))
            .map(|due| due.elapsed().as_secs_f64() * 1e3);
        tally.classify(&line, ms);
        line.clear();
    }
}

/// Merge the `serving` series into an existing schema-/5 pipeline bench
/// file (refusing to touch anything older — regenerate the benches first).
/// With `append`, new entries extend the file's existing series — that is
/// how the pools=1 and pools=N runs of the scaling gate end up in one
/// file. Either way the `pool_scaling` block is re-derived from the final
/// series (and nulled out when only one shard count is present, so a
/// stale block can never outlive the data it summarized).
fn merge_serving(path: &str, entries: Json, append: bool) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {path} (run `cargo bench --bench hotpath` first)"))?;
    let mut root = Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
    let schema = root.path("schema").and_then(Json::as_str).unwrap_or("");
    if schema != "ftgemm-bench-pipeline/5" {
        bail!(
            "{path} has schema {schema:?}; loadgen only merges into \
             ftgemm-bench-pipeline/5 — regenerate with `cargo bench --bench hotpath`"
        );
    }
    let mut serving = match (append, root.get("serving")) {
        (true, Some(Json::Arr(existing))) => existing.clone(),
        _ => Vec::new(),
    };
    if let Json::Arr(new) = entries {
        serving.extend(new);
    }
    let scaling = pool_scaling(&serving);
    root.set("serving", Json::Arr(serving));
    root.set("pool_scaling", scaling.unwrap_or(Json::Null));
    std::fs::write(path, root.to_string_pretty()).with_context(|| format!("writing {path}"))?;
    Ok(())
}

/// Derive the `pool_scaling` summary from a merged serving series: pick
/// the widest client count measured at both the smallest (baseline) and
/// largest shard count, and report the throughput ratio between them.
/// `None` (serialized as null) when the series covers fewer than two
/// distinct shard counts or shares no client count between them.
fn pool_scaling(serving: &[Json]) -> Option<Json> {
    // (pools, clients) -> rps; later entries win so re-runs supersede
    let mut rps: HashMap<(usize, usize), f64> = HashMap::new();
    for e in serving {
        let Some(pools) = e.get("pools").and_then(Json::as_usize) else { continue };
        let Some(clients) = e.get("clients").and_then(Json::as_usize) else { continue };
        let Some(r) = e.get("rps").and_then(Json::as_f64) else { continue };
        rps.insert((pools, clients), r);
    }
    let baseline_pools = rps.keys().map(|&(p, _)| p).min()?;
    let top_pools = rps.keys().map(|&(p, _)| p).max()?;
    if baseline_pools == top_pools {
        return None;
    }
    let gate_clients = rps
        .keys()
        .filter(|&&(p, _)| p == baseline_pools)
        .map(|&(_, c)| c)
        .filter(|&c| rps.contains_key(&(top_pools, c)))
        .max()?;
    let baseline_rps = rps[&(baseline_pools, gate_clients)];
    let top_rps = rps[&(top_pools, gate_clients)];
    if baseline_rps <= 0.0 {
        return None;
    }
    let mut out = Json::obj();
    out.set("baseline_pools", Json::Num(baseline_pools as f64));
    out.set("top_pools", Json::Num(top_pools as f64));
    out.set("gate_clients", Json::Num(gate_clients as f64));
    out.set("baseline_rps", Json::Num(baseline_rps));
    out.set("top_rps", Json::Num(top_rps));
    out.set("ratio", Json::Num(top_rps / baseline_rps));
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn durations_parse() {
        assert_eq!(parse_duration("60s").unwrap(), Duration::from_secs(60));
        assert_eq!(parse_duration("500ms").unwrap(), Duration::from_millis(500));
        assert_eq!(parse_duration("7").unwrap(), Duration::from_secs(7));
        assert!(parse_duration("abc").is_err());
        assert!(parse_duration("10m").is_err());
    }

    #[test]
    fn workload_cycles_the_mix() {
        let w = Workload {
            addr: String::new(),
            mode: Mode::Closed,
            requests: 0,
            rate: 1.0,
            shapes: vec![64, 128],
            policies: vec![FtPolicy::Online, FtPolicy::None],
            priorities: vec![Priority::Normal, Priority::High],
            deadline_ms: 250,
            inject: 2,
            seed: 9,
            seed_reuse_pct: 0,
            duration_cap: Duration::from_secs(1),
        };
        let s0 = w.spec_for(0, 0);
        assert_eq!((s0.m, s0.policy, s0.priority), (64, FtPolicy::Online, Priority::Normal));
        let s1 = w.spec_for(1, 1);
        assert_eq!((s1.m, s1.policy), (128, FtPolicy::Online));
        let s2 = w.spec_for(2, 2);
        assert_eq!((s2.m, s2.policy), (64, FtPolicy::None));
        let s4 = w.spec_for(4, 4);
        assert_eq!((s4.policy, s4.priority), (FtPolicy::Online, Priority::High));
        assert_eq!(s4.seed, 9 + 4);
        assert_eq!(s4.inject, 2);
        assert_eq!(s4.deadline_ms, Some(250));
    }

    #[test]
    fn seed_reuse_selects_exactly_the_configured_fraction() {
        let base = Workload {
            addr: String::new(),
            mode: Mode::Closed,
            requests: 0,
            rate: 1.0,
            shapes: vec![64],
            policies: vec![FtPolicy::None],
            priorities: vec![Priority::Normal],
            deadline_ms: 0,
            inject: 0,
            seed: 9,
            seed_reuse_pct: 50,
            duration_cap: Duration::from_secs(1),
        };
        // 0% and 100% are the degenerate patterns
        let never = Workload { seed_reuse_pct: 0, ..clone_workload(&base) };
        let always = Workload { seed_reuse_pct: 100, ..clone_workload(&base) };
        for seq in 0..100u64 {
            assert_eq!(never.spec_for(0, seq).seed, 9 + seq);
            assert_eq!(always.spec_for(0, seq).seed, 9);
        }
        // 50%: exactly half of any 100-seq window reuses the base seed,
        // and the pattern is deterministic per seq
        let reused = (0..100u64).filter(|&s| base.spec_for(0, s).seed == 9).count();
        assert_eq!(reused, 50);
        assert_eq!(base.spec_for(0, 7).seed, base.spec_for(3, 7).seed);
    }

    #[test]
    fn tally_classifies_the_taxonomy() {
        let mut t = Tally::default();
        t.classify(r#"{"ok": true, "op": "gemm", "id": 1}"#, Some(3.0));
        t.classify(r#"{"ok": false, "op": "gemm", "error": "deadline-expired"}"#, None);
        t.classify(r#"{"ok": false, "op": "gemm", "error": "admission-reject"}"#, None);
        t.classify(r#"{"ok": false, "op": "gemm", "error": "canceled"}"#, None);
        t.classify(r#"{"ok": false, "op": "request", "error": "validation"}"#, None);
        t.classify(r#"{"ok": false, "op": "gemm", "error": "failed"}"#, None);
        t.classify("not json at all", None);
        assert_eq!(
            (t.ok, t.expired, t.rejected, t.canceled, t.failed, t.protocol_errors),
            (1, 1, 1, 1, 1, 2)
        );
        assert_eq!(t.lat_ms, vec![3.0]);
    }

    #[test]
    fn run_result_serializes_a_serving_entry() {
        let tally = Tally {
            ok: 3,
            sent: 4,
            expired: 1,
            lat_ms: vec![1.0, 2.0, 10.0],
            ..Default::default()
        };
        let r = RunResult { mode: Mode::Closed, clients: 2, pools: 4, tally, wall_s: 2.0 };
        let e = r.to_json().unwrap();
        assert_eq!(e.get("mode").unwrap().as_str(), Some("closed"));
        assert_eq!(e.get("clients").unwrap().as_usize(), Some(2));
        assert_eq!(e.get("pools").unwrap().as_usize(), Some(4));
        assert_eq!(e.get("ok").unwrap().as_usize(), Some(3));
        let p50 = e.get("p50_ms").unwrap().as_f64().unwrap();
        let p99 = e.get("p99_ms").unwrap().as_f64().unwrap();
        assert!(p50 <= p99);
        assert!((e.get("rps").unwrap().as_f64().unwrap() - 1.5).abs() < 1e-9);
    }

    #[test]
    fn empty_latency_sample_yields_no_entry() {
        let r = RunResult {
            mode: Mode::Open,
            clients: 1,
            pools: 1,
            tally: Tally::default(),
            wall_s: 1.0,
        };
        assert!(r.to_json().is_none());
    }

    fn serving_entry(pools: usize, clients: usize, rps: f64) -> Json {
        let mut e = Json::obj();
        e.set("pools", Json::Num(pools as f64));
        e.set("clients", Json::Num(clients as f64));
        e.set("rps", Json::Num(rps));
        e
    }

    #[test]
    fn pool_scaling_picks_widest_common_client_count() {
        let serving = vec![
            serving_entry(1, 2, 10.0),
            serving_entry(1, 4, 20.0),
            serving_entry(1, 8, 25.0),
            serving_entry(4, 2, 18.0),
            serving_entry(4, 4, 36.0),
            // no pools=4 run at 8 clients: the gate point must be 4
        ];
        let ps = pool_scaling(&serving).expect("two shard counts present");
        assert_eq!(ps.get("baseline_pools").unwrap().as_usize(), Some(1));
        assert_eq!(ps.get("top_pools").unwrap().as_usize(), Some(4));
        assert_eq!(ps.get("gate_clients").unwrap().as_usize(), Some(4));
        assert!((ps.get("ratio").unwrap().as_f64().unwrap() - 1.8).abs() < 1e-9);
    }

    #[test]
    fn pool_scaling_needs_two_shard_counts_and_a_shared_point() {
        assert!(pool_scaling(&[]).is_none());
        assert!(pool_scaling(&[serving_entry(1, 2, 10.0), serving_entry(1, 4, 20.0)]).is_none());
        // two shard counts but disjoint client counts
        assert!(pool_scaling(&[serving_entry(1, 2, 10.0), serving_entry(4, 8, 40.0)]).is_none());
        // a later re-run supersedes the earlier measurement at the same point
        let ps = pool_scaling(&[
            serving_entry(1, 2, 5.0),
            serving_entry(1, 2, 10.0),
            serving_entry(2, 2, 30.0),
        ])
        .unwrap();
        assert!((ps.get("ratio").unwrap().as_f64().unwrap() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn presets_default_the_mix_but_flags_override() {
        let cmd = Command::new("loadgen", "test")
            .opt("preset", "", None)
            .opt("mix", "", None)
            .opt("policies", "", None)
            .opt("priorities", "", None)
            .opt("inject", "", None)
            .opt("seed-reuse", "", None)
            .opt("mode", "", Some("closed"))
            .opt("addr", "", Some("x"))
            .opt("requests", "", Some("1"))
            .opt("rate", "", Some("50"))
            .opt("deadline-ms", "", Some("0"))
            .opt("seed", "", Some("42"))
            .opt("duration-cap", "", Some("60s"));
        let sv = |a: &[&str]| a.iter().map(|s| s.to_string()).collect::<Vec<_>>();

        let w = parse_workload(&cmd.parse(&sv(&["--preset", "ci-smoke"])).unwrap()).unwrap();
        assert_eq!(w.shapes, vec![64, 128]);
        assert_eq!(w.policies, vec![FtPolicy::Online, FtPolicy::None]);
        assert_eq!(w.priorities, vec![Priority::Normal, Priority::High]);
        assert_eq!(w.inject, 1);
        assert_eq!(w.seed_reuse_pct, 50);

        // an explicit flag wins over the preset value on that axis only
        let over =
            &["--preset", "ci-smoke", "--mix", "huge", "--inject", "0", "--seed-reuse", "0"];
        let w = parse_workload(&cmd.parse(&sv(over)).unwrap()).unwrap();
        assert_eq!(w.shapes, vec![512]);
        assert_eq!(w.policies, vec![FtPolicy::Online, FtPolicy::None]);
        assert_eq!(w.inject, 0);
        assert_eq!(w.seed_reuse_pct, 0);

        // no preset: the built-in defaults hold
        let w = parse_workload(&cmd.parse(&sv(&[])).unwrap()).unwrap();
        assert_eq!(w.shapes, vec![64, 128]);
        assert_eq!(w.policies, vec![FtPolicy::Online]);
        assert_eq!(w.inject, 0);
        assert_eq!(w.seed_reuse_pct, 0);

        assert!(parse_workload(&cmd.parse(&sv(&["--preset", "nope"])).unwrap()).is_err());
        // a reuse percentage over 100 is rejected
        assert!(parse_workload(&cmd.parse(&sv(&["--seed-reuse", "101"])).unwrap()).is_err());
    }
}
