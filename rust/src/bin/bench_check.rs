//! `bench-check` — schema + perf-gate validator for `BENCH_pipeline.json`.
//!
//!     cargo run --release --bin bench-check -- [FILE] \
//!         [--min-speedup X] [--min-simd-speedup Y]
//!
//! CI runs this right after `cargo bench --bench hotpath`, replacing the
//! old silent upload-whatever-was-written flow with an enforced gate:
//!
//! * the file must parse and match schema `ftgemm-bench-pipeline/4` —
//!   1024^3 shape, a non-empty `live` series with positive wall times,
//!   all three backends measured at the workers=1 gate point, a
//!   per-kernel-ISA `ft_overhead` (clean vs fused-FT) series, and a
//!   `serving` series (gateway throughput/latency, written by the
//!   `loadgen` harness; `null` until it runs, which is only accepted
//!   without `--require-serving`);
//! * the blocked backend must be at least `--min-speedup` (default 2.0)
//!   times faster than the reference backend at that point, FT enabled;
//! * the dispatched blocked kernel must be at least `--min-simd-speedup`
//!   (default 1.0) times faster than the pinned-scalar blocked variant
//!   (skipped, with a note, when dispatch resolved to the scalar kernel
//!   — there is no SIMD to compare on such a host);
//! * every `serving[]` entry must have consistent counters, ordered
//!   finite latency percentiles, positive throughput, and **zero
//!   protocol errors**.
//!
//! Failures are classified, not lumped: a **committed placeholder**
//! (null `live`/`gate`, benches never ran) and a **stale schema** are
//! reported as exactly that, while a **perf regression** names the gate
//! point that failed and both wall times. Exit code 0 = valid and fast
//! enough; anything else fails the CI job.

use std::process::ExitCode;

use ftgemm::util::cli::Command;
use ftgemm::util::json::Json;

const SCHEMA: &str = "ftgemm-bench-pipeline/4";

/// What a passing file measured, for the success printout.
struct Report {
    blocked_speedup: f64,
    /// `None` when the dispatched kernel was scalar (gate skipped).
    simd_speedup: Option<f64>,
    kernel_isa: String,
    /// (backend, kernel_isa, fractional overhead) per ft_overhead entry.
    overheads: Vec<(String, String, f64)>,
    /// (mode, clients, ok, p99_ms, rps) per serving entry; `None` when
    /// the series is the null placeholder (loadgen has not run).
    serving: Option<Vec<(String, usize, u64, f64, f64)>>,
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = Command::new("bench-check", "validate BENCH_pipeline.json and enforce the perf gate")
        .opt("min-speedup", "required blocked-vs-reference speedup at 1024^3", Some("2.0"))
        .opt(
            "min-simd-speedup",
            "required blocked-vs-blocked-scalar speedup at 1024^3",
            Some("1.0"),
        )
        .flag("require-serving", "fail if the serving series is still the null placeholder");
    let args = match cmd.parse(&argv) {
        Ok(args) => args,
        Err(e) => {
            eprintln!("bench-check: {e}");
            return ExitCode::FAILURE;
        }
    };
    let path = args.positional.first().map(String::as_str).unwrap_or("BENCH_pipeline.json");
    let min_speedup = args.f64_or("min-speedup", 2.0);
    let min_simd = args.f64_or("min-simd-speedup", 1.0);
    let require_serving = args.flag("require-serving");
    match check(path, min_speedup, min_simd, require_serving) {
        Ok(report) => {
            println!(
                "bench-check OK: {path} valid, blocked[{}] {:.2}x reference (gate \
                 {min_speedup:.2}x)",
                report.kernel_isa, report.blocked_speedup
            );
            match report.simd_speedup {
                Some(s) => println!(
                    "  simd gate: blocked[{}] {s:.2}x blocked-scalar (gate {min_simd:.2}x)",
                    report.kernel_isa
                ),
                None => println!(
                    "  simd gate: skipped — dispatch resolved to the scalar kernel on this host"
                ),
            }
            for (backend, isa, overhead) in &report.overheads {
                println!("  ft overhead: {backend}[{isa}] fused-FT +{:.1}%", overhead * 100.0);
            }
            match &report.serving {
                None => println!(
                    "  serving: null placeholder — gateway loadgen has not run against this file"
                ),
                Some(entries) => {
                    for (mode, clients, ok, p99, rps) in entries {
                        println!(
                            "  serving: {mode} loop x{clients} clients — {ok} ok, \
                             p99 {p99:.2}ms, {rps:.1} req/s, 0 protocol errors"
                        );
                    }
                }
            }
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!("bench-check FAILED: {e:#}");
            ExitCode::FAILURE
        }
    }
}

/// Validate the file; returns the measured gate numbers for printing.
fn check(
    path: &str,
    min_speedup: f64,
    min_simd: f64,
    require_serving: bool,
) -> anyhow::Result<Report> {
    use anyhow::{anyhow, bail, Context};

    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {path} (run `cargo bench --bench hotpath` first)"))?;
    let root = Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;

    let schema = root
        .path("schema")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing schema field"))?;
    if schema != SCHEMA {
        if schema.starts_with("ftgemm-bench-pipeline/") {
            bail!(
                "stale schema: file is {schema:?}, this binary checks {SCHEMA:?} — \
                 regenerate with `cargo bench --bench hotpath`"
            );
        }
        bail!("schema {schema:?}, want {SCHEMA:?}");
    }
    // The repo carries a committed placeholder with the measured series
    // deliberately nulled (authoring environment had no toolchain).
    // Calling that out beats a generic "missing field" error: nothing
    // regressed, the benches simply have not run against this checkout.
    if matches!(root.path("live"), None | Some(Json::Null))
        || matches!(root.path("gate"), None | Some(Json::Null))
    {
        bail!(
            "committed placeholder: {path} has null live/gate series — the benches have \
             not been run; run `cargo bench --bench hotpath` to produce measured data"
        );
    }

    let shape: Vec<usize> = root
        .path("shape")
        .and_then(Json::as_arr)
        .map(|dims| dims.iter().filter_map(Json::as_usize).collect())
        .unwrap_or_default();
    if shape != [1024, 1024, 1024] {
        bail!("gate point must be 1024^3, got shape {shape:?}");
    }
    if root.path("policy").and_then(Json::as_str) != Some("online") {
        bail!("gate must run with FT enabled (policy=online)");
    }

    let live = root
        .path("live")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("live is not an array"))?;
    if live.is_empty() {
        bail!("live[] series is empty");
    }
    // (mean_s, kernel_isa) per backend at the workers=1 gate point
    let mut gate_reference = None;
    let mut gate_scalar = None;
    let mut gate_blocked = None;
    for (i, entry) in live.iter().enumerate() {
        let backend = entry
            .path("backend")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("live[{i}]: missing backend"))?;
        let isa = entry
            .path("kernel_isa")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("live[{i}]: missing kernel_isa"))?;
        let workers = entry
            .path("workers")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("live[{i}]: missing workers"))?;
        let mean_s = entry
            .path("mean_s")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("live[{i}]: missing mean_s"))?;
        if workers == 0 {
            bail!("live[{i}]: workers must be >= 1");
        }
        if !(mean_s.is_finite() && mean_s > 0.0) {
            bail!("live[{i}]: mean_s {mean_s} is not a positive finite wall time");
        }
        if workers == 1 {
            match backend {
                "reference" => gate_reference = Some((mean_s, isa.to_string())),
                "blocked-scalar" => gate_scalar = Some((mean_s, isa.to_string())),
                "blocked" => gate_blocked = Some((mean_s, isa.to_string())),
                _ => {}
            }
        }
    }
    let (reference, _) =
        gate_reference.ok_or_else(|| anyhow!("no reference-backend workers=1 measurement"))?;
    let (scalar, _) = gate_scalar
        .ok_or_else(|| anyhow!("no blocked-scalar-backend workers=1 measurement"))?;
    let (blocked, kernel_isa) =
        gate_blocked.ok_or_else(|| anyhow!("no blocked-backend workers=1 measurement"))?;

    let overheads = check_ft_overhead(&root)?;
    let serving = check_serving(&root, require_serving)?;

    let blocked_speedup = reference / blocked;
    if blocked_speedup < min_speedup {
        bail!(
            "perf gate FAILED at point blocked-vs-reference (1024^3, workers=1, FT on): \
             blocked[{kernel_isa}] is only {blocked_speedup:.2}x reference \
             (reference {reference:.4}s, blocked {blocked:.4}s; need >= {min_speedup:.2}x)"
        );
    }
    let simd_speedup = if kernel_isa == "scalar" {
        // Dispatch found no SIMD on this host; blocked and blocked-scalar
        // run the same kernel, so the ratio carries no signal.
        None
    } else {
        let s = scalar / blocked;
        if s < min_simd {
            bail!(
                "perf gate FAILED at point blocked-vs-blocked-scalar (1024^3, workers=1, \
                 FT on): blocked[{kernel_isa}] is only {s:.2}x its pinned-scalar kernel \
                 (blocked-scalar {scalar:.4}s, blocked {blocked:.4}s; need >= {min_simd:.2}x)"
            );
        }
        Some(s)
    };
    Ok(Report { blocked_speedup, simd_speedup, kernel_isa, overheads, serving })
}

/// Validate the `serving` series (schema /4): the gateway loadgen's
/// closed-loop runs. `null` means loadgen has not run — accepted (the
/// plain bench can't measure it) unless `--require-serving`.
fn check_serving(
    root: &Json,
    require_serving: bool,
) -> anyhow::Result<Option<Vec<(String, usize, u64, f64, f64)>>> {
    use anyhow::{anyhow, bail};

    let series = match root.path("serving") {
        None => bail!("missing serving field (schema /4 requires it; null = not yet measured)"),
        Some(Json::Null) => {
            if require_serving {
                bail!(
                    "serving is the null placeholder but --require-serving is set — run \
                     `loadgen --bench-out` against a live gateway first"
                );
            }
            return Ok(None);
        }
        Some(v) => v.as_arr().ok_or_else(|| anyhow!("serving is neither null nor an array"))?,
    };
    if series.is_empty() {
        bail!("serving[] series is empty — loadgen wrote no completed runs");
    }
    let mut out = Vec::new();
    for (i, entry) in series.iter().enumerate() {
        let mode = entry
            .path("mode")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("serving[{i}]: missing mode"))?;
        if mode != "closed" && mode != "open" {
            bail!("serving[{i}]: mode must be closed|open, got {mode:?}");
        }
        let num = |key: &str| {
            entry
                .path(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("serving[{i}]: missing {key}"))
        };
        let clients = num("clients")? as usize;
        let requests = num("requests")? as u64;
        let ok = num("ok")? as u64;
        let protocol_errors = num("protocol_errors")? as u64;
        let (p50, p95, p99) = (num("p50_ms")?, num("p95_ms")?, num("p99_ms")?);
        let rps = num("rps")?;
        if clients == 0 {
            bail!("serving[{i}]: clients must be >= 1");
        }
        if requests == 0 || ok == 0 {
            bail!("serving[{i}]: no completed requests (requests {requests}, ok {ok})");
        }
        if ok > requests {
            bail!("serving[{i}]: ok {ok} exceeds requests {requests}");
        }
        if protocol_errors != 0 {
            bail!("serving[{i}]: {protocol_errors} protocol errors (the gate demands 0)");
        }
        for (name, v) in [("p50_ms", p50), ("p95_ms", p95), ("p99_ms", p99)] {
            if !(v.is_finite() && v > 0.0) {
                bail!("serving[{i}]: {name} {v} is not a positive finite latency");
            }
        }
        if p50 > p95 || p95 > p99 {
            bail!("serving[{i}]: percentiles out of order (p50 {p50}, p95 {p95}, p99 {p99})");
        }
        if !(rps.is_finite() && rps > 0.0) {
            bail!("serving[{i}]: rps {rps} is not a positive finite throughput");
        }
        out.push((mode.to_string(), clients, ok, p99, rps));
    }
    Ok(Some(out))
}

/// Validate the clean-vs-FT `ft_overhead` series: both blocked variants
/// present, positive finite wall times, overhead consistent with them.
fn check_ft_overhead(root: &Json) -> anyhow::Result<Vec<(String, String, f64)>> {
    use anyhow::{anyhow, bail};

    let series = root
        .path("ft_overhead")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing ft_overhead[] series (schema /3 requires it)"))?;
    if series.is_empty() {
        bail!("ft_overhead[] series is empty");
    }
    let mut out = Vec::new();
    for (i, entry) in series.iter().enumerate() {
        let backend = entry
            .path("backend")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("ft_overhead[{i}]: missing backend"))?;
        let isa = entry
            .path("kernel_isa")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("ft_overhead[{i}]: missing kernel_isa"))?;
        let clean = entry
            .path("clean_mean_s")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("ft_overhead[{i}]: missing clean_mean_s"))?;
        let ft = entry
            .path("ft_mean_s")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("ft_overhead[{i}]: missing ft_mean_s"))?;
        let overhead = entry
            .path("overhead")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("ft_overhead[{i}]: missing overhead"))?;
        for (name, v) in [("clean_mean_s", clean), ("ft_mean_s", ft)] {
            if !(v.is_finite() && v > 0.0) {
                bail!("ft_overhead[{i}]: {name} {v} is not a positive finite wall time");
            }
        }
        if !overhead.is_finite() || (overhead - (ft / clean - 1.0)).abs() > 1e-6 {
            bail!(
                "ft_overhead[{i}]: overhead {overhead} inconsistent with ft/clean ratio \
                 ({ft:.4}s / {clean:.4}s)"
            );
        }
        out.push((backend.to_string(), isa.to_string(), overhead));
    }
    for required in ["blocked-scalar", "blocked"] {
        if !out.iter().any(|(b, _, _)| b == required) {
            bail!("ft_overhead[] has no entry for the {required} backend");
        }
    }
    Ok(out)
}
