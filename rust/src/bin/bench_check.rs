//! `bench-check` — schema + perf-gate validator for `BENCH_pipeline.json`.
//!
//!     cargo run --release --bin bench-check -- [FILE] [--min-speedup X]
//!
//! CI runs this right after `cargo bench --bench hotpath`, replacing the
//! old silent upload-whatever-was-written flow with an enforced gate:
//!
//! * the file must parse and match schema `ftgemm-bench-pipeline/2` —
//!   1024^3 shape, a non-empty `live` series with positive wall times,
//!   and both backends measured at the workers=1 gate point;
//! * the blocked backend must be at least `--min-speedup` (default 2.0)
//!   times faster than the reference backend at that point, FT enabled.
//!
//! Exit code 0 = valid and fast enough; anything else fails the CI job.

use std::process::ExitCode;

use ftgemm::util::cli::Command;
use ftgemm::util::json::Json;

const SCHEMA: &str = "ftgemm-bench-pipeline/2";

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = Command::new("bench-check", "validate BENCH_pipeline.json and enforce the perf gate")
        .opt("min-speedup", "required blocked-vs-reference speedup at 1024^3", Some("2.0"));
    let args = match cmd.parse(&argv) {
        Ok(args) => args,
        Err(e) => {
            eprintln!("bench-check: {e}");
            return ExitCode::FAILURE;
        }
    };
    let path = args.positional.first().map(String::as_str).unwrap_or("BENCH_pipeline.json");
    let min_speedup = args.f64_or("min-speedup", 2.0);
    match check(path, min_speedup) {
        Ok(speedup) => {
            println!(
                "bench-check OK: {path} valid, blocked backend {speedup:.2}x reference \
                 (gate {min_speedup:.2}x)"
            );
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!("bench-check FAILED: {e:#}");
            ExitCode::FAILURE
        }
    }
}

/// Validate the file; returns the measured blocked-vs-reference speedup.
fn check(path: &str, min_speedup: f64) -> anyhow::Result<f64> {
    use anyhow::{anyhow, bail, Context};

    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {path} (run `cargo bench --bench hotpath` first)"))?;
    let root = Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;

    let schema = root
        .path("schema")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing schema field"))?;
    if schema != SCHEMA {
        bail!("schema {schema:?}, want {SCHEMA:?} (placeholder file? bench not run?)");
    }
    let shape: Vec<usize> = root
        .path("shape")
        .and_then(Json::as_arr)
        .map(|dims| dims.iter().filter_map(Json::as_usize).collect())
        .unwrap_or_default();
    if shape != [1024, 1024, 1024] {
        bail!("gate point must be 1024^3, got shape {shape:?}");
    }
    if root.path("policy").and_then(Json::as_str) != Some("online") {
        bail!("gate must run with FT enabled (policy=online)");
    }

    let live = root
        .path("live")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing live[] series (placeholder file? bench not run?)"))?;
    if live.is_empty() {
        bail!("live[] series is empty");
    }
    let mut gate_reference = None;
    let mut gate_blocked = None;
    for (i, entry) in live.iter().enumerate() {
        let backend = entry
            .path("backend")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("live[{i}]: missing backend"))?;
        let workers = entry
            .path("workers")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("live[{i}]: missing workers"))?;
        let mean_s = entry
            .path("mean_s")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("live[{i}]: missing mean_s"))?;
        if workers == 0 {
            bail!("live[{i}]: workers must be >= 1");
        }
        if !(mean_s.is_finite() && mean_s > 0.0) {
            bail!("live[{i}]: mean_s {mean_s} is not a positive finite wall time");
        }
        if workers == 1 {
            match backend {
                "reference" => gate_reference = Some(mean_s),
                "blocked" => gate_blocked = Some(mean_s),
                _ => {}
            }
        }
    }
    let reference =
        gate_reference.ok_or_else(|| anyhow!("no reference-backend workers=1 measurement"))?;
    let blocked =
        gate_blocked.ok_or_else(|| anyhow!("no blocked-backend workers=1 measurement"))?;
    let speedup = reference / blocked;
    if speedup < min_speedup {
        bail!(
            "perf gate: blocked backend is only {speedup:.2}x reference at 1024^3 \
             (reference {reference:.4}s, blocked {blocked:.4}s; need >= {min_speedup:.2}x)"
        );
    }
    Ok(speedup)
}
