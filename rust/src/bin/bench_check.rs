//! `bench-check` — schema + perf-gate validator for `BENCH_pipeline.json`.
//!
//!     cargo run --release --bin bench-check -- [FILE] \
//!         [--min-speedup X] [--min-simd-speedup Y] [--require-serving] \
//!         [--require-scaling] [--min-pool-speedup Z] [--min-cache-speedup C] \
//!         [--min-largek-speedup L]
//!
//! CI runs this right after `cargo bench --bench hotpath`, replacing the
//! old silent upload-whatever-was-written flow with an enforced gate:
//!
//! * the file must parse and match schema `ftgemm-bench-pipeline/6` —
//!   1024^3 shape, a non-empty `live` series with positive wall times,
//!   all three backends measured at the workers=1 gate point, a
//!   per-kernel-ISA `ft_overhead` (clean vs fused-FT) series, a
//!   `repeat_cache` block (same Arc-shared operands, packed-operand
//!   cache on vs off), and a `serving` series (gateway
//!   throughput/latency, written by the `loadgen` harness; `null` until
//!   it runs, which is only accepted without `--require-serving`);
//! * the blocked backend must be at least `--min-speedup` (default 2.0)
//!   times faster than the reference backend at that point, FT enabled;
//! * the dispatched blocked kernel must be at least `--min-simd-speedup`
//!   (default 1.0) times faster than the pinned-scalar blocked variant
//!   (skipped, with a note, when dispatch resolved to the scalar kernel
//!   — there is no SIMD to compare on such a host);
//! * every `serving[]` entry must have consistent counters, ordered
//!   finite latency percentiles, positive throughput, and **zero
//!   protocol errors**;
//! * with `--require-scaling`, the file must carry the `pool_scaling`
//!   block loadgen derives when the serving series spans at least two
//!   shard counts: the sweep curve of every shard group must be monotone
//!   up to its knee (within a 0.95 noise tolerance), and the
//!   baseline-to-top throughput ratio at the shared gate point must be
//!   at least `--min-pool-speedup` (default 1.6);
//! * when the `repeat_cache` block is measured (it is `null` in the
//!   committed placeholder — accepted with a notice), the cache-off
//!   steady-state must be at least `--min-cache-speedup` (default 1.02)
//!   times the cache-on steady-state, and the cache-on run must show
//!   actual hits — a repeat-operand request path that re-packs on every
//!   iteration fails the gate;
//! * when the `largek` block is measured (it is `null` in the committed
//!   placeholder — accepted with a notice), every deep-reduction shape's
//!   KC-blocked run must be at least `--min-largek-speedup` (default
//!   1.0) times faster than the same backend pinned to KC=k — the
//!   cache-blocking win on panels that overflow L1/L2 is enforced, not
//!   just measured.
//!
//! Failures are classified, not lumped: a **committed placeholder**
//! (null `live`/`gate`, benches never ran) and a **stale schema** are
//! reported as exactly that, while a **perf regression** names the gate
//! point that failed and both wall times. Exit code 0 = valid and fast
//! enough; anything else fails the CI job.

use std::process::ExitCode;

use ftgemm::util::cli::Command;
use ftgemm::util::json::Json;

const SCHEMA: &str = "ftgemm-bench-pipeline/6";

/// A sweep point must reach this fraction of the previous point's rps to
/// count as "still climbing" — absorbs run-to-run noise on the way to the
/// knee without letting a real scalability cliff through.
const KNEE_TOLERANCE: f64 = 0.95;

/// What a passing file measured, for the success printout.
struct Report {
    blocked_speedup: f64,
    /// `None` when the dispatched kernel was scalar (gate skipped).
    simd_speedup: Option<f64>,
    kernel_isa: String,
    /// (backend, kernel_isa, fractional overhead) per ft_overhead entry.
    overheads: Vec<(String, String, f64)>,
    /// (mode, pools, clients, ok, p99_ms, rps) per serving entry; `None`
    /// when the series is the null placeholder (loadgen has not run).
    serving: Option<Vec<(String, usize, usize, u64, f64, f64)>>,
    /// The validated pool_scaling block; `None` when absent/null.
    scaling: Option<Scaling>,
    /// The validated repeat_cache block; `None` when still the null
    /// placeholder (the repeat-operand bench has not run).
    cache: Option<CacheGate>,
    /// The validated largek block; `None` when still the null
    /// placeholder (the deep-reduction bench has not run).
    largek: Option<LargekGate>,
}

/// The validated `largek` summary (class-resolved KC vs pinned KC=k on
/// deep-reduction shapes).
struct LargekGate {
    kernel_isa: String,
    /// (m, n, k, blocked_mean_s, kc_full_mean_s, speedup) per shape.
    entries: Vec<(usize, usize, usize, f64, f64, f64)>,
    min_speedup: f64,
}

/// The validated `repeat_cache` summary (packed-operand cache on vs off
/// at the 1024^3 repeat-operand point).
struct CacheGate {
    on_steady_s: f64,
    off_steady_s: f64,
    speedup: f64,
    hits: u64,
}

/// The validated `pool_scaling` summary (written by `loadgen` at merge).
struct Scaling {
    baseline_pools: usize,
    top_pools: usize,
    gate_clients: usize,
    ratio: f64,
}

/// Every gate threshold/flag the CLI resolves, in one bundle.
struct Gates {
    min_speedup: f64,
    min_simd: f64,
    require_serving: bool,
    require_scaling: bool,
    min_pool_speedup: f64,
    min_cache_speedup: f64,
    min_largek_speedup: f64,
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = Command::new("bench-check", "validate BENCH_pipeline.json and enforce the perf gate")
        .opt("min-speedup", "required blocked-vs-reference speedup at 1024^3", Some("2.0"))
        .opt(
            "min-simd-speedup",
            "required blocked-vs-blocked-scalar speedup at 1024^3",
            Some("1.0"),
        )
        .flag("require-serving", "fail if the serving series is still the null placeholder")
        .flag("require-scaling", "fail if the pool_scaling block is absent (multi-pool loadgen)")
        .opt(
            "min-pool-speedup",
            "required baseline-to-top-pools rps ratio at the scaling gate point",
            Some("1.6"),
        )
        .opt(
            "min-cache-speedup",
            "required cache-off/cache-on steady-state ratio at the repeat-operand point",
            Some("1.02"),
        )
        .opt(
            "min-largek-speedup",
            "required KC-blocked vs KC=k speedup on every deep-reduction shape",
            Some("1.0"),
        );
    let args = match cmd.parse(&argv) {
        Ok(args) => args,
        Err(e) => {
            eprintln!("bench-check: {e}");
            return ExitCode::FAILURE;
        }
    };
    let path = args.positional.first().map(String::as_str).unwrap_or("BENCH_pipeline.json");
    let min_speedup = args.f64_or("min-speedup", 2.0);
    let min_simd = args.f64_or("min-simd-speedup", 1.0);
    let require_serving = args.flag("require-serving");
    let require_scaling = args.flag("require-scaling");
    let min_pool_speedup = args.f64_or("min-pool-speedup", 1.6);
    let min_cache_speedup = args.f64_or("min-cache-speedup", 1.02);
    let min_largek_speedup = args.f64_or("min-largek-speedup", 1.0);
    let gates = Gates {
        min_speedup,
        min_simd,
        require_serving,
        require_scaling,
        min_pool_speedup,
        min_cache_speedup,
        min_largek_speedup,
    };
    match check(path, &gates) {
        Ok(report) => {
            println!(
                "bench-check OK: {path} valid, blocked[{}] {:.2}x reference (gate {:.2}x)",
                report.kernel_isa, report.blocked_speedup, gates.min_speedup
            );
            match report.simd_speedup {
                Some(s) => println!(
                    "  simd gate: blocked[{}] {s:.2}x blocked-scalar (gate {:.2}x)",
                    report.kernel_isa, gates.min_simd
                ),
                None => println!(
                    "  simd gate: skipped — dispatch resolved to the scalar kernel on this host"
                ),
            }
            for (backend, isa, overhead) in &report.overheads {
                println!("  ft overhead: {backend}[{isa}] fused-FT +{:.1}%", overhead * 100.0);
            }
            match &report.serving {
                None => println!(
                    "  serving: null placeholder — gateway loadgen has not run against this file"
                ),
                Some(entries) => {
                    for (mode, pools, clients, ok, p99, rps) in entries {
                        println!(
                            "  serving: {mode} loop x{clients} clients, {pools} pool(s) — \
                             {ok} ok, p99 {p99:.2}ms, {rps:.1} req/s, 0 protocol errors"
                        );
                    }
                }
            }
            match &report.scaling {
                None => println!(
                    "  scaling: pool_scaling absent — serving series spans one shard count"
                ),
                Some(s) => println!(
                    "  scaling gate: {}→{} pools at {} clients — {:.2}x rps (gate {:.2}x)",
                    s.baseline_pools, s.top_pools, s.gate_clients, s.ratio, gates.min_pool_speedup
                ),
            }
            match &report.cache {
                None => println!(
                    "  cache gate: repeat_cache is the null placeholder — the repeat-operand \
                     bench has not run against this file"
                ),
                Some(c) => println!(
                    "  cache gate: packed-operand cache {:.3}x at steady state ({:.4}s off vs \
                     {:.4}s on, {} hits; gate {:.2}x)",
                    c.speedup, c.off_steady_s, c.on_steady_s, c.hits, gates.min_cache_speedup
                ),
            }
            match &report.largek {
                None => println!(
                    "  largek gate: largek is the null placeholder — the deep-reduction \
                     bench has not run against this file"
                ),
                Some(l) => {
                    for (m, n, k, bs, fs, s) in &l.entries {
                        println!(
                            "  largek gate: {m}x{n}x{k} [{}] KC-blocked {bs:.4}s vs KC=k \
                             {fs:.4}s ({s:.3}x; gate {:.2}x)",
                            l.kernel_isa, gates.min_largek_speedup
                        );
                    }
                }
            }
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!("bench-check FAILED: {e:#}");
            ExitCode::FAILURE
        }
    }
}

/// Validate the file; returns the measured gate numbers for printing.
fn check(path: &str, gates: &Gates) -> anyhow::Result<Report> {
    use anyhow::{anyhow, bail, Context};

    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {path} (run `cargo bench --bench hotpath` first)"))?;
    let root = Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;

    let schema = root
        .path("schema")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing schema field"))?;
    if schema != SCHEMA {
        if schema.starts_with("ftgemm-bench-pipeline/") {
            bail!(
                "stale schema: file is {schema:?}, this binary checks {SCHEMA:?} — \
                 regenerate with `cargo bench --bench hotpath`"
            );
        }
        bail!("schema {schema:?}, want {SCHEMA:?}");
    }
    // The repo carries a committed placeholder with the measured series
    // deliberately nulled (authoring environment had no toolchain).
    // Calling that out beats a generic "missing field" error: nothing
    // regressed, the benches simply have not run against this checkout.
    if matches!(root.path("live"), None | Some(Json::Null))
        || matches!(root.path("gate"), None | Some(Json::Null))
    {
        bail!(
            "committed placeholder: {path} has null live/gate series — the benches have \
             not been run; run `cargo bench --bench hotpath` to produce measured data"
        );
    }

    let shape: Vec<usize> = root
        .path("shape")
        .and_then(Json::as_arr)
        .map(|dims| dims.iter().filter_map(Json::as_usize).collect())
        .unwrap_or_default();
    if shape != [1024, 1024, 1024] {
        bail!("gate point must be 1024^3, got shape {shape:?}");
    }
    if root.path("policy").and_then(Json::as_str) != Some("online") {
        bail!("gate must run with FT enabled (policy=online)");
    }

    let live = root
        .path("live")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("live is not an array"))?;
    if live.is_empty() {
        bail!("live[] series is empty");
    }
    // (mean_s, kernel_isa) per backend at the workers=1 gate point
    let mut gate_reference = None;
    let mut gate_scalar = None;
    let mut gate_blocked = None;
    for (i, entry) in live.iter().enumerate() {
        let backend = entry
            .path("backend")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("live[{i}]: missing backend"))?;
        let isa = entry
            .path("kernel_isa")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("live[{i}]: missing kernel_isa"))?;
        let workers = entry
            .path("workers")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("live[{i}]: missing workers"))?;
        let mean_s = entry
            .path("mean_s")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("live[{i}]: missing mean_s"))?;
        if workers == 0 {
            bail!("live[{i}]: workers must be >= 1");
        }
        if !(mean_s.is_finite() && mean_s > 0.0) {
            bail!("live[{i}]: mean_s {mean_s} is not a positive finite wall time");
        }
        // pool-scaling points carry pools > 1; the single-shard perf gate
        // below must only match the pools=1 (or legacy pool-less) entries
        let pools = entry.path("pools").and_then(Json::as_usize).unwrap_or(1);
        if pools == 0 {
            bail!("live[{i}]: pools must be >= 1");
        }
        if workers == 1 && pools == 1 {
            match backend {
                "reference" => gate_reference = Some((mean_s, isa.to_string())),
                "blocked-scalar" => gate_scalar = Some((mean_s, isa.to_string())),
                "blocked" => gate_blocked = Some((mean_s, isa.to_string())),
                _ => {}
            }
        }
    }
    let (reference, _) =
        gate_reference.ok_or_else(|| anyhow!("no reference-backend workers=1 measurement"))?;
    let (scalar, _) = gate_scalar
        .ok_or_else(|| anyhow!("no blocked-scalar-backend workers=1 measurement"))?;
    let (blocked, kernel_isa) =
        gate_blocked.ok_or_else(|| anyhow!("no blocked-backend workers=1 measurement"))?;

    let overheads = check_ft_overhead(&root)?;
    let serving = check_serving(&root, gates.require_serving)?;
    let scaling = check_scaling(&root, gates.require_scaling, gates.min_pool_speedup)?;
    let cache = check_repeat_cache(&root, gates.min_cache_speedup)?;
    let largek = check_largek(&root, gates.min_largek_speedup)?;

    let blocked_speedup = reference / blocked;
    if blocked_speedup < gates.min_speedup {
        bail!(
            "perf gate FAILED at point blocked-vs-reference (1024^3, workers=1, FT on): \
             blocked[{kernel_isa}] is only {blocked_speedup:.2}x reference \
             (reference {reference:.4}s, blocked {blocked:.4}s; need >= {:.2}x)",
            gates.min_speedup
        );
    }
    let simd_speedup = if kernel_isa == "scalar" {
        // Dispatch found no SIMD on this host; blocked and blocked-scalar
        // run the same kernel, so the ratio carries no signal.
        None
    } else {
        let s = scalar / blocked;
        if s < gates.min_simd {
            bail!(
                "perf gate FAILED at point blocked-vs-blocked-scalar (1024^3, workers=1, \
                 FT on): blocked[{kernel_isa}] is only {s:.2}x its pinned-scalar kernel \
                 (blocked-scalar {scalar:.4}s, blocked {blocked:.4}s; need >= {:.2}x)",
                gates.min_simd
            );
        }
        Some(s)
    };
    Ok(Report {
        blocked_speedup,
        simd_speedup,
        kernel_isa,
        overheads,
        serving,
        scaling,
        cache,
        largek,
    })
}

/// Validate the `largek` block (schema /6): deep-reduction shapes run on
/// the blocked backend with the class-resolved KC vs pinned KC=k. `null`
/// means the bench has not run (the committed-placeholder state) —
/// accepted with a notice; measured data must clear the
/// `--min-largek-speedup` ratio on EVERY shape (one overflowing shape
/// that regressed would otherwise hide behind a fast one).
fn check_largek(root: &Json, min_largek_speedup: f64) -> anyhow::Result<Option<LargekGate>> {
    use anyhow::{anyhow, bail};

    let block = match root.path("largek") {
        None => bail!("missing largek field (schema /6 requires it; null = not measured)"),
        Some(Json::Null) => return Ok(None),
        Some(v) => v,
    };
    let kernel_isa = block
        .path("kernel_isa")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("largek: missing kernel_isa"))?
        .to_string();
    let entries = block
        .path("entries")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("largek: entries is not an array"))?;
    if entries.is_empty() {
        bail!("largek: entries[] is empty — the deep-reduction bench wrote no shapes");
    }
    let mut out = Vec::new();
    let mut min_seen = f64::INFINITY;
    for (i, entry) in entries.iter().enumerate() {
        let shape: Vec<usize> = entry
            .path("shape")
            .and_then(Json::as_arr)
            .map(|dims| dims.iter().filter_map(Json::as_usize).collect())
            .unwrap_or_default();
        let [m, n, k] = shape[..] else {
            bail!("largek.entries[{i}]: shape is not an [m, n, k] triple");
        };
        let num = |key: &str| {
            entry
                .path(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("largek.entries[{i}]: missing {key}"))
        };
        let blocked_s = num("blocked_mean_s")?;
        let full_s = num("kc_full_mean_s")?;
        let speedup = num("speedup")?;
        for (name, v) in [("blocked_mean_s", blocked_s), ("kc_full_mean_s", full_s)] {
            if !(v.is_finite() && v > 0.0) {
                bail!("largek.entries[{i}]: {name} {v} is not a positive finite wall time");
            }
        }
        if !speedup.is_finite() || (speedup - full_s / blocked_s).abs() > 1e-6 {
            bail!(
                "largek.entries[{i}]: speedup {speedup} inconsistent with full/blocked means \
                 ({full_s:.4}s / {blocked_s:.4}s)"
            );
        }
        if speedup < min_largek_speedup {
            bail!(
                "largek gate FAILED at point {m}x{n}x{k} (blocked backend, \
                 [{kernel_isa}]): KC-blocked is only {speedup:.3}x the KC=k configuration \
                 (KC=k {full_s:.4}s, blocked {blocked_s:.4}s; need >= {min_largek_speedup:.2}x)"
            );
        }
        min_seen = min_seen.min(speedup);
        out.push((m, n, k, blocked_s, full_s, speedup));
    }
    // The writer's own min_speedup must agree with the entries it wrote.
    if let Some(written) = block.path("min_speedup").and_then(Json::as_f64) {
        if !written.is_finite() || (written - min_seen).abs() > 1e-6 {
            bail!("largek: min_speedup {written} inconsistent with entries (min {min_seen:.6})");
        }
    } else {
        bail!("largek: missing min_speedup");
    }
    Ok(Some(LargekGate { kernel_isa, entries: out, min_speedup: min_seen }))
}

/// Validate the `repeat_cache` block (schema /5): the same Arc-shared
/// operands resubmitted with the packed-operand cache on vs off. `null`
/// means the repeat-operand bench has not run (the committed-placeholder
/// state) — accepted with a notice; measured data must clear the
/// `--min-cache-speedup` steady-state ratio and show real cache hits.
fn check_repeat_cache(root: &Json, min_cache_speedup: f64) -> anyhow::Result<Option<CacheGate>> {
    use anyhow::{anyhow, bail};

    let block = match root.path("repeat_cache") {
        None => bail!("missing repeat_cache field (schema /5 requires it; null = not measured)"),
        Some(Json::Null) => return Ok(None),
        Some(v) => v,
    };
    let num = |key: &str| {
        block
            .path(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("repeat_cache: missing {key}"))
    };
    let on_first = num("cache_on.first_s")?;
    let on_steady = num("cache_on.steady_mean_s")?;
    let off_first = num("cache_off.first_s")?;
    let off_steady = num("cache_off.steady_mean_s")?;
    let speedup = num("steady_speedup")?;
    let hits = num("cache_on.hits")? as u64;
    for (name, v) in [
        ("cache_on.first_s", on_first),
        ("cache_on.steady_mean_s", on_steady),
        ("cache_off.first_s", off_first),
        ("cache_off.steady_mean_s", off_steady),
    ] {
        if !(v.is_finite() && v > 0.0) {
            bail!("repeat_cache: {name} {v} is not a positive finite wall time");
        }
    }
    if !speedup.is_finite() || (speedup - off_steady / on_steady).abs() > 1e-6 {
        bail!(
            "repeat_cache: steady_speedup {speedup} inconsistent with off/on steady means \
             ({off_steady:.4}s / {on_steady:.4}s)"
        );
    }
    if hits == 0 {
        bail!(
            "cache gate FAILED: the cache-on run recorded zero pack-cache hits — repeat \
             submissions of the same Arc operands re-packed every iteration"
        );
    }
    if speedup < min_cache_speedup {
        bail!(
            "cache gate FAILED at point repeat-operand (1024^3, FT on): cached steady state \
             is only {speedup:.3}x the uncached one (off {off_steady:.4}s, on {on_steady:.4}s; \
             need >= {min_cache_speedup:.2}x)"
        );
    }
    Ok(Some(CacheGate { on_steady_s: on_steady, off_steady_s: off_steady, speedup, hits }))
}

/// Validate the `serving` series (schema /5): the gateway loadgen's
/// closed-loop runs. `null` means loadgen has not run — accepted (the
/// plain bench can't measure it) unless `--require-serving`.
fn check_serving(
    root: &Json,
    require_serving: bool,
) -> anyhow::Result<Option<Vec<(String, usize, usize, u64, f64, f64)>>> {
    use anyhow::{anyhow, bail};

    let series = match root.path("serving") {
        None => bail!("missing serving field (schema /5 requires it; null = not yet measured)"),
        Some(Json::Null) => {
            if require_serving {
                bail!(
                    "serving is the null placeholder but --require-serving is set — run \
                     `loadgen --bench-out` against a live gateway first"
                );
            }
            return Ok(None);
        }
        Some(v) => v.as_arr().ok_or_else(|| anyhow!("serving is neither null nor an array"))?,
    };
    if series.is_empty() {
        bail!("serving[] series is empty — loadgen wrote no completed runs");
    }
    let mut out = Vec::new();
    for (i, entry) in series.iter().enumerate() {
        let mode = entry
            .path("mode")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("serving[{i}]: missing mode"))?;
        if mode != "closed" && mode != "open" {
            bail!("serving[{i}]: mode must be closed|open, got {mode:?}");
        }
        let num = |key: &str| {
            entry
                .path(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("serving[{i}]: missing {key}"))
        };
        let clients = num("clients")? as usize;
        // optional for pre-sharding files; loadgen now always writes it
        let pools = entry.path("pools").and_then(Json::as_usize).unwrap_or(1);
        if pools == 0 {
            bail!("serving[{i}]: pools must be >= 1");
        }
        let requests = num("requests")? as u64;
        let ok = num("ok")? as u64;
        let protocol_errors = num("protocol_errors")? as u64;
        let (p50, p95, p99) = (num("p50_ms")?, num("p95_ms")?, num("p99_ms")?);
        let rps = num("rps")?;
        if clients == 0 {
            bail!("serving[{i}]: clients must be >= 1");
        }
        if requests == 0 || ok == 0 {
            bail!("serving[{i}]: no completed requests (requests {requests}, ok {ok})");
        }
        if ok > requests {
            bail!("serving[{i}]: ok {ok} exceeds requests {requests}");
        }
        if protocol_errors != 0 {
            bail!("serving[{i}]: {protocol_errors} protocol errors (the gate demands 0)");
        }
        for (name, v) in [("p50_ms", p50), ("p95_ms", p95), ("p99_ms", p99)] {
            if !(v.is_finite() && v > 0.0) {
                bail!("serving[{i}]: {name} {v} is not a positive finite latency");
            }
        }
        if p50 > p95 || p95 > p99 {
            bail!("serving[{i}]: percentiles out of order (p50 {p50}, p95 {p95}, p99 {p99})");
        }
        if !(rps.is_finite() && rps > 0.0) {
            bail!("serving[{i}]: rps {rps} is not a positive finite throughput");
        }
        out.push((mode.to_string(), pools, clients, ok, p99, rps));
    }
    Ok(Some(out))
}

/// Validate the `pool_scaling` block and the shape of the serving sweep
/// curves behind it. Absent/null means the serving series spans a single
/// shard count — accepted unless `--require-scaling`.
fn check_scaling(
    root: &Json,
    require_scaling: bool,
    min_pool_speedup: f64,
) -> anyhow::Result<Option<Scaling>> {
    use anyhow::{anyhow, bail};

    let block = match root.path("pool_scaling") {
        None | Some(Json::Null) => {
            if require_scaling {
                bail!(
                    "pool_scaling is absent but --require-scaling is set — run loadgen \
                     --bench-out against a --pools 1 gateway, then again with \
                     --append-serving against a multi-pool gateway"
                );
            }
            return Ok(None);
        }
        Some(v) => v,
    };
    let num = |key: &str| {
        block
            .path(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("pool_scaling: missing {key}"))
    };
    let baseline_pools = num("baseline_pools")? as usize;
    let top_pools = num("top_pools")? as usize;
    let gate_clients = num("gate_clients")? as usize;
    let baseline_rps = num("baseline_rps")?;
    let top_rps = num("top_rps")?;
    let ratio = num("ratio")?;
    if baseline_pools == 0 || top_pools <= baseline_pools {
        bail!(
            "pool_scaling: shard counts out of order (baseline {baseline_pools}, \
             top {top_pools})"
        );
    }
    for (name, v) in [("baseline_rps", baseline_rps), ("top_rps", top_rps), ("ratio", ratio)] {
        if !(v.is_finite() && v > 0.0) {
            bail!("pool_scaling: {name} {v} is not positive and finite");
        }
    }
    if (ratio - top_rps / baseline_rps).abs() > 1e-6 {
        bail!(
            "pool_scaling: ratio {ratio} inconsistent with top/baseline rps \
             ({top_rps:.2} / {baseline_rps:.2})"
        );
    }

    // The gate ratio is only meaningful on a sane sweep: within each shard
    // group the throughput-vs-clients curve must climb monotonically (to
    // KNEE_TOLERANCE) until its knee, and the gate point must really have
    // been measured in both the baseline and the top group.
    // pools -> clients -> rps; a re-run at the same point supersedes the
    // earlier measurement, matching how loadgen derived the block
    let mut curves: std::collections::BTreeMap<usize, std::collections::BTreeMap<usize, f64>> =
        std::collections::BTreeMap::new();
    if let Some(series) = root.path("serving").and_then(Json::as_arr) {
        for e in series {
            let pools = e.path("pools").and_then(Json::as_usize).unwrap_or(1);
            let (Some(clients), Some(rps)) = (
                e.path("clients").and_then(Json::as_usize),
                e.path("rps").and_then(Json::as_f64),
            ) else {
                continue;
            };
            curves.entry(pools).or_default().insert(clients, rps);
        }
    }
    for (pools, points) in &curves {
        let curve: Vec<(usize, f64)> = points.iter().map(|(&c, &r)| (c, r)).collect();
        let knee = curve
            .iter()
            .enumerate()
            .max_by(|a, b| a.1 .1.total_cmp(&b.1 .1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        for w in curve[..=knee].windows(2) {
            let ((c0, r0), (c1, r1)) = (w[0], w[1]);
            if r1 < KNEE_TOLERANCE * r0 {
                bail!(
                    "scaling gate FAILED: pools={pools} sweep is not monotone up to its \
                     knee — rps drops {r0:.2} -> {r1:.2} between {c0} and {c1} clients \
                     (tolerance {KNEE_TOLERANCE})"
                );
            }
        }
    }
    for (name, pools) in [("baseline", baseline_pools), ("top", top_pools)] {
        let measured = curves
            .get(&pools)
            .map(|c| c.contains_key(&gate_clients))
            .unwrap_or(false);
        if !measured {
            bail!(
                "pool_scaling: gate point ({gate_clients} clients) was never measured in \
                 the {name} (pools={pools}) serving group"
            );
        }
    }

    if ratio < min_pool_speedup {
        bail!(
            "scaling gate FAILED: {baseline_pools}->{top_pools} pools at {gate_clients} \
             clients is only {ratio:.2}x the single-shard throughput \
             ({baseline_rps:.2} -> {top_rps:.2} req/s; need >= {min_pool_speedup:.2}x)"
        );
    }
    Ok(Some(Scaling { baseline_pools, top_pools, gate_clients, ratio }))
}

/// Validate the clean-vs-FT `ft_overhead` series: both blocked variants
/// present, positive finite wall times, overhead consistent with them.
fn check_ft_overhead(root: &Json) -> anyhow::Result<Vec<(String, String, f64)>> {
    use anyhow::{anyhow, bail};

    let series = root
        .path("ft_overhead")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing ft_overhead[] series (schema /3 requires it)"))?;
    if series.is_empty() {
        bail!("ft_overhead[] series is empty");
    }
    let mut out = Vec::new();
    for (i, entry) in series.iter().enumerate() {
        let backend = entry
            .path("backend")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("ft_overhead[{i}]: missing backend"))?;
        let isa = entry
            .path("kernel_isa")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("ft_overhead[{i}]: missing kernel_isa"))?;
        let clean = entry
            .path("clean_mean_s")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("ft_overhead[{i}]: missing clean_mean_s"))?;
        let ft = entry
            .path("ft_mean_s")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("ft_overhead[{i}]: missing ft_mean_s"))?;
        let overhead = entry
            .path("overhead")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("ft_overhead[{i}]: missing overhead"))?;
        for (name, v) in [("clean_mean_s", clean), ("ft_mean_s", ft)] {
            if !(v.is_finite() && v > 0.0) {
                bail!("ft_overhead[{i}]: {name} {v} is not a positive finite wall time");
            }
        }
        if !overhead.is_finite() || (overhead - (ft / clean - 1.0)).abs() > 1e-6 {
            bail!(
                "ft_overhead[{i}]: overhead {overhead} inconsistent with ft/clean ratio \
                 ({ft:.4}s / {clean:.4}s)"
            );
        }
        out.push((backend.to_string(), isa.to_string(), overhead));
    }
    for required in ["blocked-scalar", "blocked"] {
        if !out.iter().any(|(b, _, _)| b == required) {
            bail!("ft_overhead[] has no entry for the {required} backend");
        }
    }
    Ok(out)
}
