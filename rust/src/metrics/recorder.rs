//! Latency recording + atomic counters for the serving path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::stats::{Quantiles, Running};

/// Thread-safe latency recorder (seconds internally).
#[derive(Default)]
pub struct LatencyRecorder {
    inner: Mutex<(Running, Quantiles)>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, d: Duration) {
        let secs = d.as_secs_f64();
        let mut g = self.inner.lock().unwrap();
        g.0.push(secs);
        g.1.push(secs);
    }

    /// Time a closure and record its latency.
    pub fn time<R>(&self, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.record(t0.elapsed());
        r
    }

    pub fn count(&self) -> u64 {
        self.inner.lock().unwrap().0.count()
    }

    pub fn mean_secs(&self) -> f64 {
        self.inner.lock().unwrap().0.mean()
    }

    pub fn quantile_secs(&self, q: f64) -> f64 {
        self.inner.lock().unwrap().1.quantile(q)
    }

    pub fn summary(&self) -> LatencySummary {
        let mut g = self.inner.lock().unwrap();
        let count = g.0.count();
        let (mean, min, max) = (g.0.mean(), g.0.min(), g.0.max());
        let (p50, p99) = if count > 0 { (g.1.median(), g.1.p99()) } else { (0.0, 0.0) };
        LatencySummary { count, mean, min, max, p50, p99 }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySummary {
    pub count: u64,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p99: f64,
}

/// The coordinator's operation counters.
#[derive(Debug, Default)]
pub struct Counters {
    pub requests: AtomicU64,
    pub executions: AtomicU64,
    pub errors_detected: AtomicU64,
    pub errors_corrected: AtomicU64,
    pub recomputes: AtomicU64,
    pub padded_requests: AtomicU64,
    pub batched_groups: AtomicU64,
    /// Requests canceled before dispatch (ticket surface).
    pub canceled: AtomicU64,
    /// Requests whose deadline passed while queued.
    pub expired: AtomicU64,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn bump(field: &AtomicU64) {
        field.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(field: &AtomicU64, n: u64) {
        field.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(field: &AtomicU64) -> u64 {
        field.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> CounterSnapshot {
        CounterSnapshot {
            requests: Self::get(&self.requests),
            executions: Self::get(&self.executions),
            errors_detected: Self::get(&self.errors_detected),
            errors_corrected: Self::get(&self.errors_corrected),
            recomputes: Self::get(&self.recomputes),
            padded_requests: Self::get(&self.padded_requests),
            batched_groups: Self::get(&self.batched_groups),
            canceled: Self::get(&self.canceled),
            expired: Self::get(&self.expired),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CounterSnapshot {
    pub requests: u64,
    pub executions: u64,
    pub errors_detected: u64,
    pub errors_corrected: u64,
    pub recomputes: u64,
    pub padded_requests: u64,
    pub batched_groups: u64,
    pub canceled: u64,
    pub expired: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_summary_sane() {
        let rec = LatencyRecorder::new();
        for ms in [1u64, 2, 3, 4, 100] {
            rec.record(Duration::from_millis(ms));
        }
        let s = rec.summary();
        assert_eq!(s.count, 5);
        assert!(s.min > 0.0009 && s.min < 0.0015);
        assert!(s.max >= 0.1);
        assert!(s.p50 >= 0.002 && s.p50 <= 0.004);
    }

    #[test]
    fn time_records_once() {
        let rec = LatencyRecorder::new();
        let out = rec.time(|| 42);
        assert_eq!(out, 42);
        assert_eq!(rec.count(), 1);
    }

    #[test]
    fn counters_accumulate() {
        let c = Counters::new();
        Counters::bump(&c.requests);
        Counters::add(&c.errors_corrected, 5);
        let snap = c.snapshot();
        assert_eq!(snap.requests, 1);
        assert_eq!(snap.errors_corrected, 5);
        assert_eq!(snap.recomputes, 0);
    }
}
