//! Metrics: GFLOPS accounting, latency recording, counters, and the
//! markdown/CSV reporters the figures harness and EXPERIMENTS.md use.

pub mod recorder;
pub mod report;

pub use recorder::{Counters, LatencyRecorder};
pub use report::{Series, Table};

/// FLOPs of C += A·B.
pub fn gemm_flops(m: usize, n: usize, k: usize) -> f64 {
    2.0 * m as f64 * n as f64 * k as f64
}

/// GFLOPS given FLOPs and seconds.
pub fn gflops(flops: f64, seconds: f64) -> f64 {
    if seconds <= 0.0 {
        return 0.0;
    }
    flops / seconds / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flop_count_matches_closed_form() {
        assert_eq!(gemm_flops(2, 3, 4), 48.0);
    }

    #[test]
    fn gflops_zero_time_is_zero_not_inf() {
        assert_eq!(gflops(1e9, 0.0), 0.0);
        assert_eq!(gflops(2e9, 1.0), 2.0);
    }
}
