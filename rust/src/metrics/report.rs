//! Report primitives: named data series and aligned tables, with markdown
//! CSV, and JSON emitters — the output format of `ftgemm figures`.

use crate::util::json::Json;

/// One named series over a shared x-axis (a line in a paper figure).
#[derive(Debug, Clone, PartialEq)]
pub struct Series {
    pub name: String,
    pub x: Vec<f64>,
    pub y: Vec<f64>,
}

impl Series {
    pub fn new(name: impl Into<String>) -> Self {
        Series { name: name.into(), x: Vec::new(), y: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.x.push(x);
        self.y.push(y);
    }

    pub fn from_pairs(name: impl Into<String>, pairs: &[(f64, f64)]) -> Self {
        let mut s = Series::new(name);
        for &(x, y) in pairs {
            s.push(x, y);
        }
        s
    }

    pub fn mean_y(&self) -> f64 {
        if self.y.is_empty() {
            return 0.0;
        }
        self.y.iter().sum::<f64>() / self.y.len() as f64
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", Json::from(self.name.clone()));
        o.set("x", Json::from(self.x.clone()));
        o.set("y", Json::from(self.y.clone()));
        o
    }
}

/// A figure/table: a title, an x-axis label, and a set of series.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub x_label: String,
    pub y_label: String,
    pub series: Vec<Series>,
    pub notes: Vec<String>,
}

impl Table {
    pub fn new(title: impl Into<String>, x_label: impl Into<String>, y_label: impl Into<String>) -> Self {
        Table {
            title: title.into(),
            x_label: x_label.into(),
            y_label: y_label.into(),
            series: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn add(&mut self, s: Series) -> &mut Self {
        self.series.push(s);
        self
    }

    pub fn note(&mut self, n: impl Into<String>) -> &mut Self {
        self.notes.push(n.into());
        self
    }

    pub fn get(&self, name: &str) -> Option<&Series> {
        self.series.iter().find(|s| s.name == name)
    }

    /// Markdown table: one row per x value, one column per series.
    pub fn to_markdown(&self) -> String {
        let mut out = format!("### {}\n\n", self.title);
        for n in &self.notes {
            out.push_str(&format!("> {n}\n"));
        }
        if self.series.is_empty() {
            return out;
        }
        out.push_str(&format!("\n| {} |", self.x_label));
        for s in &self.series {
            out.push_str(&format!(" {} |", s.name));
        }
        out.push_str("\n|---|");
        for _ in &self.series {
            out.push_str("---|");
        }
        out.push('\n');
        let xs = &self.series[0].x;
        for (i, x) in xs.iter().enumerate() {
            out.push_str(&format!("| {} |", fmt_num(*x)));
            for s in &self.series {
                let y = s.y.get(i).copied().unwrap_or(f64::NAN);
                out.push_str(&format!(" {} |", fmt_num(y)));
            }
            out.push('\n');
        }
        out
    }

    /// CSV: header `x,<series...>`, one row per x.
    pub fn to_csv(&self) -> String {
        let mut out = format!("{}", self.x_label);
        for s in &self.series {
            out.push_str(&format!(",{}", s.name.replace(',', ";")));
        }
        out.push('\n');
        if let Some(first) = self.series.first() {
            for (i, x) in first.x.iter().enumerate() {
                out.push_str(&fmt_num(*x));
                for s in &self.series {
                    out.push_str(&format!(",{}", fmt_num(s.y.get(i).copied().unwrap_or(f64::NAN))));
                }
                out.push('\n');
            }
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("title", Json::from(self.title.clone()));
        o.set("x_label", Json::from(self.x_label.clone()));
        o.set("y_label", Json::from(self.y_label.clone()));
        o.set(
            "series",
            Json::Arr(self.series.iter().map(|s| s.to_json()).collect()),
        );
        o.set(
            "notes",
            Json::Arr(self.notes.iter().map(|n| Json::from(n.clone())).collect()),
        );
        o
    }
}

fn fmt_num(x: f64) -> String {
    if x.is_nan() {
        "nan".into()
    } else if x == x.trunc() && x.abs() < 1e12 {
        format!("{}", x as i64)
    } else if x.abs() >= 100.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> Table {
        let mut t = Table::new("Fig X", "size", "GFLOPS");
        t.add(Series::from_pairs("ours", &[(128.0, 100.0), (256.0, 200.0)]));
        t.add(Series::from_pairs("cublas", &[(128.0, 110.0), (256.0, 190.0)]));
        t
    }

    #[test]
    fn markdown_has_header_and_rows() {
        let md = table().to_markdown();
        assert!(md.contains("| size | ours | cublas |"));
        assert!(md.contains("| 128 | 100 | 110 |"));
        assert!(md.contains("| 256 | 200 | 190 |"));
    }

    #[test]
    fn csv_rows_align() {
        let csv = table().to_csv();
        let lines: Vec<_> = csv.lines().collect();
        assert_eq!(lines[0], "size,ours,cublas");
        assert_eq!(lines[1], "128,100,110");
    }

    #[test]
    fn json_roundtrips_through_parser() {
        let j = table().to_json();
        let parsed = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.path("title").unwrap().as_str(), Some("Fig X"));
        assert_eq!(parsed.path("series").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn series_mean() {
        let s = Series::from_pairs("s", &[(0.0, 1.0), (1.0, 3.0)]);
        assert_eq!(s.mean_y(), 2.0);
    }
}
