//! Vendored, dependency-free subset of the `log` facade.
//!
//! The build environment has no crates.io access; this shim provides the
//! `log::{error, warn, info, debug, trace}!` macros with env-var-gated
//! stderr output. Levels at or above the one named in `RUST_LOG`
//! (`error|warn|info|debug|trace`, default `warn`) are printed as
//! `[LEVEL target] message`. Swapping in the real crate plus a logger
//! implementation requires no source changes.

use std::sync::OnceLock;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

fn max_level() -> Level {
    static MAX: OnceLock<Level> = OnceLock::new();
    *MAX.get_or_init(|| match std::env::var("RUST_LOG").as_deref() {
        Ok(s) if s.eq_ignore_ascii_case("error") => Level::Error,
        Ok(s) if s.eq_ignore_ascii_case("warn") => Level::Warn,
        Ok(s) if s.eq_ignore_ascii_case("info") => Level::Info,
        Ok(s) if s.eq_ignore_ascii_case("debug") => Level::Debug,
        Ok(s) if s.eq_ignore_ascii_case("trace") => Level::Trace,
        _ => Level::Warn,
    })
}

/// Macro plumbing — not part of the public `log` API.
#[doc(hidden)]
pub fn __emit(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if level <= max_level() {
        eprintln!("[{} {}] {}", level.as_str(), target, args);
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::__emit($crate::Level::Error, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::__emit($crate::Level::Warn, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::__emit($crate::Level::Info, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::__emit($crate::Level::Debug, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { $crate::__emit($crate::Level::Trace, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_ordered() {
        assert!(Level::Error < Level::Warn && Level::Warn < Level::Trace);
    }

    #[test]
    fn macros_expand() {
        // Output is env-gated; this just exercises the expansion paths.
        error!("e {}", 1);
        warn!("w");
        info!("i {x}", x = 2);
        debug!("d");
        trace!("t");
    }
}
