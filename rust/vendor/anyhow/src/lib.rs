//! Vendored, dependency-free subset of the `anyhow` API.
//!
//! The build environment for this repository has no crates.io access, so
//! the handful of `anyhow` features the codebase uses are reimplemented
//! here behind the same names (`Error`, `Result`, `anyhow!`, `bail!`,
//! `ensure!`, `Context`). Drop-in: replacing this path dependency with the
//! real crate requires no source changes.
//!
//! Semantics mirrored from upstream:
//! * `Display` prints the outermost message; `{:#}` prints the whole
//!   context chain joined by `": "`.
//! * `Debug` prints the outermost message plus a `Caused by:` list.
//! * `Error` deliberately does NOT implement `std::error::Error`, which is
//!   what makes the blanket `From<E: std::error::Error>` impl coherent.

use std::fmt;

/// An error chain: `frames[0]` is the outermost context, the last frame is
/// the root cause.
pub struct Error {
    frames: Vec<String>,
}

impl Error {
    /// Construct from a single message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { frames: vec![message.to_string()] }
    }

    /// Wrap with an outer context frame (what `Context::context` does).
    pub fn wrap<C: fmt::Display>(mut self, context: C) -> Error {
        self.frames.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        self.frames.first().map(String::as_str).unwrap_or("")
    }

    /// All frames, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.frames.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.frames.join(": "))
        } else {
            write!(f, "{}", self.root_message())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.root_message())?;
        if self.frames.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for frame in &self.frames[1..] {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut frames = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            frames.push(s.to_string());
            source = s.source();
        }
        Error { frames }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and to `None`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Err::<(), _>(io_err()).context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing thing");
    }

    #[test]
    fn debug_lists_causes() {
        let e = anyhow!("root").wrap("outer");
        let d = format!("{e:?}");
        assert!(d.contains("outer") && d.contains("Caused by") && d.contains("root"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "missing thing");
    }

    #[test]
    fn bail_and_ensure() {
        fn b() -> Result<()> {
            bail!("bad {}", 7);
        }
        fn e(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert_eq!(b().unwrap_err().to_string(), "bad 7");
        assert!(e(3).is_ok());
        assert_eq!(e(-1).unwrap_err().to_string(), "x must be positive, got -1");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        assert_eq!(v.context("nothing there").unwrap_err().to_string(), "nothing there");
    }

    #[test]
    fn context_on_anyhow_result_nests() {
        let e: Error = Err::<(), _>(anyhow!("inner")).with_context(|| "outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
    }
}
