//! End-to-end integration: engine pool + submission queue +
//! planner/scheduler + coordinator + batcher + ding baseline. Runs
//! against the AOT artifacts when `make artifacts` has been run, and
//! against the built-in manifest + reference backend otherwise — the
//! serving semantics under test are identical.

use std::sync::OnceLock;
use std::time::Duration;

use ftgemm::abft::checksum::Thresholds;
use ftgemm::abft::injection::{Injection, InjectionPlan};
use ftgemm::abft::matrix::Matrix;
use ftgemm::coordinator::batcher::{Batcher, BatcherConfig};
use ftgemm::coordinator::ding::DingPipeline;
use ftgemm::coordinator::{
    Coordinator, CoordinatorConfig, FtLevel, FtPolicy, GemmRequest, HostVerify, Priority,
    TicketStatus,
};
use ftgemm::faults::{FaultCampaign, SeuModel};
use ftgemm::runtime::{Engine, EngineConfig};

fn engine() -> Engine {
    static ENGINE: OnceLock<Engine> = OnceLock::new();
    ENGINE
        .get_or_init(|| Engine::start(EngineConfig::default()).expect("engine starts"))
        .clone()
}

fn pool_engine(workers: usize) -> Engine {
    Engine::start(EngineConfig { workers, ..Default::default() }).expect("engine starts")
}

fn coordinator() -> Coordinator {
    Coordinator::new(engine(), CoordinatorConfig::default())
}

fn check_close(got: &Matrix, want: &Matrix, tol: f32, what: &str) {
    let diff = got.max_abs_diff(want);
    assert!(diff < tol, "{what}: max diff {diff} > {tol}");
}

// ---------------------------------------------------------------------
// Plain serving path
// ---------------------------------------------------------------------

#[test]
fn exact_bucket_gemm_matches_host() {
    let coord = coordinator();
    let a = Matrix::rand_uniform(128, 128, 1);
    let b = Matrix::rand_uniform(128, 128, 2);
    let out = coord.gemm(&a, &b, FtPolicy::None).unwrap();
    check_close(&out.c, &a.matmul(&b), 1e-3, "exact bucket");
    assert_eq!(out.kernel_launches, 1);
    assert_eq!(out.buckets, vec!["medium"]);
}

#[test]
fn padded_irregular_shape_matches_host() {
    let coord = coordinator();
    // 100x90x70: fits nothing exactly -> padded into medium
    let a = Matrix::rand_uniform(100, 70, 3);
    let b = Matrix::rand_uniform(70, 90, 4);
    let out = coord.gemm(&a, &b, FtPolicy::None).unwrap();
    assert_eq!((out.c.rows(), out.c.cols()), (100, 90));
    check_close(&out.c, &a.matmul(&b), 1e-3, "padded");
}

#[test]
fn tall_shape_routes_to_tall_bucket() {
    let coord = coordinator();
    let a = Matrix::rand_uniform(100, 200, 5);
    let b = Matrix::rand_uniform(200, 480, 6);
    let out = coord.gemm(&a, &b, FtPolicy::None).unwrap();
    assert_eq!(out.buckets, vec!["tall"]);
    check_close(&out.c, &a.matmul(&b), 2e-3, "tall");
}

#[test]
fn oversize_gemm_splits_and_accumulates() {
    let coord = coordinator();
    // 600^3 > huge bucket -> 2x2x2 block decomposition
    let a = Matrix::rand_uniform(600, 600, 7);
    let b = Matrix::rand_uniform(600, 600, 8);
    let out = coord.gemm(&a, &b, FtPolicy::None).unwrap();
    assert_eq!(out.kernel_launches, 8);
    check_close(&out.c, &a.matmul(&b), 5e-3, "split");
}

#[test]
fn host_verify_accepts_clean_results() {
    let cfg = CoordinatorConfig { host_verify: HostVerify::CleanOnly, ..Default::default() };
    let coord = Coordinator::new(engine(), cfg);
    let a = Matrix::rand_uniform(64, 64, 9);
    let b = Matrix::rand_uniform(64, 64, 10);
    coord.gemm(&a, &b, FtPolicy::None).unwrap();
}

#[test]
fn mismatched_inner_dims_rejected() {
    let coord = coordinator();
    let a = Matrix::rand_uniform(8, 9, 1);
    let b = Matrix::rand_uniform(10, 8, 2);
    assert!(coord.gemm(&a, &b, FtPolicy::None).is_err());
}

// ---------------------------------------------------------------------
// Online (fused) fault tolerance
// ---------------------------------------------------------------------

#[test]
fn online_ft_fault_free_matches_plain() {
    let coord = coordinator();
    let a = Matrix::rand_uniform(128, 128, 11);
    let b = Matrix::rand_uniform(128, 128, 12);
    let plain = coord.gemm(&a, &b, FtPolicy::None).unwrap();
    let ft = coord.gemm(&a, &b, FtPolicy::Online).unwrap();
    assert_eq!(ft.errors_detected, 0);
    check_close(&ft.c, &plain.c, 1e-3, "ft vs plain");
}

#[test]
fn online_ft_corrects_injected_errors() {
    let coord = coordinator();
    let a = Matrix::rand_uniform(128, 128, 13);
    let b = Matrix::rand_uniform(128, 128, 14);
    let want = a.matmul(&b);
    let inj = InjectionPlan {
        injections: vec![
            Injection { row: 5, col: 9, step: 0, magnitude: 300.0 },
            Injection { row: 77, col: 40, step: 6, magnitude: -1000.0 },
            Injection { row: 127, col: 127, step: 12, magnitude: 64.0 },
        ],
    };
    let out = coord.gemm_with_faults(&a, &b, FtPolicy::Online, &inj).unwrap();
    assert_eq!(out.errors_corrected, 3);
    assert_eq!(out.recomputes, 0);
    check_close(&out.c, &want, 2e-2, "online corrected");
}

#[test]
fn online_ft_on_padded_shape_corrects() {
    let coord = coordinator();
    let a = Matrix::rand_uniform(100, 60, 15);
    let b = Matrix::rand_uniform(60, 90, 16);
    let want = a.matmul(&b);
    let inj = InjectionPlan::single(50, 45, 1, 500.0);
    let out = coord.gemm_with_faults(&a, &b, FtPolicy::Online, &inj).unwrap();
    assert_eq!(out.errors_corrected, 1);
    check_close(&out.c, &want, 2e-2, "padded + injected");
}

#[test]
fn warp_and_thread_levels_also_correct() {
    for level in [FtLevel::Warp, FtLevel::Thread] {
        let cfg = CoordinatorConfig { ft_level: level, ..Default::default() };
        let coord = Coordinator::new(engine(), cfg);
        let a = Matrix::rand_uniform(128, 128, 17);
        let b = Matrix::rand_uniform(128, 128, 18);
        let want = a.matmul(&b);
        let inj = InjectionPlan::single(30, 31, 2, 777.0);
        let out = coord.gemm_with_faults(&a, &b, FtPolicy::Online, &inj).unwrap();
        assert_eq!(out.errors_corrected, 1, "{level}");
        check_close(&out.c, &want, 2e-2, level.as_str());
    }
}

#[test]
fn injecting_into_unprotected_kernel_is_refused() {
    let coord = coordinator();
    let a = Matrix::rand_uniform(64, 64, 19);
    let b = Matrix::rand_uniform(64, 64, 20);
    let inj = InjectionPlan::single(0, 0, 0, 100.0);
    assert!(coord.gemm_with_faults(&a, &b, FtPolicy::None, &inj).is_err());
}

// ---------------------------------------------------------------------
// Offline (detect + recompute)
// ---------------------------------------------------------------------

#[test]
fn offline_detects_and_recomputes() {
    let coord = coordinator();
    // medium bucket has a detect-only artifact
    let a = Matrix::rand_uniform(128, 128, 21);
    let b = Matrix::rand_uniform(128, 128, 22);
    let want = a.matmul(&b);
    let inj = InjectionPlan::single(10, 10, 3, 444.0);
    let out = coord.gemm_with_faults(&a, &b, FtPolicy::Offline, &inj).unwrap();
    assert!(out.errors_detected >= 1);
    assert_eq!(out.recomputes, 1);
    assert!(out.kernel_launches >= 2, "detection must trigger a second run");
    check_close(&out.c, &want, 1e-3, "offline recomputed");
}

#[test]
fn offline_fault_free_runs_once() {
    let coord = coordinator();
    let a = Matrix::rand_uniform(128, 128, 23);
    let b = Matrix::rand_uniform(128, 128, 24);
    let out = coord.gemm(&a, &b, FtPolicy::Offline).unwrap();
    assert_eq!(out.recomputes, 0);
    assert_eq!(out.kernel_launches, 1);
}

#[test]
fn offline_without_detect_artifact_uses_host_detector() {
    let coord = coordinator();
    // small bucket has no ftdetect artifact -> host path
    let a = Matrix::rand_uniform(64, 64, 25);
    let b = Matrix::rand_uniform(64, 64, 26);
    let want = a.matmul(&b);
    let inj = InjectionPlan::single(3, 3, 0, 256.0);
    let out = coord.gemm_with_faults(&a, &b, FtPolicy::Offline, &inj).unwrap();
    assert!(out.errors_detected >= 1);
    assert_eq!(out.recomputes, 1);
    check_close(&out.c, &want, 1e-3, "host-detector offline");
}

// ---------------------------------------------------------------------
// Ding non-fused baseline
// ---------------------------------------------------------------------

#[test]
fn ding_pipeline_matches_host_gemm() {
    let pipe = DingPipeline::new(coordinator(), "medium").unwrap();
    let a = Matrix::rand_uniform(128, 128, 27);
    let b = Matrix::rand_uniform(128, 128, 28);
    let out = pipe.gemm(&a, &b).unwrap();
    assert_eq!(out.errors_corrected, 0);
    // 1 encode + 2 per panel
    assert_eq!(out.kernel_launches as usize, 1 + 2 * pipe.panels());
    check_close(&out.c, &a.matmul(&b), 2e-3, "ding clean");
}

#[test]
fn ding_pipeline_corrects_per_panel_faults() {
    let pipe = DingPipeline::new(coordinator(), "medium").unwrap();
    let a = Matrix::rand_uniform(128, 128, 29);
    let b = Matrix::rand_uniform(128, 128, 30);
    let want = a.matmul(&b);
    let inj = InjectionPlan {
        injections: vec![
            Injection { row: 3, col: 4, step: 0, magnitude: 512.0 },
            Injection { row: 90, col: 100, step: 1, magnitude: -128.0 },
        ],
    };
    let out = pipe.gemm_with_faults(&a, &b, &inj).unwrap();
    assert_eq!(out.errors_corrected, 2);
    check_close(&out.c, &want, 2e-2, "ding corrected");
}

#[test]
fn fused_uses_fewer_launches_than_ding() {
    // the structural claim behind the paper's speedup: one launch vs 1+2P
    let coord = coordinator();
    let pipe = DingPipeline::new(coord.clone(), "medium").unwrap();
    let a = Matrix::rand_uniform(128, 128, 31);
    let b = Matrix::rand_uniform(128, 128, 32);
    let fused = coord.gemm(&a, &b, FtPolicy::Online).unwrap();
    let ding = pipe.gemm(&a, &b).unwrap();
    assert!(fused.kernel_launches < ding.kernel_launches);
}

// ---------------------------------------------------------------------
// Batcher
// ---------------------------------------------------------------------

#[test]
fn batcher_serves_mixed_shapes_and_policies() {
    let batcher = Batcher::start(coordinator(), BatcherConfig::default());
    let mut tickets = Vec::new();
    let mut wants = Vec::new();
    for i in 0..12u64 {
        let (m, n, k) = match i % 3 {
            0 => (64, 64, 64),
            1 => (128, 128, 128),
            _ => (100, 80, 60),
        };
        let policy = if i % 2 == 0 { FtPolicy::None } else { FtPolicy::Online };
        let a = Matrix::rand_uniform(m, k, 100 + i);
        let b = Matrix::rand_uniform(k, n, 200 + i);
        wants.push(a.matmul(&b));
        tickets.push(batcher.submit(GemmRequest::new(a, b).policy(policy)).unwrap());
    }
    for (t, want) in tickets.into_iter().zip(&wants) {
        let out = t.wait().unwrap();
        check_close(&out.result.c, want, 2e-3, "batched");
    }
    let stats = batcher.stats();
    assert_eq!(stats.requests, 12);
    assert!(stats.groups >= 1);
}

#[test]
fn batcher_tickets_are_coordinator_tickets() {
    // A ticket handed out by the batcher supports the same cancel/poll
    // surface as a direct submit, and ids stay coordinator-unique.
    let coord = coordinator();
    let batcher = Batcher::start(coord.clone(), BatcherConfig::default());
    let a = Matrix::rand_uniform(64, 64, 61);
    let b = Matrix::rand_uniform(64, 64, 62);
    let batched = batcher.submit(GemmRequest::new(a.clone(), b.clone())).unwrap();
    let direct = coord.submit(GemmRequest::new(a, b)).unwrap();
    assert_ne!(batched.id(), direct.id());
    let br = batched.wait().unwrap();
    let dr = direct.wait().unwrap();
    check_close(&br.result.c, &dr.result.c, 1e-4, "batched vs direct");
}

// ---------------------------------------------------------------------
// Fault campaigns
// ---------------------------------------------------------------------

#[test]
fn campaign_online_corrects_everything() {
    let campaign = FaultCampaign::new(
        coordinator(),
        SeuModel::PerGemm { count: 4 },
        FtPolicy::Online,
        42,
    );
    let report = campaign.run(128, 128, 128, 3).unwrap();
    assert_eq!(report.gemms, 3);
    assert_eq!(report.injected, 12);
    // corrected >= injected: a correction of a huge (2^20) offset leaves an
    // O(eps*mag) residue that a later verification pass refines again
    assert!(report.corrected >= 12, "{}", report.corrected);
    assert_eq!(report.recomputes, 0);
    // correction residue is O(eps * |magnitude|); bit-flip magnitudes go up
    // to 2^20, so the corrected result can be ~0.1 off in absolute terms
    // (relative to elements of size ~K/4 that's still ~1e-5 relative).
    assert!(report.max_error_vs_reference < 0.5, "{}", report.max_error_vs_reference);
}

#[test]
fn campaign_offline_recomputes_instead_of_correcting() {
    let campaign = FaultCampaign::new(
        coordinator(),
        SeuModel::PerGemm { count: 1 },
        FtPolicy::Offline,
        43,
    );
    let report = campaign.run(128, 128, 128, 2).unwrap();
    assert_eq!(report.corrected, 0);
    assert!(report.recomputes >= 2);
    assert!(report.max_error_vs_reference < 1e-3);
}

#[test]
fn coordinator_counters_accumulate() {
    let coord = coordinator();
    let a = Matrix::rand_uniform(64, 64, 50);
    let b = Matrix::rand_uniform(64, 64, 51);
    coord.gemm(&a, &b, FtPolicy::None).unwrap();
    coord
        .gemm_with_faults(&a, &b, FtPolicy::Online, &InjectionPlan::single(1, 1, 0, 99.0))
        .unwrap();
    let snap = coord.counters().snapshot();
    assert_eq!(snap.requests, 2);
    assert_eq!(snap.errors_corrected, 1);
    assert_eq!(coord.latency().count(), 2);
}

// ---------------------------------------------------------------------
// Failure injection: the system must fail loudly, not silently
// ---------------------------------------------------------------------

#[test]
fn unknown_artifact_rejected_by_engine() {
    let eng = engine();
    let err = eng.warm("nonexistent_kernel").unwrap_err();
    assert!(err.to_string().contains("not in manifest"));
}

#[test]
fn wrong_input_count_rejected() {
    let eng = engine();
    let err = eng
        .execute("gemm_small", vec![ftgemm::runtime::engine::Tensor::zeros(vec![64, 64])])
        .unwrap_err();
    assert!(err.to_string().contains("inputs"));
}

#[test]
fn ding_pipeline_rejects_wrong_shape() {
    let pipe = DingPipeline::new(coordinator(), "medium").unwrap();
    let a = Matrix::rand_uniform(64, 64, 1);
    let b = Matrix::rand_uniform(64, 64, 2);
    assert!(pipe.gemm(&a, &b).is_err());
}

#[test]
fn ding_pipeline_missing_bucket_errors() {
    // "small" has no ding artifacts
    assert!(DingPipeline::new(coordinator(), "small").is_err());
}

#[test]
fn serve_config_roundtrip() {
    // the shipped sample config must parse and build all three configs
    let cfg = ftgemm::util::config::Config::load("ftgemm.toml")
        .or_else(|_| ftgemm::util::config::Config::load("../ftgemm.toml"))
        .unwrap();
    let coord = cfg.coordinator().unwrap();
    assert_eq!(coord.ft_level, FtLevel::Tb);
    let eng = cfg.engine().unwrap();
    assert!(eng.precompile.contains(&"gemm_medium".to_string()));
    assert_eq!(eng.backend, "blocked", "sample config serves on the blocked backend");
    assert!(cfg.batcher().is_ok());
}

#[test]
fn engine_survives_failed_request_then_serves() {
    let eng = engine();
    let _ = eng.warm("nope");
    // after an error the engine thread must still serve
    let coord = Coordinator::new(eng, CoordinatorConfig::default());
    let a = Matrix::rand_uniform(64, 64, 90);
    let b = Matrix::rand_uniform(64, 64, 91);
    coord.gemm(&a, &b, FtPolicy::None).unwrap();
}

#[test]
fn oversize_online_ft_corrects_in_owning_block() {
    // injection into a split GEMM lands in the right block
    let coord = coordinator();
    let a = Matrix::rand_uniform(600, 600, 92);
    let b = Matrix::rand_uniform(600, 600, 93);
    let want = a.matmul(&b);
    let inj = InjectionPlan::single(550, 13, 2, 4096.0); // block (1, 0)
    let out = coord.gemm_with_faults(&a, &b, FtPolicy::Online, &inj).unwrap();
    assert!(out.errors_corrected >= 1);
    check_close(&out.c, &want, 5e-2, "split + injected");
}

// ---------------------------------------------------------------------
// The plan -> schedule -> execute pipeline over the engine worker pool
// ---------------------------------------------------------------------

#[test]
fn split_gemm_executes_blocks_concurrently_with_pool() {
    // 4 workers, 8 independent huge blocks: the engine must observe
    // overlapping executions (the concurrency the refactor exists for).
    let engine = pool_engine(4);
    let coord = Coordinator::new(engine.clone(), CoordinatorConfig::default());
    let a = Matrix::rand_uniform(1024, 1024, 94);
    let b = Matrix::rand_uniform(1024, 1024, 95);
    let out = coord.gemm(&a, &b, FtPolicy::None).unwrap();
    assert_eq!(out.kernel_launches, 8);
    check_close(&out.c, &a.matmul(&b), 1e-2, "pooled split");
    assert!(
        engine.peak_inflight() >= 2,
        "blocks never overlapped (peak inflight {})",
        engine.peak_inflight()
    );
    let busy = engine
        .stats_per_worker()
        .unwrap()
        .iter()
        .filter(|s| s.executions > 0)
        .count();
    assert!(busy >= 2, "all blocks served by {busy} worker(s)");
}

#[test]
fn pool_results_match_single_worker_results() {
    let a = Matrix::rand_uniform(700, 600, 96);
    let b = Matrix::rand_uniform(600, 650, 97);
    let single = Coordinator::new(pool_engine(1), CoordinatorConfig::default())
        .gemm(&a, &b, FtPolicy::Online)
        .unwrap();
    let pooled = Coordinator::new(pool_engine(4), CoordinatorConfig::default())
        .gemm(&a, &b, FtPolicy::Online)
        .unwrap();
    assert_eq!(single.kernel_launches, pooled.kernel_launches);
    assert_eq!(single.buckets, pooled.buckets);
    // accumulation order differs (completion order), so roundoff-level drift
    check_close(&pooled.c, &single.c, 1e-3, "pool determinism");
}

#[test]
fn plan_introspection_matches_execution() {
    let coord = coordinator();
    let plan = coord.plan(600, 600, 600, FtPolicy::Online, &InjectionPlan::none()).unwrap();
    assert!(plan.split);
    assert_eq!(plan.nodes.len(), 8);
    assert_eq!(plan.roots(), 8);
    let a = Matrix::rand_uniform(600, 600, 98);
    let b = Matrix::rand_uniform(600, 600, 99);
    let out = coord.gemm(&a, &b, FtPolicy::Online).unwrap();
    assert_eq!(out.kernel_launches as usize, plan.nodes.len());
    assert_eq!(out.buckets, plan.block_buckets());
}

#[test]
fn batcher_rides_the_same_pipeline_under_a_pool() {
    let coord = Coordinator::new(pool_engine(2), CoordinatorConfig::default());
    let batcher = Batcher::start(coord.clone(), BatcherConfig::default());
    let mut tickets = Vec::new();
    let mut wants = Vec::new();
    for i in 0..4u64 {
        let a = Matrix::rand_uniform(600, 600, 300 + i);
        let b = Matrix::rand_uniform(600, 600, 400 + i);
        wants.push(a.matmul(&b));
        tickets.push(
            batcher.submit(GemmRequest::new(a, b).policy(FtPolicy::None)).unwrap(),
        );
    }
    for (t, want) in tickets.into_iter().zip(&wants) {
        check_close(&t.wait().unwrap().result.c, want, 1e-2, "batched split");
    }
    // every split request went through the scheduler: 8 launches each
    assert_eq!(coord.counters().snapshot().executions, 4 * 8);
}

// ---------------------------------------------------------------------
// The async submission surface: GemmRequest -> submit -> Ticket
// ---------------------------------------------------------------------

/// Occupies a single dispatcher for ~hundreds of ms (one exact huge-bucket
/// block on the reference backend) so follow-up submissions stay queued.
fn occupier_request(seed: u64) -> GemmRequest {
    let a = Matrix::rand_uniform(512, 512, seed);
    let b = Matrix::rand_uniform(512, 512, seed + 1);
    GemmRequest::new(a, b).policy(FtPolicy::None)
}

/// A coordinator with exactly one dispatcher: everything behind the
/// occupier is dequeued strictly in priority order.
fn single_dispatch_coordinator(max_queue: usize) -> Coordinator {
    let cfg = CoordinatorConfig { max_inflight: 1, max_queue, ..Default::default() };
    Coordinator::new(pool_engine(1), cfg)
}

#[test]
fn gemm_is_a_submit_wait_wrapper() {
    let coord = coordinator();
    let a = Matrix::rand_uniform(128, 128, 500);
    let b = Matrix::rand_uniform(128, 128, 501);
    let direct = coord.gemm(&a, &b, FtPolicy::Online).unwrap();
    let resp = coord
        .submit(GemmRequest::new(a.clone(), b.clone()).policy(FtPolicy::Online))
        .unwrap()
        .wait()
        .unwrap();
    check_close(&resp.result.c, &direct.c, 1e-4, "submit vs gemm");
    assert_eq!(resp.result.buckets, direct.buckets);
    assert_eq!(resp.meta.policy, FtPolicy::Online);
    assert_eq!(resp.meta.priority, Priority::Normal);
}

#[test]
fn ticket_polls_through_to_done() {
    let coord = coordinator();
    let a = Matrix::rand_uniform(64, 64, 510);
    let b = Matrix::rand_uniform(64, 64, 511);
    let t = coord.submit(GemmRequest::new(a, b).policy(FtPolicy::None)).unwrap();
    assert!(t.id() >= 1);
    let mut spins = 0usize;
    loop {
        match t.poll() {
            TicketStatus::Done => break,
            TicketStatus::Queued | TicketStatus::Running => {
                spins += 1;
                assert!(spins < 20_000, "request never settled");
                std::thread::sleep(Duration::from_millis(1));
            }
            other => panic!("unexpected status {other:?}"),
        }
    }
    assert!(t.wait().is_ok());
}

#[test]
fn eight_concurrent_tickets_match_single_worker_reference() {
    // The acceptance bar: >= 8 tickets from distinct requests in flight
    // at once on a multi-worker engine, every result matching the
    // single-worker reference coordinator.
    let single = Coordinator::new(pool_engine(1), CoordinatorConfig::default());
    let pooled = Coordinator::new(
        pool_engine(4),
        CoordinatorConfig { max_inflight: 8, ..Default::default() },
    );
    let mk = |m: usize, k: usize, n: usize, seed: u64| {
        (Matrix::rand_uniform(m, k, seed), Matrix::rand_uniform(k, n, seed + 1000))
    };
    let requests: Vec<(Matrix, Matrix, FtPolicy)> = vec![
        { let (a, b) = mk(64, 64, 64, 600); (a, b, FtPolicy::None) },
        { let (a, b) = mk(128, 128, 128, 601); (a, b, FtPolicy::Online) },
        { let (a, b) = mk(100, 70, 90, 602); (a, b, FtPolicy::Online) },
        { let (a, b) = mk(64, 64, 64, 603); (a, b, FtPolicy::Offline) },
        { let (a, b) = mk(128, 128, 128, 604); (a, b, FtPolicy::None) },
        { let (a, b) = mk(100, 200, 480, 605); (a, b, FtPolicy::None) },
        { let (a, b) = mk(600, 600, 600, 606); (a, b, FtPolicy::Online) },
        { let (a, b) = mk(128, 128, 128, 607); (a, b, FtPolicy::Offline) },
        { let (a, b) = mk(64, 64, 64, 608); (a, b, FtPolicy::Online) },
    ];
    let wants: Vec<Matrix> = requests
        .iter()
        .map(|(a, b, policy)| single.gemm(a, b, *policy).unwrap().c)
        .collect();

    let tickets: Vec<_> = requests
        .iter()
        .map(|(a, b, policy)| {
            pooled
                .submit(GemmRequest::new(a.clone(), b.clone()).policy(*policy))
                .unwrap()
        })
        .collect();
    assert!(tickets.len() >= 8, "need >= 8 tickets in flight");

    let mut ids = std::collections::HashSet::new();
    let mut seqs = std::collections::HashSet::new();
    for (i, (t, want)) in tickets.into_iter().zip(&wants).enumerate() {
        let resp = t.wait().unwrap();
        // completion-order accumulation drifts at roundoff level only
        check_close(&resp.result.c, want, 5e-3, &format!("request {i} vs single-worker"));
        assert!(ids.insert(resp.meta.id), "duplicate request id");
        assert!(seqs.insert(resp.meta.dispatch_seq), "duplicate dispatch seq");
    }
}

#[test]
fn cancel_before_dispatch_returns_canceled_status() {
    let coord = single_dispatch_coordinator(0);
    let blocker = coord.submit(occupier_request(620)).unwrap();
    let a = Matrix::rand_uniform(64, 64, 622);
    let b = Matrix::rand_uniform(64, 64, 623);
    let victim = coord.submit(GemmRequest::new(a, b).policy(FtPolicy::None)).unwrap();
    assert!(victim.cancel(), "queued request must be cancelable");
    assert!(!victim.cancel(), "second cancel reports false");
    assert_eq!(victim.poll(), TicketStatus::Canceled);
    let err = victim.wait().unwrap_err();
    assert!(err.to_string().contains("canceled"), "{err}");
    // the blocker is unaffected and the coordinator keeps serving
    assert!(blocker.wait().is_ok());
    // the dispatcher discards the canceled entry shortly after the blocker
    // frees it; the counter bump is asynchronous to victim.wait()
    let mut spins = 0usize;
    while coord.counters().snapshot().canceled == 0 {
        spins += 1;
        assert!(spins < 10_000, "canceled counter never bumped");
        std::thread::sleep(Duration::from_millis(1));
    }
}

#[test]
fn deadline_expired_requests_fail_cleanly() {
    let coord = single_dispatch_coordinator(0);
    let blocker = coord.submit(occupier_request(630)).unwrap();
    let a = Matrix::rand_uniform(64, 64, 632);
    let b = Matrix::rand_uniform(64, 64, 633);
    let doomed = coord
        .submit(
            GemmRequest::new(a.clone(), b.clone())
                .policy(FtPolicy::None)
                .deadline(Duration::ZERO),
        )
        .unwrap();
    let err = doomed.wait().unwrap_err();
    assert!(err.to_string().contains("deadline"), "{err}");
    assert!(blocker.wait().is_ok());
    // the expired-counter bump happens when the dispatcher reaps the
    // entry, asynchronously to doomed.wait() (which can self-expire)
    let mut spins = 0usize;
    while coord.counters().snapshot().expired == 0 {
        spins += 1;
        assert!(spins < 10_000, "expired counter never bumped");
        std::thread::sleep(Duration::from_millis(1));
    }
    // a generous deadline passes untouched
    let relaxed = coord
        .submit(GemmRequest::new(a, b).policy(FtPolicy::None).deadline(Duration::from_secs(60)))
        .unwrap();
    assert!(relaxed.wait().is_ok());
}

#[test]
fn deadline_fires_without_a_dispatcher_ever_dequeuing() {
    // Starvation case: the only dispatcher is busy for the whole deadline
    // window, so expiry must come from the ticket side — wait() returns
    // at the deadline, not when the blocker finally frees the dispatcher.
    let coord = single_dispatch_coordinator(0);
    let blocker = coord.submit(occupier_request(720)).unwrap();
    // make sure the blocker holds the dispatcher before queueing behind it
    let mut spins = 0usize;
    while blocker.poll() == TicketStatus::Queued {
        spins += 1;
        assert!(spins < 20_000, "blocker never dispatched");
        std::thread::sleep(Duration::from_millis(1));
    }
    let a = Matrix::rand_uniform(64, 64, 722);
    let b = Matrix::rand_uniform(64, 64, 723);
    let starved = coord
        .submit(
            GemmRequest::new(a, b)
                .policy(FtPolicy::None)
                .deadline(Duration::from_millis(20)),
        )
        .unwrap();
    let err = starved.wait().unwrap_err();
    assert!(err.to_string().contains("deadline"), "{err}");
    // the blocker was still running when the starved request expired
    assert!(blocker.wait().is_ok());
}

#[test]
fn priority_ordering_observed_under_saturated_pool() {
    let coord = single_dispatch_coordinator(0);
    let blocker = coord.submit(occupier_request(640)).unwrap();
    let submit_small = |seed: u64, p: Priority| {
        let a = Matrix::rand_uniform(64, 64, seed);
        let b = Matrix::rand_uniform(64, 64, seed + 1);
        coord
            .submit(GemmRequest::new(a, b).policy(FtPolicy::None).priority(p))
            .unwrap()
    };
    let low1 = submit_small(642, Priority::Low);
    let high = submit_small(644, Priority::High);
    let normal = submit_small(646, Priority::Normal);
    let low2 = submit_small(648, Priority::Low);
    blocker.wait().unwrap();
    let (low1, high, normal, low2) = (
        low1.wait().unwrap().meta,
        high.wait().unwrap().meta,
        normal.wait().unwrap().meta,
        low2.wait().unwrap().meta,
    );
    assert!(high.dispatch_seq < normal.dispatch_seq, "high before normal");
    assert!(normal.dispatch_seq < low1.dispatch_seq, "normal before low");
    assert!(low1.dispatch_seq < low2.dispatch_seq, "FIFO within a priority");
}

#[test]
fn admission_control_rejects_when_queue_is_full() {
    let coord = single_dispatch_coordinator(2);
    let mut settled = vec![coord.submit(occupier_request(650)).unwrap()];
    let mut rejected = 0usize;
    for i in 0..5u64 {
        let a = Matrix::rand_uniform(64, 64, 660 + i);
        let b = Matrix::rand_uniform(64, 64, 670 + i);
        match coord.submit(GemmRequest::new(a, b).policy(FtPolicy::None)) {
            Ok(t) => settled.push(t),
            Err(e) => {
                rejected += 1;
                assert!(e.to_string().contains("admission"), "{e}");
            }
        }
    }
    assert!(rejected >= 1, "queue bound never enforced");
    // everything that was admitted still completes
    for t in settled {
        assert!(t.wait().is_ok());
    }
}

#[test]
fn host_verify_gate_is_explicit_for_injected_requests() {
    // Impossible thresholds make any host re-verification fail — which is
    // exactly how we can observe whether it ran.
    let coord = coordinator();
    let a = Matrix::rand_uniform(128, 128, 680);
    let b = Matrix::rand_uniform(128, 128, 681);
    let inj = InjectionPlan::single(5, 9, 0, 500.0);
    let strict = Thresholds { rel: 0.0, abs: 1e-12 };

    // CleanOnly (what `host_verify = true` maps to): the injected run is
    // deliberately NOT re-verified, so even impossible thresholds pass.
    let skipped = coord
        .submit(
            GemmRequest::new(a.clone(), b.clone())
                .policy(FtPolicy::Online)
                .inject(inj.clone())
                .host_verify(HostVerify::CleanOnly)
                .thresholds(strict),
        )
        .unwrap()
        .wait();
    assert!(skipped.is_ok(), "CleanOnly must skip injected runs: {skipped:?}");

    // Always: the gate is opened explicitly and the verification runs.
    let verified = coord
        .submit(
            GemmRequest::new(a, b)
                .policy(FtPolicy::Online)
                .inject(inj)
                .host_verify(HostVerify::Always)
                .thresholds(strict),
        )
        .unwrap()
        .wait();
    let err = verified.unwrap_err();
    assert!(err.to_string().contains("re-verification"), "{err}");
}

#[test]
fn per_request_ft_level_overrides_coordinator_default() {
    let coord = coordinator(); // default level: tb
    let a = Matrix::rand_uniform(128, 128, 690);
    let b = Matrix::rand_uniform(128, 128, 691);
    let want = a.matmul(&b);
    let inj = InjectionPlan::single(30, 31, 2, 777.0);
    for level in [FtLevel::Warp, FtLevel::Thread] {
        let resp = coord
            .submit(
                GemmRequest::new(a.clone(), b.clone())
                    .policy(FtPolicy::Online)
                    .inject(inj.clone())
                    .ft_level(level),
            )
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(resp.result.errors_corrected, 1, "{level}");
        check_close(&resp.result.c, &want, 2e-2, level.as_str());
    }
}

#[test]
fn ding_request_shape_validated_at_submit() {
    // GemmRequest::ding is public; wrong-shape operands must be rejected
    // at the fail-fast validation point with the bucket geometry, not as
    // an opaque backend error from inside the encode node.
    let coord = coordinator();
    let err = coord
        .submit(GemmRequest::ding(Matrix::zeros(64, 64), Matrix::zeros(64, 64), "medium"))
        .unwrap_err();
    assert!(err.to_string().contains("fixed-shape"), "{err}");
    // unknown bucket also fails fast
    let err = coord
        .submit(GemmRequest::ding(Matrix::zeros(64, 64), Matrix::zeros(64, 64), "nope"))
        .unwrap_err();
    assert!(err.to_string().contains("ding_encode"), "{err}");
}

#[test]
fn canceled_entries_do_not_hold_admission_quota() {
    // max_queue corpses: cancel everything queued, then a live request
    // must still be admitted (lazy deletion is compacted at admission).
    let coord = single_dispatch_coordinator(2);
    let blocker = coord.submit(occupier_request(710)).unwrap();
    // wait until the blocker actually occupies the dispatcher, so it no
    // longer holds a queue slot itself
    let mut spins = 0usize;
    while blocker.poll() == TicketStatus::Queued {
        spins += 1;
        assert!(spins < 20_000, "blocker never dispatched");
        std::thread::sleep(Duration::from_millis(1));
    }
    let mk = |s: u64| {
        let a = Matrix::rand_uniform(64, 64, s);
        let b = Matrix::rand_uniform(64, 64, s + 1);
        GemmRequest::new(a, b).policy(FtPolicy::None)
    };
    let q1 = coord.submit(mk(712)).unwrap();
    let q2 = coord.submit(mk(714)).unwrap();
    assert!(q1.cancel() && q2.cancel());
    // both queue slots are corpses now; a live submit must succeed
    let live = coord.submit(mk(716)).unwrap();
    assert!(blocker.wait().is_ok());
    assert!(live.wait().is_ok());
}

#[test]
fn ding_submission_rides_the_ticket_surface() {
    let pipe = DingPipeline::new(coordinator(), "medium").unwrap();
    let a = Matrix::rand_uniform(128, 128, 700);
    let b = Matrix::rand_uniform(128, 128, 701);
    let t = pipe.submit(a.clone(), b.clone(), InjectionPlan::none()).unwrap();
    let resp = t.wait().unwrap();
    assert_eq!(resp.result.kernel_launches as usize, 1 + 2 * pipe.panels());
    assert!(resp.result.buckets.is_empty(), "ding plans have no block nodes");
    check_close(&resp.result.c, &a.matmul(&b), 2e-3, "ding via ticket");
}

// ---------------------------------------------------------------------
// Blocked backend behind the registry
// ---------------------------------------------------------------------

fn blocked_coordinator(workers: usize) -> Coordinator {
    let engine = Engine::start(EngineConfig {
        workers,
        backend: "blocked".into(),
        ..Default::default()
    })
    .expect("blocked engine starts");
    assert_eq!(engine.backend().name, "blocked");
    Coordinator::new(engine, CoordinatorConfig::default())
}

#[test]
fn blocked_backend_serves_every_policy() {
    let coord = blocked_coordinator(2);
    let a = Matrix::rand_uniform(200, 150, 901);
    let b = Matrix::rand_uniform(150, 120, 902);
    let want = a.matmul(&b);
    for policy in [FtPolicy::None, FtPolicy::Online, FtPolicy::Offline] {
        let out = coord.gemm(&a, &b, policy).unwrap();
        check_close(&out.c, &want, 1e-2, policy.name());
    }
    let inj = InjectionPlan::single(10, 20, 0, 4096.0);
    let out = coord.gemm_with_faults(&a, &b, FtPolicy::Online, &inj).unwrap();
    assert!(out.errors_corrected >= 1, "blocked fused kernel must correct");
    assert_eq!(out.recomputes, 0);
    check_close(&out.c, &want, 1e-1, "blocked injected online");
}

#[test]
fn blocked_backend_runs_the_ding_baseline() {
    let pipe = DingPipeline::new(blocked_coordinator(1), "medium").unwrap();
    let a = Matrix::rand_uniform(128, 128, 910);
    let b = Matrix::rand_uniform(128, 128, 911);
    let t = pipe.submit(a.clone(), b.clone(), InjectionPlan::single(3, 4, 0, 512.0)).unwrap();
    let resp = t.wait().unwrap();
    assert!(resp.result.errors_corrected >= 1);
    check_close(&resp.result.c, &a.matmul(&b), 2e-2, "blocked ding");
}

#[test]
fn blocked_split_gemm_spreads_over_the_pool() {
    let coord = blocked_coordinator(4);
    let a = Matrix::rand_uniform(600, 600, 920);
    let b = Matrix::rand_uniform(600, 600, 921);
    let out = coord.gemm(&a, &b, FtPolicy::Online).unwrap();
    assert_eq!(out.kernel_launches, 8);
    check_close(&out.c, &a.matmul(&b), 5e-2, "blocked split");
}

// ---------------------------------------------------------------------
// TCP serving gateway over loopback (single-connection smoke lives in
// serve::tests; this exercises real concurrency + fault injection)
// ---------------------------------------------------------------------

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};

use ftgemm::serve::proto::GemmSpec;
use ftgemm::serve::{Gateway, ServeConfig};
use ftgemm::util::json::Json;

fn wire_client(addr: SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).expect("connect gateway");
    stream.set_nodelay(true).unwrap();
    let reader = BufReader::new(stream.try_clone().unwrap());
    (stream, reader)
}

fn wire_send(stream: &mut TcpStream, line: &str) {
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
}

fn wire_recv(reader: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(!line.is_empty(), "server closed the connection unexpectedly");
    Json::parse(line.trim()).unwrap_or_else(|e| panic!("bad response {line:?}: {e}"))
}

/// 16 concurrent clients pipeline mixed-policy/priority GEMMs (the online
/// ones with an injected SEU), plus a depth-bomb frame that must poison
/// only its own slot, against the blocked backend. Every client also runs
/// one canonical spec — identical across clients — whose checksum must be
/// identical everywhere (seeded operands make results content-addressed).
#[test]
fn gateway_serves_sixteen_concurrent_clients_with_faults() {
    const CLIENTS: usize = 16;
    const PER_CLIENT: usize = 3;
    let policies = [FtPolicy::Online, FtPolicy::None, FtPolicy::Offline];
    let priorities = [Priority::Low, Priority::Normal, Priority::High];

    let gw = Gateway::start(
        blocked_coordinator(4),
        ServeConfig { listen: "127.0.0.1:0".into(), threads: 8, ..Default::default() },
    )
    .unwrap();
    let addr = gw.local_addr();

    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            std::thread::spawn(move || {
                let (mut stream, mut reader) = wire_client(addr);
                // pipeline everything first, then settle in order
                for i in 0..PER_CLIENT {
                    let seq = c * PER_CLIENT + i;
                    let mut spec = GemmSpec::new(96, 96, 96);
                    spec.id = 1000 + seq as u64;
                    spec.policy = policies[seq % policies.len()];
                    spec.priority = priorities[seq % priorities.len()];
                    spec.seed = seq as u64 + 1;
                    if spec.policy == FtPolicy::Online {
                        spec.inject = 1;
                    }
                    wire_send(&mut stream, &spec.to_wire_json());
                }
                let bomb = format!("{}1{}", "[".repeat(900), "]".repeat(900));
                wire_send(&mut stream, &bomb);
                let mut canon = GemmSpec::new(64, 64, 64);
                canon.id = 7;
                canon.seed = 123;
                wire_send(&mut stream, &canon.to_wire_json());
                wire_send(&mut stream, r#"{"op": "ping"}"#);

                for i in 0..PER_CLIENT {
                    let seq = c * PER_CLIENT + i;
                    let v = wire_recv(&mut reader);
                    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "{v}");
                    assert_eq!(v.get("id").and_then(Json::as_usize), Some(1000 + seq));
                    if policies[seq % policies.len()] == FtPolicy::Online {
                        let detected = v.get("detected").and_then(Json::as_usize).unwrap();
                        assert!(detected >= 1, "injected SEU went undetected: {v}");
                    }
                }
                let v = wire_recv(&mut reader);
                assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false), "{v}");
                let kind = v.get("error").and_then(Json::as_str);
                assert!(
                    kind == Some("parse") || kind == Some("validation"),
                    "depth bomb must yield a structured protocol error: {v}"
                );
                let v = wire_recv(&mut reader);
                assert_eq!(v.get("id").and_then(Json::as_usize), Some(7), "{v}");
                let checksum = v.get("checksum").and_then(Json::as_f64).unwrap();
                assert!(checksum.is_finite());
                let v = wire_recv(&mut reader);
                assert_eq!(v.get("op").and_then(Json::as_str), Some("ping"), "{v}");
                wire_send(&mut stream, r#"{"op": "quit"}"#);
                checksum
            })
        })
        .collect();

    let checksums: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert!(
        checksums.windows(2).all(|w| w[0] == w[1]),
        "canonical spec produced diverging checksums: {checksums:?}"
    );

    let snap = gw.snapshot();
    assert_eq!(snap.connections as usize, CLIENTS);
    assert_eq!(snap.protocol_errors as usize, CLIENTS, "one depth bomb per client");
    assert_eq!(snap.gemms as usize, CLIENTS * (PER_CLIENT + 1));
}

/// A queue deadline that passes before dispatch must come back as the
/// structured `deadline-expired` error, not a generic failure: a High
/// priority slow request occupies the only dispatch slot, so the doomed
/// Normal request's 1ms deadline expires while it waits.
#[test]
fn gateway_reports_queue_deadline_expiry_as_such() {
    let coord = Coordinator::new(
        pool_engine(1),
        CoordinatorConfig { max_inflight: 1, ..Default::default() },
    );
    let gw = Gateway::start(
        coord,
        ServeConfig { listen: "127.0.0.1:0".into(), threads: 1, ..Default::default() },
    )
    .unwrap();
    let (mut stream, mut reader) = wire_client(gw.local_addr());

    let mut slow = GemmSpec::new(512, 512, 512);
    slow.id = 1;
    slow.priority = Priority::High; // priority trumps deadline ordering
    let mut doomed = GemmSpec::new(64, 64, 64);
    doomed.id = 2;
    doomed.deadline_ms = Some(1);
    wire_send(&mut stream, &slow.to_wire_json());
    wire_send(&mut stream, &doomed.to_wire_json());

    let first = wire_recv(&mut reader);
    assert_eq!(first.get("id").and_then(Json::as_usize), Some(1), "{first}");
    assert_eq!(first.get("ok").and_then(Json::as_bool), Some(true), "{first}");
    let second = wire_recv(&mut reader);
    assert_eq!(second.get("id").and_then(Json::as_usize), Some(2), "{second}");
    assert_eq!(second.get("ok").and_then(Json::as_bool), Some(false), "{second}");
    assert_eq!(
        second.get("error").and_then(Json::as_str),
        Some("deadline-expired"),
        "{second}"
    );
}
