//! End-to-end integration: engine pool + planner/scheduler + coordinator +
//! batcher + ding baseline. Runs against the AOT artifacts when `make
//! artifacts` has been run, and against the built-in manifest + reference
//! backend otherwise — the serving semantics under test are identical.

use std::sync::OnceLock;

use ftgemm::abft::injection::{Injection, InjectionPlan};
use ftgemm::abft::matrix::Matrix;
use ftgemm::coordinator::batcher::{Batcher, BatcherConfig};
use ftgemm::coordinator::ding::DingPipeline;
use ftgemm::coordinator::{Coordinator, CoordinatorConfig, FtPolicy};
use ftgemm::faults::{FaultCampaign, SeuModel};
use ftgemm::runtime::{Engine, EngineConfig};

fn engine() -> Engine {
    static ENGINE: OnceLock<Engine> = OnceLock::new();
    ENGINE
        .get_or_init(|| Engine::start(EngineConfig::default()).expect("engine starts"))
        .clone()
}

fn pool_engine(workers: usize) -> Engine {
    Engine::start(EngineConfig { workers, ..Default::default() }).expect("engine starts")
}

fn coordinator() -> Coordinator {
    Coordinator::new(engine(), CoordinatorConfig::default())
}

fn check_close(got: &Matrix, want: &Matrix, tol: f32, what: &str) {
    let diff = got.max_abs_diff(want);
    assert!(diff < tol, "{what}: max diff {diff} > {tol}");
}

// ---------------------------------------------------------------------
// Plain serving path
// ---------------------------------------------------------------------

#[test]
fn exact_bucket_gemm_matches_host() {
    let coord = coordinator();
    let a = Matrix::rand_uniform(128, 128, 1);
    let b = Matrix::rand_uniform(128, 128, 2);
    let out = coord.gemm(&a, &b, FtPolicy::None).unwrap();
    check_close(&out.c, &a.matmul(&b), 1e-3, "exact bucket");
    assert_eq!(out.kernel_launches, 1);
    assert_eq!(out.buckets, vec!["medium"]);
}

#[test]
fn padded_irregular_shape_matches_host() {
    let coord = coordinator();
    // 100x90x70: fits nothing exactly -> padded into medium
    let a = Matrix::rand_uniform(100, 70, 3);
    let b = Matrix::rand_uniform(70, 90, 4);
    let out = coord.gemm(&a, &b, FtPolicy::None).unwrap();
    assert_eq!((out.c.rows(), out.c.cols()), (100, 90));
    check_close(&out.c, &a.matmul(&b), 1e-3, "padded");
}

#[test]
fn tall_shape_routes_to_tall_bucket() {
    let coord = coordinator();
    let a = Matrix::rand_uniform(100, 200, 5);
    let b = Matrix::rand_uniform(200, 480, 6);
    let out = coord.gemm(&a, &b, FtPolicy::None).unwrap();
    assert_eq!(out.buckets, vec!["tall"]);
    check_close(&out.c, &a.matmul(&b), 2e-3, "tall");
}

#[test]
fn oversize_gemm_splits_and_accumulates() {
    let coord = coordinator();
    // 600^3 > huge bucket -> 2x2x2 block decomposition
    let a = Matrix::rand_uniform(600, 600, 7);
    let b = Matrix::rand_uniform(600, 600, 8);
    let out = coord.gemm(&a, &b, FtPolicy::None).unwrap();
    assert_eq!(out.kernel_launches, 8);
    check_close(&out.c, &a.matmul(&b), 5e-3, "split");
}

#[test]
fn host_verify_accepts_clean_results() {
    let cfg = CoordinatorConfig { host_verify: true, ..Default::default() };
    let coord = Coordinator::new(engine(), cfg);
    let a = Matrix::rand_uniform(64, 64, 9);
    let b = Matrix::rand_uniform(64, 64, 10);
    coord.gemm(&a, &b, FtPolicy::None).unwrap();
}

#[test]
fn mismatched_inner_dims_rejected() {
    let coord = coordinator();
    let a = Matrix::rand_uniform(8, 9, 1);
    let b = Matrix::rand_uniform(10, 8, 2);
    assert!(coord.gemm(&a, &b, FtPolicy::None).is_err());
}

// ---------------------------------------------------------------------
// Online (fused) fault tolerance
// ---------------------------------------------------------------------

#[test]
fn online_ft_fault_free_matches_plain() {
    let coord = coordinator();
    let a = Matrix::rand_uniform(128, 128, 11);
    let b = Matrix::rand_uniform(128, 128, 12);
    let plain = coord.gemm(&a, &b, FtPolicy::None).unwrap();
    let ft = coord.gemm(&a, &b, FtPolicy::Online).unwrap();
    assert_eq!(ft.errors_detected, 0);
    check_close(&ft.c, &plain.c, 1e-3, "ft vs plain");
}

#[test]
fn online_ft_corrects_injected_errors() {
    let coord = coordinator();
    let a = Matrix::rand_uniform(128, 128, 13);
    let b = Matrix::rand_uniform(128, 128, 14);
    let want = a.matmul(&b);
    let inj = InjectionPlan {
        injections: vec![
            Injection { row: 5, col: 9, step: 0, magnitude: 300.0 },
            Injection { row: 77, col: 40, step: 6, magnitude: -1000.0 },
            Injection { row: 127, col: 127, step: 12, magnitude: 64.0 },
        ],
    };
    let out = coord.gemm_with_faults(&a, &b, FtPolicy::Online, &inj).unwrap();
    assert_eq!(out.errors_corrected, 3);
    assert_eq!(out.recomputes, 0);
    check_close(&out.c, &want, 2e-2, "online corrected");
}

#[test]
fn online_ft_on_padded_shape_corrects() {
    let coord = coordinator();
    let a = Matrix::rand_uniform(100, 60, 15);
    let b = Matrix::rand_uniform(60, 90, 16);
    let want = a.matmul(&b);
    let inj = InjectionPlan::single(50, 45, 1, 500.0);
    let out = coord.gemm_with_faults(&a, &b, FtPolicy::Online, &inj).unwrap();
    assert_eq!(out.errors_corrected, 1);
    check_close(&out.c, &want, 2e-2, "padded + injected");
}

#[test]
fn warp_and_thread_levels_also_correct() {
    for level in ["warp", "thread"] {
        let cfg = CoordinatorConfig { ft_level: level.into(), ..Default::default() };
        let coord = Coordinator::new(engine(), cfg);
        let a = Matrix::rand_uniform(128, 128, 17);
        let b = Matrix::rand_uniform(128, 128, 18);
        let want = a.matmul(&b);
        let inj = InjectionPlan::single(30, 31, 2, 777.0);
        let out = coord.gemm_with_faults(&a, &b, FtPolicy::Online, &inj).unwrap();
        assert_eq!(out.errors_corrected, 1, "{level}");
        check_close(&out.c, &want, 2e-2, level);
    }
}

#[test]
fn injecting_into_unprotected_kernel_is_refused() {
    let coord = coordinator();
    let a = Matrix::rand_uniform(64, 64, 19);
    let b = Matrix::rand_uniform(64, 64, 20);
    let inj = InjectionPlan::single(0, 0, 0, 100.0);
    assert!(coord.gemm_with_faults(&a, &b, FtPolicy::None, &inj).is_err());
}

// ---------------------------------------------------------------------
// Offline (detect + recompute)
// ---------------------------------------------------------------------

#[test]
fn offline_detects_and_recomputes() {
    let coord = coordinator();
    // medium bucket has a detect-only artifact
    let a = Matrix::rand_uniform(128, 128, 21);
    let b = Matrix::rand_uniform(128, 128, 22);
    let want = a.matmul(&b);
    let inj = InjectionPlan::single(10, 10, 3, 444.0);
    let out = coord.gemm_with_faults(&a, &b, FtPolicy::Offline, &inj).unwrap();
    assert!(out.errors_detected >= 1);
    assert_eq!(out.recomputes, 1);
    assert!(out.kernel_launches >= 2, "detection must trigger a second run");
    check_close(&out.c, &want, 1e-3, "offline recomputed");
}

#[test]
fn offline_fault_free_runs_once() {
    let coord = coordinator();
    let a = Matrix::rand_uniform(128, 128, 23);
    let b = Matrix::rand_uniform(128, 128, 24);
    let out = coord.gemm(&a, &b, FtPolicy::Offline).unwrap();
    assert_eq!(out.recomputes, 0);
    assert_eq!(out.kernel_launches, 1);
}

#[test]
fn offline_without_detect_artifact_uses_host_detector() {
    let coord = coordinator();
    // small bucket has no ftdetect artifact -> host path
    let a = Matrix::rand_uniform(64, 64, 25);
    let b = Matrix::rand_uniform(64, 64, 26);
    let want = a.matmul(&b);
    let inj = InjectionPlan::single(3, 3, 0, 256.0);
    let out = coord.gemm_with_faults(&a, &b, FtPolicy::Offline, &inj).unwrap();
    assert!(out.errors_detected >= 1);
    assert_eq!(out.recomputes, 1);
    check_close(&out.c, &want, 1e-3, "host-detector offline");
}

// ---------------------------------------------------------------------
// Ding non-fused baseline
// ---------------------------------------------------------------------

#[test]
fn ding_pipeline_matches_host_gemm() {
    let pipe = DingPipeline::new(engine(), "medium").unwrap();
    let a = Matrix::rand_uniform(128, 128, 27);
    let b = Matrix::rand_uniform(128, 128, 28);
    let out = pipe.gemm(&a, &b).unwrap();
    assert_eq!(out.errors_corrected, 0);
    // 1 encode + 2 per panel
    assert_eq!(out.kernel_launches as usize, 1 + 2 * pipe.panels());
    check_close(&out.c, &a.matmul(&b), 2e-3, "ding clean");
}

#[test]
fn ding_pipeline_corrects_per_panel_faults() {
    let pipe = DingPipeline::new(engine(), "medium").unwrap();
    let a = Matrix::rand_uniform(128, 128, 29);
    let b = Matrix::rand_uniform(128, 128, 30);
    let want = a.matmul(&b);
    let inj = InjectionPlan {
        injections: vec![
            Injection { row: 3, col: 4, step: 0, magnitude: 512.0 },
            Injection { row: 90, col: 100, step: 1, magnitude: -128.0 },
        ],
    };
    let out = pipe.gemm_with_faults(&a, &b, &inj).unwrap();
    assert_eq!(out.errors_corrected, 2);
    check_close(&out.c, &want, 2e-2, "ding corrected");
}

#[test]
fn fused_uses_fewer_launches_than_ding() {
    // the structural claim behind the paper's speedup: one launch vs 1+2P
    let coord = coordinator();
    let pipe = DingPipeline::new(engine(), "medium").unwrap();
    let a = Matrix::rand_uniform(128, 128, 31);
    let b = Matrix::rand_uniform(128, 128, 32);
    let fused = coord.gemm(&a, &b, FtPolicy::Online).unwrap();
    let ding = pipe.gemm(&a, &b).unwrap();
    assert!(fused.kernel_launches < ding.kernel_launches);
}

// ---------------------------------------------------------------------
// Batcher
// ---------------------------------------------------------------------

#[test]
fn batcher_serves_mixed_shapes_and_policies() {
    let batcher = Batcher::start(coordinator(), BatcherConfig::default());
    let mut tickets = Vec::new();
    let mut wants = Vec::new();
    for i in 0..12u64 {
        let (m, n, k) = match i % 3 {
            0 => (64, 64, 64),
            1 => (128, 128, 128),
            _ => (100, 80, 60),
        };
        let policy = if i % 2 == 0 { FtPolicy::None } else { FtPolicy::Online };
        let a = Matrix::rand_uniform(m, k, 100 + i);
        let b = Matrix::rand_uniform(k, n, 200 + i);
        wants.push(a.matmul(&b));
        tickets.push(batcher.submit(a, b, policy, InjectionPlan::none()).unwrap());
    }
    for (t, want) in tickets.into_iter().zip(&wants) {
        let out = t.wait().unwrap();
        check_close(&out.c, want, 2e-3, "batched");
    }
    let stats = batcher.stats();
    assert_eq!(stats.requests, 12);
    assert!(stats.groups >= 1);
}

// ---------------------------------------------------------------------
// Fault campaigns
// ---------------------------------------------------------------------

#[test]
fn campaign_online_corrects_everything() {
    let campaign = FaultCampaign::new(
        coordinator(),
        SeuModel::PerGemm { count: 4 },
        FtPolicy::Online,
        42,
    );
    let report = campaign.run(128, 128, 128, 3).unwrap();
    assert_eq!(report.gemms, 3);
    assert_eq!(report.injected, 12);
    // corrected >= injected: a correction of a huge (2^20) offset leaves an
    // O(eps*mag) residue that a later verification pass refines again
    assert!(report.corrected >= 12, "{}", report.corrected);
    assert_eq!(report.recomputes, 0);
    // correction residue is O(eps * |magnitude|); bit-flip magnitudes go up
    // to 2^20, so the corrected result can be ~0.1 off in absolute terms
    // (relative to elements of size ~K/4 that's still ~1e-5 relative).
    assert!(report.max_error_vs_reference < 0.5, "{}", report.max_error_vs_reference);
}

#[test]
fn campaign_offline_recomputes_instead_of_correcting() {
    let campaign = FaultCampaign::new(
        coordinator(),
        SeuModel::PerGemm { count: 1 },
        FtPolicy::Offline,
        43,
    );
    let report = campaign.run(128, 128, 128, 2).unwrap();
    assert_eq!(report.corrected, 0);
    assert!(report.recomputes >= 2);
    assert!(report.max_error_vs_reference < 1e-3);
}

#[test]
fn coordinator_counters_accumulate() {
    let coord = coordinator();
    let a = Matrix::rand_uniform(64, 64, 50);
    let b = Matrix::rand_uniform(64, 64, 51);
    coord.gemm(&a, &b, FtPolicy::None).unwrap();
    coord
        .gemm_with_faults(&a, &b, FtPolicy::Online, &InjectionPlan::single(1, 1, 0, 99.0))
        .unwrap();
    let snap = coord.counters().snapshot();
    assert_eq!(snap.requests, 2);
    assert_eq!(snap.errors_corrected, 1);
    assert_eq!(coord.latency().count(), 2);
}

// ---------------------------------------------------------------------
// Failure injection: the system must fail loudly, not silently
// ---------------------------------------------------------------------

#[test]
fn unknown_artifact_rejected_by_engine() {
    let eng = engine();
    let err = eng.warm("nonexistent_kernel").unwrap_err();
    assert!(err.to_string().contains("not in manifest"));
}

#[test]
fn wrong_input_count_rejected() {
    let eng = engine();
    let err = eng
        .execute("gemm_small", vec![ftgemm::runtime::engine::Tensor::zeros(vec![64, 64])])
        .unwrap_err();
    assert!(err.to_string().contains("inputs"));
}

#[test]
fn ding_pipeline_rejects_wrong_shape() {
    let pipe = DingPipeline::new(engine(), "medium").unwrap();
    let a = Matrix::rand_uniform(64, 64, 1);
    let b = Matrix::rand_uniform(64, 64, 2);
    assert!(pipe.gemm(&a, &b).is_err());
}

#[test]
fn ding_pipeline_missing_bucket_errors() {
    // "small" has no ding artifacts
    assert!(DingPipeline::new(engine(), "small").is_err());
}

#[test]
fn serve_config_roundtrip() {
    // the shipped sample config must parse and build all three configs
    let cfg = ftgemm::util::config::Config::load("ftgemm.toml")
        .or_else(|_| ftgemm::util::config::Config::load("../ftgemm.toml"))
        .unwrap();
    let coord = cfg.coordinator().unwrap();
    assert_eq!(coord.ft_level, "tb");
    let eng = cfg.engine().unwrap();
    assert!(eng.precompile.contains(&"gemm_medium".to_string()));
    assert!(cfg.batcher().is_ok());
}

#[test]
fn engine_survives_failed_request_then_serves() {
    let eng = engine();
    let _ = eng.warm("nope");
    // after an error the engine thread must still serve
    let coord = Coordinator::new(eng, CoordinatorConfig::default());
    let a = Matrix::rand_uniform(64, 64, 90);
    let b = Matrix::rand_uniform(64, 64, 91);
    coord.gemm(&a, &b, FtPolicy::None).unwrap();
}

#[test]
fn oversize_online_ft_corrects_in_owning_block() {
    // injection into a split GEMM lands in the right block
    let coord = coordinator();
    let a = Matrix::rand_uniform(600, 600, 92);
    let b = Matrix::rand_uniform(600, 600, 93);
    let want = a.matmul(&b);
    let inj = InjectionPlan::single(550, 13, 2, 4096.0); // block (1, 0)
    let out = coord.gemm_with_faults(&a, &b, FtPolicy::Online, &inj).unwrap();
    assert!(out.errors_corrected >= 1);
    check_close(&out.c, &want, 5e-2, "split + injected");
}

// ---------------------------------------------------------------------
// The plan -> schedule -> execute pipeline over the engine worker pool
// ---------------------------------------------------------------------

#[test]
fn split_gemm_executes_blocks_concurrently_with_pool() {
    // 4 workers, 8 independent huge blocks: the engine must observe
    // overlapping executions (the concurrency the refactor exists for).
    let engine = pool_engine(4);
    let coord = Coordinator::new(engine.clone(), CoordinatorConfig::default());
    let a = Matrix::rand_uniform(1024, 1024, 94);
    let b = Matrix::rand_uniform(1024, 1024, 95);
    let out = coord.gemm(&a, &b, FtPolicy::None).unwrap();
    assert_eq!(out.kernel_launches, 8);
    check_close(&out.c, &a.matmul(&b), 1e-2, "pooled split");
    assert!(
        engine.peak_inflight() >= 2,
        "blocks never overlapped (peak inflight {})",
        engine.peak_inflight()
    );
    let busy = engine
        .stats_per_worker()
        .unwrap()
        .iter()
        .filter(|s| s.executions > 0)
        .count();
    assert!(busy >= 2, "all blocks served by {busy} worker(s)");
}

#[test]
fn pool_results_match_single_worker_results() {
    let a = Matrix::rand_uniform(700, 600, 96);
    let b = Matrix::rand_uniform(600, 650, 97);
    let single = Coordinator::new(pool_engine(1), CoordinatorConfig::default())
        .gemm(&a, &b, FtPolicy::Online)
        .unwrap();
    let pooled = Coordinator::new(pool_engine(4), CoordinatorConfig::default())
        .gemm(&a, &b, FtPolicy::Online)
        .unwrap();
    assert_eq!(single.kernel_launches, pooled.kernel_launches);
    assert_eq!(single.buckets, pooled.buckets);
    // accumulation order differs (completion order), so roundoff-level drift
    check_close(&pooled.c, &single.c, 1e-3, "pool determinism");
}

#[test]
fn plan_introspection_matches_execution() {
    let coord = coordinator();
    let plan = coord.plan(600, 600, 600, FtPolicy::Online, &InjectionPlan::none()).unwrap();
    assert!(plan.split);
    assert_eq!(plan.nodes.len(), 8);
    assert_eq!(plan.roots(), 8);
    let a = Matrix::rand_uniform(600, 600, 98);
    let b = Matrix::rand_uniform(600, 600, 99);
    let out = coord.gemm(&a, &b, FtPolicy::Online).unwrap();
    assert_eq!(out.kernel_launches as usize, plan.nodes.len());
    assert_eq!(out.buckets, plan.block_buckets());
}

#[test]
fn batcher_rides_the_same_pipeline_under_a_pool() {
    let coord = Coordinator::new(pool_engine(2), CoordinatorConfig::default());
    let batcher = Batcher::start(coord.clone(), BatcherConfig::default());
    let mut tickets = Vec::new();
    let mut wants = Vec::new();
    for i in 0..4u64 {
        let a = Matrix::rand_uniform(600, 600, 300 + i);
        let b = Matrix::rand_uniform(600, 600, 400 + i);
        wants.push(a.matmul(&b));
        tickets.push(batcher.submit(a, b, FtPolicy::None, InjectionPlan::none()).unwrap());
    }
    for (t, want) in tickets.into_iter().zip(&wants) {
        check_close(&t.wait().unwrap().c, want, 1e-2, "batched split");
    }
    // every split request went through the scheduler: 8 launches each
    assert_eq!(coord.counters().snapshot().executions, 4 * 8);
}
